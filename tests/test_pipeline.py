"""GPipe-style pipeline schedule equals the sequential layer scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.pipeline import pipeline_apply


def _block(layer_p, h):
    return jnp.tanh(h @ layer_p["w"]) + h


@pytest.mark.parametrize("n_stages,n_micro", [(2, 2), (4, 2), (2, 4), (1, 1)])
def test_pipeline_matches_sequential(n_stages, n_micro):
    rng = np.random.default_rng(0)
    n_layers, b, d = 8, 8, 16
    params = {"w": jnp.asarray(rng.standard_normal((n_layers, d, d)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)

    def seq(x):
        def body(h, lp):
            return _block(lp, h), None
        h, _ = jax.lax.scan(body, x, params)
        return h

    want = seq(x)
    got = pipeline_apply(params, x, _block, n_stages=n_stages, n_micro=n_micro)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_pipeline_is_differentiable():
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.standard_normal((4, 8, 8)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    g = jax.grad(lambda p: jnp.sum(pipeline_apply(p, x, _block, 2, 2) ** 2))(params)
    assert bool(jnp.all(jnp.isfinite(g["w"])))
