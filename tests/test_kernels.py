"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracle in ref.py, plus gradient checks for the custom-VJP flash attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distance import distance_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_xla import flash_attention_xla
from repro.kernels.pq_adc import pq_adc_pallas

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,n,d", [(17, 300, 96), (128, 1024, 128), (5, 64, 33), (1, 7, 256)])
@pytest.mark.parametrize("kind", ["ip", "l2"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_kernel_matches_ref(q, n, d, kind, dtype):
    Q = jnp.asarray(RNG.standard_normal((q, d)), dtype)
    X = jnp.asarray(RNG.standard_normal((n, d)), dtype)
    got = distance_pallas(Q, X, kind=kind, interpret=True)
    want = ref.batched_ip(Q, X) if kind == "ip" else ref.l2_distance(Q, X)
    tol = 2e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("q,n,m,c", [(16, 300, 8, 256), (7, 1000, 12, 64), (128, 512, 4, 16), (3, 33, 2, 16)])
def test_pq_adc_kernel_matches_ref(q, n, m, c):
    lut = jnp.asarray(RNG.standard_normal((q, m, c)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, c, (n, m)), jnp.int32)
    got = pq_adc_pallas(lut, codes, interpret=True)
    want = ref.pq_adc(lut, codes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


FA_CASES = [
    (2, 64, 64, 4, 2, 32, True, None),
    (1, 128, 256, 8, 8, 64, True, None),
    (2, 100, 100, 4, 1, 32, True, 48),
    (1, 1, 96, 4, 2, 64, True, None),  # decode-shaped
    (2, 48, 48, 6, 3, 16, False, None),  # bidirectional (encoder)
]


@pytest.mark.parametrize("b,sq,sk,hq,hkv,dh,causal,win", FA_CASES)
def test_flash_pallas_matches_ref(b, sq, sk, hq, hkv, dh, causal, win):
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=win, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


@pytest.mark.parametrize("b,sq,sk,hq,hkv,dh,causal,win", FA_CASES)
def test_flash_xla_matches_ref_fwd_and_grad(b, sq, sk, hq, hkv, dh, causal, win):
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    got = flash_attention_xla(q, k, v, causal, win, 32, 64)
    want = ref.flash_attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    f1 = lambda *a: jnp.sum(jnp.sin(flash_attention_xla(*a, causal, win, 32, 64)))
    f2 = lambda *a: jnp.sum(jnp.sin(ref.flash_attention(*a, causal=causal, window=win)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=3e-4)


def test_flash_pallas_skips_fully_masked_tiles_correctly():
    # window smaller than one tile: many tiles fully masked
    q = jnp.asarray(RNG.standard_normal((1, 256, 2, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 256, 2, 32)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=16, bq=64, bk=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)
