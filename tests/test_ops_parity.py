"""Impl-switch parity for the public kernel wrappers in kernels/ops.py.

The CI ``kernel-parity`` job runs exactly this module: every op dispatched
through ``impl="pallas_interpret"`` (the Pallas kernel executed in interpret
mode on CPU) must match ``impl="xla"`` (the reference path), so TPU kernel
changes cannot land unexercised.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q,n,d", [(16, 256, 64), (5, 100, 96)])
def test_batched_ip_parity(q, n, d):
    Q = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    X = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    got = ops.batched_ip(Q, X, impl="pallas_interpret")
    want = ops.batched_ip(Q, X, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("q,n,d", [(16, 256, 64), (3, 80, 33)])
def test_l2_distance_parity(q, n, d):
    Q = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    X = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    got = ops.l2_distance(Q, X, impl="pallas_interpret")
    want = ops.l2_distance(Q, X, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("q,n,m,c", [(8, 200, 8, 64), (4, 64, 4, 16)])
def test_pq_adc_parity(q, n, m, c):
    lut = jnp.asarray(RNG.standard_normal((q, m, c)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, c, (n, m)), jnp.int32)
    got = ops.pq_adc(lut, codes, impl="pallas_interpret")
    want = ops.pq_adc(lut, codes, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize(
    "b,sq,sk,hq,hkv,dh,causal,win",
    [(1, 64, 64, 4, 2, 32, True, None), (1, 96, 96, 2, 1, 32, True, 48)],
)
def test_flash_attention_parity(b, sq, sk, hq, hkv, dh, causal, win):
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=win, impl="pallas_interpret")
    want = ops.flash_attention(q, k, v, causal=causal, window=win, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_impl_switch_roundtrip():
    before = ops.get_default_impl()
    try:
        ops.set_default_impl("pallas_interpret")
        assert ops.get_default_impl() == "pallas_interpret"
        X = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
        out = ops.batched_ip(X, X)  # default impl resolves to interpret mode
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ops.batched_ip(X, X, impl="xla")),
            atol=2e-4,
            rtol=2e-4,
        )
    finally:
        ops.set_default_impl(before)
