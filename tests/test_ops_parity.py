"""Impl-switch parity for the public kernel wrappers in kernels/ops.py.

The CI ``kernel-parity`` job runs exactly this module: every op dispatched
through ``impl="pallas_interpret"`` (the Pallas kernel executed in interpret
mode on CPU) must match ``impl="xla"`` (the reference path), so TPU kernel
changes cannot land unexercised.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q,n,d", [(16, 256, 64), (5, 100, 96)])
def test_batched_ip_parity(q, n, d):
    Q = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    X = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    got = ops.batched_ip(Q, X, impl="pallas_interpret")
    want = ops.batched_ip(Q, X, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("q,n,d", [(16, 256, 64), (3, 80, 33)])
def test_l2_distance_parity(q, n, d):
    Q = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    X = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    got = ops.l2_distance(Q, X, impl="pallas_interpret")
    want = ops.l2_distance(Q, X, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("q,n,m,c", [(8, 200, 8, 64), (4, 64, 4, 16)])
def test_pq_adc_parity(q, n, m, c):
    lut = jnp.asarray(RNG.standard_normal((q, m, c)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, c, (n, m)), jnp.int32)
    got = ops.pq_adc(lut, codes, impl="pallas_interpret")
    want = ops.pq_adc(lut, codes, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize(
    "b,sq,sk,hq,hkv,dh,causal,win",
    [(1, 64, 64, 4, 2, 32, True, None), (1, 96, 96, 2, 1, 32, True, 48)],
)
def test_flash_attention_parity(b, sq, sk, hq, hkv, dh, causal, win):
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, sk, hkv, dh)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=win, impl="pallas_interpret")
    want = ops.flash_attention(q, k, v, causal=causal, window=win, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_impl_switch_roundtrip():
    before = ops.get_default_impl()
    try:
        ops.set_default_impl("pallas_interpret")
        assert ops.get_default_impl() == "pallas_interpret"
        X = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
        out = ops.batched_ip(X, X)  # default impl resolves to interpret mode
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ops.batched_ip(X, X, impl="xla")),
            atol=2e-4,
            rtol=2e-4,
        )
    finally:
        ops.set_default_impl(before)


# ---------------------------------------------------------------------------
# fused search pipelines (probe -> scan -> in-kernel top-k)
# ---------------------------------------------------------------------------
def _ivf_fixture(n_seg, s, d, nlist, nprobe, dead_tail=0, seed=3):
    """Segments + centroids + member lists + gids for the fused ops, built
    with the same member-list layout (capacity-bound, -1 padded) the real
    IVF builds use."""
    from repro.vdms.indexes import _ivf_cap, _member_lists

    rng = np.random.default_rng(seed)
    segs = rng.standard_normal((n_seg, s, d)).astype(np.float32)
    assign = rng.integers(0, nlist, (n_seg, s))
    cents = np.stack([
        np.stack([
            segs[z][assign[z] == l].mean(0) if (assign[z] == l).any() else np.zeros(d)
            for l in range(nlist)
        ])
        for z in range(n_seg)
    ]).astype(np.float32)
    cap = _ivf_cap(s, nlist, nprobe)
    members = np.stack([_member_lists(assign[z], nlist, cap) for z in range(n_seg)])
    gids = np.arange(n_seg * s, dtype=np.int32).reshape(n_seg, s)
    if dead_tail:
        gids[:, -dead_tail:] = -1
    return segs, cents, members, gids


def _assert_topk_sets_match(a, b, atol=2e-4):
    """Fused contract: candidate SETS and scores match; tie order may not."""
    (la, sa), (lb, sb) = a, b
    la, sa, lb, sb = map(np.asarray, (la, sa, lb, sb))
    assert la.shape == lb.shape and sa.shape == sb.shape
    for z in range(la.shape[0]):
        for i in range(la.shape[1]):
            fa = {int(v) for v, x in zip(la[z, i], sa[z, i]) if np.isfinite(x)}
            fb = {int(v) for v, x in zip(lb[z, i], sb[z, i]) if np.isfinite(x)}
            assert fa == fb, f"lid sets differ at seg {z} row {i}: {fa ^ fb}"
            np.testing.assert_allclose(
                np.sort(sa[z, i][np.isfinite(sa[z, i])]),
                np.sort(sb[z, i][np.isfinite(sb[z, i])]),
                atol=atol,
            )


@pytest.mark.parametrize(
    "s,nlist,nprobe,k,dead,mask_dead",
    [
        (100, 10, 3, 16, 0, False),   # n < block size
        (256, 8, 4, 10, 0, False),    # exactly block-aligned n
        (120, 6, 2, 400, 20, False),  # k > candidate pool, dead slots kept
        (120, 6, 2, 12, 20, True),    # dead slots dropped pre-top-k
    ],
)
def test_fused_sq8_topk_parity(s, nlist, nprobe, k, dead, mask_dead):
    d, b = 40, 5
    segs, cents, members, gids = _ivf_fixture(2, s, d, nlist, nprobe, dead_tail=dead)
    scale = (np.abs(segs).max(axis=(0, 1)) / 127.0 + 1e-12).astype(np.float32)
    codes = np.clip(np.round(segs / scale), -127, 127).astype(np.int8)
    q = np.random.default_rng(4).standard_normal((b, d)).astype(np.float32)
    args = (jnp.asarray(q), jnp.asarray(codes), jnp.asarray(scale),
            jnp.asarray(cents), jnp.asarray(members), jnp.asarray(gids))
    kw = dict(nprobe=nprobe, k=k, mask_dead=mask_dead)
    _assert_topk_sets_match(
        ops.fused_ivf_sq8_topk(*args, impl="pallas_interpret", **kw),
        ops.fused_ivf_sq8_topk(*args, impl="xla", **kw),
    )


@pytest.mark.parametrize(
    "s,nlist,nprobe,k,dead,mask_dead",
    [
        (100, 10, 3, 16, 0, False),
        (256, 8, 4, 10, 0, False),
        (120, 6, 2, 400, 20, True),
    ],
)
def test_fused_pq_topk_parity(s, nlist, nprobe, k, dead, mask_dead):
    d, b, m, c = 40, 5, 4, 16
    segs, cents, members, gids = _ivf_fixture(2, s, d, nlist, nprobe, dead_tail=dead)
    rng = np.random.default_rng(5)
    dsub = d // m
    cb = (rng.standard_normal((m, c, dsub)) * 0.1).astype(np.float32)
    x = segs.reshape(-1, m, dsub)
    codes = np.empty((segs.shape[0], s, m), np.uint8)
    for j in range(m):
        d2 = (np.sum(x[:, j] ** 2, 1)[:, None] - 2 * x[:, j] @ cb[j].T
              + np.sum(cb[j] ** 2, 1)[None, :])
        codes[..., j] = np.argmin(d2, 1).astype(np.uint8).reshape(segs.shape[0], s)
    q = rng.standard_normal((b, d)).astype(np.float32)
    lut = np.einsum("bmd,mcd->bmc", q.reshape(b, m, dsub), cb).astype(np.float32)
    args = (jnp.asarray(q), jnp.asarray(lut), jnp.asarray(codes),
            jnp.asarray(cents), jnp.asarray(members), jnp.asarray(gids))
    kw = dict(nprobe=nprobe, k=k, mask_dead=mask_dead)
    _assert_topk_sets_match(
        ops.fused_ivf_pq_topk(*args, impl="pallas_interpret", **kw),
        ops.fused_ivf_pq_topk(*args, impl="xla", **kw),
    )
