"""Device-resident acquisition engine regression tests (no optional deps).

Covers the contracts of the fused jitted recommend path:
* JAX HVI / MC-EHVI / EI / CEI match the numpy references (including
  degenerate cases: empty fronts, points below the reference, padded-front
  invariance, infeasible CEI incumbents),
* the rank-1 bordered-Cholesky ``GP.condition_on`` matches a full
  refactorization (including growth across the PAD boundary),
* ``VDTuner(engine="jax")`` selects the same seeded configuration sequences
  as the numpy path for q=1 and q=4, rlim on and off — the headline
  argmax-equivalence guarantee (the numpy path itself is pinned to the
  pre-redesign loops by ``test_session.py``),
* GP warm starts: reduced-step refits, state threading, and bit-identical
  checkpoint/resume with ``warm_start=True``,
* bulk candidate generation consumes the RNG exactly like the legacy
  per-config loop and snaps to the identical encoded matrix.
"""
import json

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    GP,
    Param,
    SearchSpace,
    StopSession,
    TuningSession,
    VDTuner,
    cei,
    cei_jax,
    ehvi_mc,
    ehvi_mc_jax,
    ei,
    ei_jax,
    hvi_2d,
    hvi_2d_jax,
    non_dominated_mask,
    pareto_front,
)
from repro.core.gp import _posterior_padded

_FAST = dict(gp_fit_steps=24, n_candidates=48, mc_samples=16)


def _toy_objective(cfg):
    t = cfg["index_type"]
    k = cfg.get("ka", cfg.get("kb", 0.5))
    k = k / 8.0 if t == "A" else k
    sysq = 1.0 - (cfg["s1"] - 0.6) ** 2
    if t == "A":
        return {"speed": 80 * (1 - k) * sysq, "recall": 0.5 + 0.45 * k, "mem_gib": 1.0}
    return {"speed": 50 * (1 - k) * sysq, "recall": 0.6 + 0.39 * k, "mem_gib": 0.5}


def _toy_space():
    return SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 8), default=2)],
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )


def _pad_front(front, extra=4):
    k0 = front.shape[0]
    fp = np.zeros((k0 + extra, 2))
    fm = np.zeros((k0 + extra,), bool)
    fp[:k0] = front
    fm[:k0] = True
    return fp, fm


# ---------------------------------------------------------------------------
# JAX acquisition primitives vs numpy references
# ---------------------------------------------------------------------------
def test_hvi_jax_matches_numpy_random_fronts():
    rng = np.random.default_rng(0)
    for trial in range(20):
        k = int(rng.integers(1, 12))
        front = pareto_front(rng.random((k, 2)) * 10 - 1.0)
        ref = rng.normal(0.0, 1.0, size=2)
        pts = rng.random((64, 2)) * 12 - 2.0  # includes below-ref points
        want = hvi_2d(pts, front, ref)
        fp, fm = _pad_front(front)
        with enable_x64():
            got = np.asarray(hvi_2d_jax(pts, fp, fm, ref))
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


def test_hvi_jax_padding_and_dominated_points_are_inert():
    rng = np.random.default_rng(1)
    front = pareto_front(rng.random((6, 2)) * 5)
    ref = np.zeros(2)
    pts = rng.random((32, 2)) * 6
    fp, fm = _pad_front(front, extra=9)
    # a dominated extra point must not change the staircase
    fp_dom, fm_dom = fp.copy(), fm.copy()
    fp_dom[len(front)] = front.min(axis=0) * 0.5
    fm_dom[len(front)] = True
    with enable_x64():
        base = np.asarray(hvi_2d_jax(pts, fp, fm, ref))
        dom = np.asarray(hvi_2d_jax(pts, fp_dom, fm_dom, ref))
    np.testing.assert_allclose(dom, base, rtol=1e-12, atol=1e-12)


def test_hvi_jax_empty_front():
    pts = np.array([[2.0, 3.0], [-1.0, 5.0]])
    ref = np.zeros(2)
    fp = np.zeros((4, 2))
    fm = np.zeros((4,), bool)  # fully masked == empty front
    with enable_x64():
        got = np.asarray(hvi_2d_jax(pts, fp, fm, ref))
    np.testing.assert_allclose(got, [6.0, 0.0], rtol=1e-12)


def test_ehvi_jax_matches_numpy_with_shared_draws():
    rng = np.random.default_rng(2)
    front = pareto_front(rng.random((8, 2)))
    ref = np.array([0.1, 0.1])
    mean = rng.random((40, 2)).astype(np.float32).astype(np.float64)
    std = (rng.random((40, 2)) * 0.3 + 0.01).astype(np.float32).astype(np.float64)
    eps = np.random.default_rng(3).standard_normal((64, 40, 2))
    want = ehvi_mc(mean, std, front, ref, _FixedEps(eps), n_samples=64)
    fp, fm = _pad_front(front)
    with enable_x64():
        got = np.asarray(ehvi_mc_jax(mean, std, fp, fm, ref, eps))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


class _FixedEps:
    """Generator stand-in replaying fixed normal draws into ``ehvi_mc``."""

    def __init__(self, eps):
        self._eps = eps

    def standard_normal(self, shape):
        assert shape == self._eps.shape
        return self._eps


@pytest.mark.parametrize("best", [1.0, float("-inf")], ids=["feasible", "no-incumbent"])
def test_ei_cei_jax_match_numpy(best):
    rng = np.random.default_rng(4)
    mean = rng.normal(1.0, 2.0, size=50)
    std = np.abs(rng.normal(0.0, 1.0, size=50)) + 1e-13
    mean_r = rng.random(50)
    std_r = rng.random(50) * 0.1 + 1e-13
    with enable_x64():
        got_ei = np.asarray(ei_jax(mean, std, 1.0))
        got_cei = np.asarray(cei_jax(mean, std, mean_r, std_r, best, 0.9))
    np.testing.assert_allclose(got_ei, ei(mean, std, 1.0), rtol=1e-9, atol=1e-15)
    np.testing.assert_allclose(got_cei, cei(mean, std, mean_r, std_r, best, 0.9), rtol=1e-9, atol=1e-15)


# ---------------------------------------------------------------------------
# rank-1 Cholesky conditioning vs full refactorization
# ---------------------------------------------------------------------------
def _full_refactorization(gp):
    s = gp.state
    return _posterior_padded(s.params.log_ls, s.params.log_sf, s.params.log_noise, s.x, s.y, s.mask)


@pytest.mark.parametrize("n0,k", [(20, 1), (20, 5), (30, 4), (32, 3)], ids=str)
def test_rank1_condition_matches_full_refactorization(n0, k):
    # (32, 3) crosses the PAD boundary: growth is an exact block extension
    rng = np.random.default_rng(n0 + k)
    X = rng.random((n0, 3))
    Y = np.stack([np.sin(3 * X[:, 0]), X[:, 1] - X[:, 2]], axis=1)
    gp = GP(seed=0).fit(X, Y)
    Xn = rng.random((k, 3))
    mean, _ = gp.predict(Xn)  # Kriging-believer-style (self-consistent) values
    g2 = gp.condition_on(Xn, mean)
    chol_full, alpha_full = _full_refactorization(g2)
    np.testing.assert_allclose(np.asarray(g2.state.chol), np.asarray(chol_full), atol=2e-4)
    # the posterior itself agrees tightly
    Xt = rng.random((16, 3))
    m1, s1 = g2.predict(Xt)
    g3 = GP(seed=0)
    g3.state = type(g2.state)(
        params=g2.state.params,
        x=g2.state.x,
        y=g2.state.y,
        mask=g2.state.mask,
        chol=chol_full,
        alpha=alpha_full,
        y_mean=g2.state.y_mean,
        y_std=g2.state.y_std,
    )
    m2, s2 = g3.predict(Xt)
    np.testing.assert_allclose(m1, m2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-4)


def test_with_capacity_is_exact_and_preserves_posterior():
    rng = np.random.default_rng(9)
    X = rng.random((32, 2))  # full PAD block
    Y = X[:, :1] * 2.0
    gp = GP(seed=0).fit(X, Y)
    big = gp.with_capacity(40)
    assert big.state.x.shape[0] == 64
    m0, s0 = gp.predict(X[:8])
    m1, s1 = big.predict(X[:8])
    np.testing.assert_array_equal(m0, m1)
    np.testing.assert_array_equal(s0, s1)


# ---------------------------------------------------------------------------
# fused engine == numpy engine (the seeded regression criterion)
# ---------------------------------------------------------------------------
def _run(engine, q, rlim, warm=False, n=12, seed=5):
    t = VDTuner(
        _toy_space(),
        _toy_objective,
        seed=seed,
        abandon_window=6,
        rlim=rlim,
        q=q,
        engine=engine,
        warm_start=warm,
        **_FAST,
    )
    return t.run(n)


@pytest.mark.parametrize("q", [1, 4], ids=["q1", "q4"])
@pytest.mark.parametrize("rlim", [None, 0.85], ids=["ehvi", "cei"])
def test_jax_engine_selects_same_configs_as_numpy(q, rlim):
    a = _run("numpy", q, rlim)
    b = _run("jax", q, rlim)
    assert [o.config for o in a.history] == [o.config for o in b.history]
    assert np.array_equal(a.Y, b.Y)


def test_jax_engine_matches_numpy_with_warm_start_too():
    a = _run("numpy", 4, None, warm=True)
    b = _run("jax", 4, None, warm=True)
    assert [o.config for o in a.history] == [o.config for o in b.history]


def test_engines_handle_q_larger_than_candidate_pool():
    kw = dict(_FAST, n_candidates=4)
    for engine in ("numpy", "jax"):
        t = VDTuner(_toy_space(), _toy_objective, seed=1, q=6, engine=engine, **kw)
        t._initial_sampling()
        cfgs = t.ask(6)
        assert len(cfgs) == 4  # clamped to the candidate pool
        assert len({tuple(sorted(c.items())) for c in cfgs}) == 4


def test_invalid_engine_rejected():
    with pytest.raises(ValueError):
        VDTuner(_toy_space(), _toy_objective, engine="fortran")


# ---------------------------------------------------------------------------
# warm-started GP refits
# ---------------------------------------------------------------------------
def test_warm_start_threads_state_and_checkpoints():
    tuner = VDTuner(_toy_space(), _toy_objective, seed=7, warm_start=True, **_FAST)
    tuner.run(5)
    state = tuner.state_dict()
    warm = state["extra"]["gp_warm"]
    assert warm is not None and set(warm) == {"log_ls", "log_sf", "log_noise"}
    fresh = VDTuner(_toy_space(), _toy_objective, seed=7, warm_start=True, **_FAST)
    fresh.load_state_dict(json.loads(json.dumps(state)))
    assert fresh._gp_warm.to_lists() == warm  # exact f32 round-trip through JSON


@pytest.mark.parametrize("q", [1, 4], ids=["q1", "q4"])
def test_warm_start_resume_is_bit_identical(q):
    def make():
        return VDTuner(_toy_space(), _toy_objective, seed=7, q=q, warm_start=True, **_FAST)

    full = make()
    TuningSession(full).run(9)

    def stopper(session, obs):
        if session.n_observations >= 5:
            raise StopSession

    part = make()
    session = TuningSession(part, callbacks=[stopper]).run(9)
    state = json.loads(json.dumps(session.state_dict()))
    fresh = make()
    TuningSession.restore(state, fresh).run(9)
    assert [o.config for o in fresh.history] == [o.config for o in full.history]
    assert np.array_equal(fresh.Y, full.Y)


def test_baseline_warm_start_threads_and_checkpoints():
    from repro.core import OtterTuneLike

    tuner = OtterTuneLike(_toy_space(), _toy_objective, seed=2, n_init=4, n_candidates=32, warm_start=True)
    tuner.run(7)
    assert tuner._gp_warm is not None
    state = json.loads(json.dumps(tuner.state_dict()))
    fresh = OtterTuneLike(_toy_space(), _toy_objective, seed=2, n_init=4, n_candidates=32, warm_start=True)
    fresh.load_state_dict(state)
    assert fresh._gp_warm.to_lists() == state["extra"]["gp_warm"]


def test_warm_fit_uses_reduced_steps_and_previous_params():
    rng = np.random.default_rng(0)
    X = rng.random((24, 2))
    Y = np.sin(4 * X[:, 0]) + X[:, 1]
    cold = GP(seed=0, fit_steps=120).fit(X, Y)
    warm = GP(seed=0, fit_steps=120, warm_fit_steps=0).fit(X, Y, init=cold.params)
    # 0 warm steps == the init itself: threading works end to end
    np.testing.assert_array_equal(np.asarray(warm.params.log_ls), np.asarray(cold.params.log_ls))
    # shape-mismatched init falls back to a cold fit instead of crashing
    other = GP(seed=0).fit(rng.random((10, 3)), rng.random(10), init=cold.params)
    assert other.state is not None


# ---------------------------------------------------------------------------
# bulk candidate generation
# ---------------------------------------------------------------------------
def _legacy_candidates(self, t):
    """Verbatim copy of the pre-bulk per-config candidate loop."""
    n_uniform = self.n_candidates // 2
    cands = self.space.sample(self.rng, n_uniform, index_type=t)
    ys = self.Y
    nd = non_dominated_mask(ys)
    seeds = [o.config for o, keep in zip(self.history, nd) if keep and o.index_type == t]
    if not seeds:
        mine = [o for o in self.history if o.index_type == t and not o.failed]
        if mine:
            seeds = [
                max(mine, key=lambda o: o.y[0]).config,
                max(mine, key=lambda o: o.y[1]).config,
            ]
    while len(cands) < self.n_candidates and seeds:
        base = seeds[len(cands) % len(seeds)]
        scale = float(self.rng.choice([0.05, 0.1, 0.2]))
        cands.append(self.space.perturb(self.rng, base, scale=scale))
    if len(cands) < self.n_candidates:
        cands += self.space.sample(self.rng, self.n_candidates - len(cands), index_type=t)
    return cands


def test_bulk_candidates_match_legacy_loop_and_rng_stream():
    a = VDTuner(_toy_space(), _toy_objective, seed=3, **_FAST).run(6)
    b = VDTuner(_toy_space(), _toy_objective, seed=3, **_FAST).run(6)
    for t in ("A", "B"):
        assert _legacy_candidates(a, t) == b._candidates(t)
        assert a.rng.bit_generator.state == b.rng.bit_generator.state


def test_snap_encoded_matches_scalar_roundtrip():
    tuner = VDTuner(_toy_space(), _toy_objective, seed=7, **_FAST).run(6)
    raw, Xc = tuner._candidates_encoded("A")
    want = np.stack([tuner.space.encode(tuner.space.decode(r, index_type="A")) for r in raw])
    np.testing.assert_array_equal(Xc, want)
