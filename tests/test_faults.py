"""Fault injection, degraded-mode serving, and the honest failure taxonomy.

Covers the robustness contracts:
* `FaultPlan` is data: JSON round-trips exactly, validates kinds/knobs, and
  seeded generation is reproducible;
* the no-fault fast path is untouched: an engine with no plan (or an armed
  empty plan) replays bit-identically to pre-fault behavior, and no-retry
  session ledgers carry no retry keys;
* segment loss degrades honestly: partial results from the searchable set
  only, `coverage` < 1 while quarantined, and the background rebuild
  restores the exact pre-fault search results (bitwise build replica);
* seal crashes retry with backoff instead of raising; exhausted budgets
  raise `TransientEngineFault`;
* the taxonomy routes eval errors correctly (transient vs config fault vs
  programmer error) and `TuningSession` retries transients with backoff,
  charging the wasted time to the recovered observation;
* controller hardening: shadow-OOM canary aborts, hysteresis cooldown;
* straggler monitor wiring and README/ROBUSTNESS doc sync.
"""
import copy
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core import RetryPolicy, TuningFailure, TuningSession
from repro.core.baselines import RandomLHS
from repro.core.space import Param, SearchSpace
from repro.serving import (
    ControllerParams,
    ServingController,
    SLOSpec,
    attach_straggler,
    ledger_table,
    serving_ledger,
)
from repro.vdms import (
    BuildCrashFault,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    LiveVDMS,
    ShadowBuildOOM,
    TransientEngineFault,
    VDMSTuningEnv,
    canned_fault_plans,
    classify_eval_error,
    make_space,
    make_trace,
    replay_trace,
)
from repro.vdms.faults import FAULT_KINDS, HEALTH_STATES

#: wall-clock result keys (nondeterministic run-to-run even in analytic mode)
WALL_KEYS = {"build_time", "compile_time"}


def _det(result):
    return {k: v for k, v in result.items() if k not in WALL_KEYS}


def _trace(n_base=400, n_ops=200, seed=0, drift=None):
    kw = {"drift": drift} if drift else {}
    return make_trace("glove_like", n_base=n_base, n_ops=n_ops, seed=seed,
                      mix=(0.3, 0.6, 0.1), **kw)


def _cfg(family="FLAT", **over):
    cfg = dict(make_space().default_config(family),
               segment_max_size=128, graceful_time=0.0)
    cfg.update(over)
    return cfg


# ---------------------------------------------------------------------------
# FaultPlan: data, validation, generation
# ---------------------------------------------------------------------------
def test_fault_plan_json_round_trip_exact():
    plan = canned_fault_plans(200)["latency_storm"]
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert FaultPlan.from_dict(json.loads(plan.to_json())) == plan
    # every event field survives the trip (plans are self-describing)
    d = plan.to_dict()
    assert all(set(e) == {f.name for f in dataclasses.fields(FaultEvent)} for e in d["events"])


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultEvent(kind="quantum_flip")
    with pytest.raises(ValueError):
        FaultEvent(kind="build_crash", fails=0)
    with pytest.raises(ValueError):
        FaultEvent(kind="latency_storm", duration_ticks=0)
    with pytest.raises(ValueError):
        FaultPlan(backoff_base_ticks=0)
    with pytest.raises(ValueError):
        FaultInjector(FaultPlan(), scope="tertiary")


def test_fault_plan_generate_is_reproducible():
    a = FaultPlan.generate(7, horizon_ticks=300)
    b = FaultPlan.generate(7, horizon_ticks=300)
    assert a == b and len(a.events) == 3
    assert FaultPlan.generate(8, horizon_ticks=300) != a


# ---------------------------------------------------------------------------
# no-fault fast path stays byte-identical
# ---------------------------------------------------------------------------
def test_unarmed_and_empty_plan_replay_identical():
    trace = _trace()
    cfg = _cfg("IVF_SQ8")
    plain = replay_trace(trace, cfg)
    empty = replay_trace(trace, cfg, fault_injector=FaultInjector(FaultPlan()))
    # fault bookkeeping keys appear ONLY when an injector is armed
    assert "coverage_min" not in plain and "n_quarantines" not in plain
    assert empty["coverage_min"] == 1.0 and empty["n_quarantines"] == 0
    for k in _det(plain):
        assert empty[k] == plain[k], f"fast path drifted on {k!r}"


def test_same_plan_replay_is_deterministic():
    trace = _trace()
    cfg = _cfg("FLAT")
    plan = canned_fault_plans(120)["segment_loss"]
    a = replay_trace(trace, cfg, fault_injector=FaultInjector(plan))
    b = replay_trace(trace, cfg, fault_injector=FaultInjector(plan))
    assert _det(a) == _det(b)
    assert a["n_quarantines"] >= 1  # the plan genuinely fired


def test_no_retry_session_ledger_has_no_retry_keys():
    space = SearchSpace(
        index_types={"A": [Param("ka", "grid", choices=(1, 2), default=1)]},
        system_params=[Param("s1", "float", 0.0, 1.0, default=0.5)],
    )
    tuner = RandomLHS(space, lambda cfg: {"speed": 1.0, "recall": 0.9}, seed=0)
    session = TuningSession(tuner)
    session.run(3)
    led = session.ledger_dict()
    assert "n_retries" not in led["totals"]
    assert all("retries" not in e for r in led["rounds"] for e in r["evals"])


# ---------------------------------------------------------------------------
# degraded mode: quarantine, partial serving, exact rebuild
# ---------------------------------------------------------------------------
def test_segment_loss_serves_partial_results_from_searchable_set():
    trace = _trace(n_base=512)
    cfg = _cfg("FLAT")
    live = LiveVDMS(cfg, trace.dim, trace.capacity, seed=0)
    live.bootstrap(trace.base)
    # long backoff keeps the quarantine open so we can observe it
    plan = FaultPlan(
        events=(FaultEvent(kind="segment_loss", at_tick=2, segment=0),),
        backoff_base_ticks=1000,
    )
    live.arm_faults(FaultInjector(plan))
    q = trace.queries[:16]
    ids0, _ = live.search(q, trace.k, mode="analytic")
    assert live.last_coverage == 1.0
    ids1, _ = live.search(q, trace.k, mode="analytic")  # tick 2: loss fires
    assert 0.0 < live.last_coverage < 1.0
    assert live.health() == "rebuilding"
    assert live.quarantined and live.stats()["n_quarantines"] == 1
    svis = live.searchable_ids()
    got = np.unique(ids1[ids1 >= 0])
    assert np.isin(got, svis).all(), "served ids outside the searchable set"
    assert not np.array_equal(ids0, ids1)  # the lost segment really dropped out


@pytest.mark.parametrize("family", ["FLAT", "IVF_SQ8"])
def test_rebuild_restores_exact_prefault_results(family):
    """The background rebuild is a bitwise replica: after recovery, a faulted
    engine's searches equal an identical never-faulted engine's exactly."""
    trace = _trace(n_base=512, n_ops=160, seed=3)
    cfg = _cfg(family)
    plan = FaultPlan(
        events=(
            FaultEvent(kind="segment_loss", at_tick=30, segment=0),
            FaultEvent(kind="segment_corruption", at_tick=50, segment=1),
        ),
        backoff_base_ticks=2,
    )
    engines = []
    for injector in (None, FaultInjector(plan)):
        live = LiveVDMS(cfg, trace.dim, trace.capacity, seed=0)
        live.bootstrap(trace.base)
        if injector is not None:
            live.arm_faults(injector)
        for i in range(trace.n_ops):
            kind = int(trace.kinds[i])
            row = int(trace.payload[i])
            if kind == 0:
                live.insert(trace.inserts[row])
            elif kind == 1:
                live.search(trace.queries[row][None, :], trace.k, mode="analytic")
            else:
                live.delete(row)
        engines.append(live)
    clean, faulted = engines
    assert faulted.stats()["n_rebuilds"] == 2
    assert faulted.health() == "healthy" and not faulted.quarantined
    ids_clean, _ = clean.search(trace.queries[:32], trace.k, mode="analytic")
    ids_fault, _ = faulted.search(trace.queries[:32], trace.k, mode="analytic")
    assert np.array_equal(ids_clean, ids_fault)
    assert faulted.last_coverage == 1.0


def test_seal_crash_retries_with_backoff_then_succeeds():
    cfg = _cfg("FLAT", segment_max_size=64)
    live = LiveVDMS(cfg, 16, 1024, seed=0)
    rng = np.random.default_rng(0)
    live.bootstrap(rng.standard_normal((16, 16)).astype(np.float32))
    plan = FaultPlan(
        events=(FaultEvent(kind="build_crash", at_tick=1, fails=2),),
        backoff_base_ticks=2, max_seal_retries=6,
    )
    live.arm_faults(FaultInjector(plan))
    for _ in range(120):  # each insert ticks the fault clock
        live.insert(rng.standard_normal((16,)).astype(np.float32))
    st = live.stats()
    assert st["n_seal_retries"] == 2  # crashed twice, retried, then sealed
    assert st["n_seals"] >= 1
    assert live._pending_seal is None and live.health() == "healthy"


def test_seal_retry_budget_exhaustion_raises_transient():
    cfg = _cfg("FLAT", segment_max_size=64)
    live = LiveVDMS(cfg, 16, 1024, seed=0)
    rng = np.random.default_rng(0)
    live.bootstrap(rng.standard_normal((16, 16)).astype(np.float32))
    plan = FaultPlan(
        events=(FaultEvent(kind="build_crash", at_tick=1, fails=50),),
        backoff_base_ticks=1, max_seal_retries=2,
    )
    live.arm_faults(FaultInjector(plan))
    with pytest.raises(TransientEngineFault):
        for _ in range(200):
            live.insert(rng.standard_normal((16,)).astype(np.float32))


def test_rebuild_budget_exhaustion_goes_permanently_degraded():
    trace = _trace(n_base=512)
    cfg = _cfg("FLAT")
    live = LiveVDMS(cfg, trace.dim, trace.capacity, seed=0)
    live.bootstrap(trace.base)
    plan = FaultPlan(
        events=(
            FaultEvent(kind="segment_loss", at_tick=2, segment=0),
            FaultEvent(kind="build_crash", at_tick=1, fails=100),
        ),
        backoff_base_ticks=1, max_rebuild_retries=2,
    )
    live.arm_faults(FaultInjector(plan))
    q = trace.queries[:4]
    for _ in range(30):
        live.search(q, trace.k, mode="analytic")
    assert live.health() == "degraded"
    assert live.stats()["n_rebuild_failures"] == 1
    assert 0.0 < live.last_coverage < 1.0  # still serving, honestly partial


# ---------------------------------------------------------------------------
# hypothesis property: generated plans replay bit-identically
# ---------------------------------------------------------------------------
def test_generated_plans_replay_bit_identical():
    hyp = pytest.importorskip("hypothesis", reason="optional test dep")
    from hypothesis import given, settings, strategies as st

    trace = _trace(n_base=256, n_ops=96, seed=1)
    cfg = _cfg("FLAT", segment_max_size=64)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**20))
    def prop(seed):
        plan = FaultPlan.generate(seed, horizon_ticks=80)
        try:
            a = replay_trace(trace, cfg, fault_injector=FaultInjector(plan))
        except TransientEngineFault:
            # a legal outcome for brutal plans — but it must be deterministic
            with pytest.raises(TransientEngineFault):
                replay_trace(trace, cfg, fault_injector=FaultInjector(plan))
            return
        b = replay_trace(trace, cfg, fault_injector=FaultInjector(plan))
        assert _det(a) == _det(b)

    prop()
    assert hyp  # silence linters


# ---------------------------------------------------------------------------
# failure taxonomy + session retries
# ---------------------------------------------------------------------------
def test_classify_eval_error_taxonomy():
    tf = TuningFailure("already classified")
    assert classify_eval_error(tf) is tf
    out = classify_eval_error(TransientEngineFault("gave up"))
    assert isinstance(out, TuningFailure) and out.transient
    out = classify_eval_error(BuildCrashFault("boom"))
    assert isinstance(out, TuningFailure) and out.transient
    out = classify_eval_error(ValueError("bad shape"))
    assert isinstance(out, TuningFailure) and not out.transient
    out = classify_eval_error(ZeroDivisionError("div"))
    assert isinstance(out, TuningFailure) and not out.transient
    assert classify_eval_error(TypeError("programmer error")) is None
    assert classify_eval_error(KeyError("programmer error")) is None


def test_env_routes_faults_and_propagates_programmer_errors(monkeypatch):
    import repro.vdms.tuning_env as te

    trace = _trace(n_base=256, n_ops=64)
    env = VDMSTuningEnv(trace=trace, workload="streaming", mode="analytic",
                        seed=0, n_phases=1)
    cfg = _cfg("FLAT")

    def boom_type(*a, **kw):
        raise TypeError("programmer error")

    monkeypatch.setattr(te, "replay_trace", boom_type)
    with pytest.raises(TypeError):
        env(dict(cfg))

    def boom_value(*a, **kw):
        raise ValueError("config-dependent crash")

    monkeypatch.setattr(te, "replay_trace", boom_value)
    with pytest.raises(TuningFailure) as ei:
        env(dict(cfg, nprobe=1) if "nprobe" in cfg else dict(cfg))
    assert not ei.value.transient


def test_env_with_fault_plan_raises_transient_failure():
    # insert-heavy trace so the growing tail actually reaches a seal attempt
    trace = make_trace("glove_like", n_base=256, n_ops=200, seed=0,
                       mix=(0.8, 0.15, 0.05))
    plan = FaultPlan(
        events=(FaultEvent(kind="build_crash", at_tick=1, fails=100),),
        backoff_base_ticks=1, max_seal_retries=1,
    )
    env = VDMSTuningEnv(trace=trace, workload="streaming", mode="analytic",
                        seed=0, n_phases=1, faults=plan)
    with pytest.raises(TuningFailure) as ei:
        env(_cfg("FLAT", segment_max_size=64))
    assert ei.value.transient
    with pytest.raises(ValueError):
        VDMSTuningEnv(trace=trace, workload="static", faults=plan)


class _FlakyBackend:
    """Transient-fails the first ``fail_times`` calls, then succeeds."""

    def __init__(self, fail_times):
        self.calls = 0
        self.fail_times = fail_times

    def __call__(self, cfg):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise TuningFailure("injected flake", transient=True)
        return {"speed": 10.0, "recall": 0.9, "build_time": 1.0}


def _tiny_space():
    return SearchSpace(
        index_types={"A": [Param("ka", "grid", choices=(1, 2), default=1)]},
        system_params=[Param("s1", "float", 0.0, 1.0, default=0.5)],
    )


def test_session_retries_transient_and_charges_cost():
    backend = _FlakyBackend(fail_times=2)
    tuner = RandomLHS(_tiny_space(), backend, seed=0)
    session = TuningSession(
        tuner, retry=RetryPolicy(max_retries=2, backoff_s=0.0)
    )
    session.run(1)
    assert backend.calls == 3  # two flakes + the recovery
    obs = tuner.history[0]
    assert not obs.failed  # the GP sees a NORMAL observation
    led = session.ledger_dict()
    assert led["totals"]["n_retries"] == 2
    rows = [e for r in led["rounds"] for e in r["evals"]]
    assert rows[0]["retries"] == 2
    # the wasted attempts' wall time was charged into the eval time
    assert rows[0]["eval_s"] > 0.0


def test_session_retry_budget_exhausts_to_failure_feedback():
    backend = _FlakyBackend(fail_times=99)
    tuner = RandomLHS(_tiny_space(), backend, seed=0)
    session = TuningSession(
        tuner, retry=RetryPolicy(max_retries=2, backoff_s=0.0)
    )
    session.run(1)
    assert backend.calls == 3  # initial + 2 retries, then give up
    assert tuner.history[0].failed


def test_session_checkpoint_round_trips_mid_retry():
    backend = _FlakyBackend(fail_times=99)
    tuner = RandomLHS(_tiny_space(), backend, seed=0)
    session = TuningSession(
        tuner, retry=RetryPolicy(max_retries=5, backoff_s=0.125)
    )
    cfg = {"index_type": "A", "ka": 1, "s1": 0.5}
    session._pending = [cfg]
    session._pending_recommend_s = 0.0
    session._drain()  # one transient failure -> retry state armed
    assert session._pending == [cfg]  # config stays at the head of the queue
    state = session.state_dict()
    key = TuningSession._cfg_key(cfg)
    assert state["retry"][key]["attempts"] == 1
    assert state["retry"][key]["backoff_s"] == pytest.approx(0.125)
    # restore into a fresh session: backoff state intact, bit-identical
    fresh = TuningSession(
        RandomLHS(_tiny_space(), backend, seed=0),
        retry=RetryPolicy(max_retries=5, backoff_s=0.125),
    )
    fresh.load_state_dict(copy.deepcopy(state))
    assert fresh._retry_state == session._retry_state
    assert fresh.state_dict()["retry"] == state["retry"]
    # pre-retry checkpoints (no key) load fine
    old = {k: v for k, v in state.items() if k != "retry"}
    fresh.load_state_dict(copy.deepcopy(old))
    assert fresh._retry_state == {}


def test_retry_policy_validation_and_backoff():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(eval_timeout_s=0.0)
    p = RetryPolicy(backoff_s=0.5, backoff_factor=2.0)
    assert [p.backoff(i) for i in (1, 2, 3)] == [0.5, 1.0, 2.0]


def test_eval_timeout_is_transient():
    import time as _time

    def slow(cfg):
        _time.sleep(0.5)
        return {"speed": 1.0, "recall": 0.9}

    tuner = RandomLHS(_tiny_space(), slow, seed=0)
    session = TuningSession(
        tuner,
        retry=RetryPolicy(max_retries=0, backoff_s=0.0, eval_timeout_s=0.05),
    )
    session.run(1)
    assert tuner.history[0].failed  # timed out -> transient -> budget 0 -> fail


# ---------------------------------------------------------------------------
# controller hardening + straggler wiring
# ---------------------------------------------------------------------------
def test_rollback_cooldown_hysteresis_grows_and_caps():
    ctrl = ServingController(
        SLOSpec(recall_floor=0.9),
        params=ControllerParams(
            cooldown_ops=48, storm_cooldown_factor=2.0, storm_cooldown_cap_ops=100
        ),
    )
    expected = {0: 48, 1: 48, 2: 96, 3: 100, 7: 100}
    for n, want in expected.items():
        ctrl._consec_rollbacks = n
        assert ctrl._rollback_cooldown() == want
    with pytest.raises(ValueError):
        ControllerParams(storm_cooldown_factor=0.5)


def test_shadow_scope_injector_only_serves_oom():
    plan = canned_fault_plans(200)["latency_storm"]  # has a shadow_oom at ordinal 0
    shadow = FaultInjector(plan, scope="shadow")
    assert shadow.advance() == []  # primary events don't leak into shadow scope
    with pytest.raises(ShadowBuildOOM):
        shadow.on_bootstrap(64)
    shadow.on_bootstrap(64)  # the next canary's bootstrap is fine
    primary = FaultInjector(plan, scope="primary")
    primary.on_bootstrap(64)  # ooms never fire in primary scope


def test_guarded_serve_aborts_canary_on_shadow_oom():
    trace = _trace(n_base=400, n_ops=260, seed=2, drift="step")
    env = VDMSTuningEnv(trace=trace.window(0, 100), workload="streaming",
                        mode="analytic", seed=2, n_phases=1)
    from repro.core import VDTuner

    tuner = VDTuner(make_space(), env, seed=2, warm_start=True)
    session = TuningSession(tuner)
    session.run(4)
    plan = FaultPlan(events=(FaultEvent(kind="shadow_oom", at_tick=0),))
    cfg = _cfg("FLAT", segment_max_size=256, graceful_time=0.4)
    ctrl = ServingController(
        SLOSpec(recall_floor=0.999, min_samples=8), session=session,
        params=ControllerParams(
            check_every=24, canary_queries=16, retune_iters=4,
            retune_window_ops=128, cooldown_ops=48, min_window_searches=8,
            repair_anchors=False, floor_margin=0.0,
        ),
        seed=2,
    )
    report = ctrl.serve(trace, cfg, guard=True, fault_plan=plan)
    events = [e["event"] for e in report["timeline"]]
    assert "canary_aborted_oom" in events  # the first canary's build OOMed
    assert report["n_rollbacks"] >= 1
    assert ctrl.ledger.counter("vdms_canary_fault_abort_total").value >= 1
    assert report["fault"]["n_injected"] >= 1


def test_straggler_monitor_flags_latency_storm():
    trace = _trace(n_base=512)
    cfg = _cfg("FLAT")
    live = LiveVDMS(cfg, trace.dim, trace.capacity, seed=0)
    live.bootstrap(trace.base)
    plan = FaultPlan(
        events=(
            FaultEvent(kind="latency_storm", at_tick=12, duration_ticks=100,
                       latency_mult=50.0, latency_add_s=1e-3),
        ),
    )
    live.arm_faults(FaultInjector(plan))
    ledger = serving_ledger()
    monitor = attach_straggler(ledger, live)
    q = trace.queries[:8]
    for _ in range(24):  # 12 calm ticks, then the storm hits
        live.search(q, trace.k, mode="analytic")
    assert any(s.flagged for s in monitor.history)
    assert ledger.gauge("vdms_straggler_flagged").value > 0
    # re-attach keeps the same monitor across promotes
    assert attach_straggler(ledger, live, monitor) is monitor


# ---------------------------------------------------------------------------
# docs stay in sync
# ---------------------------------------------------------------------------
def _repo_root():
    return pathlib.Path(__file__).resolve().parents[1]


def test_readme_ledger_table_in_sync():
    text = (_repo_root() / "README.md").read_text()
    begin, end = "<!-- ledger-table:begin -->", "<!-- ledger-table:end -->"
    assert begin in text and end in text, "README lost the ledger-table markers"
    block = text.split(begin)[1].split(end)[0].strip()
    assert block == ledger_table().strip(), (
        "README ledger table is stale; regenerate with "
        "python -c \"from repro.serving import ledger_table; print(ledger_table())\""
    )


def test_readme_links_robustness_doc():
    text = (_repo_root() / "README.md").read_text()
    assert "docs/ROBUSTNESS.md" in text


def test_robustness_doc_covers_taxonomy_and_states():
    doc = (_repo_root() / "docs" / "ROBUSTNESS.md").read_text()
    for kind in FAULT_KINDS:
        assert f"`{kind}`" in doc, f"ROBUSTNESS.md lost fault kind {kind!r}"
    for state in HEALTH_STATES:
        assert state.upper() in doc, f"ROBUSTNESS.md lost health state {state!r}"
    assert "FaultPlan" in doc and "coverage" in doc
