"""plan_segments edge cases and live-lifecycle properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.vdms import live_seg_size, make_trace, plan_segments, replay_trace
from repro.vdms.workload import OP_DELETE


# ---------------------------------------------------------------------------
# plan_segments edges
# ---------------------------------------------------------------------------
def test_seal_proportion_exactly_at_boundary():
    # rem == seal_proportion * seg_size: the trailing remainder seals (>=)
    plan = plan_segments(1500, 1000, 0.5, 0.0)
    assert plan.n_sealed == 2
    assert plan.sealed_valid.tolist() == [1000, 500]
    assert plan.growing_size == 0
    # nudge the threshold above the remainder: it stays growing
    plan = plan_segments(1500, 1000, 0.5001, 0.0)
    assert plan.n_sealed == 1
    assert plan.growing_size == 500


def test_graceful_time_extremes():
    plan0 = plan_segments(1500, 1000, 0.9, 0.0)
    assert plan0.growing_size == 500
    assert plan0.growing_searched == 500  # 0.0 scans the whole tail
    plan9 = plan_segments(1500, 1000, 0.9, 0.9)
    assert plan9.growing_searched == int(np.ceil(0.1 * 500))
    # out-of-range graceful values clamp instead of exploding
    assert plan_segments(1500, 1000, 0.9, 2.0).growing_searched == 0
    assert plan_segments(1500, 1000, 0.9, -1.0).growing_searched == 500


def test_n_smaller_than_segment_max_size():
    # seg size clamps to n: everything lands in one sealed segment
    plan = plan_segments(500, 4096, 0.75, 0.2)
    assert plan.seg_size == 500
    assert plan.n_sealed == 1
    assert plan.growing_size == 0


@settings(max_examples=80, deadline=None)
@given(
    st.integers(1, 400),
    st.integers(64, 8192),
    st.floats(0.1, 1.0),
    st.floats(0.0, 0.9),
)
def test_single_sealed_segment_invariant(n, smax, seal, graceful):
    # the forced single-sealed-segment regime: however small n gets, the plan
    # always yields >= 1 sealed segment and partitions every vector
    plan = plan_segments(n, smax, seal, graceful)
    assert plan.n_sealed >= 1
    assert plan.sealed_valid.sum() + plan.growing_size == n
    assert 0 <= plan.growing_searched <= plan.growing_size


def test_live_seg_size_bounds_and_monotonicity():
    assert live_seg_size(1024, 0.5) == 512
    assert live_seg_size(1, 0.1) == 64  # clamps to the static minimum
    assert live_seg_size(8192, 1.0) == 8192
    sizes = [live_seg_size(4096, p) for p in (0.1, 0.3, 0.5, 0.8, 1.0)]
    assert sizes == sorted(sizes)
    assert all(64 <= s <= 4096 for s in sizes)


# ---------------------------------------------------------------------------
# lifecycle properties: replay == re-plan from scratch (visible sets)
# ---------------------------------------------------------------------------
FLAT_CFG = dict(
    index_type="FLAT",
    seal_proportion=0.5,
    graceful_time=0.0,
    search_batch_size=8,
    topk_merge_width=32,
    kmeans_iters=4,
    storage_bf16=False,
)


@settings(max_examples=6, deadline=None)
@given(
    st.integers(80, 250),
    st.integers(64, 512),
    st.sampled_from(["none", "ramp", "step"]),
    st.integers(0, 3),
)
def test_replay_visible_set_matches_replan_from_scratch(n_base, smax, drift, seed):
    trace = make_trace(
        "glove_like",
        n_base=n_base,
        n_ops=60,
        seed=seed,
        drift=drift,
        mix=(0.35, 0.45, 0.20),
        dim=16,
    )
    cfg = dict(FLAT_CFG, segment_max_size=smax)
    _, live = replay_trace(trace, cfg, mode="analytic", with_live=True)
    # sealed-segment count never decreases over the lifecycle
    assert all(b >= a for a, b in zip(live.seal_history, live.seal_history[1:]))
    # the replayed visible set equals the trace-derived alive set
    deleted = {int(trace.payload[i]) for i in range(trace.n_ops) if trace.kinds[i] == OP_DELETE}
    expected = set(range(trace.capacity)) - deleted
    assert set(live.visible_ids().tolist()) == expected
    # re-planning from scratch over the surviving corpus partitions exactly
    # the same visible set (sealed + growing covers every survivor)
    plan = plan_segments(
        len(expected),
        int(cfg["segment_max_size"]),
        cfg["seal_proportion"],
        cfg["graceful_time"],
    )
    assert plan.sealed_valid.sum() + plan.growing_size == len(expected)
