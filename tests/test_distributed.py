"""Distribution layer: sharding rules, divisibility fallbacks, conflict
resolution, and a real (subprocess) mini-dry-run with 8 host devices."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.sharding import ShardingRules, param_axes_for
from repro.launch import hlo_analysis


# ---------------------------------------------------------------------------
# rules (mesh=None paths are pure logic — no devices needed)
# ---------------------------------------------------------------------------
def test_rules_no_mesh_is_noop():
    rules = ShardingRules(None)
    assert rules.sharding(("batch", None)) is None


def test_param_axes_inference():
    assert param_axes_for(("layers", "attn", "wq"), (4, 128, 256)) == ("layers", "fsdp", "heads")
    assert param_axes_for(("embed",), (1024, 64)) == ("vocab", "fsdp")
    assert param_axes_for(("norm", "scale"), (64,)) == (None,)
    # unknown names fall back to replicated
    assert param_axes_for(("mystery",), (3, 4)) == (None, None)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
HLO_SAMPLE = textwrap.dedent(
    """
    %x = bf16[16,128]{1,0} parameter(0)
    ROOT %all-reduce = f32[64,128]{1,0} all-reduce(%dot), channel_id=1
    %ag = bf16[32,256]{1,0} all-gather(%y), dimensions={0}
    %rs.1 = (f32[8,8]{1,0}, f32[8,8]{1,0}) reduce-scatter(%a, %b), dimensions={0}
    %not-a-collective = f32[9,9]{1,0} add(%c, %d)
    """
)


def test_collective_bytes_parsing():
    cb = hlo_analysis.collective_bytes(HLO_SAMPLE)
    assert cb["all-reduce"] == 64 * 128 * 4
    assert cb["all-gather"] == 32 * 256 * 2
    assert cb["reduce-scatter"] == 2 * 8 * 8 * 4
    assert "add" not in cb
    counts = hlo_analysis.count_collectives(HLO_SAMPLE)
    assert counts == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1}


def test_roofline_terms_and_bottleneck():
    r = hlo_analysis.Roofline(
        arch="a",
        shape="s",
        mesh="16x16",
        chips=256,
        hlo_flops=1e18,
        hlo_bytes=1e12,
        coll_bytes=1e12,
        coll_breakdown={},
        coll_counts={},
        model_flops=5e17,
        peak_mem_per_dev=1e9,
    )
    assert r.compute_s == pytest.approx(1e18 / (256 * hlo_analysis.PEAK_FLOPS))
    assert r.bottleneck == "compute"
    assert 0 < r.roofline_fraction <= 1.0
    assert r.useful_ratio == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# mini dry-run in a subprocess (needs its own XLA_FLAGS before jax import)
# ---------------------------------------------------------------------------
MINI_DRYRUN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    from repro.configs.base import get_arch, reduce, SHAPES
    from repro.distributed.sharding import ShardingRules
    from repro.launch.dryrun import _compile_step
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        reduce(get_arch("glm4-9b")), name="mini", d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab=1024, n_layers=2,
    )
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
    rules = ShardingRules(mesh)
    lowered, compiled = _compile_step(cfg, shape, mesh, rules, "nothing")
    from repro.launch import hlo_analysis
    cb = hlo_analysis.collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    print(json.dumps({
        "ok": True,
        "has_collectives": bool(cb),
        "temp": int(ma.temp_size_in_bytes),
    }))
    """
)


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"] and result["has_collectives"]
    assert result["temp"] > 0
