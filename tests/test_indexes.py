"""Index-build helper regressions (no optional deps)."""
import numpy as np

from repro.vdms.indexes import _ivf_cap, _member_lists


def _member_lists_reference(assign, nlist, cap):
    """Verbatim copy of the pre-vectorization per-cluster loop."""
    out = -np.ones((nlist, cap), dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    sa = assign[order]
    starts = np.searchsorted(sa, np.arange(nlist), "left")
    ends = np.searchsorted(sa, np.arange(nlist), "right")
    for j in range(nlist):
        mem = order[starts[j] : ends[j]][:cap]
        out[j, : len(mem)] = mem
    return out


def test_member_lists_matches_loop_reference():
    rng = np.random.default_rng(0)
    for _ in range(100):
        nlist = int(rng.integers(1, 48))
        n = int(rng.integers(0, 600))
        cap = int(rng.integers(1, 40))
        assign = rng.integers(0, nlist, size=n).astype(np.int64)
        np.testing.assert_array_equal(
            _member_lists(assign, nlist, cap),
            _member_lists_reference(assign, nlist, cap),
        )


def test_member_lists_overflow_and_empty_clusters():
    # cluster 0 overflows cap (extra members dropped), cluster 2 is empty
    assign = np.array([0, 0, 0, 0, 1, 0], dtype=np.int64)
    out = _member_lists(assign, nlist=3, cap=2)
    np.testing.assert_array_equal(out[0], [0, 1])  # stable: first two ids kept
    np.testing.assert_array_equal(out[1], [4, -1])
    np.testing.assert_array_equal(out[2], [-1, -1])


def test_ivf_cap_bounds_scan_cost():
    assert _ivf_cap(1024, 16, 4) >= 8
    assert _ivf_cap(1024, 4, 4) * 4 <= 1024 + 8 * 4
