"""Streaming workload traces: generation, time-aware ground truth, windows."""
import numpy as np
import pytest

from repro.vdms import (
    make_trace,
    recall_at_k_masked,
    replay_trace,
    time_aware_ground_truth,
)
from repro.vdms.workload import DRIFT_SCHEDULES, OP_DELETE, OP_INSERT, OP_SEARCH

FLAT_CFG = dict(
    index_type="FLAT",
    segment_max_size=256,
    seal_proportion=0.5,
    graceful_time=0.0,
    search_batch_size=8,
    topk_merge_width=64,
    kmeans_iters=4,
    storage_bf16=False,
)


def small_trace(**kw):
    kw.setdefault("n_base", 400)
    kw.setdefault("n_ops", 160)
    kw.setdefault("seed", 3)
    kw.setdefault("mix", (0.3, 0.55, 0.15))
    return make_trace("glove_like", **kw)


def test_trace_shapes_and_payload_validity():
    t = small_trace()
    assert t.kinds.shape == t.payload.shape == t.times.shape == (t.n_ops,)
    assert t.inserts.shape == (int((t.kinds == OP_INSERT).sum()), t.dim)
    assert t.queries.shape == (int((t.kinds == OP_SEARCH).sum()), t.dim)
    assert (np.diff(t.times) >= 0).all() and t.times[0] >= 0 and t.times[-1] <= 1
    # insert/search payloads are sequential rows into their arrays
    assert (t.payload[t.kinds == OP_INSERT] == np.arange(t.n_inserts)).all()
    assert (t.payload[t.kinds == OP_SEARCH] == np.arange(t.n_searches)).all()
    # delete victims: unique, in range, and inserted before being deleted
    n_inserted = 0
    seen = set()
    for i in range(t.n_ops):
        if t.kinds[i] == OP_INSERT:
            n_inserted += 1
        elif t.kinds[i] == OP_DELETE:
            victim = int(t.payload[i])
            assert 0 <= victim < t.n_base + n_inserted
            assert victim not in seen  # never double-deleted
            seen.add(victim)


def test_drift_schedules_bounded():
    tau = np.linspace(0.0, 1.0, 101)
    for name, fn in DRIFT_SCHEDULES.items():
        w = fn(tau)
        assert ((w >= -1e-12) & (w <= 1 + 1e-12)).all(), name
    assert (DRIFT_SCHEDULES["none"](tau) == 0).all()


def test_mix_drift_shifts_arrival_mix():
    t = make_trace(
        "glove_like",
        n_base=64,
        n_ops=3000,
        seed=0,
        drift="ramp",
        mix=(0.05, 0.90, 0.05),
        mix_to=(0.70, 0.20, 0.10),
    )
    third = t.n_ops // 3
    early = (t.kinds[:third] == OP_INSERT).mean()
    late = (t.kinds[-third:] == OP_INSERT).mean()
    assert late > early + 0.3


def _slow_oracle_gt(trace, k):
    """Independent per-query python sweep (no batching, no masks)."""
    all_vec = trace.all_vectors()
    visible = set(range(trace.n_base))
    out = -np.ones((trace.n_searches, k), np.int32)
    n_ins = 0
    for i in range(trace.n_ops):
        kind = int(trace.kinds[i])
        if kind == OP_INSERT:
            visible.add(trace.n_base + n_ins)
            n_ins += 1
        elif kind == OP_DELETE:
            visible.discard(int(trace.payload[i]))
        else:
            ids = np.fromiter(sorted(visible), np.int64)
            sims = all_vec[ids] @ trace.queries[int(trace.payload[i])]
            order = np.argsort(-sims, kind="stable")[: min(k, ids.size)]
            out[int(trace.payload[i]), : order.size] = ids[order].astype(np.int32)
    return out


def test_time_aware_gt_matches_slow_oracle():
    t = small_trace(n_base=150, n_ops=120)
    fast = time_aware_ground_truth(t)
    slow = _slow_oracle_gt(t, t.k)
    for row, (a, b) in enumerate(zip(fast, slow)):
        assert set(a.tolist()) == set(b.tolist()), row


def test_gt_respects_insert_visibility():
    t = small_trace(n_base=100, n_ops=100, seed=7)
    gt = time_aware_ground_truth(t)
    n_inserted = 0
    for i in range(t.n_ops):
        if t.kinds[i] == OP_INSERT:
            n_inserted += 1
        elif t.kinds[i] == OP_SEARCH:
            row = gt[int(t.payload[i])]
            assert (row < t.n_base + n_inserted).all()


def test_window_folds_prefix_into_base():
    t = small_trace(n_base=200, n_ops=150)
    lo = t.n_ops // 2
    w = t.window(lo, t.n_ops)
    # the window's base is exactly the visible set at op lo
    dead = np.zeros(t.capacity, bool)
    n_vis = t.n_base
    for i in range(lo):
        if t.kinds[i] == OP_INSERT:
            n_vis += 1
        elif t.kinds[i] == OP_DELETE:
            dead[t.payload[i]] = True
    vis_ids = np.flatnonzero(~dead[:n_vis])
    np.testing.assert_array_equal(w.base, t.all_vectors()[vis_ids])
    # window ground truth equals the full-trace ground truth on shared
    # searches, modulo the dense re-assignment of global ids
    old_of_new = np.concatenate([vis_ids, t.n_base + t.payload[np.flatnonzero(t.kinds[lo:] == OP_INSERT) + lo]])
    gt_full = time_aware_ground_truth(t)
    gt_win = time_aware_ground_truth(w)
    win_q_rows = t.payload[np.flatnonzero(t.kinds[lo:] == OP_SEARCH) + lo]
    for new_row, old_row in enumerate(win_q_rows):
        got = {int(old_of_new[g]) for g in gt_win[new_row] if g >= 0}
        want = {int(g) for g in gt_full[int(old_row)] if g >= 0}
        assert got == want


def test_split_covers_all_ops():
    t = small_trace()
    phases = t.split(4)
    assert sum(p.n_ops for p in phases) <= t.n_ops  # pre-window deletes may fold
    assert sum(p.n_searches for p in phases) == t.n_searches
    assert sum(p.n_inserts for p in phases) == t.n_inserts


def test_replay_flat_graceful0_is_exact():
    t = small_trace(n_base=300, n_ops=120)
    r = replay_trace(t, FLAT_CFG, mode="analytic")
    assert r["recall"] == pytest.approx(1.0)
    assert r["speed"] > 0 and r["mem_gib"] > 0


def test_recall_at_k_masked_padding():
    gt = np.array([[0, 1, -1], [-1, -1, -1]], np.int32)
    pred = np.array([[0, 1, 2], [5, 6, 7]], np.int32)
    assert recall_at_k_masked(pred, gt) == 1.0  # all-pad row drops out
    pred2 = np.array([[0, 9, 9], [5, 6, 7]], np.int32)
    assert recall_at_k_masked(pred2, gt) == 0.5


def test_delete_heavy_mix_survives_victim_exhaustion():
    # deletes outpace inserts until the victim pool empties: exhausted delete
    # ops are dropped instead of crashing, and every kept victim is valid
    t = make_trace("glove_like", n_base=4, n_ops=200, mix=(0.0, 0.4, 0.6), dim=16, seed=0)
    n_deletes = int((t.kinds == OP_DELETE).sum())
    assert n_deletes <= t.n_base + t.n_inserts
    victims = t.payload[t.kinds == OP_DELETE]
    assert len(set(victims.tolist())) == n_deletes
    assert ((victims >= 0) & (victims < t.capacity)).all()
    time_aware_ground_truth(t)  # replayable end-to-end


def test_make_trace_validates_inputs():
    with pytest.raises(ValueError):
        make_trace("glove_like", n_base=10, n_ops=10, drift="warp")
    with pytest.raises(ValueError):
        make_trace("glove_like", n_base=10, n_ops=10, mix=(1.0, -0.5, 0.5))
    with pytest.raises(ValueError):
        small_trace().window(5, 3)
