"""Property tests (hypothesis) for the device-resident acquisition engine:
the JAX EHVI/CEI/HVI ports match the numpy references across random fronts,
refs and degenerate cases, and the rank-1 Cholesky update in
``GP.condition_on`` matches a full refactorization."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep; pip install -e .[test]")
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from repro.core import GP, cei, cei_jax, ehvi_mc, ehvi_mc_jax, hvi_2d, hvi_2d_jax, pareto_front
from repro.core.gp import _posterior_padded

points2d = st.lists(
    st.tuples(st.floats(0.01, 100.0, allow_nan=False), st.floats(0.01, 100.0, allow_nan=False)),
    min_size=1,
    max_size=16,
).map(lambda ps: np.array(ps, dtype=np.float64))


def _pad_front(front, extra):
    k0 = front.shape[0]
    fp = np.zeros((k0 + extra, 2))
    fm = np.zeros((k0 + extra,), bool)
    fp[:k0] = front
    fm[:k0] = True
    return fp, fm


@settings(max_examples=40, deadline=None)
@given(points2d, points2d, st.floats(-1.0, 1.0), st.floats(-1.0, 1.0), st.integers(0, 8))
def test_hvi_jax_matches_numpy(front_pts, pts, r0, r1, extra):
    ref = np.array([r0, r1])
    front = pareto_front(front_pts)
    want = hvi_2d(pts, front, ref)
    fp, fm = _pad_front(front, extra)
    with enable_x64():
        got = np.asarray(hvi_2d_jax(pts, fp, fm, ref))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


class _FixedEps:
    def __init__(self, eps):
        self._eps = eps

    def standard_normal(self, shape):
        assert shape == self._eps.shape
        return self._eps


@settings(max_examples=25, deadline=None)
@given(points2d, st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_ehvi_jax_matches_numpy(front_pts, seed, extra):
    rng = np.random.default_rng(seed)
    front = pareto_front(front_pts)
    ref = np.array([0.5, 0.5])
    c = 12
    mean = (rng.random((c, 2)) * 2).astype(np.float32).astype(np.float64)
    std = (rng.random((c, 2)) * 0.5 + 1e-3).astype(np.float32).astype(np.float64)
    eps = rng.standard_normal((16, c, 2))
    want = ehvi_mc(mean, std, front, ref, _FixedEps(eps), n_samples=16)
    fp, fm = _pad_front(front, extra)
    with enable_x64():
        got = np.asarray(ehvi_mc_jax(mean, std, fp, fm, ref, eps))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.one_of(st.just(float("-inf")), st.floats(-2.0, 2.0)),
    st.floats(0.1, 1.5),
)
def test_cei_jax_matches_numpy(seed, best, rlim):
    rng = np.random.default_rng(seed)
    mean = rng.normal(0.0, 2.0, size=20)
    std = np.abs(rng.normal(0.0, 1.0, size=20)) + 1e-12
    mean_r = rng.random(20) * 1.5
    std_r = rng.random(20) * 0.2 + 1e-12
    want = cei(mean, std, mean_r, std_r, best, rlim)
    with enable_x64():
        got = np.asarray(cei_jax(mean, std, mean_r, std_r, best, rlim))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(5, 40), st.integers(1, 6))
def test_rank1_cholesky_matches_full_refactorization(seed, n0, k):
    rng = np.random.default_rng(seed)
    X = rng.random((n0, 2))
    Y = np.stack([np.sin(3 * X[:, 0]), X[:, 1]], axis=1)
    gp = GP(seed=0, fit_steps=40).fit(X, Y)
    Xn = rng.random((k, 2))
    mean, _ = gp.predict(Xn)
    g2 = gp.condition_on(Xn, mean)
    s = g2.state
    chol_full, _ = _posterior_padded(s.params.log_ls, s.params.log_sf, s.params.log_noise, s.x, s.y, s.mask)
    np.testing.assert_allclose(np.asarray(s.chol), np.asarray(chol_full), atol=2e-4)
