"""Unit + property tests for the VDTuner core (GP, Pareto, HV, EHVI,
NPI normalization, successive abandon, the full Algorithm-1 loop)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import (
    GP,
    Param,
    SearchSpace,
    SuccessiveAbandon,
    VDTuner,
    RandomLHS,
    balanced_base,
    cei,
    ehvi_mc,
    ei,
    hv_2d,
    hvi_2d,
    non_dominated_mask,
    npi_normalize,
    pareto_front,
    scores_by_hv_influence,
)

# ---------------------------------------------------------------------------
# hypervolume / pareto
# ---------------------------------------------------------------------------
points2d = st.lists(
    st.tuples(st.floats(0.01, 100.0, allow_nan=False), st.floats(0.01, 100.0, allow_nan=False)),
    min_size=1,
    max_size=24,
).map(lambda ps: np.array(ps, dtype=np.float64))


def test_hv_known_values():
    assert hv_2d(np.array([[3.0, 1.0], [1.0, 3.0]]), np.zeros(2)) == pytest.approx(5.0)
    assert hv_2d(np.array([[2.0, 2.0]]), np.zeros(2)) == pytest.approx(4.0)
    assert hv_2d(np.zeros((0, 2)), np.zeros(2)) == 0.0
    # below-ref points contribute nothing
    assert hv_2d(np.array([[-1.0, 5.0]]), np.zeros(2)) == 0.0


def test_hvi_matches_hv_difference():
    rng = np.random.default_rng(0)
    front = pareto_front(rng.random((12, 2)) * 10)
    pts = rng.random((40, 2)) * 12
    ref = np.zeros(2)
    base = hv_2d(front, ref)
    got = hvi_2d(pts, front, ref)
    for p, g in zip(pts, got):
        expect = hv_2d(np.vstack([front, p[None]]), ref) - base
        assert g == pytest.approx(expect, rel=1e-9, abs=1e-9)


@settings(max_examples=60, deadline=None)
@given(points2d)
def test_hv_monotone_under_union(ps):
    ref = np.zeros(2)
    hv_all = hv_2d(ps, ref)
    hv_sub = hv_2d(ps[: max(1, len(ps) // 2)], ref)
    assert hv_all >= hv_sub - 1e-9


@settings(max_examples=60, deadline=None)
@given(points2d)
def test_dominated_point_adds_no_hv(ps):
    ref = np.zeros(2)
    base = hv_2d(ps, ref)
    dominated = ps.min(axis=0) * 0.5  # dominated by every point
    assert hv_2d(np.vstack([ps, dominated[None]]), ref) == pytest.approx(base)
    assert hvi_2d(dominated[None], ps, ref)[0] == pytest.approx(0.0, abs=1e-12)


@settings(max_examples=60, deadline=None)
@given(points2d)
def test_pareto_front_idempotent_and_non_dominated(ps):
    f = pareto_front(ps)
    assert len(f) >= 1
    assert non_dominated_mask(f).all()
    f2 = pareto_front(f)
    assert np.array_equal(np.sort(f, axis=0), np.sort(f2, axis=0))


# ---------------------------------------------------------------------------
# GP
# ---------------------------------------------------------------------------
def test_gp_fits_smooth_function():
    rng = np.random.default_rng(1)
    X = rng.random((50, 2))
    Y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2
    gp = GP(seed=0).fit(X, Y)
    mean, std = gp.predict(X[:10])
    assert np.abs(mean[:, 0] - Y[:10]).max() < 0.05
    # uncertainty grows away from data
    far = np.full((1, 2), 5.0)
    _, std_far = gp.predict(far)
    assert std_far[0, 0] > std.mean() * 2


def test_gp_multi_output_independent():
    rng = np.random.default_rng(2)
    X = rng.random((40, 3))
    Y = np.stack([X[:, 0] * 2, -X[:, 1]], axis=1)
    gp = GP(seed=0).fit(X, Y)
    mean, _ = gp.predict(X[:5])
    assert np.abs(mean - Y[:5]).max() < 0.1


# ---------------------------------------------------------------------------
# acquisitions
# ---------------------------------------------------------------------------
def test_ei_properties():
    # higher mean -> higher EI; zero std + mean below best -> 0
    assert ei(np.array([2.0]), np.array([0.1]), best=1.0) > ei(np.array([1.5]), np.array([0.1]), best=1.0)
    assert ei(np.array([0.5]), np.array([1e-12]), best=1.0)[0] == pytest.approx(0.0, abs=1e-9)


def test_cei_feasibility_gates_ei():
    # same speed posterior, one candidate's recall is clearly below the limit
    out = cei(
        mean_spd=np.array([2.0, 2.0]),
        std_spd=np.array([0.1, 0.1]),
        mean_rec=np.array([0.95, 0.5]),
        std_rec=np.array([0.01, 0.01]),
        best_feasible=1.0,
        rlim=0.9,
    )
    assert out[0] > 100 * out[1]


def test_ehvi_prefers_front_extension():
    rng = np.random.default_rng(3)
    front = np.array([[1.0, 0.2], [0.5, 0.6]])
    ref = np.zeros(2)
    mean = np.array([[1.2, 0.7], [0.4, 0.3]])  # first dominates the front
    std = np.full((2, 2), 0.01)
    acq = ehvi_mc(mean, std, front, ref, rng, n_samples=256)
    assert acq[0] > acq[1] * 10


# ---------------------------------------------------------------------------
# NPI normalization + abandon scoring
# ---------------------------------------------------------------------------
def test_balanced_base_picks_balanced_point():
    Y = np.array([[10.0, 0.1], [5.0, 0.5], [1.0, 1.0]])
    base = balanced_base(Y)
    # (5, 0.5) is the most balanced: |5/10 - 0.5/1| = 0
    assert np.allclose(base, [5.0, 0.5])


def test_npi_normalization_removes_scale():
    Y = np.array([[100.0, 0.5], [200.0, 0.25], [1.0, 0.9], [2.0, 0.45]])
    types = np.array(["fast", "fast", "slow", "slow"])
    Yn, bases = npi_normalize(Y, types)
    # each type's base maps to ~(1, 1): inter-type offsets removed
    assert Yn[types == "fast"].max() <= 2.0 + 1e-9
    assert Yn[types == "slow"].max() <= 2.0 + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.floats(0.1, 100.0), st.floats(0.1, 100.0))
def test_npi_scale_invariance(s1, s2):
    rng = np.random.default_rng(7)
    Y = rng.random((12, 2)) + 0.1
    types = np.array(["a", "b"] * 6)
    Yn1, _ = npi_normalize(Y, types)
    Yn2, _ = npi_normalize(Y * np.array([s1, s2]), types)
    assert np.allclose(Yn1, Yn2, rtol=1e-9)


def test_scores_reward_contributing_type():
    # type "good" owns the whole front; "bad" is dominated
    Y = np.array([[10, 0.9], [8, 0.95], [1, 0.1], [2, 0.2]], dtype=float)
    types = np.array(["good", "good", "bad", "bad"])
    scores = scores_by_hv_influence(Y, types, ["good", "bad"])
    assert scores["good"] > scores["bad"]


def test_successive_abandon_windowed_trigger():
    ab = SuccessiveAbandon(["a", "b", "c"], window=3)
    # a and b both own part of the Pareto front; c is strictly dominated
    Y = np.array([[10, 0.5], [6, 0.92], [1, 0.1]], dtype=float)
    types = np.array(["a", "b", "c"])
    dropped = []
    for _ in range(4):
        out = ab.step(Y, types)
        if out:
            dropped.append(out)
    assert dropped == ["c"]  # consistently-worst type dropped exactly once
    assert sorted(ab.remaining) == ["a", "b"]
    # never drops below one type
    ab2 = SuccessiveAbandon(["a"], window=1)
    assert ab2.step(Y[:1], types[:1]) is None


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------
def _toy_space():
    return SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 8), default=2)],
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_space_encode_decode_roundtrip(seed):
    space = _toy_space()
    rng = np.random.default_rng(seed)
    cfg = space.sample(rng, 1)[0]
    x = space.encode(cfg)
    assert x.shape == (space.dims,)
    back = space.decode(x, index_type=cfg["index_type"])
    for k, v in cfg.items():
        if isinstance(v, float):
            assert back[k] == pytest.approx(v, abs=1e-6)
        else:
            assert back[k] == v


def test_grid_encode_snaps_off_grid_numeric_to_nearest_choice():
    # a hand-tuned serving config (e.g. segment_max_size=256 when the grid
    # starts at 1024) must still be embeddable when it is re-anchored into
    # a retune history — encode snaps to the nearest numeric choice
    p = Param("ka", "grid", choices=(1, 2, 4, 8), default=2)
    assert p.encode(3) == p.encode(2)  # ties break toward the earlier choice
    assert p.encode(100) == p.encode(8)
    assert p.encode(0) == p.encode(1)
    space = _toy_space()
    cfg = space.default_config("A")
    x_off = space.encode(dict(cfg, ka=5))
    assert np.array_equal(x_off, space.encode(dict(cfg, ka=4)))
    # non-numeric mismatches still refuse loudly
    with pytest.raises(ValueError):
        Param("s2", "cat", choices=(False, True), default=False).encode("yes")
    with pytest.raises(ValueError):
        Param("kc", "cat", choices=("a", "b"), default="a").encode(1)


def test_space_free_mask_owns_right_dims():
    space = _toy_space()
    ma, mb = space.free_mask("A"), space.free_mask("B")
    # both include the two system params; each owns exactly its index param
    assert ma.sum() == 3 and mb.sum() == 3
    assert not np.array_equal(ma, mb)


def test_lhs_covers_all_types():
    space = _toy_space()
    cfgs = space.lhs(np.random.default_rng(0), 8)
    assert {c["index_type"] for c in cfgs} == {"A", "B"}


# ---------------------------------------------------------------------------
# end-to-end tuner on a cheap synthetic objective
# ---------------------------------------------------------------------------
def _toy_objective(cfg):
    t = cfg["index_type"]
    k = cfg.get("ka", cfg.get("kb", 0.5))
    k = k / 8.0 if t == "A" else k
    sysq = 1.0 - (cfg["s1"] - 0.6) ** 2
    if t == "A":
        return {"speed": 80 * (1 - k) * sysq, "recall": 0.5 + 0.45 * k, "mem_gib": 1.0}
    return {"speed": 50 * (1 - k) * sysq, "recall": 0.6 + 0.39 * k, "mem_gib": 0.5}


def test_vdtuner_runs_and_beats_random():
    space = _toy_space()
    vt = VDTuner(space, _toy_objective, seed=0, abandon_window=6).run(25)
    rl = RandomLHS(space, _toy_objective, seed=0).run(25)
    ref = np.zeros(2)
    norm = np.array([80.0, 1.0])
    hv_vt = hv_2d(pareto_front(vt.Y) / norm, ref)
    hv_rl = hv_2d(pareto_front(rl.Y) / norm, ref)
    assert hv_vt >= hv_rl * 0.95  # statistically dominant; allow slack for one seed
    assert len(vt.history) == 25
    assert all(np.isfinite(o.y).all() for o in vt.history)


def test_vdtuner_constraint_mode_respects_floor():
    space = _toy_space()
    vt = VDTuner(space, _toy_objective, seed=1, rlim=0.85).run(25)
    feas = [o for o in vt.history if o.y[1] >= 0.85]
    assert len(feas) >= 5  # the CEI acquisition concentrates sampling in-feasible


def test_vdtuner_bootstrap_warm_start():
    space = _toy_space()
    first = VDTuner(space, _toy_objective, seed=2, rlim=0.8).run(15)
    second = VDTuner(space, _toy_objective, seed=3, rlim=0.9, bootstrap_history=first.history)
    second.run(10)
    fresh = [o for o in second.history if not o.bootstrap]
    assert len(fresh) == 10  # bootstrapped points are not re-evaluated


def test_failed_config_gets_worst_feedback():
    space = _toy_space()
    calls = {"n": 0}

    def flaky(cfg):
        calls["n"] += 1
        if calls["n"] % 5 == 0:
            from repro.core import TuningFailure

            raise TuningFailure("boom")
        return _toy_objective(cfg)

    vt = VDTuner(space, flaky, seed=4).run(15)
    failed = [o for o in vt.history if o.failed]
    assert failed, "some configs should have failed"
    for o in failed:
        # feedback = worst values in history AT FAILURE TIME (paper §V-A)
        prior = np.stack([p.y for p in vt.history[: o.iteration] if not p.failed])
        assert (o.y <= prior.min(axis=0) + 1e-12).all()
