"""Per-architecture smoke tests (reduced same-family configs): one forward /
train step on CPU asserting output shapes + no NaNs, plus prefill/decode
consistency where cheap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, input_specs, list_archs, reduce, shape_applicable
from repro.models import build_model

ARCHS = list_archs()
RNG = np.random.default_rng(0)


def _train_batch(cfg, b=2, s=17):
    if cfg.family == "encdec":
        return {
            "src_embeds": jnp.asarray(RNG.standard_normal((b, 24, cfg.d_model)), jnp.float32),
            "tgt_tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, 9)), jnp.int32),
        }
    return {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = reduce(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _train_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0  # near ln(vocab) at init
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = reduce(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _train_batch(cfg)
    if cfg.family == "encdec":
        pbatch = {"src_embeds": batch["src_embeds"], "tgt_tokens": batch["tgt_tokens"][:, :-1]}
        pos0 = pbatch["tgt_tokens"].shape[1]
    else:
        pbatch = {"tokens": batch["tokens"][:, :-1]}
        pos0 = pbatch["tokens"].shape[1]
    logits, cache = model.prefill(params, pbatch)
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode(params, cache, toks, jnp.asarray(pos0, jnp.int32))
    assert logits2.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_dense_decode_matches_full_forward():
    """Prefill(t tokens) then decode(token t) must equal forward over t+1."""
    from repro.models import transformer as tr

    cfg = reduce(get_arch("glm4-9b"))
    params = tr.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    # full forward logits at the last position
    x = tr.forward(params, tokens, cfg)
    from repro.models import common as cm

    full_logits = cm.lm_logits(params, x, cfg)[:, -1]
    # prefill on the prefix + one decode step
    _, cache = tr.prefill(params, {"tokens": tokens[:, :-1]}, cfg, cache_len=12)
    dec_logits, _ = tr.decode_step(params, cache, tokens[:, -1], jnp.asarray(11, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits), atol=2e-3, rtol=2e-3)


def test_mamba2_decode_matches_full_forward():
    from repro.models import common as cm, mamba2 as mb

    cfg = reduce(get_arch("mamba2-130m"))
    params = mb.init_params(jax.random.PRNGKey(3), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    x = mb.forward(params, tokens, cfg)
    full_logits = cm.lm_logits(params, x, cfg)[:, -1]
    _, cache = mb.prefill(params, {"tokens": tokens[:, :-1]}, cfg)
    dec_logits, _ = mb.decode_step(params, cache, tokens[:, -1], jnp.asarray(11, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits), atol=5e-3, rtol=5e-3)


def test_moe_routing_conserves_mass():
    """Every kept (token, slot) contributes its normalized gate weight."""
    from repro.models.moe import init_moe_mlp, moe_mlp

    cfg = reduce(get_arch("mixtral-8x7b"))
    p = init_moe_mlp(jax.random.PRNGKey(4), cfg)
    x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe_mlp(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    assert float(aux) >= 1.0 - 1e-6  # Switch aux loss lower bound E*sum(m*c) >= 1


def test_param_counts_match_published():
    expected = {
        "deepseek-67b": 67e9,
        "qwen2.5-32b": 32.5e9,
        "glm4-9b": 9.4e9,
        "mixtral-8x7b": 46.7e9,
        "mamba2-130m": 0.13e9,
    }
    for name, n in expected.items():
        got = get_arch(name).param_count()
        assert abs(got - n) / n < 0.06, (name, got)


def test_shape_applicability_rules():
    long = SHAPES["long_500k"]
    ok, _ = shape_applicable(get_arch("mamba2-130m"), long)
    assert ok
    ok, why = shape_applicable(get_arch("deepseek-67b"), long)
    assert not ok and "full-attention" in why
    ok, _ = shape_applicable(get_arch("mixtral-8x7b"), long)
    assert ok  # SWA bounds the KV cache


def test_input_specs_no_allocation():
    for arch in ARCHS:
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
