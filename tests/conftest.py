import pytest


@pytest.fixture(scope="session")
def small_dataset():
    from repro.vdms import make_dataset

    return make_dataset("glove_like", n=2048, n_queries=32, k=10, seed=0)
