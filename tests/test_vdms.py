"""VDMS substrate behaviour: segments, indexes, engine measurements, and the
structural properties the paper's tuning problem depends on."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.vdms import (
    VDMSInstance,
    VDMSTuningEnv,
    make_dataset,
    make_space,
    plan_segments,
    recall_at_k,
    stack_sealed,
)

BASE_SYS = dict(
    segment_max_size=1024,
    seal_proportion=0.75,
    graceful_time=0.2,
    search_batch_size=16,
    topk_merge_width=32,
    kmeans_iters=8,
    storage_bf16=False,
)


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(
    st.integers(256, 20000),
    st.integers(64, 8192),
    st.floats(0.1, 1.0),
    st.floats(0.0, 0.9),
)
def test_segment_plan_partitions_data(n, smax, seal, graceful):
    plan = plan_segments(n, smax, seal, graceful)
    assert plan.growing_start + plan.growing_size == n
    assert plan.sealed_valid.sum() == plan.growing_start
    assert 0 <= plan.growing_searched <= plan.growing_size
    assert plan.n_sealed >= 1


def test_stack_sealed_ids_complete():
    data = np.random.default_rng(0).standard_normal((1000, 8)).astype(np.float32)
    plan = plan_segments(1000, 300, 0.5, 0.0)
    segs, gids = stack_sealed(data, plan)
    valid = gids[gids >= 0]
    assert len(np.unique(valid)) == plan.growing_start
    assert segs.shape == (plan.n_sealed, plan.seg_size, 8)


# ---------------------------------------------------------------------------
# indexes / engine
# ---------------------------------------------------------------------------
INDEX_CFGS = [
    dict(index_type="FLAT"),
    dict(index_type="IVF_FLAT", nlist=32, nprobe=8),
    dict(index_type="IVF_SQ8", nlist=32, nprobe=8),
    dict(index_type="IVF_PQ", nlist=32, nprobe=8, m=8, nbits=8),
    dict(index_type="HNSW", M=16, efConstruction=64, ef=64),
    dict(index_type="SCANN", nlist=32, nprobe=8, reorder_k=64),
    dict(index_type="AUTOINDEX"),
]


@pytest.mark.parametrize("icfg", INDEX_CFGS, ids=lambda c: c["index_type"])
def test_index_search_and_measure(small_dataset, icfg):
    cfg = {**BASE_SYS, **icfg}
    inst = VDMSInstance(small_dataset, cfg, seed=0)
    r = inst.measure(repeats=1, mode="analytic")
    assert r["speed"] > 0 and 0.0 <= r["recall"] <= 1.0
    assert r["mem_gib"] > 0
    # a sane index on easy clustered data should retrieve something real
    min_recall = {"IVF_PQ": 0.02}.get(icfg["index_type"], 0.3)
    assert r["recall"] >= min_recall, icfg


def test_flat_exact_when_everything_searched(small_dataset):
    cfg = {**BASE_SYS, "index_type": "FLAT", "graceful_time": 0.0, "topk_merge_width": 128}
    inst = VDMSInstance(small_dataset, cfg, seed=0)
    r = inst.measure(repeats=1, mode="analytic")
    assert r["recall"] == pytest.approx(1.0)


def test_nprobe_monotone_recall_and_cost(small_dataset):
    recalls, costs = [], []
    for nprobe in (1, 4, 16):
        cfg = {**BASE_SYS, "index_type": "IVF_FLAT", "nlist": 32, "nprobe": nprobe}
        inst = VDMSInstance(small_dataset, cfg, seed=0)
        r = inst.measure(repeats=1, mode="analytic")
        recalls.append(r["recall"])
        costs.append(1.0 / r["speed"])
    assert recalls[0] <= recalls[-1] + 1e-9
    assert costs[0] < costs[-1]  # probing more clusters costs more


def test_graceful_time_trades_recall_for_speed():
    ds = make_dataset("glove_like", n=1500, n_queries=32, k=10, seed=1)
    # growing tail = everything beyond one sealed segment
    out = {}
    for g in (0.0, 0.9):
        cfg = {**BASE_SYS, "segment_max_size": 1024, "seal_proportion": 1.0, "graceful_time": g, "index_type": "FLAT"}
        r = VDMSInstance(ds, cfg, seed=0).measure(repeats=1, mode="analytic")
        out[g] = r
    assert out[0.0]["recall"] >= out[0.9]["recall"]
    assert out[0.9]["speed"] >= out[0.0]["speed"]


def test_storage_bf16_cuts_memory(small_dataset):
    cfgs = [{**BASE_SYS, "index_type": "FLAT", "storage_bf16": b} for b in (False, True)]
    mems = [VDMSInstance(small_dataset, c, seed=0).measure(repeats=1, mode="analytic")["mem_gib"] for c in cfgs]
    assert mems[1] < mems[0]


def test_recall_at_k_bounds():
    gt = np.array([[0, 1, 2], [3, 4, 5]], dtype=np.int32)
    assert recall_at_k(gt, gt) == 1.0
    assert recall_at_k(np.full_like(gt, 99), gt) == 0.0


# ---------------------------------------------------------------------------
# tuning env
# ---------------------------------------------------------------------------
def test_tuning_env_objective_and_cache(small_dataset):
    env = VDMSTuningEnv(small_dataset, mode="analytic", seed=0)
    space = make_space()
    cfg = space.default_config("IVF_FLAT")
    r1 = env(cfg)
    n = env.n_evals
    r2 = env(cfg)  # cached
    assert env.n_evals == n
    assert r1["speed"] == r2["speed"]
    assert set(r1) >= {"speed", "recall", "mem_gib", "build_time"}


def test_tuning_env_space_is_16_dimensional():
    space = make_space()
    # index type + 8 distinct index params + 7 system params (paper §V-A)
    n_index_params = sum(len(ps) for ps in space.index_types.values())
    assert len(space.system_params) == 7
    distinct = {p.name for ps in space.index_types.values() for p in ps}
    assert len(distinct) == 8
    assert space.dims == len(space.type_names) + n_index_params + 7
