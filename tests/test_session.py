"""Ask/tell core + TuningSession regression tests (no optional deps).

Covers the contracts the ask/tell redesign must keep:
* the legacy ``tuner.run(n)`` shim reproduces the pre-redesign observation
  sequence (configs, y, failure flags) EXACTLY, for VDTuner (q=1 and q=4,
  rlim on/off) and every baseline — verbatim copies of the pre-redesign
  per-tuner loops are the reference implementations,
* ``TuningSession`` mechanics: budgets, exhaustion, stop conditions,
  callbacks/StopSession, executors, the recommend/eval ledger schema,
* objective specs and the EvalBackend adapter,
* ``state_dict``/``restore`` JSON round-trips (deterministic checks; the
  hypothesis property tests live in ``test_checkpoint_resume.py``).
"""
import json
import time

import numpy as np
import pytest

from repro.core import (
    GP,
    BatchExecutor,
    ObjectiveSpec,
    OpenTunerLike,
    OtterTuneLike,
    Param,
    QEHVI,
    RandomLHS,
    DefaultOnly,
    SearchSpace,
    SequentialBatchMixin,
    StopSession,
    ThreadedExecutor,
    TuningFailure,
    TuningSession,
    VDTuner,
    as_eval_backend,
    cost_aware,
    ehvi_mc,
    ei,
    non_dominated_mask,
    npi_normalize,
    qehvi_sequential_greedy,
    recall_floor,
    speed_recall,
)
from repro.core.baselines import _weighted_sum


def _toy_objective(cfg):
    t = cfg["index_type"]
    k = cfg.get("ka", cfg.get("kb", 0.5))
    k = k / 8.0 if t == "A" else k
    sysq = 1.0 - (cfg["s1"] - 0.6) ** 2
    if t == "A":
        return {"speed": 80 * (1 - k) * sysq, "recall": 0.5 + 0.45 * k, "mem_gib": 1.0}
    return {"speed": 50 * (1 - k) * sysq, "recall": 0.6 + 0.39 * k, "mem_gib": 0.5}


class _ToyBatchObjective(SequentialBatchMixin):
    """Toy EvalBackend with a real ``evaluate_batch`` (counts batch calls)."""

    def __init__(self):
        self.n_calls = 0
        self.n_batch_calls = 0

    def __call__(self, cfg):
        self.n_calls += 1
        return _toy_objective(cfg)

    def evaluate_batch(self, cfgs):
        self.n_batch_calls += 1
        return super().evaluate_batch(cfgs)


def _toy_space():
    return SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 8), default=2)],
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )


_FAST = dict(gp_fit_steps=24, n_candidates=48, mc_samples=16)


def _same_trajectory(a, b):
    assert [o.config for o in a.history] == [o.config for o in b.history]
    assert np.array_equal(np.stack([o.y for o in a.history]), np.stack([o.y for o in b.history]))
    assert [o.failed for o in a.history] == [o.failed for o in b.history]
    assert [o.bootstrap for o in a.history] == [o.bootstrap for o in b.history]


# ---------------------------------------------------------------------------
# Verbatim pre-redesign reference implementations
# ---------------------------------------------------------------------------
def _legacy_vdtuner_step(self, max_new=None):
    """Verbatim copy of the pre-ask/tell VDTuner.step() (PR 1) used as the
    reference for the run()-shim equivalence tests."""
    t0 = time.perf_counter()
    q = self.q if max_new is None else max(1, min(self.q, max_new))
    Y, types = self.Y, self.types
    self.abandon.step(Y, types)
    mode = "balanced" if self.rlim is None else "max"
    Yn, bases = npi_normalize(Y, types, mode=mode)
    gp = GP(seed=int(self.rng.integers(2**31)), fit_steps=self.gp_fit_steps)
    gp.fit(self.X_enc, Yn)
    t = self._next_poll_type()
    cands = self._candidates(t)
    Xc = np.stack([self.space.encode(c) for c in cands])
    if self.rlim is None:
        front = Yn[non_dominated_mask(Yn)]
        ref = np.array([0.5, 0.5])
        idx = qehvi_sequential_greedy(gp, Xc, front, ref, self.rng, q, self.mc_samples)
    else:
        idx = self._cei_select(gp, Xc, Y, bases, t, q)
    cfgs = [cands[i] for i in idx]
    rec_time = time.perf_counter() - t0
    return self._evaluate_batch(cfgs, recommend_time=rec_time / len(cfgs))


def _legacy_vdtuner_run(self, n_iters):
    """Verbatim copy of the pre-ask/tell VDTuner.run() loop."""
    self._initial_sampling()
    while True:
        done = len([o for o in self.history if not o.bootstrap])
        if done >= n_iters:
            break
        _legacy_vdtuner_step(self, max_new=n_iters - done)
    return self


def _legacy_default_run(self, n_iters):
    for t in self.space.type_names:
        if len(self.history) >= n_iters:
            break
        self._evaluate(self.space.default_config(t), recommend_time=0.0)
    return self


def _legacy_random_lhs_run(self, n_iters):
    t0 = time.perf_counter()
    cfgs = self.space.lhs(self.rng, n_iters)
    rec = time.perf_counter() - t0
    for c in cfgs:
        self._evaluate(c, recommend_time=rec / max(n_iters, 1))
    return self


def _legacy_ottertune_run(self, n_iters):
    for c in self.space.lhs(self.rng, min(self.n_init, n_iters)):
        self._evaluate(c, recommend_time=0.0)
    while len(self.history) < n_iters:
        t0 = time.perf_counter()
        Y = self.Y
        scal = _weighted_sum(Y)
        gp = GP(seed=int(self.rng.integers(2**31)))
        gp.fit(self.X_enc, scal[:, None])
        cands = self.space.sample(self.rng, self.n_candidates)
        Xc = np.stack([self.space.encode(c) for c in cands])
        mean, std = gp.predict(Xc)
        acq = ei(mean[:, 0], std[:, 0], float(scal.max()))
        cfg = cands[int(np.argmax(acq))]
        self._evaluate(cfg, recommend_time=time.perf_counter() - t0)
    return self


def _legacy_qehvi_run(self, n_iters):
    for c in self.space.lhs(self.rng, min(self.n_init, n_iters)):
        self._evaluate(c, recommend_time=0.0)
    while len(self.history) < n_iters:
        t0 = time.perf_counter()
        Y = self.Y
        gp = GP(seed=int(self.rng.integers(2**31)))
        gp.fit(self.X_enc, Y)
        cands = self.space.sample(self.rng, self.n_candidates)
        Xc = np.stack([self.space.encode(c) for c in cands])
        mean, std = gp.predict(Xc)
        front = Y[non_dominated_mask(Y)]
        ref = np.zeros(2)
        acq = ehvi_mc(mean, std, front, ref, self.rng, self.mc_samples)
        cfg = cands[int(np.argmax(acq))]
        self._evaluate(cfg, recommend_time=time.perf_counter() - t0)
    return self


def _legacy_opentuner_run(self, n_iters):
    while len(self.history) < n_iters:
        t0 = time.perf_counter()
        tech = self._pick_technique()
        cfg = self._propose(tech)
        rec = time.perf_counter() - t0
        before = _weighted_sum(self.Y).max() if self.history else -np.inf
        self._evaluate(cfg, recommend_time=rec)
        after = _weighted_sum(self.Y).max()
        self._uses.append(tech)
        self._credits.append(1.0 if after > before else 0.0)
    return self


# ---------------------------------------------------------------------------
# Legacy-equivalence: run() shim == pre-redesign loops
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", [1, 4], ids=["q1", "q4"])
@pytest.mark.parametrize("rlim", [None, 0.85], ids=["ehvi", "cei"])
def test_vdtuner_run_shim_matches_legacy(q, rlim):
    ref = VDTuner(_toy_space(), _toy_objective, seed=5, abandon_window=6, rlim=rlim, q=q, **_FAST)
    _legacy_vdtuner_run(ref, 11)
    new = VDTuner(_toy_space(), _toy_objective, seed=5, abandon_window=6, rlim=rlim, q=q, **_FAST)
    new.run(11)
    _same_trajectory(new, ref)


def test_vdtuner_run_shim_matches_legacy_with_batch_backend():
    """q=4 through a backend exposing evaluate_batch: same dispatch both ways."""
    env_ref = _ToyBatchObjective()
    ref = VDTuner(_toy_space(), env_ref, seed=2, q=4, **_FAST)
    _legacy_vdtuner_run(ref, 10)
    env_new = _ToyBatchObjective()
    new = VDTuner(_toy_space(), env_new, seed=2, q=4, **_FAST)
    new.run(10)
    _same_trajectory(new, ref)
    assert env_new.n_batch_calls == env_ref.n_batch_calls  # same vectorized dispatch


def test_vdtuner_run_shim_matches_legacy_with_bootstrap():
    first = VDTuner(_toy_space(), _toy_objective, seed=2, rlim=0.8, **_FAST).run(6)
    ref = VDTuner(_toy_space(), _toy_objective, seed=3, rlim=0.9, bootstrap_history=first.history, **_FAST)
    _legacy_vdtuner_run(ref, 5)
    new = VDTuner(_toy_space(), _toy_objective, seed=3, rlim=0.9, bootstrap_history=first.history, **_FAST)
    new.run(5)
    _same_trajectory(new, ref)
    assert sum(1 for o in new.history if o.bootstrap) == len(first.history)


@pytest.mark.parametrize(
    "cls,legacy,kw",
    [
        (DefaultOnly, _legacy_default_run, {}),
        (RandomLHS, _legacy_random_lhs_run, {}),
        (OtterTuneLike, _legacy_ottertune_run, dict(n_init=4, n_candidates=64)),
        (QEHVI, _legacy_qehvi_run, dict(n_init=4, n_candidates=64, mc_samples=16)),
        (OpenTunerLike, _legacy_opentuner_run, {}),
    ],
    ids=["default", "random_lhs", "ottertune", "qehvi", "opentuner"],
)
def test_baseline_run_shim_matches_legacy(cls, legacy, kw):
    ref = cls(_toy_space(), _toy_objective, seed=9, **kw)
    legacy(ref, 9)
    new = cls(_toy_space(), _toy_objective, seed=9, **kw)
    new.run(9)
    _same_trajectory(new, ref)
    if cls is OpenTunerLike:
        assert new._uses == ref._uses
        assert new._credits == ref._credits


def test_opentuner_failure_credits_match_legacy():
    calls = {"n": 0}

    def flaky(cfg):
        calls["n"] += 1
        if calls["n"] % 3 == 0:
            raise TuningFailure("boom")
        return _toy_objective(cfg)

    ref = OpenTunerLike(_toy_space(), flaky, seed=6)
    calls["n"] = 0
    _legacy_opentuner_run(ref, 10)
    new = OpenTunerLike(_toy_space(), flaky, seed=6)
    calls["n"] = 0
    new.run(10)
    _same_trajectory(new, ref)
    assert new._credits == ref._credits


# ---------------------------------------------------------------------------
# Session mechanics
# ---------------------------------------------------------------------------
def test_session_budget_and_ledger_schema():
    tuner = VDTuner(_toy_space(), seed=0, q=2, **_FAST)
    session = TuningSession(tuner, backend=_toy_objective)
    session.run(7)
    assert session.n_observations == 7
    ledger = session.ledger_dict()
    assert ledger["schema"] == 1
    assert ledger["tuner"] == "vdtuner"
    assert ledger["totals"]["n_evals"] == 7
    assert ledger["totals"]["n_rounds"] == len(ledger["rounds"])
    for r in ledger["rounds"]:
        assert set(r) == {"round", "n_asked", "ask_s", "evals"}
        for e in r["evals"]:
            assert set(e) == {"iteration", "recommend_s", "eval_s", "failed"}
    assert json.dumps(ledger)  # JSON-stable


def test_session_backend_separate_from_tuner():
    tuner = VDTuner(_toy_space(), seed=0, **_FAST)  # no objective: pure recommender
    assert tuner.objective is None
    TuningSession(tuner, backend=_toy_objective).run(4)
    assert len(tuner.history) == 4
    with pytest.raises(ValueError):
        TuningSession(VDTuner(_toy_space(), seed=0))


def test_session_stops_on_exhausted_recommender():
    tuner = DefaultOnly(_toy_space(), _toy_objective, seed=0)
    session = TuningSession(tuner).run(50)
    assert session.n_observations == 2  # one per index type, then empty ask


def test_session_stop_predicate_and_callbacks():
    seen = []
    tuner = VDTuner(_toy_space(), _toy_objective, seed=1, **_FAST)
    session = TuningSession(tuner, callbacks=[lambda s, o: seen.append(o.iteration)])
    session.run(6, stop=lambda s: s.n_observations >= 4)
    assert session.n_observations == 4
    assert seen == [0, 1, 2, 3]


def test_stop_session_mid_round_keeps_pending():
    def stopper(session, obs):
        if session.n_observations >= 3:
            raise StopSession

    tuner = VDTuner(_toy_space(), _toy_objective, seed=1, q=4, **_FAST)
    session = TuningSession(tuner, callbacks=[stopper]).run(8)
    assert session.n_observations == 3
    assert len(session.pending) >= 1  # untold remainder of the q=4 round survives
    state = session.state_dict()
    assert state["pending"] == session.pending


def test_failed_configs_get_worst_feedback_through_session():
    calls = {"n": 0}

    def flaky(cfg):
        calls["n"] += 1
        if calls["n"] % 4 == 0:
            raise TuningFailure("boom")
        return _toy_objective(cfg)

    tuner = VDTuner(_toy_space(), flaky, seed=3, q=3, **_FAST)
    TuningSession(tuner).run(12)
    failed = [o for o in tuner.history if o.failed]
    assert failed
    for o in failed:
        prior = np.stack([p.y for p in tuner.history[: o.iteration] if not p.failed])
        assert (o.y <= prior.min(axis=0) + 1e-12).all()


def test_threaded_executor_preserves_order_and_results():
    cfgs = _toy_space().lhs(np.random.default_rng(0), 8)
    seq = list(BatchExecutor().execute(as_eval_backend(_toy_objective), cfgs))
    thr = list(ThreadedExecutor(max_workers=4).execute(as_eval_backend(_toy_objective), cfgs))
    assert [r for r, _ in seq] == [r for r, _ in thr]


def test_custom_executor_object():
    log = []

    class Spy:
        name = "spy"

        def execute(self, backend, cfgs):
            log.append(len(cfgs))
            for c in cfgs:
                yield backend(c), 0.0

    tuner = RandomLHS(_toy_space(), _toy_objective, seed=0)
    TuningSession(tuner, executor=Spy()).run(5)
    assert log == [5]
    with pytest.raises(ValueError):
        TuningSession(tuner, executor="warp-drive")


# ---------------------------------------------------------------------------
# Objectives + EvalBackend protocol
# ---------------------------------------------------------------------------
def test_objective_spec_validation():
    spec = speed_recall()
    assert spec.names == ("speed", "recall")
    assert spec.directions == ("max", "max")
    assert spec({"speed": 2.0, "recall": 0.5}) == (2.0, 0.5)
    with pytest.raises(ValueError):
        ObjectiveSpec(name="bad", directions=("max",))
    with pytest.raises(ValueError):
        ObjectiveSpec(name="bad", directions=("max", "sideways"))
    with pytest.raises(ValueError):
        recall_floor(1.5)


def test_recall_floor_spec_sets_constraint_mode():
    t = VDTuner(_toy_space(), _toy_objective, seed=1, objective_spec=recall_floor(0.85), **_FAST)
    assert t.rlim == 0.85
    TuningSession(t).run(8)
    assert sum(1 for o in t.history if o.y[1] >= 0.85) >= 3


def test_cost_aware_spec_matches_eq8():
    spec = cost_aware(eta=2.0)
    y = spec({"speed": 100.0, "recall": 0.9, "mem_gib": 4.0})
    assert y == (100.0 / (2.0 * 4.0), 0.9)
    assert spec.names == ("qpd", "recall")
    t = VDTuner(_toy_space(), _toy_objective, seed=1, objective_spec=spec, **_FAST)
    TuningSession(t).run(5)
    for o in t.history:
        if not o.failed:
            assert o.y[0] == pytest.approx(o.raw["speed"] / (2.0 * o.raw["mem_gib"]))


def test_transform_and_spec_are_mutually_exclusive():
    with pytest.raises(ValueError):
        VDTuner(
            _toy_space(),
            _toy_objective,
            transform=lambda r: (r["speed"], r["recall"]),
            objective_spec=speed_recall(),
        )


def test_conflicting_rlim_and_spec_rlim_rejected():
    with pytest.raises(ValueError):
        VDTuner(_toy_space(), _toy_objective, rlim=0.85, objective_spec=recall_floor(0.92))
    # agreeing values are fine
    t = VDTuner(_toy_space(), _toy_objective, rlim=0.9, objective_spec=recall_floor(0.9))
    assert t.rlim == 0.9


def test_as_eval_backend_adapter_captures_failures():
    def flaky(cfg):
        if cfg["index_type"] == "A":
            raise TuningFailure("nope")
        return _toy_objective(cfg)

    backend = as_eval_backend(flaky)
    out = backend.evaluate_batch([_toy_space().default_config("A"), _toy_space().default_config("B")])
    assert isinstance(out[0], TuningFailure)
    assert isinstance(out[1], dict)
    # objects already exposing evaluate_batch pass through unchanged
    env = _ToyBatchObjective()
    assert as_eval_backend(env) is env


def test_serve_tuning_env_implements_eval_backend():
    from repro.tuning.serve_tuner import ServeTuningEnv

    assert issubclass(ServeTuningEnv, SequentialBatchMixin)
    assert hasattr(ServeTuningEnv, "evaluate_batch")


# ---------------------------------------------------------------------------
# Checkpoint round-trips (deterministic; property tests live in
# test_checkpoint_resume.py)
# ---------------------------------------------------------------------------
def test_state_dict_json_roundtrip_resumes_bit_identically():
    full = VDTuner(_toy_space(), _toy_objective, seed=7, q=2, **_FAST)
    TuningSession(full).run(9)

    # interrupt (don't re-budget: a shorter run(n) legitimately clamps the
    # last round to the budget and so recommends differently)
    def stopper(session, obs):
        if session.n_observations >= 5:
            raise StopSession

    part = VDTuner(_toy_space(), _toy_objective, seed=7, q=2, **_FAST)
    session = TuningSession(part, callbacks=[stopper]).run(9)
    state = json.loads(json.dumps(session.state_dict()))
    fresh = VDTuner(_toy_space(), _toy_objective, seed=7, q=2, **_FAST)
    TuningSession.restore(state, fresh).run(9)
    _same_trajectory(fresh, full)


def test_restore_carries_bootstrap_observations():
    first = VDTuner(_toy_space(), _toy_objective, seed=2, rlim=0.8, **_FAST).run(6)
    full = VDTuner(_toy_space(), _toy_objective, seed=3, rlim=0.9, bootstrap_history=first.history, **_FAST)
    TuningSession(full).run(7)

    part = VDTuner(_toy_space(), _toy_objective, seed=3, rlim=0.9, bootstrap_history=first.history, **_FAST)
    session = TuningSession(part).run(3)
    state = json.loads(json.dumps(session.state_dict()))
    # restore() overwrites history wholesale — the §IV-F bootstrap
    # observations travel inside the checkpoint, not the constructor
    fresh = VDTuner(_toy_space(), _toy_objective, seed=3, rlim=0.9, **_FAST)
    TuningSession.restore(state, fresh).run(7)
    _same_trajectory(fresh, full)


def test_restore_rejects_wrong_tuner_or_version():
    session = TuningSession(RandomLHS(_toy_space(), _toy_objective, seed=0)).run(3)
    state = session.state_dict()
    with pytest.raises(ValueError):
        TuningSession.restore(state, QEHVI(_toy_space(), _toy_objective, seed=0))
    bad = dict(state, version=99)
    with pytest.raises(ValueError):
        TuningSession.restore(bad, RandomLHS(_toy_space(), _toy_objective, seed=0))


def test_legacy_step_and_initial_sampling_still_work():
    tuner = VDTuner(_toy_space(), _toy_objective, seed=1, q=3, **_FAST)
    tuner._initial_sampling()
    batch = tuner.step()
    assert len(batch) == 3
    assert len({o.index_type for o in batch}) == 1
