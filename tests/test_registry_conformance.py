"""Registry-parametrized conformance battery.

Every registered index family — the seven built-ins, the public-hook
``IVF_PQR``, and a throwaway family registered inside this module — gets the
same checks: build→search shape/gid invariants, encode/decode round-trips of
every declared ``Param``, frozen-state rebuild equivalence where supported,
and ``concat_bundles``/``replace_segment`` closure. A new family registered
through :func:`repro.vdms.register_family` inherits the full battery by
appearing in ``ALL_FAMILIES`` below.

Also here: the ``INDEX_TYPES``-vs-registry drift test, the dispatch error-UX
tests, the bit-identity regression of the registry-derived space against the
pre-registry hand-coded table, the README registry-table doc-sync test, and
the quick static + streaming ``IVF_PQR`` tuning runs.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.vdms as vdms
from repro.core import TuningSession, VDTuner
from repro.core.space import Param, SearchSpace
from repro.vdms import (
    IndexBundle,
    IndexFamily,
    VDMSInstance,
    VDMSTuningEnv,
    build_index,
    concat_bundles,
    frozen_state,
    get_family,
    ivf_pqr,
    make_dataset,
    make_space,
    make_trace,
    register_family,
    registered_names,
    registry_table,
    replace_segment,
    replay_trace,
    search_index,
    temporary_family,
    unregister_family,
)
from repro.vdms.registry import SYSTEM_PARAMS

BUILTIN_FAMILIES = ("FLAT", "IVF_FLAT", "IVF_SQ8", "IVF_PQ", "HNSW", "SCANN", "AUTOINDEX")


# ---------------------------------------------------------------------------
# a throwaway family, registered through the public hook only: brute force
# over a strided subsample of each segment (one tunable parameter)
# ---------------------------------------------------------------------------
def _build_toy(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    stride = int(params["stride"])
    return IndexBundle(
        kind="TOY_STRIDED",
        arrays={
            "data": jnp.asarray(segs[:, ::stride]),
            "gids": jnp.asarray(gids[:, ::stride]),
        },
        static={"stride": stride},
    )


def _search_toy(q, arrays, *, k_seg: int, stride: int):
    def per_seg(seg):
        data, gids = seg
        sims = jnp.einsum("bd,sd->bs", q, data.astype(jnp.float32))
        sims = jnp.where(gids[None, :] >= 0, sims, -jnp.inf)
        k = min(k_seg, sims.shape[1])
        top_s, top_i = jax.lax.top_k(sims, k)
        ids = jnp.where(jnp.isfinite(top_s), gids[top_i], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:
            pad = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(per_seg, (arrays["data"], arrays["gids"]))


TOY_FAMILY = IndexFamily(
    name="TOY_STRIDED",
    params=(Param("stride", "grid", choices=(1, 2, 4), default=2),),
    build=_build_toy,
    search=_search_toy,
    # no chunk_cost/build_cost on purpose: exercises the engine's fallbacks
    description="test-only brute force over a strided subsample",
)

ALL_FAMILIES = BUILTIN_FAMILIES + ("IVF_PQR", "TOY_STRIDED")


@pytest.fixture
def extra_families():
    """Register the non-builtin families through the public hook, then
    restore the builtin-only registry."""
    ivf_pqr.register()
    register_family(TOY_FAMILY)
    yield
    unregister_family(TOY_FAMILY.name)
    unregister_family(ivf_pqr.FAMILY.name)


# ---------------------------------------------------------------------------
# shared build fixture data
# ---------------------------------------------------------------------------
# SEG_SIZE >= 256 so even a single-segment PQ build can train its default
# 2^8-codeword codebooks (kmeans inits sample points without replacement)
N_SEG, SEG_SIZE, DIM, N_Q, K_SEG = 2, 288, 16, 8, 16
SYS = {"kmeans_iters": 4, "storage_bf16": False}


def _normed(rng, shape):
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


@pytest.fixture(scope="module")
def seg_data():
    rng = np.random.default_rng(7)
    segs = _normed(rng, (N_SEG, SEG_SIZE, DIM))
    gids = np.arange(N_SEG * SEG_SIZE, dtype=np.int32).reshape(N_SEG, SEG_SIZE)
    queries = jnp.asarray(_normed(rng, (N_Q, DIM)))
    return segs, gids, queries


def _default_params(name):
    return {p.name: p.default for p in get_family(name).params}


def _build(name, segs, gids, seed=0, frozen=None):
    key = jax.random.PRNGKey(seed)
    return build_index(key, segs, gids, name, _default_params(name), SYS, frozen=frozen)


# ---------------------------------------------------------------------------
# build -> search invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_build_search_shape_and_gid_invariants(extra_families, seg_data, name):
    segs, gids, queries = seg_data
    bundle = _build(name, segs, gids)
    assert bundle.kind == get_family(name).kind
    assert bundle.memory_bytes() > 0
    ids, sims = search_index(bundle, queries, K_SEG)
    ids, sims = np.asarray(ids), np.asarray(sims)
    assert ids.shape == (N_SEG, N_Q, K_SEG)
    assert sims.shape == (N_SEG, N_Q, K_SEG)
    assert np.issubdtype(ids.dtype, np.integer)
    valid = ids >= 0
    assert valid.any(), "search returned no hits at all"
    assert np.isin(ids[valid], gids.ravel()).all(), "ids outside the segment gids"
    assert np.isfinite(sims[valid]).all()
    assert np.all(np.isneginf(sims[~valid])), "padded slots must carry -inf sims"


@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_dispatch_is_transparent(extra_families, seg_data, name):
    """build_index/search_index are pure registry dispatch: identical arrays
    to calling the family's own callables directly."""
    segs, gids, queries = seg_data
    fam = get_family(name)
    via_dispatch = _build(name, segs, gids)
    direct = fam.build(jax.random.PRNGKey(0), segs, gids, _default_params(name), SYS, frozen=None)
    assert via_dispatch.kind == direct.kind
    assert via_dispatch.static == direct.static
    assert set(via_dispatch.arrays) == set(direct.arrays)
    for k in via_dispatch.arrays:
        np.testing.assert_array_equal(np.asarray(via_dispatch.arrays[k]), np.asarray(direct.arrays[k]))
    ids_a, _ = search_index(via_dispatch, queries, K_SEG)
    ids_b, _ = fam.search(queries, via_dispatch.arrays, k_seg=K_SEG, **via_dispatch.static)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))


# ---------------------------------------------------------------------------
# Param encode/decode round-trips (hypothesis)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_param_roundtrip_properties(extra_families, name):
    pytest.importorskip("hypothesis", reason="optional test dep")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    params = get_family(name).params + SYSTEM_PARAMS

    @settings(max_examples=60, deadline=None)
    @given(st.floats(0.0, 1.0))
    def check(u):
        for p in params:
            if p.kind in ("grid", "cat"):
                for v in p.choices:
                    assert p.decode(p.encode(v)) == v
            v = p.decode(u)
            if p.kind in ("float", "log_float"):
                assert p.low - 1e-9 <= v <= p.high + 1e-9
            snap = p.encode(v)
            # snapping is idempotent: re-encoding the decoded value is stable
            assert p.encode(p.decode(snap)) == snap

    check()


# ---------------------------------------------------------------------------
# frozen-state rebuild equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_frozen_state_rebuild_equivalence(extra_families, seg_data, name):
    fam = get_family(name)
    segs, gids, _ = seg_data
    b1 = _build(name, segs, gids)
    frozen = frozen_state(b1)
    if not fam.supports_frozen:
        assert frozen == {}
        return
    assert set(frozen) == set(fam.shared_arrays) & set(b1.arrays)
    b2 = _build(name, segs, gids, frozen=frozen)
    assert b2.static == b1.static
    for k in b1.arrays:
        np.testing.assert_array_equal(np.asarray(b1.arrays[k]), np.asarray(b2.arrays[k]))


# ---------------------------------------------------------------------------
# concat / replace closure (the incremental seal + compaction contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_concat_and_replace_closure(extra_families, seg_data, name):
    fam = get_family(name)
    segs, gids, queries = seg_data
    a = _build(name, segs[:1], gids[:1])
    b = _build(name, segs[1:], gids[1:], seed=1, frozen=frozen_state(a) or None)
    merged = concat_bundles(a, b)
    assert merged.kind == a.kind and merged.static == a.static
    for k, arr in merged.arrays.items():
        if k in fam.shared_arrays:
            np.testing.assert_array_equal(np.asarray(arr), np.asarray(a.arrays[k]))
        else:
            assert arr.shape[0] == 2, (k, arr.shape)
    ids, _ = search_index(merged, queries, K_SEG)
    assert np.asarray(ids).shape == (2, N_Q, K_SEG)

    # compaction: replace segment 1 with a rebuild over half its survivors
    survivors = np.array(gids[1:], copy=True)
    survivors[0, SEG_SIZE // 2 :] = -1
    seg2 = np.where((survivors >= 0)[..., None], segs[1:], 0.0).astype(np.float32)
    b2 = _build(name, seg2, survivors, seed=2, frozen=frozen_state(a) or None)
    spliced = replace_segment(merged, 1, b2)
    for k, arr in spliced.arrays.items():
        assert arr.shape == merged.arrays[k].shape, k
    ids2 = np.asarray(search_index(spliced, queries, K_SEG)[0])
    seg1_ids = ids2[1]
    live = seg1_ids[seg1_ids >= 0]
    assert np.isin(live, survivors[survivors >= 0]).all(), "splice leaked dropped gids"


def test_concat_rejects_mismatched_bundles(extra_families, seg_data):
    segs, gids, _ = seg_data
    a = _build("FLAT", segs[:1], gids[:1])
    b = _build("IVF_FLAT", segs[1:], gids[1:])
    with pytest.raises(ValueError, match="kind/static mismatch"):
        concat_bundles(a, b)


# ---------------------------------------------------------------------------
# INDEX_TYPES <-> registry drift + error UX
# ---------------------------------------------------------------------------
def test_index_types_is_the_registry():
    """INDEX_TYPES is derived, never a second source of truth."""
    from repro.vdms import indexes

    assert vdms.INDEX_TYPES == registered_names()
    assert indexes.INDEX_TYPES == registered_names()
    with temporary_family(TOY_FAMILY):
        assert "TOY_STRIDED" in vdms.INDEX_TYPES
        assert vdms.INDEX_TYPES == registered_names()
    assert "TOY_STRIDED" not in vdms.INDEX_TYPES


def test_unknown_family_errors_list_registered():
    listing = ", ".join(f"'{n}'" for n in sorted(registered_names()))
    with pytest.raises(ValueError, match="NOPE") as ei:
        build_index(jax.random.PRNGKey(0), None, None, "NOPE", {}, {})
    assert listing in str(ei.value)
    bogus = IndexBundle(kind="NOPE", arrays={}, static={})
    with pytest.raises(ValueError, match="NOPE") as ei:
        search_index(bogus, jnp.zeros((1, 4)), 4)
    assert listing in str(ei.value)


def test_search_space_unknown_type_errors():
    space = make_space()
    families = str(sorted(space.index_types))
    for call in (
        lambda: space.default_config("NOPE"),
        lambda: space.encode({"index_type": "NOPE"}),
        lambda: space.decode(np.zeros(space.dims), index_type="NOPE"),
        lambda: space.free_mask("NOPE"),
    ):
        with pytest.raises(ValueError, match="NOPE") as ei:
            call()
        assert families in str(ei.value)
    with pytest.raises(ValueError, match="NOPE"):
        make_space(include=("FLAT", "NOPE"))


def test_register_family_validates():
    with pytest.raises(ValueError, match="already registered"):
        register_family(get_family("FLAT"))
    with pytest.raises(ValueError, match="supports_frozen"):
        IndexFamily(
            name="BAD",
            params=(),
            build=_build_toy,
            search=_search_toy,
            supports_frozen=True,
        )


# ---------------------------------------------------------------------------
# registry-derived space == pre-registry hand-coded space (bit-identical)
# ---------------------------------------------------------------------------
def _pre_registry_space() -> SearchSpace:
    """Verbatim copy of the hand-coded table `make_space` used before the
    registry redesign — the checkpoint-compatibility reference."""
    _NLIST = (16, 32, 64, 128, 256, 512)
    _NPROBE = (1, 2, 4, 8, 16, 32, 64, 128)
    index_types = {
        "FLAT": [],
        "IVF_FLAT": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ],
        "IVF_SQ8": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ],
        "IVF_PQ": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("m", "grid", choices=(4, 8, 16, 32), default=8),
            Param("nbits", "grid", choices=(4, 6, 8), default=8),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ],
        "HNSW": [
            Param("M", "grid", choices=(8, 16, 32, 48), default=16),
            Param("efConstruction", "grid", choices=(32, 64, 128, 256), default=128),
            Param("ef", "grid", choices=(16, 32, 64, 128, 256), default=64),
        ],
        "SCANN": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
            Param("reorder_k", "grid", choices=(32, 64, 128, 256, 512), default=64),
        ],
        "AUTOINDEX": [],
    }
    system = [
        Param("segment_max_size", "grid", choices=(1024, 2048, 4096, 8192), default=4096),
        Param("seal_proportion", "float", 0.1, 1.0, default=0.75),
        Param("graceful_time", "float", 0.0, 0.9, default=0.2),
        Param("search_batch_size", "grid", choices=(8, 16, 32, 64, 128), default=32),
        Param("topk_merge_width", "grid", choices=(16, 32, 64, 128), default=64),
        Param("kmeans_iters", "grid", choices=(4, 8, 16, 25), default=8),
        Param("storage_bf16", "cat", choices=(False, True), default=False),
    ]
    return SearchSpace(index_types=index_types, system_params=system)


def test_registry_space_bit_identical_to_pre_registry_table():
    old, new = _pre_registry_space(), make_space()
    assert new.type_names == old.type_names == BUILTIN_FAMILIES
    assert new.system_params == old.system_params
    assert [(c, o) for c, o, _ in new._cols] == [(c, o) for c, o, _ in old._cols]
    assert [p for _, _, p in new._cols] == [p for _, _, p in old._cols]
    assert new.dims == old.dims
    rng = np.random.default_rng(0)
    for cfg in old.sample(rng, 64) + [old.default_config(t) for t in old.type_names]:
        np.testing.assert_array_equal(new.encode(cfg), old.encode(cfg))
        np.testing.assert_array_equal(new.free_mask(cfg["index_type"]), old.free_mask(cfg["index_type"]))


# ---------------------------------------------------------------------------
# IVF_PQR end-to-end: quick static + streaming tuning through the session
# ---------------------------------------------------------------------------
def test_ivf_pqr_static_tuning_quick(extra_families):
    ds = make_dataset("glove_like", n=1536, n_queries=16, k=10, seed=0)
    env = VDMSTuningEnv(ds, mode="analytic", seed=0)
    space = make_space(include=("IVF_PQR",))
    tuner = VDTuner(space, env, seed=0)
    TuningSession(tuner).run(4)
    assert len(tuner.Y) == 4
    assert max(y[1] for y in tuner.Y) > 0.3  # re-rank should retrieve well
    # the exact re-rank must not hurt recall vs the plain ADC scan
    cfg = space.default_config("IVF_PQR")
    plain = dict(cfg, index_type="IVF_PQ")
    plain.pop("reorder_k")
    r_pqr = VDMSInstance(ds, cfg, seed=0).measure(repeats=1, mode="analytic")
    r_pq = VDMSInstance(ds, plain, seed=0).measure(repeats=1, mode="analytic")
    assert r_pqr["recall"] >= r_pq["recall"] - 1e-9


def test_ivf_pqr_streaming_tuning_quick(extra_families):
    trace = make_trace("glove_like", n_base=600, n_ops=160, seed=0, mix=(0.3, 0.6, 0.1))
    space = make_space(include=("IVF_PQR",))
    cfg = dict(space.default_config("IVF_PQR"), segment_max_size=512, seal_proportion=0.5)
    result = replay_trace(trace, cfg, seed=0, mode="analytic")
    assert result["n_seals"] >= 1, "trace too small to exercise the seal path"
    assert result["recall"] > 0.2
    env = VDMSTuningEnv(trace=trace, workload="streaming", mode="analytic", seed=0)
    tuner = VDTuner(space, env, seed=0)
    TuningSession(tuner).run(3)
    assert len(tuner.Y) == 3


# ---------------------------------------------------------------------------
# README doc-sync: the registry table in the docs is generated, not typed
# ---------------------------------------------------------------------------
def test_readme_registry_table_in_sync():
    readme = pathlib.Path(__file__).resolve().parents[1] / "README.md"
    text = readme.read_text()
    begin, end = "<!-- registry-table:begin -->", "<!-- registry-table:end -->"
    assert begin in text and end in text, "README lost the registry-table markers"
    block = text.split(begin, 1)[1].split(end, 1)[0].strip()
    families = list(vdms.registered_families())
    if ivf_pqr.FAMILY.name not in registered_names():
        families.append(ivf_pqr.FAMILY)
    assert block == registry_table(families).strip(), (
        "README registry table is stale; regenerate it with: python -c "
        "'from repro.vdms import registry_table, ivf_pqr; "
        "ivf_pqr.register(); print(registry_table())'"
    )
