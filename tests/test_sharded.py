"""Sharded multi-device segment serving: merge extraction + shard invariants.

Four contract layers, weakest to strongest:

1. **merge regression** — the extracted ``repro.vdms.merge.merge_topk`` is
   bitwise-identical to verbatim copies of the pre-extraction engine merge
   code (``_pipeline_impl``'s static flavor and ``_live_chunk``'s tombstone
   flavor), on adversarial inputs: -1 padding, dead segments, empty tails,
   score ties.
2. **single shard** — ``ShardedVDMS`` at ``n_shards=1`` returns byte-identical
   ids to the unsharded engine (static and live, composed and fused).
3. **shard-count invariance** — a seeded randomized property sweep: for any
   corpus/shape/shard count, the per-query (gid, score) sets never change
   (hypothesis is not available in this environment; the sweep draws many
   cases from a fixed-seed rng instead).
4. **degenerate shapes** — more shards than sealed segments (dead padding
   shards), every segment on one shard fully tombstoned, and the Poisson
   multi-stream driver's bookkeeping.

Doc-sync tests at the bottom keep ``docs/SHARDING.md``'s generated tables
and the README links honest.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.vdms as V
from repro.vdms.merge import merge_topk
from repro.vdms.sharded import SHARD_INVARIANTS, ShardedVDMS, shard_invariants_table
from repro.vdms.workload import make_query_streams, poisson_arrivals, replay_query_streams

BASE = {
    "segment_max_size": 512, "seal_proportion": 1.0, "graceful_time": 0.2,
    "search_batch_size": 16, "topk_merge_width": 32, "kmeans_iters": 3,
    "storage_bf16": False,
}


@pytest.fixture
def pipeline_guard():
    prev = V.get_search_pipeline()
    yield
    V.set_search_pipeline(prev)


def _dataset(n=4096, dim=32, nq=16, seed=0):
    return V.make_dataset("glove_like", n=n, n_queries=nq, dim=dim, k=10, seed=seed)


def _instance(ds, seed=0, **over):
    cfg = dict(BASE, index_type="IVF_SQ8", nlist=16, nprobe=8, **over)
    return V.VDMSInstance(ds, cfg, seed=seed)


# ---------------------------------------------------------------------------
# 1. merge_topk is bitwise the old engine merge (verbatim pre-extraction code)
# ---------------------------------------------------------------------------
def _old_static_merge(ids, sims, q, growing, growing_gids, topk):
    """Verbatim merge tail of the pre-extraction ``_pipeline_impl`` chunk_fn."""
    n_seg, b, ks = ids.shape
    ids2 = jnp.moveaxis(ids, 0, 1).reshape(b, n_seg * ks)
    sims2 = jnp.moveaxis(sims, 0, 1).reshape(b, n_seg * ks)
    if growing.shape[0] > 0:
        gs = jnp.dot(q, growing.T.astype(q.dtype), preferred_element_type=jnp.float32)
        gk = min(topk, growing.shape[0])
        gtop_s, gtop_i = jax.lax.top_k(gs, gk)
        ids2 = jnp.concatenate([ids2, growing_gids[gtop_i]], axis=1)
        sims2 = jnp.concatenate([sims2, gtop_s], axis=1)
    k = min(topk, sims2.shape[1])
    top_s, top_i = jax.lax.top_k(sims2, k)
    out = jnp.take_along_axis(ids2, top_i, axis=1)
    if k < topk:
        out = jnp.pad(out, ((0, 0), (0, topk - k)), constant_values=-1)
    return out


def _old_live_merge(ids, sims, q, alive_g, growing, growing_gids, topk):
    """Verbatim merge tail of the pre-extraction ``_live_chunk``."""
    sentinel = alive_g.shape[0] - 1
    n_seg, b, ks = ids.shape
    ids2 = jnp.moveaxis(ids, 0, 1).reshape(b, n_seg * ks)
    sims2 = jnp.moveaxis(sims, 0, 1).reshape(b, n_seg * ks)
    ok = alive_g[jnp.where(ids2 >= 0, ids2, sentinel)]
    sims2 = jnp.where(ok, sims2, -jnp.inf)
    if growing.shape[0] > 0:
        gs = jnp.dot(q, growing.T.astype(q.dtype), preferred_element_type=jnp.float32)
        gs = jnp.where(growing_gids[None, :] >= 0, gs, -jnp.inf)
        gk = min(topk, growing.shape[0])
        gtop_s, gtop_i = jax.lax.top_k(gs, gk)
        ids2 = jnp.concatenate([ids2, growing_gids[gtop_i]], axis=1)
        sims2 = jnp.concatenate([sims2, gtop_s], axis=1)
    k = min(topk, sims2.shape[1])
    top_s, top_i = jax.lax.top_k(sims2, k)
    out = jnp.take_along_axis(ids2, top_i, axis=1)
    out = jnp.where(jnp.isfinite(top_s), out, -1)
    if k < topk:
        out = jnp.pad(out, ((0, 0), (0, topk - k)), constant_values=-1)
    return out


def _random_merge_case(rng, n_seg, b, ks, dim, n_grow, n_gids, tie_prob=0.3):
    """Adversarial candidates: -1 pads, dead segments, duplicated (tied)
    scores, a tail with -1 (pad) gid rows."""
    ids = rng.integers(0, n_gids, size=(n_seg, b, ks)).astype(np.int32)
    dead = rng.random((n_seg, b, ks)) < 0.25
    ids = np.where(dead, -1, ids)
    sims = rng.standard_normal((n_seg, b, ks)).astype(np.float32)
    # force score ties so the lowest-flat-index tie-break is exercised
    ties = rng.random((n_seg, b, ks)) < tie_prob
    sims = np.where(ties, np.float32(0.5), sims)
    sims = np.where(dead, -np.inf, sims)
    q = rng.standard_normal((b, dim)).astype(np.float32)
    growing = rng.standard_normal((n_grow, dim)).astype(np.float32)
    ggids = rng.integers(0, n_gids, size=n_grow).astype(np.int32)
    ggids[rng.random(n_grow) < 0.3] = -1
    return ids, sims, q, growing, ggids


@pytest.mark.parametrize("n_grow", [0, 7, 32])
@pytest.mark.parametrize("topk", [4, 10, 64])
def test_merge_topk_matches_old_static_merge(n_grow, topk):
    rng = np.random.default_rng(hash(("static", n_grow, topk)) % 2**32)
    for _ in range(5):
        ids, sims, q, growing, ggids = _random_merge_case(
            rng, n_seg=4, b=3, ks=6, dim=8, n_grow=n_grow, n_gids=64
        )
        got = merge_topk(ids, sims, q, growing, ggids, topk)
        want = _old_static_merge(ids, sims, q, growing, ggids, topk)
        assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_grow", [0, 7, 32])
@pytest.mark.parametrize("topk", [4, 10, 64])
def test_merge_topk_matches_old_live_merge(n_grow, topk):
    rng = np.random.default_rng(hash(("live", n_grow, topk)) % 2**32)
    for _ in range(5):
        ids, sims, q, growing, ggids = _random_merge_case(
            rng, n_seg=4, b=3, ks=6, dim=8, n_grow=n_grow, n_gids=64
        )
        alive = rng.random(65) < 0.8
        alive[-1] = False  # the always-dead sentinel slot
        got = merge_topk(ids, sims, q, growing, ggids, topk, alive=jnp.asarray(alive))
        want = _old_live_merge(
            ids, sims, q, jnp.asarray(alive), growing, ggids, topk
        )
        assert np.array_equal(np.asarray(got), np.asarray(want))


def test_merge_topk_all_dead_returns_minus_one():
    ids = np.zeros((2, 3, 4), np.int32)
    sims = np.full((2, 3, 4), -np.inf, np.float32)
    q = np.zeros((3, 8), np.float32)
    growing = np.empty((0, 8), np.float32)
    ggids = np.empty((0,), np.int32)
    alive = jnp.zeros(11, bool)
    out = np.asarray(merge_topk(ids, sims, q, growing, ggids, 5, alive=alive))
    assert (out == -1).all()


# ---------------------------------------------------------------------------
# 2. single shard is byte-identical to the unsharded engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["composed", "fused"])
def test_one_shard_bitwise_equals_instance(mode, pipeline_guard):
    ds = _dataset()
    inst = _instance(ds)
    V.set_search_pipeline(mode)
    want = inst.search(ds.queries, 10)
    sharded = ShardedVDMS.from_instance(inst, n_shards=1)
    assert sharded.dispatch == "direct"
    got, elapsed = sharded.search(ds.queries, 10, mode="analytic")
    assert np.array_equal(got, want)
    assert elapsed > 0


@pytest.mark.parametrize("mode", ["composed", "fused"])
def test_one_shard_bitwise_equals_live(mode, pipeline_guard):
    ds = _dataset()
    cfg = dict(BASE, index_type="IVF_SQ8", nlist=16, nprobe=8)
    live = V.LiveVDMS(cfg, dim=ds.dim, capacity=ds.n, seed=0)
    live.insert(ds.data[:3000])
    rng = np.random.default_rng(0)
    for g in rng.choice(2500, 200, replace=False):
        live.delete(int(g))
    V.set_search_pipeline(mode)
    want, _ = live.search(ds.queries, 10)
    sharded = ShardedVDMS.from_live(live, n_shards=1)
    got, _ = sharded.search(ds.queries, 10, mode="analytic")
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# 3. property sweep: shard count never changes the (gid, score) sets
# ---------------------------------------------------------------------------
def _gid_score_sets(ids, scores):
    return [
        frozenset(
            (int(g), int(b)) for g, b in zip(ri, rb.view(np.int32)) if g >= 0
        )
        for ri, rb in zip(ids, scores)
    ]


@pytest.mark.parametrize("case", range(6))
def test_property_shard_count_invariant_result_sets(case, pipeline_guard):
    """Seeded randomized property (hypothesis is unavailable here): random
    corpus size / segment size / topk / pipeline, shard counts 1..4 via the
    vmap dispatch — the per-query (gid, score) sets must be identical, and
    on this XLA build the id arrays are bitwise identical too."""
    rng = np.random.default_rng(1000 + case)
    n = int(rng.integers(1536, 4096))
    seg = int(rng.choice([256, 512, 1024]))
    topk = int(rng.choice([5, 10, 17]))
    V.set_search_pipeline(str(rng.choice(["composed", "fused"])))
    ds = _dataset(n=n, nq=8, seed=case)
    inst = _instance(ds, segment_max_size=seg)
    ref = None
    for n_shards in (1, 2, 3, 4):
        sharded = ShardedVDMS.from_instance(
            inst, n_shards=n_shards, dispatch="vmap" if n_shards > 1 else "direct"
        )
        ids, scores, _ = sharded.search(
            ds.queries, topk, mode="analytic", return_scores=True
        )
        if ref is None:
            ref = (ids, _gid_score_sets(ids, scores))
        else:
            assert _gid_score_sets(ids, scores) == ref[1], (
                f"(gid, score) sets changed at n_shards={n_shards}"
            )
            assert np.array_equal(ids, ref[0])


# ---------------------------------------------------------------------------
# 4. degenerate shard shapes
# ---------------------------------------------------------------------------
def test_more_shards_than_segments(pipeline_guard):
    V.set_search_pipeline("fused")
    ds = _dataset(n=2048)
    inst = _instance(ds, segment_max_size=1024)  # 2 sealed segments
    want = inst.search(ds.queries, 10)
    sharded = ShardedVDMS.from_instance(inst, n_shards=4, dispatch="vmap")
    assert inst.plan.n_sealed == 2 and sharded.n_pad == 2
    got, _ = sharded.search(ds.queries, 10, mode="analytic")
    assert np.array_equal(got, want)
    segs = sharded.shard_segments()
    assert segs.tolist() == [1, 1, 0, 0]
    cov = sharded.shard_coverage()
    assert cov[2] == 0.0 and cov[3] == 0.0  # padding-only shards report honestly


def test_one_shard_fully_tombstoned(pipeline_guard):
    """Delete every vector of the segments landing on shard 0; results must
    equal the live engine's (which sees the same tombstones) and shard 0's
    coverage must read 0."""
    V.set_search_pipeline("composed")
    cfg = dict(BASE, index_type="IVF_SQ8", nlist=16, nprobe=8)
    ds = _dataset()
    live = V.LiveVDMS(cfg, dim=ds.dim, capacity=ds.n, seed=0)
    live.insert(ds.data[:3100])  # seals segments, leaves a tail
    n_shards = 2
    per = -(-live.n_sealed // n_shards)
    for z in range(min(per, live.n_sealed)):  # shard 0's segments
        for g in live.seg_gids[z]:
            if g >= 0 and live.alive[g]:
                live.delete(int(g))
    want, _ = live.search(ds.queries, 10)
    sharded = ShardedVDMS.from_live(live, n_shards=n_shards, dispatch="vmap")
    got, _ = sharded.search(ds.queries, 10, mode="analytic")
    assert np.array_equal(got, want)
    assert sharded.shard_coverage()[0] == 0.0
    assert sharded.stats()["min_shard_coverage"] == 0.0


def test_nothing_sealed_raises():
    cfg = dict(BASE, index_type="IVF_SQ8", nlist=16, nprobe=8)
    live = V.LiveVDMS(cfg, dim=16, capacity=1024, seed=0)
    live.insert(np.random.default_rng(0).standard_normal((8, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="nothing sealed"):
        ShardedVDMS.from_live(live, n_shards=2)


def test_analytic_model_reduces_to_engine_at_one_shard():
    ds = _dataset()
    inst = _instance(ds)
    sharded = ShardedVDMS.from_instance(inst, n_shards=1)
    assert sharded._analytic_seconds_per_chunk() == pytest.approx(
        inst._analytic_seconds_per_chunk()
    )
    s4 = ShardedVDMS.from_instance(inst, n_shards=4, dispatch="vmap")
    assert s4._analytic_seconds_per_chunk() < sharded._analytic_seconds_per_chunk()


def test_search_streams_splits_per_stream(pipeline_guard):
    V.set_search_pipeline("fused")
    ds = _dataset()
    inst = _instance(ds)
    sharded = ShardedVDMS.from_instance(inst, n_shards=2, dispatch="vmap")
    streams = [ds.queries[:5], ds.queries[5:8], ds.queries[8:16]]
    outs, elapsed = sharded.search_streams(streams, 10)
    assert [o.shape for o in outs] == [(5, 10), (3, 10), (8, 10)]
    whole, _ = sharded.search(ds.queries[:16], 10)
    assert np.array_equal(np.concatenate(outs), whole)


# ---------------------------------------------------------------------------
# Poisson multi-stream driver
# ---------------------------------------------------------------------------
def test_poisson_arrivals_properties():
    t = poisson_arrivals(100.0, 5000, seed=1)
    assert t.shape == (5000,) and (np.diff(t) > 0).all()
    assert np.mean(np.diff(t)) == pytest.approx(0.01, rel=0.1)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(0.0, 10)


def test_make_query_streams_superposition():
    q = np.zeros((32, 8), np.float32)
    streams = make_query_streams(q, 4, rate=80.0, n_per_stream=50, seed=0)
    assert len(streams) == 4
    rows = np.concatenate([r for _, r in streams])
    assert set(rows.tolist()) == set(range(32))  # round-robin covers the pool
    merged = np.sort(np.concatenate([t for t, _ in streams]))
    # superposed rate ~ aggregate
    assert 1.0 / np.mean(np.diff(merged)) == pytest.approx(80.0, rel=0.25)


def test_replay_query_streams_accounting(pipeline_guard):
    V.set_search_pipeline("fused")
    ds = _dataset()
    inst = _instance(ds)
    sharded = ShardedVDMS.from_instance(inst, n_shards=2, dispatch="vmap")
    qps = ds.queries.shape[0] / sharded.search(ds.queries, 10, mode="analytic")[1]
    rep = replay_query_streams(
        sharded, ds.queries, rate=0.5 * qps, n_streams=4, n_per_stream=16, topk=10
    )
    assert rep["n_queries"] == 64
    assert rep["min_stream_queries"] == 16
    assert rep["served_qps"] > 0 and rep["utilization"] <= 1.0 + 1e-9
    assert rep["sojourn_p50_s"] <= rep["sojourn_p95_s"] <= rep["sojourn_p99_s"]
    # overload: a rate far beyond capacity must flag saturation
    hot = replay_query_streams(
        sharded, ds.queries, rate=50 * qps, n_streams=4, n_per_stream=64, topk=10
    )
    assert hot["saturated"] == 1.0


def test_sharded_search_hooks_fire(pipeline_guard):
    V.set_search_pipeline("fused")
    ds = _dataset()
    inst = _instance(ds)
    sharded = ShardedVDMS.from_instance(inst, n_shards=2, dispatch="vmap")
    seen = []
    sharded.search_hooks.append(lambda nq, lat, el: seen.append((nq, lat.size, el)))
    sharded.search(ds.queries, 10, mode="analytic")
    assert seen and seen[0][0] == ds.queries.shape[0] == seen[0][1]


def test_sharded_ledger_attach(pipeline_guard):
    from repro.serving import attach_sharded, serving_ledger

    V.set_search_pipeline("fused")
    ds = _dataset()
    inst = _instance(ds)
    sharded = ShardedVDMS.from_instance(inst, n_shards=2, dispatch="vmap")
    led = serving_ledger()
    attach_sharded(led, sharded)
    sharded.search(ds.queries, 10, mode="analytic")
    assert led.get("vdms_shards").value == 2.0
    assert led.get("vdms_queries_total").value == ds.queries.shape[0]
    assert led.get("vdms_shard_min_coverage").value == 1.0


# ---------------------------------------------------------------------------
# docs stay in sync
# ---------------------------------------------------------------------------
def _repo_root():
    return pathlib.Path(__file__).resolve().parents[1]


def test_sharding_doc_invariants_table_in_sync():
    doc = (_repo_root() / "docs" / "SHARDING.md").read_text()
    begin, end = "<!-- shard-invariants:begin -->", "<!-- shard-invariants:end -->"
    assert begin in doc and end in doc, "SHARDING.md lost the shard-invariants markers"
    block = doc.split(begin)[1].split(end)[0].strip()
    assert block == shard_invariants_table().strip(), (
        "SHARDING.md invariants table is stale; regenerate with "
        "python -c \"from repro.vdms import shard_invariants_table; "
        "print(shard_invariants_table())\""
    )


def test_sharding_doc_pipeline_table_in_sync():
    from repro.vdms import ivf_pqr

    ivf_pqr.register()
    doc = (_repo_root() / "docs" / "SHARDING.md").read_text()
    begin, end = "<!-- shard-pipelines:begin -->", "<!-- shard-pipelines:end -->"
    assert begin in doc and end in doc, "SHARDING.md lost the shard-pipelines markers"
    block = doc.split(begin)[1].split(end)[0].strip()
    assert block == V.shard_pipeline_table().strip(), (
        "SHARDING.md shard-pipeline table is stale; regenerate with "
        "python -c \"from repro.vdms import shard_pipeline_table, ivf_pqr; "
        "ivf_pqr.register(); print(shard_pipeline_table())\""
    )


def test_sharding_doc_covers_contract():
    doc = (_repo_root() / "docs" / "SHARDING.md").read_text()
    for name, _, _ in SHARD_INVARIANTS:
        assert name in doc
    for needle in (
        "shard_map", "segment_placement", "make_shard_mesh", "partial_topk",
        "merge_flat", "xla_force_host_platform_device_count", "bench_sharded",
    ):
        assert needle in doc, f"SHARDING.md lost {needle!r}"


def test_architecture_doc_exists_and_maps_subsystems():
    doc = (_repo_root() / "docs" / "ARCHITECTURE.md").read_text()
    for needle in (
        "core", "registry", "kernels", "serving", "faults", "sharded",
        "ShardedVDMS", "LiveVDMS", "docs/SHARDING.md",
    ):
        assert needle in doc, f"ARCHITECTURE.md lost {needle!r}"


def test_readme_links_new_docs():
    text = (_repo_root() / "README.md").read_text()
    assert "docs/SHARDING.md" in text
    assert "docs/ARCHITECTURE.md" in text
    assert "bench_sharded" in text
