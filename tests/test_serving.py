"""Serving control plane: metrics ledger, SLO guardrails, shadow/canary loop."""
import copy
import json

import numpy as np
import pytest

from repro.core import TuningSession, VDTuner, promotion_score
from repro.serving import (
    ControllerParams,
    GidMappedVDMS,
    Histogram,
    MetricsLedger,
    ServingController,
    SLOMonitor,
    SLOSpec,
    attach_live,
    observe_stats,
    serving_ledger,
)
from repro.serving.controller import mirror_count
from repro.vdms import LiveVDMS, VDMSTuningEnv, make_space, make_trace
from repro.vdms.workload import time_aware_ground_truth

LIVE_CFG = dict(
    index_type="IVF_FLAT",
    nlist=16,
    nprobe=16,
    segment_max_size=256,
    seal_proportion=0.5,
    graceful_time=0.0,
    search_batch_size=8,
    topk_merge_width=64,
    kmeans_iters=4,
    storage_bf16=False,
)


def _vectors(n, dim=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# metrics ledger
# ---------------------------------------------------------------------------
def test_counter_monotone_and_gauge_free():
    led = MetricsLedger()
    c = led.counter("x_total")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = led.gauge("x_now")
    g.set(4.0)
    g.inc(-1.0)
    assert g.value == 3.0


def test_histogram_buckets_percentiles_and_exposition():
    h = Histogram("lat_seconds", "latency", buckets=(0.01, 0.1, 1.0), window=100)
    h.observe_many([0.005, 0.05, 0.5, 5.0])
    assert h.count == 4 and h.bucket_counts == [1, 1, 1, 1]
    assert h.percentile(0.0) == 0.005 and h.percentile(100.0) == 5.0
    text = "\n".join(h.exposition())
    assert "# TYPE lat_seconds histogram" in text
    # bucket lines are cumulative, +Inf last equals the total count
    assert 'lat_seconds_bucket{le="0.1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(1.0, 0.5))


def test_ledger_get_or_create_and_kind_mismatch():
    led = MetricsLedger()
    assert led.counter("a_total") is led.counter("a_total")
    with pytest.raises(ValueError):
        led.gauge("a_total")
    assert "a_total" in led and led.names() == ["a_total"]


def test_ledger_json_is_strict_and_text_scrapes(tmp_path):
    led = serving_ledger()
    led.histogram("vdms_query_latency_seconds").observe(float("inf"))
    led.counter("vdms_queries_total").inc(3)
    path = tmp_path / "ledger.json"
    led.dump_json(str(path))
    dumped = json.loads(path.read_text())  # strict JSON must parse
    assert dumped["vdms_queries_total"]["value"] == 3.0
    text = led.to_text()
    assert "# TYPE vdms_queries_total counter" in text
    assert "vdms_rollback_total 0" in text


def test_attach_live_feeds_ledger_and_observe_stats_syncs():
    led = serving_ledger()
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024)
    attach_live(led, live)
    live.bootstrap(_vectors(300))
    live.search(_vectors(10, seed=1), topk=5)
    assert led.counter("vdms_queries_total").value == 10
    assert led.histogram("vdms_query_latency_seconds").count == 10
    assert led.gauge("vdms_qps").value > 0
    observe_stats(led, live.stats())
    assert led.counter("vdms_seals_total").value == 2
    assert led.gauge("vdms_sealed_segments").value == 2
    observe_stats(led, live.stats())  # idempotent re-sync
    assert led.counter("vdms_seals_total").value == 2
    assert led.gauge("vdms_mem_gib").value > 0


# ---------------------------------------------------------------------------
# SLO guardrails
# ---------------------------------------------------------------------------
def test_slo_spec_validation_and_objective_mapping():
    with pytest.raises(ValueError):
        SLOSpec()  # every guardrail disabled
    with pytest.raises(ValueError):
        SLOSpec(recall_floor=1.5)
    with pytest.raises(ValueError):
        SLOSpec(p99_latency_s=-1.0)
    spec = SLOSpec(recall_floor=0.9)
    obj = spec.objective_spec(alpha=0.5)
    assert obj.rlim == 0.9 and obj.names == ("sustained_qps", "recall")


def test_slo_monitor_latency_guardrail_arms_after_min_samples():
    spec = SLOSpec(p99_latency_s=0.01, min_samples=8, latency_window=32)
    mon = SLOMonitor(spec)
    mon.observe_query([0.5] * 4)  # hot, but below min_samples
    assert mon.evaluate().ok
    mon.observe_query([0.5] * 8)
    status = mon.evaluate(at_time=0.25)
    assert not status.ok and status.breaches == ("p99_latency",)
    assert status.at_time == 0.25 and len(mon.events) == 1
    mon.reset()
    assert mon.evaluate().ok  # cold window never breaches


def test_slo_monitor_recall_and_mem_guardrails():
    spec = SLOSpec(recall_floor=0.9, mem_gib_cap=1.0)
    mon = SLOMonitor(spec)
    assert mon.evaluate().ok  # no probes yet: recall guardrail unarmed
    mon.observe_recall(0.85)
    mon.observe_mem(2.0)
    status = mon.evaluate()
    assert set(status.breaches) == {"recall_floor", "mem_cap"}
    mon.observe_recall(0.99)  # window mean recovers
    mon.observe_recall(0.99)
    mon.observe_mem(0.5)
    assert "mem_cap" not in mon.evaluate().breaches


def test_promotion_score_is_lexicographic_on_feasibility():
    feas = {"speed": 100.0, "recall": 0.95, "n_searches": 10.0, "search_s": 0.1, "seal_build_s": 0.0}
    fast_infeas = {"speed": 900.0, "recall": 0.5, "n_searches": 10.0, "search_s": 0.01, "seal_build_s": 0.0}
    assert promotion_score(feas, rlim=0.9) > promotion_score(fast_infeas, rlim=0.9)
    # among feasible configs sustained QPS decides
    faster = dict(feas, search_s=0.05)
    assert promotion_score(faster, rlim=0.9) > promotion_score(feas, rlim=0.9)
    # among infeasible configs the higher recall is the least-bad candidate
    less_bad = dict(fast_infeas, recall=0.7)
    assert promotion_score(less_bad, rlim=0.9) > promotion_score(fast_infeas, rlim=0.9)
    # without a floor everything is feasible
    assert promotion_score(fast_infeas)[0] == 1.0


# ---------------------------------------------------------------------------
# gid-mapped instances
# ---------------------------------------------------------------------------
def test_gid_mapped_vdms_speaks_global_ids():
    data = _vectors(120, seed=3)
    gids = np.arange(1000, 1120)
    inst = GidMappedVDMS(dict(LIVE_CFG, index_type="FLAT"), dim=16, capacity=512)
    inst.bootstrap(data, gids)
    assert set(inst.visible_gids().tolist()) == set(gids.tolist())
    extra = _vectors(1, seed=4)[0]
    inst.insert(5000, extra)
    assert inst.delete(1003) and not inst.delete(1003)
    assert not inst.delete(777)  # unknown global id is a no-op
    ids, _ = inst.search(data[:8], topk=5)
    returned = set(ids.ravel().tolist()) - {-1}
    assert returned <= (set(gids.tolist()) | {5000}) - {1003}
    # the nearest neighbor of a bootstrapped vector is its own global id
    assert ids[0, 0] == 1000


def test_gid_mapped_bootstrap_validates_lengths():
    inst = GidMappedVDMS(LIVE_CFG, dim=16, capacity=64)
    with pytest.raises(ValueError):
        inst.bootstrap(_vectors(4), np.arange(5))


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------
def _drifted_trace(n_base=800, n_ops=400, seed=0):
    return make_trace(
        "glove_like", n_base=n_base, n_ops=n_ops, drift="step",
        seed=seed, mix=(0.3, 0.6, 0.1),
    )


def _served_session(trace, n_pre_ops=150, n_iters=6, seed=0):
    """Tune an incumbent on the pre-drift prefix, as a deployment would."""
    env = VDMSTuningEnv(
        trace=trace.window(0, n_pre_ops), workload="streaming",
        mode="analytic", seed=seed, n_phases=1,
    )
    tuner = VDTuner(make_space(), env, seed=seed, warm_start=True)
    session = TuningSession(tuner)
    session.run(n_iters)
    return session, env


def test_unguarded_serve_reports_and_is_deterministic():
    trace = _drifted_trace(n_base=400, n_ops=200)
    slo = SLOSpec(recall_floor=0.99, min_samples=8)
    cfg = dict(LIVE_CFG, index_type="FLAT", graceful_time=0.4)
    reports = []
    for _ in range(2):
        ctrl = ServingController(slo, params=ControllerParams(check_every=24), seed=0)
        reports.append(ctrl.serve(trace, cfg, guard=False))
    a, b = reports
    assert a["violation_minutes"] == b["violation_minutes"]
    assert a["recall"] == b["recall"] and a["n_retunes"] == 0
    assert a["n_breach_events"] > 0  # the scenario genuinely breaches
    assert a["violation_time"] * 60.0 == pytest.approx(a["violation_minutes"])
    assert a["config_history"] == [{"op": 0, "time": 0.0, "config": cfg}]
    assert a["final_stats"]["queries_served"] == a["n_searches"]


def test_guarded_serve_requires_session():
    slo = SLOSpec(recall_floor=0.9)
    with pytest.raises(ValueError):
        ServingController(slo).serve(_drifted_trace(400, 50), LIVE_CFG, guard=True)


def test_losing_canary_rolls_back_bit_identical():
    trace = _drifted_trace(n_base=400, n_ops=260, seed=2)
    session, _ = _served_session(trace, n_pre_ops=100, n_iters=4, seed=2)
    cfg = dict(LIVE_CFG, index_type="FLAT", graceful_time=0.4)
    # unreachable floor + no repair anchors: every retune's knee fallback is
    # an approximate-index candidate that loses the canary on live traffic
    slo = SLOSpec(recall_floor=0.999, min_samples=8)
    ctrl = ServingController(
        slo, session=session,
        params=ControllerParams(
            check_every=24, canary_queries=16, retune_iters=4,
            retune_window_ops=128, cooldown_ops=48, min_window_searches=8,
            repair_anchors=False, floor_margin=0.0, canary_feedback=False,
        ),
        seed=2,
    )
    state_before = copy.deepcopy(session.state_dict())
    backend_before = session.backend
    report = ctrl.serve(trace, cfg, guard=True)
    assert report["n_retunes"] > 0
    assert report["n_promotes"] == 0
    assert report["n_rollbacks"] == report["n_retunes"]
    # checkpoint-exact: the losing canaries left no trace in the session
    assert session.state_dict() == state_before
    assert session.backend is backend_before
    assert [e["event"] for e in report["timeline"] if e["event"] == "rollback"]


def test_mirror_count_honors_fraction_on_small_flushes():
    # regression: ceil-rounding mirrored EVERYTHING on small flushes — at
    # fraction 0.25 a stream of 3-query flushes must mirror ~1/4, not all
    credit, mirrored, total = 0.0, 0, 0
    for _ in range(40):
        m, credit = mirror_count(credit, 0.25, 3)
        mirrored += m
        total += 3
    assert mirrored == int(0.25 * total)  # exact: credit carries, never ceils
    # fraction 1.0 reduces to the legacy everything-mirrored path exactly
    assert mirror_count(0.0, 1.0, 7) == (7, 0.0)
    # a flush smaller than 1/fraction mirrors nothing and banks the credit
    m, credit = mirror_count(0.0, 0.1, 3)
    assert m == 0 and credit == pytest.approx(0.3)


def test_fractional_mirror_still_reaches_decisions():
    trace = _drifted_trace(n_base=400, n_ops=260, seed=2)
    session, _ = _served_session(trace, n_pre_ops=100, n_iters=4, seed=2)
    cfg = dict(LIVE_CFG, index_type="FLAT", graceful_time=0.4)
    slo = SLOSpec(recall_floor=0.999, min_samples=8)
    ctrl = ServingController(
        slo, session=session,
        params=ControllerParams(
            check_every=24, canary_queries=8, retune_iters=4,
            retune_window_ops=128, cooldown_ops=48, min_window_searches=8,
            repair_anchors=False, floor_margin=0.0, canary_feedback=False,
            traffic_mirror=0.5,
        ),
        seed=2,
    )
    report = ctrl.serve(trace, cfg, guard=True)
    # mirroring half the traffic still accumulates enough mirrored queries
    # to reach promote-or-rollback decisions
    assert report["n_promotes"] + report["n_rollbacks"] > 0


def test_canary_feedback_feeds_gp_outside_budget():
    trace = _drifted_trace(n_base=400, n_ops=260, seed=2)
    session, _ = _served_session(trace, n_pre_ops=100, n_iters=4, seed=2)
    cfg = dict(LIVE_CFG, index_type="FLAT", graceful_time=0.4)
    slo = SLOSpec(recall_floor=0.999, min_samples=8)
    n_obs_before = session.n_observations
    hist_before = len(session.tuner.history)
    outcomes = []
    ctrl = ServingController(
        slo, session=session,
        params=ControllerParams(
            check_every=24, canary_queries=16, retune_iters=4,
            retune_window_ops=128, cooldown_ops=48, min_window_searches=8,
            repair_anchors=False, floor_margin=0.0,
        ),
        seed=2,
        outcome_hook=lambda kind, c, raw: outcomes.append((kind, c, raw)),
    )
    report = ctrl.serve(trace, cfg, guard=True)
    decisions = report["n_promotes"] + report["n_rollbacks"]
    assert decisions > 0
    # every decision told BOTH arms' live measurements into the tuner; with
    # all canaries losing, the rollback restore wiped the retune evals so
    # exactly the feedback rows survive
    assert report["n_promotes"] == 0
    fed = session.tuner.history[hist_before:]
    assert len(fed) == 2 * decisions
    assert all(o.bootstrap and not o.failed for o in fed)
    assert all({"speed", "recall"} <= set(o.raw) for o in fed)
    # free byproducts of serving: the fresh-evaluation budget is untouched
    assert session.n_observations == n_obs_before
    # the outcome hook saw each decision with the candidate's measurements
    assert [k for k, _, _ in outcomes].count("rollback") == report["n_rollbacks"]
    assert len(outcomes) == decisions
    assert all({"speed", "recall"} <= set(raw) for _, _, raw in outcomes)


def test_breach_triggers_canary_and_promotion_repairs_recall():
    # step drift moves queries toward the drifted inserts AND turns the mix
    # insert-heavy, so the incumbent's wide bounded-consistency window
    # (graceful_time=0.4 hides the newest tail) starts losing exactly the
    # vectors the drifted queries need: a recall breach the repair-anchor
    # retune fixes by opening the window (graceful_time -> 0)
    trace = make_trace(
        "glove_like", n_base=800, n_ops=640, drift="step", seed=0,
        mix=(0.2, 0.75, 0.05), mix_to=(0.65, 0.3, 0.05),
    )
    cfg = dict(
        make_space().default_config("FLAT"), segment_max_size=256, graceful_time=0.4
    )
    session, _ = _served_session(trace)
    slo = SLOSpec(recall_floor=0.9, min_samples=16)
    ctrl = ServingController(
        slo, session=session,
        params=ControllerParams(
            retune_iters=6, check_every=24, canary_queries=24,
            retune_window_ops=112, cooldown_ops=48, floor_margin=0.02,
        ),
        seed=0,
    )
    guarded = ctrl.serve(trace, cfg, guard=True)
    baseline = ServingController(
        slo, params=ControllerParams(check_every=24), seed=0
    ).serve(trace, cfg, guard=False)
    assert guarded["n_promotes"] >= 1
    events = [e["event"] for e in guarded["timeline"]]
    assert "breach" in events and "canary_start" in events and "promote" in events
    # the promoted config took over serving
    assert len(guarded["config_history"]) == 1 + guarded["n_promotes"]
    # and the guardrails did their job vs the frozen baseline
    assert guarded["violation_minutes"] < baseline["violation_minutes"]
    assert guarded["recall"] > baseline["recall"]
    # ledger counters agree with the report
    led = ctrl.ledger
    assert led.counter("vdms_promote_total").value == guarded["n_promotes"]
    assert led.counter("vdms_retune_total").value == guarded["n_retunes"]
    assert led.counter("vdms_slo_breach_total").value == guarded["n_breach_events"]
    assert led.histogram("vdms_query_latency_seconds").count > 0
    json.dumps(led.to_json())  # the CI artifact serializes strictly


def test_serve_with_precomputed_ground_truth_matches():
    trace = _drifted_trace(n_base=400, n_ops=150)
    gt = time_aware_ground_truth(trace, trace.k)
    slo = SLOSpec(recall_floor=0.5, min_samples=8)
    cfg = dict(LIVE_CFG, index_type="FLAT")
    a = ServingController(slo, seed=0).serve(trace, cfg, guard=False)
    b = ServingController(slo, seed=0).serve(trace, cfg, ground_truth=gt, guard=False)
    assert a["recall"] == b["recall"] and a["lat_p99_s"] == b["lat_p99_s"]
