"""Fleet tuning subsystem: descriptors, cross-tenant transfer, shared budget.

Covers the four layers the fleet is built from:

1. **Descriptors/embedding** — workload fingerprints separate the dataset
   families, the PCA embedding is deterministic and JSON round-trips, and
   similarity uses the absolute characteristic scales (not fleet-relative).
2. **Core hooks** — ``SearchSpace.encoding_signature``, the GP's per-row
   ``noise_scale`` and ``prior_mean`` hooks, ``TuningSession.tell`` /
   ``import_observations`` budget semantics.
3. **Transfer policy** — source ranking, Pareto-first selection, the
   cold-start fallback (bit-identical session) and the divergence guard.
4. **FleetSession** — scheduler policies, shared-budget stop, the
   schema-versioned ledger, and a hypothesis property: ``state_dict`` ->
   restore mid-round (pending queues included) is bit-identical.

Doc-sync tests at the bottom keep ``docs/FLEET.md``'s generated feature
table and the README/ARCHITECTURE links honest.
"""
import copy
import json
import pathlib

import numpy as np
import pytest

from repro.core import Param, SearchSpace, StopSession, TuningSession, VDTuner
from repro.core.gp import GP
from repro.core.tuner import Observation
from repro.fleet import (
    FEATURE_NAMES,
    FLEET_LEDGER_SCHEMA,
    DescriptorEmbedding,
    FleetBudget,
    FleetScheduler,
    FleetSession,
    TransferPolicy,
    WorkloadDescriptor,
    apply_transfer,
    check_divergence,
    describe_trace,
    divergence_score,
    feature_table,
    purge_imports,
    rank_sources,
    select_observations,
)
from repro.vdms import make_trace

_FAST = dict(gp_fit_steps=24, n_candidates=48, mc_samples=16)


def _toy_objective(cfg):
    t = cfg["index_type"]
    k = cfg.get("ka", cfg.get("kb", 0.5))
    k = k / 8.0 if t == "A" else k
    sysq = 1.0 - (cfg["s1"] - 0.6) ** 2
    speed = 80 * (1 - k) * sysq if t == "A" else 50 * (1 - k) * sysq
    recall = 0.5 + 0.45 * k if t == "A" else 0.6 + 0.39 * k
    # deterministic modeled replay seconds -> deterministic fleet charges
    return {"speed": speed, "recall": recall, "search_s": 0.01 + 0.001 * k}


def _toy_space():
    return SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 8), default=2)],
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )


def _toy_session(seed=11, **kw):
    return TuningSession(VDTuner(_toy_space(), _toy_objective, seed=seed, **_FAST), **kw)


_BASE_FEATURES = dict(
    log_corpus=4.0, log_dim=2.0, log_k=1.0,
    insert_frac=0.2, search_frac=0.75, delete_frac=0.05,
    drift=0.1, dispersion=0.9, centroid_align=0.2, coord_kurtosis=3.0,
)


def _desc(name, **over):
    return WorkloadDescriptor(name=name, features=dict(_BASE_FEATURES, **over))


# ---------------------------------------------------------------------------
# descriptors + embedding
# ---------------------------------------------------------------------------
def test_describe_trace_is_finite_and_separates_families():
    glove = describe_trace(
        make_trace("glove_like", n_base=256, n_ops=96, seed=0, mix=(0.2, 0.75, 0.05))
    )
    keyword = describe_trace(
        make_trace("keyword_like", n_base=256, n_ops=96, seed=1, mix=(0.2, 0.75, 0.05))
    )
    for d in (glove, keyword):
        v = d.vector()
        assert v.shape == (len(FEATURE_NAMES),) and np.all(np.isfinite(v))
        mix = d.features["insert_frac"] + d.features["search_frac"] + d.features["delete_frac"]
        assert mix == pytest.approx(1.0)
    # sparse keyword corpora have much heavier coordinate kurtosis
    assert keyword.features["coord_kurtosis"] > 2 * glove.features["coord_kurtosis"]


def test_descriptor_roundtrip_and_validation():
    d = _desc("t")
    assert WorkloadDescriptor.from_dict(json.loads(json.dumps(d.to_dict()))) == d
    with pytest.raises(ValueError, match="missing features"):
        WorkloadDescriptor(name="bad", features={"log_corpus": 1.0})


def test_embedding_similarity_structure():
    a1 = _desc("a1")
    a2 = _desc("a2", insert_frac=0.25, search_frac=0.7, drift=0.12)  # seed jitter
    b = _desc("b", coord_kurtosis=9.0, insert_frac=0.6, search_frac=0.35, dispersion=0.5)
    emb = DescriptorEmbedding().fit([a1, a2, b])
    assert emb.similarity(a1, a1) == pytest.approx(1.0)
    assert emb.similarity(a1, a2) == pytest.approx(emb.similarity(a2, a1))
    # same family (jitter apart) scores well above the cross-family pair:
    # fixed characteristic scales keep seed noise off the family-signal axis
    assert emb.similarity(a1, a2) > 0.5
    assert emb.similarity(a1, b) < 0.2
    # deterministic: refitting produces the identical embedding
    emb2 = DescriptorEmbedding().fit([a1, a2, b])
    assert np.array_equal(emb.embed(a1), emb2.embed(a1))


def test_embedding_state_roundtrips_exactly():
    emb = DescriptorEmbedding(n_components=3).fit([_desc("x"), _desc("y", drift=0.4)])
    state = json.loads(json.dumps(emb.state_dict()))
    emb2 = DescriptorEmbedding().load_state_dict(state)
    assert np.array_equal(emb.embed(_desc("z")), emb2.embed(_desc("z")))
    assert emb.similarity(_desc("x"), _desc("y", drift=0.4)) == emb2.similarity(
        _desc("x"), _desc("y", drift=0.4)
    )


# ---------------------------------------------------------------------------
# core hooks: encoding signature, GP noise_scale / prior_mean, tell / import
# ---------------------------------------------------------------------------
def test_encoding_signature_keys_the_uniform_encoding():
    assert _toy_space().encoding_signature() == _toy_space().encoding_signature()
    other = SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 16), default=2)],  # 16 != 8
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )
    assert other.encoding_signature() != _toy_space().encoding_signature()


def test_gp_noise_scale_ones_is_bitwise_inert():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(10, 3))
    Y = np.stack([X.sum(axis=1), X[:, 0] - X[:, 1]], axis=1)
    m0, s0 = GP(seed=0, fit_steps=40).fit(X, Y).predict(X)
    m1, s1 = GP(seed=0, fit_steps=40).fit(X, Y, noise_scale=np.ones(10)).predict(X)
    assert np.array_equal(m0, m1) and np.array_equal(s0, s1)


def test_gp_noise_scale_downweights_inflated_rows():
    X = np.linspace(0, 1, 12)[:, None]
    Y = (2.0 * X).astype(np.float64)
    Yc = Y.copy()
    Yc[5, 0] += 5.0  # one corrupted observation
    scale = np.ones(12)
    scale[5] = 100.0
    m_plain, _ = GP(seed=0, fit_steps=80).fit(X, Yc).predict(X[5:6])
    m_down, _ = GP(seed=0, fit_steps=80).fit(X, Yc, noise_scale=scale).predict(X[5:6])
    true = Y[5, 0]
    assert abs(m_down[0, 0] - true) < abs(m_plain[0, 0] - true)


def test_gp_prior_mean_guides_extrapolation():
    X = np.array([[0.1], [0.2], [0.3]])
    Y = 3.0 + 2.0 * X
    Xt = np.array([[0.9]])
    prior = lambda A: 3.0 + 2.0 * np.asarray(A)[:, :1]  # noqa: E731
    m_cold, _ = GP(seed=0, fit_steps=60).fit(X, Y).predict(Xt)
    m_warm, _ = GP(seed=0, fit_steps=60).fit(X, Y, prior_mean=prior).predict(Xt)
    true = 3.0 + 2.0 * 0.9
    assert abs(m_warm[0, 0] - true) < abs(m_cold[0, 0] - true)


def test_observation_noise_scale_serialization_is_backward_compatible():
    o = Observation(
        iteration=0, config={"index_type": "A"}, y=np.array([1.0, 2.0]), raw={},
        recommend_time=0.0, eval_time=0.0,
    )
    assert "noise_scale" not in o.to_dict()  # pre-fleet checkpoints byte-identical
    o.noise_scale = 2.5
    d = o.to_dict()
    assert d["noise_scale"] == 2.5
    assert Observation.from_dict(d).noise_scale == 2.5
    assert Observation.from_dict({k: v for k, v in d.items() if k != "noise_scale"}).noise_scale == 1.0


def test_session_tell_feeds_history_not_ledger():
    session = _toy_session().run(3)
    n_rounds = len(session.rounds)
    n_obs = session.n_observations
    cfg = session.tuner.space.default_config("A")
    obs = session.tell(cfg, _toy_objective(cfg))
    assert obs is session.tuner.history[-1] and not obs.bootstrap
    assert session.n_observations == n_obs + 1  # fresh external measurement
    boot = session.tell(cfg, _toy_objective(cfg), bootstrap=True, noise_scale=2.0)
    assert boot.bootstrap and boot.noise_scale == 2.0
    assert session.n_observations == n_obs + 1  # bootstrap stays off-budget
    assert len(session.rounds) == n_rounds  # external tells are never ledgered


def test_import_observations_skips_warmup_and_budget():
    source = _toy_session(seed=3).run(6)
    target = _toy_session(seed=4)
    sig = source.tuner.space.encoding_signature()
    n = target.import_observations(source.history, noise_scale=3.0, space_signature=sig)
    assert n == len([o for o in source.history if not o.failed])
    assert target.n_observations == 0
    assert all(o.bootstrap and o.noise_scale == 3.0 for o in target.tuner.history)
    # imports recomputed objectives through the local transform
    assert all(np.all(np.isfinite(o.y)) for o in target.tuner.history)
    # every index type is marked seen: the first ask is one BO candidate,
    # not the mandatory per-type default sweep (the warm-start win)
    assert len(target.tuner.ask(1)) == 1
    cold = _toy_session(seed=4)
    assert len(cold.tuner.ask(1)) == len(_toy_space().type_names)


def test_import_observations_refuses_signature_mismatch():
    target = _toy_session()
    with pytest.raises(ValueError, match="signature"):
        target.import_observations([], space_signature="not-the-right-space")


# ---------------------------------------------------------------------------
# transfer policy
# ---------------------------------------------------------------------------
def test_transfer_policy_validation_and_noise():
    p = TransferPolicy(noise_base=2.0, noise_ceil=8.0)
    assert p.noise_for(1.0) == 2.0
    assert p.noise_for(0.5) == 4.0
    assert p.noise_for(0.01) == 8.0  # clipped at the ceiling
    with pytest.raises(ValueError):
        TransferPolicy(k_sources=0)
    with pytest.raises(ValueError):
        TransferPolicy(noise_base=0.5)


def test_rank_sources_floor_and_order():
    a = _desc("a")
    near = _desc("near", drift=0.12)
    far = _desc("far", coord_kurtosis=9.0, insert_frac=0.6, search_frac=0.35)
    emb = DescriptorEmbedding().fit([a, near, far])
    policy = TransferPolicy(k_sources=2, min_similarity=0.3)
    ranked = rank_sources(emb, a, [("far", far), ("near", near)], policy)
    assert [n for n, _ in ranked] == ["near"]  # far fails the floor
    assert ranked[0][1] > 0.3


def test_select_observations_prefers_front_and_excludes_noise():
    def obs(i, speed, recall, failed=False, bootstrap=False):
        o = Observation(
            iteration=i, config={"index_type": "A", "ka": 2, "s1": 0.5, "s2": False},
            y=np.array([speed, recall]), raw={"speed": speed, "recall": recall},
            recommend_time=0.0, eval_time=0.0, failed=failed,
        )
        o.bootstrap = bootstrap
        return o

    history = [
        obs(0, 10.0, 0.99),   # front
        obs(1, 80.0, 0.50),   # front
        obs(2, 9.0, 0.50),    # dominated
        obs(3, 50.0, 0.90),   # front
        obs(4, 99.0, 0.99, failed=True),
        obs(5, 99.0, 0.99, bootstrap=True),
    ]
    picked = select_observations(history, 3)
    # knee first (balanced on both axes), then the extremes in stable order
    assert [o.iteration for o in picked] == [3, 0, 1]
    assert select_observations(history, 4)[-1].iteration == 2  # then the rest
    assert select_observations([], 4) == []


def test_apply_transfer_fallback_is_bit_identical():
    session = _toy_session()
    before = json.dumps(session.state_dict(), sort_keys=True)
    report = apply_transfer(session, "t", [], {}, TransferPolicy())
    assert report.fallback and report.n_imported == 0 and report.sources == []
    assert json.dumps(session.state_dict(), sort_keys=True) == before


def test_divergence_guard_purges_garbage_imports():
    target = _toy_session(seed=5)
    fake = [
        Observation(
            iteration=i,
            config={"index_type": "A", "ka": 2, "s1": 0.4 + 0.05 * i, "s2": False},
            y=np.zeros(2),
            raw={"speed": 4000.0 + 500.0 * i, "recall": 0.99, "search_s": 0.01},
            recommend_time=0.0, eval_time=0.0,
        )
        for i in range(5)
    ]
    target.import_observations(fake, noise_scale=4.0)
    policy = TransferPolicy(check_after=3)
    assert check_divergence(target, policy) is None  # no fresh evidence yet
    target.run(4)
    score = divergence_score(target, policy)
    assert score is not None and score > policy.divergence_threshold
    assert check_divergence(target, policy) is True
    assert not any(o.bootstrap and o.noise_scale != 1.0 for o in target.history)
    assert [o.iteration for o in target.history] == list(range(len(target.history)))


def test_divergence_guard_keeps_consistent_imports():
    source = _toy_session(seed=3).run(6)
    target = _toy_session(seed=6)
    target.import_observations(source.history, noise_scale=2.0)
    policy = TransferPolicy(check_after=3)
    target.run(4)
    score = divergence_score(target, policy)
    assert score is not None and score <= policy.divergence_threshold
    assert check_divergence(target, policy) is False
    assert any(o.bootstrap for o in target.history)  # imports survived


def test_purge_imports_renumbers():
    target = _toy_session(seed=7).run(2)
    source = _toy_session(seed=3).run(4)
    target.import_observations(source.history, noise_scale=2.0)
    n_imported = sum(1 for o in target.history if o.bootstrap)
    assert purge_imports(target) == n_imported
    assert [o.iteration for o in target.history] == list(range(len(target.history)))


# ---------------------------------------------------------------------------
# scheduler + budget + fleet session
# ---------------------------------------------------------------------------
def test_round_robin_scheduler_cycles_and_skips():
    s = FleetScheduler("round_robin")
    order = ["a", "b", "c"]
    assert [s.pick(order, order) for _ in range(4)] == ["a", "b", "c", "a"]
    assert s.pick(order, ["c"]) == "c"
    with pytest.raises(ValueError):
        s.pick(order, [])


def test_gain_per_cost_scheduler_allocates_to_the_winner():
    s = FleetScheduler("gain_per_cost", decay=0.5)
    order = ["a", "b"]
    assert s.pick(order, order) == "a"  # never-run optimism, in order
    s.update("a", hv_gain=1.0, cost_s=1.0)
    assert s.pick(order, order) == "b"  # b still never-run
    s.update("b", hv_gain=10.0, cost_s=1.0)
    assert s.pick(order, order) == "b"  # higher realized gain per second
    for _ in range(4):  # 10 -> 5 -> 2.5 -> 1.25 -> 0.625 < a's 1.0
        s.update("b", hv_gain=0.0, cost_s=100.0)
    assert s.pick(order, order) == "a"  # decayed estimate falls below a's
    state = json.loads(json.dumps(s.state_dict()))
    assert FleetScheduler().load_state_dict(state).state_dict() == s.state_dict()
    with pytest.raises(ValueError):
        FleetScheduler("priority")


def test_fleet_budget_bounds_the_run():
    fleet = FleetSession(FleetBudget(2.5), cost_fn=lambda o: 1.0)
    fleet.add_tenant("a", _toy_session(seed=11), _desc("a"), n_iters=50)
    fleet.run()
    assert fleet.budget.exhausted
    # each round after warm-up costs n_evals * 1.0; the loop stops at the
    # first pick once spent >= total
    assert fleet.budget.spent_s >= 2.5
    assert fleet.tenant("a").session.n_observations < 50


def test_fleet_warm_start_guards_and_ledger():
    fleet = FleetSession(FleetBudget(1e9), transfer_policy=TransferPolicy())
    fleet.add_tenant("src", _toy_session(seed=11), _desc("src"), n_iters=4)
    fleet.run()
    fleet.add_tenant("tgt", _toy_session(seed=12), _desc("tgt", drift=0.12), n_iters=4)
    report = fleet.warm_start("tgt")
    assert not report.fallback and report.n_imported > 0
    with pytest.raises(ValueError, match="already warm-started"):
        fleet.warm_start("tgt")
    fleet.run()
    with pytest.raises(ValueError, match="fresh observations"):
        fleet.warm_start("src")
    led = json.loads(json.dumps(fleet.ledger_dict()))
    assert led["schema"] == FLEET_LEDGER_SCHEMA
    assert set(led["tenants"]) == {"src", "tgt"}
    for block in led["tenants"].values():
        assert {"descriptor", "rounds", "events", "transfer", "session"} <= set(block)
    assert led["tenants"]["tgt"]["transfer"]["n_imported"] == report.n_imported
    assert led["budget"]["spent_s"] == fleet.budget.spent_s


def test_fleet_outcome_hook_lands_in_tenant_events():
    fleet = FleetSession(FleetBudget(1e9))
    fleet.add_tenant("a", _toy_session(seed=11), _desc("a"), n_iters=2)
    hook = fleet.outcome_hook("a")
    hook("promote", {"index_type": "A"}, {"recall": 0.9, "speed": 10.0})
    hook("rollback", {"index_type": "B"}, {"recall": 0.5, "speed": 90.0})
    events = fleet.tenant("a").events
    assert [e["event"] for e in events] == ["promote", "rollback"]
    assert events[0]["raw"]["recall"] == 0.9
    json.dumps(fleet.ledger_dict())  # events serialize strictly


# ---------------------------------------------------------------------------
# property: mid-round checkpoint/resume is bit-identical. Runs under
# hypothesis when installed; otherwise sweeps every cut point directly
# (same cases, deterministic).
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional test dep; pip install -e .[test]
    HAVE_HYPOTHESIS = False

_N_ITERS = 6


def _build_fleet(with_stop_at=None):
    fleet = FleetSession(
        FleetBudget(1e9),
        scheduler=FleetScheduler("round_robin"),
        cost_fn=lambda o: 1.0,
    )
    for i, name in enumerate(("a", "b")):
        callbacks = []
        if with_stop_at is not None:
            def _stop(session, obs, cut=with_stop_at):
                if session.n_observations >= cut:
                    raise StopSession

            callbacks = [_stop]
        fleet.add_tenant(
            name,
            _toy_session(seed=11 + i, callbacks=callbacks),
            _desc(name, drift=0.1 + 0.02 * i),
            n_iters=_N_ITERS,
        )
    return fleet


def _fleet_projection(fleet):
    return {
        "scheduler": fleet.scheduler.state_dict(),
        "spent_s": fleet.budget.spent_s,
        "tenants": {
            n: {
                "rounds": [
                    (r["n_evals"], r["cost_s"], r["hv"], r["hv_gain"])
                    for r in fleet.tenant(n).rounds
                ],
                "history": [
                    (o.config, o.y.tolist(), o.failed, o.bootstrap, o.noise_scale)
                    for o in fleet.session_of(n).tuner.history
                ],
            }
            for n in fleet.tenant_names
        },
    }


def _check_resume_at(cut):
    # the partial fleet's sessions stop mid-drain at `cut` fresh observations,
    # so the checkpoint lands with non-empty per-tenant pending queues
    part = _build_fleet(with_stop_at=cut)
    part.run(max_rounds=3)
    state = json.loads(json.dumps(part.state_dict()))
    assert any(
        part.session_of(n).n_observations < _N_ITERS for n in part.tenant_names
    )  # the checkpoint is genuinely mid-run

    # reference arm: the original fleet simply keeps going to completion
    part.run()
    want = _fleet_projection(part)

    # resume arm: a fresh identically-built fleet restored from the JSON
    # round-tripped checkpoint must reproduce the remaining rounds exactly —
    # scheduler cursor/estimates, budget charges, round ledgers and history
    resumed = _build_fleet(with_stop_at=cut)
    resumed.load_state_dict(state)
    resumed.run()
    assert _fleet_projection(resumed) == want


if HAVE_HYPOTHESIS:

    @settings(max_examples=4, deadline=None)
    @given(cut=st.integers(1, _N_ITERS - 1))
    def test_fleet_resume_mid_round_is_bit_identical(cut):
        _check_resume_at(cut)

else:

    @pytest.mark.parametrize("cut", range(1, _N_ITERS))
    def test_fleet_resume_mid_round_is_bit_identical(cut):
        _check_resume_at(cut)


def test_fleet_restore_rejects_mismatched_tenants():
    fleet = _build_fleet()
    state = fleet.state_dict()
    other = FleetSession(FleetBudget(1e9))
    other.add_tenant("x", _toy_session(seed=1), _desc("x"), n_iters=2)
    with pytest.raises(ValueError, match="do not match"):
        other.load_state_dict(state)
    with pytest.raises(ValueError, match="version"):
        fleet.load_state_dict(dict(state, version=999))


# ---------------------------------------------------------------------------
# doc sync
# ---------------------------------------------------------------------------
def _repo_root():
    return pathlib.Path(__file__).resolve().parents[1]


def test_fleet_doc_feature_table_in_sync():
    doc = (_repo_root() / "docs" / "FLEET.md").read_text()
    begin, end = "<!-- fleet-features:begin -->", "<!-- fleet-features:end -->"
    assert begin in doc and end in doc, "FLEET.md lost the fleet-features markers"
    block = doc.split(begin)[1].split(end)[0].strip()
    assert block == feature_table().strip(), (
        "FLEET.md feature table is stale; regenerate with "
        "python -c \"from repro.fleet import feature_table; print(feature_table())\""
    )


def test_fleet_doc_covers_contract():
    doc = (_repo_root() / "docs" / "FLEET.md").read_text()
    for needle in (
        "WorkloadDescriptor", "DescriptorEmbedding", "TransferPolicy",
        "FleetSession", "warm_start", "gain_per_cost", "encoding_signature",
        "noise_scale", "divergence", "bench_fleet", "state_dict",
    ):
        assert needle in doc, f"FLEET.md lost {needle!r}"


def test_architecture_and_readme_link_fleet():
    arch = (_repo_root() / "docs" / "ARCHITECTURE.md").read_text()
    assert "fleet" in arch and "docs/FLEET.md" in arch
    readme = (_repo_root() / "README.md").read_text()
    assert "docs/FLEET.md" in readme and "bench_fleet" in readme
