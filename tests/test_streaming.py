"""Live VDMS lifecycle, the streaming tuning environment, and drift re-tuning."""
import json

import numpy as np
import pytest

from repro.core import (
    DriftDetector,
    TuningFailure,
    TuningSession,
    VDTuner,
    streaming_sustained,
)
from repro.vdms import (
    LiveVDMS,
    VDMSInstance,
    VDMSTuningEnv,
    exact_topk_masked,
    live_seg_size,
    make_dataset,
    make_space,
    make_trace,
    replay_trace,
)

LIVE_CFG = dict(
    index_type="IVF_FLAT",
    nlist=16,
    nprobe=16,
    segment_max_size=256,
    seal_proportion=0.5,
    graceful_time=0.0,
    search_batch_size=8,
    topk_merge_width=64,
    kmeans_iters=4,
    storage_bf16=False,
)


def _vectors(n, dim=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------
def test_seal_fires_exactly_at_threshold():
    s = live_seg_size(256, 0.5)
    assert s == 128
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024)
    live.insert(_vectors(s - 1))
    assert live.n_sealed == 0 and len(live.tail) == s - 1
    live.insert(_vectors(1, seed=1))
    assert live.n_sealed == 1 and len(live.tail) == 0
    assert live.seal_history == [1]


def test_bulk_insert_seals_multiple_segments():
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024)
    live.bootstrap(_vectors(300))
    assert live.n_sealed == 2 and len(live.tail) == 300 - 2 * 128
    assert live.seal_build_s == 0.0  # bootstrap seals are initial build time
    assert live.build_time > 0.0


def test_capacity_guard():
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=100)
    with pytest.raises(ValueError):
        live.insert(_vectors(101))


def test_tombstoned_ids_never_returned():
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024)
    data = _vectors(200)
    live.insert(data)  # 128 sealed + 72 growing
    victims = [3, 150]  # one sealed, one in the tail
    for v in victims:
        assert live.delete(v)
        assert not live.delete(v)  # second delete is a no-op
    ids, _ = live.search(data[victims], topk=10)
    assert not set(np.asarray(victims).tolist()) & set(ids.ravel().tolist())


def test_compaction_triggers_and_preserves_visible_set():
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024, compact_threshold=0.2)
    live.insert(_vectors(128))  # exactly one sealed segment
    for gid in range(40):  # > 20% of the segment
        live.delete(gid)
    assert live.n_compactions >= 1
    assert set(live.visible_ids().tolist()) == set(range(40, 128))
    # compacted segment still searchable and never returns dead ids
    ids, _ = live.search(_vectors(8, seed=2), topk=10)
    returned = set(ids.ravel().tolist()) - {-1}
    assert returned <= set(range(40, 128))


def test_live_search_exact_with_flat_and_zero_graceful():
    cfg = dict(LIVE_CFG, index_type="FLAT")
    live = LiveVDMS(cfg, dim=16, capacity=1024)
    data = _vectors(300)
    live.insert(data)
    for gid in (5, 17, 200):
        live.delete(gid)
    queries = _vectors(20, seed=9)
    ids, _ = live.search(queries, topk=5)
    dead = np.ones(300, bool)
    dead[live.visible_ids()] = False
    want = exact_topk_masked(data, queries, dead, 5)
    for got_row, want_row in zip(ids, want):
        assert set(got_row.tolist()) == set(want_row.tolist())


def test_incremental_builds_freeze_shared_calibration():
    cfg = dict(LIVE_CFG, index_type="IVF_SQ8")
    live = LiveVDMS(cfg, dim=16, capacity=1024)
    live.insert(_vectors(128))
    scale_first = np.asarray(live.bundle.arrays["scale"]).copy()
    live.insert(_vectors(128, seed=5) * 0.5)  # different dynamic range
    assert live.n_sealed == 2
    np.testing.assert_array_equal(np.asarray(live.bundle.arrays["scale"]), scale_first)


# ---------------------------------------------------------------------------
# streaming environment
# ---------------------------------------------------------------------------
def _streaming_env(n_phases=2, **kw):
    trace = make_trace("glove_like", n_base=500, n_ops=150, seed=1, mix=(0.3, 0.6, 0.1), **kw)
    return VDMSTuningEnv(trace=trace, workload="streaming", mode="analytic", seed=0, n_phases=n_phases)


def test_env_constructor_validation():
    with pytest.raises(ValueError):
        VDMSTuningEnv(workload="static")  # needs a dataset
    with pytest.raises(ValueError):
        VDMSTuningEnv(workload="streaming")  # needs a trace
    with pytest.raises(ValueError):
        VDMSTuningEnv(make_dataset("glove_like", n=256, n_queries=8), workload="bogus")


def test_env_phase_keyed_cache():
    env = _streaming_env(n_phases=2)
    cfg = make_space().default_config("IVF_FLAT")
    r0 = env(cfg)
    assert env.n_evals == 1
    env(cfg)
    assert env.n_evals == 1  # cached within the phase
    env.set_phase(1)
    r1 = env(cfg)
    assert env.n_evals == 2  # the workload moved: genuine re-evaluation
    assert r0 != r1
    with pytest.raises(ValueError):
        env.set_phase(2)


def test_env_streaming_evaluate_batch_dedupes():
    env = _streaming_env(n_phases=1)
    space = make_space()
    a = space.default_config("FLAT")
    b = space.default_config("IVF_FLAT")
    out = env.evaluate_batch([a, b, dict(a)])
    assert env.n_evals == 2
    assert out[0] == out[2]
    assert {"speed", "recall", "mem_gib", "seal_build_s"} <= set(out[1])


def test_static_mode_results_and_cache_keys_unchanged(small_dataset):
    env = VDMSTuningEnv(small_dataset, mode="analytic", seed=0)
    cfg = make_space().default_config("IVF_FLAT")
    got = env(cfg)
    inst = VDMSInstance(small_dataset, cfg, seed=0)
    want = inst.measure(repeats=env.repeats, mode="analytic")
    for key in ("speed", "recall", "mem_gib"):
        assert got[key] == want[key], key  # bit-identical static path
    # static cache keys carry no phase prefix (pre-streaming format)
    (key,) = env.cache
    assert all(isinstance(k, str) and k != "__phase__" for k, _ in key)


# ---------------------------------------------------------------------------
# drift detection + re-tuning
# ---------------------------------------------------------------------------
def test_drift_detector_fires_on_relative_change():
    det = DriftDetector(metrics=("speed", "recall"), rel_threshold=0.2, warmup=2)
    assert not det.observe({"speed": 100.0, "recall": 0.9})
    assert not det.observe({"speed": 110.0, "recall": 0.9})  # still warming up
    assert det.reference == {"speed": 105.0, "recall": 0.9}
    assert not det.observe({"speed": 120.0, "recall": 0.9})  # +14% < 20%
    assert det.observe({"speed": 60.0, "recall": 0.9})  # -43% fires
    assert det.n_fired == 1
    det.reset()
    assert det.reference is None
    assert not det.observe({"speed": 60.0, "recall": 0.9})  # new reference


def test_drift_detector_state_roundtrip():
    det = DriftDetector(rel_threshold=0.1)
    det.observe({"speed": 10.0, "recall": 0.5})
    det.observe({"speed": 20.0, "recall": 0.5})
    state = json.loads(json.dumps(det.state_dict()))
    det2 = DriftDetector().load_state_dict(state)
    assert det2.reference == det.reference
    assert det2.n_fired == det.n_fired == 1
    assert det2.log == det.log


class _FakeBackend:
    """Deterministic cheap objective so session tests avoid real replays."""

    def __init__(self):
        self.n_evals = 0

    def __call__(self, cfg):
        self.n_evals += 1
        rng = np.random.default_rng(abs(hash(cfg["index_type"])) % 2**32)
        return {"speed": 100.0 + 50.0 * rng.random(), "recall": 0.5 + 0.4 * rng.random()}


def _tuned_session(n=9, **kw):
    space = make_space()
    backend = _FakeBackend()
    tuner = VDTuner(space, backend, seed=0, warm_start=True, **kw)
    session = TuningSession(tuner)
    session.run(n)
    return session, tuner, backend


def test_retune_drops_stale_and_keeps_warm_gp():
    session, tuner, _ = _tuned_session(9)
    assert tuner._gp_warm is not None  # warm GP state exists pre-drift
    stale = session.retune()
    assert stale == 9
    assert tuner.history == [] and session.n_observations == 0
    assert tuner._gp_warm is not None  # hyperparameters survive the reset
    assert tuner.abandon.remaining == list(tuner.space.type_names)


def test_retune_keep_stale_demotes_to_bootstrap():
    session, tuner, _ = _tuned_session(9)
    stale = session.retune(keep_stale=True)
    assert stale == 9
    assert len(tuner.history) == 9
    assert all(o.bootstrap for o in tuner.history)
    assert session.n_observations == 0


def test_retune_reanchors_and_tops_up_budget():
    session, tuner, backend = _tuned_session(9)
    anchors = tuner.pareto_configs(max_n=2)
    n_before = backend.n_evals
    session.retune(5, reanchor=anchors)
    assert session.n_observations >= 5
    assert backend.n_evals > n_before
    # the anchors landed first, as fresh observations
    for obs, cfg in zip(tuner.history, anchors):
        assert obs.config == cfg and not obs.bootstrap


def test_probe_drift_counts_backend_failure_as_drift():
    session, tuner, _ = _tuned_session(9)

    class Failing:
        def __call__(self, cfg):
            raise TuningFailure("gone")

    session.backend = Failing()
    det = DriftDetector()
    assert session.probe_drift(det, tuner.best_config())
    assert det.n_fired == 1


def test_best_config_and_pareto_configs():
    session, tuner, _ = _tuned_session(9)
    best = tuner.best_config()
    assert best["index_type"] in tuner.space.type_names
    floor = float(np.median(tuner.Y[:, 1]))
    feas_best = tuner.best_config(rlim=floor)
    got = [o for o in tuner.history if o.config == feas_best]
    assert got and got[0].y[1] >= floor
    front = tuner.pareto_configs(max_n=3)
    assert 1 <= len(front) <= 3


def test_streaming_objective_charges_ingest_overhead():
    spec = streaming_sustained(alpha=1.0)
    raw = {"speed": 1000.0, "recall": 0.9, "n_searches": 100.0, "search_s": 0.1, "seal_build_s": 0.1}
    qps, recall = spec(raw)
    assert qps == pytest.approx(500.0)  # half the search-only throughput
    assert recall == 0.9
    qps0, _ = streaming_sustained(alpha=0.0)(raw)
    assert qps0 == pytest.approx(1000.0)
    static_raw = {"speed": 1234.0, "recall": 0.8}
    assert streaming_sustained()(static_raw) == (1234.0, 0.8)


# ---------------------------------------------------------------------------
# drift detector edge cases (serving control plane probes)
# ---------------------------------------------------------------------------
def test_drift_detector_roundtrip_mid_warmup():
    det = DriftDetector(rel_threshold=0.25, warmup=3)
    det.observe({"speed": 10.0, "recall": 0.5})
    det.observe({"speed": 14.0, "recall": 0.5})
    assert det.reference is None  # still warming up
    state = json.loads(json.dumps(det.state_dict()))
    det2 = DriftDetector().load_state_dict(state)
    assert det2.reference is None and len(det2._ref_buf) == 2
    det2.observe({"speed": 12.0, "recall": 0.5})
    assert det2.reference == {"speed": 12.0, "recall": 0.5}


def test_drift_detector_threshold_boundary_is_strict():
    det = DriftDetector(metrics=("speed",), rel_threshold=0.25, warmup=1)
    det.observe({"speed": 1.0})
    # rel == threshold exactly must NOT fire (strict >)
    assert not det.observe({"speed": 1.25})
    assert det.n_fired == 0
    assert det.observe({"speed": 1.2500001})
    assert det.n_fired == 1


def test_drift_detector_resume_then_probe_bit_identical():
    probes = [
        {"speed": 10.0, "recall": 0.9},
        {"speed": 11.0, "recall": 0.9},
        {"speed": 14.0, "recall": 0.8},
        {"speed": 7.0, "recall": 0.6},
    ]
    a = DriftDetector(rel_threshold=0.2, warmup=2)
    for p in probes:
        a.observe(p)
    b = DriftDetector(rel_threshold=0.2, warmup=2)
    for p in probes[:2]:
        b.observe(p)
    b = DriftDetector().load_state_dict(json.loads(json.dumps(b.state_dict())))
    for p in probes[2:]:
        b.observe(p)
    assert b.log == a.log and b.n_fired == a.n_fired and b.reference == a.reference


# ---------------------------------------------------------------------------
# per-query latency instrumentation + lifecycle stats
# ---------------------------------------------------------------------------
def test_live_search_records_per_query_latencies():
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024)
    live.bootstrap(_vectors(300))
    seen = []
    live.search_hooks.append(lambda nq, lat, elapsed: seen.append((nq, lat.copy(), elapsed)))
    _, elapsed = live.search(_vectors(20, seed=7), topk=5)
    assert live.queries_served == 20
    assert live.last_latencies.shape == (20,)
    # per-query latencies partition the batch elapsed time
    assert float(live.last_latencies.sum()) == pytest.approx(elapsed, rel=1e-6)
    (nq, lat, el), = seen
    assert nq == 20 and el == elapsed
    np.testing.assert_array_equal(lat, live.last_latencies)


def test_live_stats_snapshot_is_structured_and_json_safe():
    live = LiveVDMS(LIVE_CFG, dim=16, capacity=1024)
    live.bootstrap(_vectors(300))
    live.insert(_vectors(10, seed=3))
    live.delete(0)
    live.search(_vectors(4, seed=4), topk=5)
    stats = live.stats()
    assert stats["n_total"] == 310 and stats["n_alive"] == 309
    assert stats["tombstone_fraction"] == pytest.approx(1.0 / 310.0)
    assert stats["n_sealed"] == 2 and stats["n_deletes"] == 1
    assert stats["queries_served"] == 4
    assert stats["tail_size"] == 310 - 2 * 128
    json.dumps(stats)  # plain ints/floats only
    assert all(isinstance(v, (int, float)) for v in stats.values())


def test_replay_trace_reports_latency_percentiles_and_hooks():
    trace = make_trace("glove_like", n_base=400, n_ops=120, seed=3, mix=(0.3, 0.6, 0.1))
    calls = []
    result = replay_trace(
        trace, LIVE_CFG, mode="analytic",
        search_hooks=[lambda nq, lat, elapsed: calls.append(nq)],
    )
    assert 0.0 < result["lat_p50_s"] <= result["lat_p95_s"] <= result["lat_p99_s"]
    assert sum(calls) == trace.n_searches
    assert result["tombstone_fraction"] >= 0.0
