"""Fused-vs-composed search-pipeline equivalence (the CI ``fused-parity`` job).

The engine routes chunks through a family's registered ``fused_search`` hook
when ``set_search_pipeline("fused")`` (the default) — these tests pin the
contract that routing must be INVISIBLE in results: identical result sets
(bitwise-identical under the XLA impl for every case here) across static
instances, clamped static instances, partial-seal plans, live instances with
tombstones, fully-dead segments, and families without a hook (composed
fallback). Adversarial shapes cover sub-block segments, ``k_seg > n``, and
dead padding.
"""
import numpy as np
import pytest

import repro.vdms as V
from repro.vdms import engine
from repro.vdms.ivf_pqr import register as register_ivf_pqr

register_ivf_pqr()

BASE = {
    "segment_max_size": 512, "seal_proportion": 0.75, "graceful_time": 0.2,
    "search_batch_size": 16, "topk_merge_width": 32, "kmeans_iters": 4,
    "storage_bf16": False,
}
FUSED_CONFIGS = {
    "IVF_SQ8": {"nlist": 8, "nprobe": 4},
    "IVF_PQ": {"nlist": 8, "nprobe": 4, "m": 8, "nbits": 4},
    "IVF_PQR": {"nlist": 8, "nprobe": 4, "m": 8, "nbits": 4, "reorder_k": 32},
}
FALLBACK_CONFIGS = {
    "IVF_FLAT": {"nlist": 8, "nprobe": 4},
    "AUTOINDEX": {},
}


@pytest.fixture
def fused_mode():
    prev = V.get_search_pipeline()
    yield
    V.set_search_pipeline(prev)


def _search_both(inst, queries, topk):
    V.set_search_pipeline("composed")
    a = inst.search(queries, topk)
    V.set_search_pipeline("fused")
    b = inst.search(queries, topk)
    return a, b


def _sets_match(a, b):
    return all(set(x[x >= 0]) == set(y[y >= 0]) for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# pipeline mode API
# ---------------------------------------------------------------------------
def test_pipeline_mode_api(fused_mode):
    assert V.get_search_pipeline() in ("fused", "composed")
    V.set_search_pipeline("composed")
    assert V.get_search_pipeline() == "composed"
    V.set_search_pipeline("fused")
    assert V.get_search_pipeline() == "fused"
    with pytest.raises(ValueError, match="unknown search pipeline"):
        V.set_search_pipeline("warp")


def test_fused_hooks_registered_where_expected():
    for fam in FUSED_CONFIGS:
        assert V.get_family(fam).fused_search is not None, fam
    for fam in ("FLAT", "IVF_FLAT", "HNSW", "SCANN", "AUTOINDEX"):
        assert V.get_family(fam).fused_search is None, fam


# ---------------------------------------------------------------------------
# static instances
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fam", sorted(FUSED_CONFIGS))
def test_static_fused_equals_composed(fam, fused_mode):
    # 1450 into 512-slot segments: the 426-vector remainder crosses the
    # seal threshold (0.75 * 512 = 384) -> partial trailing seal, so clamp is
    # disabled and dead (-1) padding is present in the last sealed segment
    ds = V.make_dataset("glove_like", n=1450, dim=64, n_queries=24, k=10, seed=0)
    inst = V.VDMSInstance(ds, dict(BASE, index_type=fam, **FUSED_CONFIGS[fam]), seed=0)
    assert not inst._clamp_ok  # the partial seal pads with -1 gids
    a, b = _search_both(inst, ds.queries, 10)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("fam", sorted(FUSED_CONFIGS))
def test_static_clamped_fused_equals_composed(fam, fused_mode):
    # 1280 = 2 full seals + 256 growing (< seal size) -> clamp active
    ds = V.make_dataset("glove_like", n=1280, dim=64, n_queries=24, k=10, seed=1)
    inst = V.VDMSInstance(ds, dict(BASE, index_type=fam, **FUSED_CONFIGS[fam]), seed=0)
    assert inst._clamp_ok
    a, b = _search_both(inst, ds.queries, 10)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("fam", sorted(FALLBACK_CONFIGS))
def test_fallback_family_mode_invariant(fam, fused_mode):
    """Families without a fused hook must run the identical composed program
    in both modes — the registry fallback the engine guarantees."""
    assert V.get_family(fam).fused_search is None
    ds = V.make_dataset("glove_like", n=1280, dim=64, n_queries=16, k=10, seed=2)
    inst = V.VDMSInstance(ds, dict(BASE, index_type=fam, **FALLBACK_CONFIGS[fam]), seed=0)
    a, b = _search_both(inst, ds.queries, 10)
    assert np.array_equal(a, b)


def test_adversarial_tiny_segment_kseg_gt_n(fused_mode):
    """k_seg (128) > segment size (64) and segments far below one kernel block."""
    ds = V.make_dataset("glove_like", n=200, dim=32, n_queries=8, k=5, seed=3)
    cfg = dict(BASE, segment_max_size=64, topk_merge_width=128,
               index_type="IVF_SQ8", nlist=4, nprobe=2)
    inst = V.VDMSInstance(ds, cfg, seed=0)
    assert inst.k_seg > inst.plan.seg_size
    a, b = _search_both(inst, ds.queries, 5)
    assert np.array_equal(a, b)


def test_fused_topk_wider_than_results(fused_mode):
    """topk larger than every candidate pool: both modes pad with -1."""
    ds = V.make_dataset("glove_like", n=300, dim=32, n_queries=6, k=5, seed=4)
    cfg = dict(BASE, segment_max_size=128, index_type="IVF_PQ",
               nlist=4, nprobe=1, m=4, nbits=4)
    inst = V.VDMSInstance(ds, cfg, seed=0)
    a, b = _search_both(inst, ds.queries, 200)
    assert np.array_equal(a, b)
    assert (a == -1).any()  # padding actually exercised


# ---------------------------------------------------------------------------
# live instances (tombstones, compaction padding, fully-dead segments)
# ---------------------------------------------------------------------------
def _live_pair(fam, deletes, compact_threshold=1.1, seed=5):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((1200, 48)).astype(np.float32)
    data /= np.linalg.norm(data, axis=1, keepdims=True)
    queries = rng.standard_normal((12, 48)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    cfg = dict(BASE, index_type=fam, **FUSED_CONFIGS[fam])
    outs = {}
    for mode in ("composed", "fused"):
        V.set_search_pipeline(mode)
        live = V.LiveVDMS(cfg, dim=48, capacity=2048, seed=0,
                          compact_threshold=compact_threshold)
        live.bootstrap(data)
        for g in deletes:
            live.delete(int(g))
        ids, _ = live.search(queries, 10, mode="analytic")
        outs[mode] = ids
    return outs["composed"], outs["fused"]


@pytest.mark.parametrize("fam", sorted(FUSED_CONFIGS))
def test_live_tombstones_fused_equals_composed(fam, fused_mode):
    a, b = _live_pair(fam, deletes=range(50, 500, 3))
    assert np.array_equal(a, b)


def test_live_fully_dead_segment(fused_mode):
    """Every vector of sealed segment 0 tombstoned (compaction disabled):
    the fused live merge must drop the whole segment exactly like composed."""
    seg = V.live_seg_size(BASE["segment_max_size"], BASE["seal_proportion"])
    a, b = _live_pair("IVF_SQ8", deletes=range(0, seg))
    assert np.array_equal(a, b)
    assert not set(range(seg)) & set(a[a >= 0].tolist())


def test_live_compaction_padding(fused_mode):
    """Deletes past the compact threshold rebuild a segment with -1 padding;
    live fused search never clamps, so the padded slots stay width-consuming
    and the two modes agree."""
    seg = V.live_seg_size(BASE["segment_max_size"], BASE["seal_proportion"])
    a, b = _live_pair("IVF_SQ8", deletes=range(0, seg // 2), compact_threshold=0.3)
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# engine internals: the clamp invariant
# ---------------------------------------------------------------------------
def test_clamp_ok_matches_plan():
    ds_full = V.make_dataset("glove_like", n=1280, dim=32, n_queries=4, k=5, seed=6)
    ds_part = V.make_dataset("glove_like", n=1450, dim=32, n_queries=4, k=5, seed=6)
    cfg = dict(BASE, index_type="IVF_SQ8", nlist=8, nprobe=4)
    full = V.VDMSInstance(ds_full, cfg, seed=0)
    part = V.VDMSInstance(ds_part, cfg, seed=0)
    assert full._clamp_ok
    assert not part._clamp_ok
    assert bool(np.all(part.plan.sealed_valid == part.plan.seg_size)) is False


def test_measure_wall_both_modes(fused_mode):
    """measure(mode='wall') runs under either pipeline and reports identical
    recall (same result sets)."""
    ds = V.make_dataset("glove_like", n=1280, dim=32, n_queries=16, k=5, seed=7)
    cfg = dict(BASE, index_type="IVF_SQ8", nlist=8, nprobe=4)
    inst = V.VDMSInstance(ds, cfg, seed=0)
    V.set_search_pipeline("composed")
    r_c = inst.measure(topk=5, repeats=1, mode="wall")
    V.set_search_pipeline("fused")
    r_f = inst.measure(topk=5, repeats=1, mode="wall")
    assert r_c["recall"] == pytest.approx(r_f["recall"])


# ---------------------------------------------------------------------------
# property-based round-trips (skipped when hypothesis isn't installed)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=150, max_value=900),
        topk=st.integers(min_value=1, max_value=40),
        nprobe=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(min_value=0, max_value=3),
    )
    def test_fused_equals_composed_random_shapes(n, topk, nprobe, seed):
        prev = V.get_search_pipeline()
        try:
            ds = V.make_dataset("glove_like", n=n, dim=32, n_queries=8, k=5, seed=seed)
            cfg = dict(BASE, segment_max_size=256, index_type="IVF_SQ8",
                       nlist=8, nprobe=nprobe)
            inst = V.VDMSInstance(ds, cfg, seed=seed)
            a, b = _search_both(inst, ds.queries, topk)
            assert np.array_equal(a, b)
        finally:
            V.set_search_pipeline(prev)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_equals_composed_random_shapes():
        pass


# ---------------------------------------------------------------------------
# README doc-sync: the generated fused-pipeline table
# ---------------------------------------------------------------------------
def test_readme_fused_table_in_sync():
    import pathlib

    readme = pathlib.Path(__file__).resolve().parents[1] / "README.md"
    text = readme.read_text()
    begin, end = "<!-- fused-table:begin -->", "<!-- fused-table:end -->"
    assert begin in text and end in text
    block = text.split(begin)[1].split(end)[0].strip()
    assert block == V.fused_pipeline_table().strip(), (
        "README fused-pipeline table is stale; regenerate with "
        "python -c \"from repro.vdms import fused_pipeline_table, ivf_pqr; "
        "ivf_pqr.register(); print(fused_pipeline_table())\""
    )


def test_fused_table_marks_hooks():
    table = V.fused_pipeline_table()
    for fam, line in zip(
        [f.name for f in V.registered_families()],
        table.splitlines()[2:],
    ):
        fused = V.get_family(fam).fused_search is not None
        assert ("fused (composed fallback)" in line) == fused, line
        if fused:
            stages = getattr(V.get_family(fam).fused_search, "stages")
            assert stages in line
