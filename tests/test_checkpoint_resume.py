"""Property tests: checkpoint/resume at ANY iteration is bit-identical.

For VDTuner (q=1 and q=4, rlim on/off) and the stateful OpenTuner baseline,
``TuningSession.restore(json.loads(json.dumps(session.state_dict())))`` taken
after an arbitrary hypothesis-chosen number of observations — including
mid-batch for q=4 — must continue exactly like the uninterrupted session:
same configs, same objective values, same failure flags, in the same order.
"""
import json

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import OpenTunerLike, Param, SearchSpace, StopSession, TuningSession, VDTuner

N_ITERS = 8
_FAST = dict(gp_fit_steps=24, n_candidates=48, mc_samples=16)


def _toy_objective(cfg):
    t = cfg["index_type"]
    k = cfg.get("ka", cfg.get("kb", 0.5))
    k = k / 8.0 if t == "A" else k
    sysq = 1.0 - (cfg["s1"] - 0.6) ** 2
    if t == "A":
        return {"speed": 80 * (1 - k) * sysq, "recall": 0.5 + 0.45 * k, "mem_gib": 1.0}
    return {"speed": 50 * (1 - k) * sysq, "recall": 0.6 + 0.39 * k, "mem_gib": 0.5}


def _toy_space():
    return SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 8), default=2)],
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )


def _make_vdtuner(q, rlim):
    return VDTuner(_toy_space(), _toy_objective, seed=11, q=q, rlim=rlim, **_FAST)


# uninterrupted reference trajectories, one per (q, rlim) combo — computed
# once, reused across hypothesis examples
_reference = {}


def _reference_history(q, rlim):
    key = (q, rlim)
    if key not in _reference:
        tuner = _make_vdtuner(q, rlim)
        TuningSession(tuner).run(N_ITERS)
        _reference[key] = tuner.history
    return _reference[key]


def _stop_after(cut):
    def cb(session, obs):
        if session.n_observations >= cut:
            raise StopSession

    return cb


def _assert_same_history(got, want):
    assert [o.config for o in got] == [o.config for o in want]
    assert np.array_equal(np.stack([o.y for o in got]), np.stack([o.y for o in want]))
    assert [o.failed for o in got] == [o.failed for o in want]


@pytest.mark.parametrize("q", [1, 4], ids=["q1", "q4"])
@pytest.mark.parametrize("rlim", [None, 0.85], ids=["ehvi", "cei"])
@settings(max_examples=5, deadline=None)
@given(cut=st.integers(1, N_ITERS - 1))
def test_vdtuner_resume_is_bit_identical(q, rlim, cut):
    want = _reference_history(q, rlim)

    part = _make_vdtuner(q, rlim)
    session = TuningSession(part, callbacks=[_stop_after(cut)]).run(N_ITERS)
    assert session.n_observations == cut  # checkpoint lands exactly at the cut

    state = json.loads(json.dumps(session.state_dict()))
    fresh = _make_vdtuner(q, rlim)
    TuningSession.restore(state, fresh).run(N_ITERS)
    _assert_same_history(fresh.history, want)


_opentuner_reference = {}


@settings(max_examples=10, deadline=None)
@given(cut=st.integers(1, 11))
def test_opentuner_resume_is_bit_identical(cut):
    if "history" not in _opentuner_reference:
        tuner = OpenTunerLike(_toy_space(), _toy_objective, seed=13)
        TuningSession(tuner).run(12)
        _opentuner_reference["history"] = tuner.history
        _opentuner_reference["credits"] = list(tuner._credits)
    want = _opentuner_reference["history"]

    part = OpenTunerLike(_toy_space(), _toy_objective, seed=13)
    session = TuningSession(part, callbacks=[_stop_after(cut)]).run(12)
    state = json.loads(json.dumps(session.state_dict()))
    fresh = OpenTunerLike(_toy_space(), _toy_objective, seed=13)
    TuningSession.restore(state, fresh).run(12)
    _assert_same_history(fresh.history, want)
    assert fresh._credits == _opentuner_reference["credits"]  # bandit state too
