"""End-to-end integration: VDTuner over the real VDMS env beats the default
configuration; serving driver produces tokens; roofline table builds from
artifacts; the serve-tuning space has the paper's non-fixed structure."""
import json
from pathlib import Path

import pytest

from repro.core import VDTuner
from repro.vdms import VDMSTuningEnv, make_dataset, make_space


@pytest.mark.slow
def test_vdtuner_improves_over_default_on_real_vdms():
    ds = make_dataset("glove_like", n=2048, n_queries=64, k=10, seed=3)
    env = VDMSTuningEnv(ds, mode="analytic", seed=3)
    space = make_space()
    default = env(space.default_config("AUTOINDEX"))
    tuner = VDTuner(space, env, seed=3, abandon_window=8).run(20)
    # there must be a sampled config that dominates or matches default recall
    # with better speed
    better = [
        o for o in tuner.history
        if not o.failed and o.y[1] >= default["recall"] - 1e-9 and o.y[0] > default["speed"]
    ]
    assert better, "tuning should find configs dominating the default"


def test_serve_driver_generates_tokens():
    from repro.launch.serve import run

    out = run("glm4-9b", smoke=True, batch=2, prompt_len=16, gen=4)
    assert out["tokens"].shape == (2, 5)
    assert out["decode_tokens_per_s"] > 0


def test_serving_space_is_nonfixed():
    from repro.tuning.serve_tuner import make_serving_space

    space = make_serving_space()
    assert len(space.type_names) == 3  # remat strategies = "index types"
    cfg = space.default_config("remat_nothing")
    assert "flash_bq" in cfg and "seq_parallel" in cfg


def test_roofline_table_builds_from_artifacts(tmp_path):
    rec = {
        "arch": "glm4-9b",
        "shape": "train_4k",
        "mesh": "16x16",
        "chips": 256,
        "hlo_flops": 1e18,
        "hlo_bytes": 1e15,
        "coll_bytes": 1e13,
        "coll_breakdown": {},
        "coll_counts": {},
        "model_flops": 5e17,
        "peak_mem_per_dev": 2**30,
        "compute_s": 0.02,
        "memory_s": 0.005,
        "collective_s": 0.001,
        "bottleneck": "compute",
        "useful_ratio": 0.5,
        "roofline_fraction": 0.5,
        "memory_analysis": {"temp_size_in_bytes": 2**30},
    }
    (tmp_path / "glm4-9b_train_4k_256.json").write_text(json.dumps(rec))
    (tmp_path / "x_long_500k_256.json").write_text(
        json.dumps({"arch": "x", "shape": "long_500k", "skipped": "full attention"})
    )
    from benchmarks.roofline_table import markdown_table

    table = markdown_table(str(tmp_path))
    assert "glm4-9b" in table and "compute" in table and "SKIP" in table


def test_dryrun_artifacts_if_present():
    d = Path("experiments/dryrun")
    if not d.exists() or not list(d.glob("*_256.json")):
        pytest.skip("no dry-run artifacts in this checkout")
    for f in d.glob("*_256.json"):
        r = json.loads(f.read_text())
        if "skipped" in r:
            continue
        assert r["hlo_flops"] > 0, f.name
        assert r["memory_analysis"]["temp_size_in_bytes"] > 0, f.name
        assert r["bottleneck"] in ("compute", "memory", "collective")
