"""Batch-parallel tuning engine regression tests (no optional deps).

Covers the three contracts the batch refactor must keep:
* ``VDTuner(q=1)`` reproduces the pre-batch single-point trajectory exactly
  (a verbatim copy of the seed ``step()`` is the reference implementation),
* ``q > 1`` proposes q distinct configurations of the polled index type,
* ``VDMSTuningEnv.evaluate_batch`` returns the same per-config results as
  sequential ``__call__`` (vectorized same-shape groups included).
"""
import time

import numpy as np
import pytest

from repro.core import (
    GP,
    Param,
    SearchSpace,
    TuningFailure,
    VDTuner,
    cei,
    ehvi_mc,
    non_dominated_mask,
    npi_normalize,
    qehvi_sequential_greedy,
)
from repro.vdms import VDMSTuningEnv, make_space


def _toy_objective(cfg):
    t = cfg["index_type"]
    k = cfg.get("ka", cfg.get("kb", 0.5))
    k = k / 8.0 if t == "A" else k
    sysq = 1.0 - (cfg["s1"] - 0.6) ** 2
    if t == "A":
        return {"speed": 80 * (1 - k) * sysq, "recall": 0.5 + 0.45 * k, "mem_gib": 1.0}
    return {"speed": 50 * (1 - k) * sysq, "recall": 0.6 + 0.39 * k, "mem_gib": 0.5}


def _toy_space():
    return SearchSpace(
        index_types={
            "A": [Param("ka", "grid", choices=(1, 2, 4, 8), default=2)],
            "B": [Param("kb", "float", 0.0, 1.0, default=0.5)],
        },
        system_params=[
            Param("s1", "float", 0.0, 1.0, default=0.5),
            Param("s2", "cat", choices=(False, True), default=False),
        ],
    )


def _legacy_step(self):
    """Verbatim copy of the pre-batch VDTuner.step() (seed commit) used as the
    reference implementation for the q=1 bit-identity regression test."""
    t0 = time.perf_counter()
    Y, types = self.Y, self.types
    self.abandon.step(Y, types)
    mode = "balanced" if self.rlim is None else "max"
    Yn, bases = npi_normalize(Y, types, mode=mode)
    gp = GP(seed=int(self.rng.integers(2**31)), fit_steps=self.gp_fit_steps)
    gp.fit(self.X_enc, Yn)
    t = self._next_poll_type()
    cands = self._candidates(t)
    Xc = np.stack([self.space.encode(c) for c in cands])
    mean, std = gp.predict(Xc)
    if self.rlim is None:
        front = Yn[non_dominated_mask(Yn)]
        ref = np.array([0.5, 0.5])
        acq = ehvi_mc(mean, std, front, ref, self.rng, self.mc_samples)
    else:
        base_t = bases.get(t, np.array([1.0, 1.0]))
        rlim_n = self.rlim / base_t[1]
        feas = Y[:, 1] >= self.rlim
        if feas.any():
            spd_n = np.array([o.y[0] / bases[o.index_type][0] for o, f in zip(self.history, feas) if f])
            best_feasible = float(spd_n.max())
        else:
            best_feasible = float("-inf")
        acq = cei(mean[:, 0], std[:, 0], mean[:, 1], std[:, 1], best_feasible, rlim_n)
    cfg = cands[int(np.argmax(acq))]
    return self._evaluate(cfg, recommend_time=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# q=1 regression: identical trajectory to the pre-batch tuner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rlim", [None, 0.85], ids=["ehvi", "cei"])
def test_q1_trajectory_identical_to_legacy(rlim):
    ref = VDTuner(_toy_space(), _toy_objective, seed=5, abandon_window=6, rlim=rlim)
    ref._initial_sampling()
    for _ in range(8):
        _legacy_step(ref)
    new = VDTuner(_toy_space(), _toy_objective, seed=5, abandon_window=6, rlim=rlim, q=1).run(len(ref.history))
    assert [o.config for o in new.history] == [o.config for o in ref.history]
    assert np.array_equal(new.Y, ref.Y)


# ---------------------------------------------------------------------------
# q>1 semantics
# ---------------------------------------------------------------------------
def test_batch_step_returns_q_distinct_configs_of_polled_type():
    tuner = VDTuner(_toy_space(), _toy_objective, seed=1, q=3)
    tuner._initial_sampling()
    batch = tuner.step()
    assert len(batch) == 3
    assert len({o.index_type for o in batch}) == 1  # one polled type per round
    assert len({tuple(sorted(o.config.items())) for o in batch}) == 3
    # recorded in proposal order with contiguous iteration numbers
    assert [o.iteration for o in batch] == [2, 3, 4]


def test_batch_run_respects_iteration_budget():
    for n in (9, 10, 11):
        tuner = VDTuner(_toy_space(), _toy_objective, seed=2, q=4).run(n)
        assert len(tuner.history) == n


def test_batch_failures_get_worst_so_far_feedback():
    calls = {"n": 0}

    def flaky(cfg):
        calls["n"] += 1
        if calls["n"] % 4 == 0:
            raise TuningFailure("boom")
        return _toy_objective(cfg)

    tuner = VDTuner(_toy_space(), flaky, seed=3, q=3).run(14)
    failed = [o for o in tuner.history if o.failed]
    assert failed
    for o in failed:
        prior = np.stack([p.y for p in tuner.history[: o.iteration] if not p.failed])
        assert (o.y <= prior.min(axis=0) + 1e-12).all()


def test_qehvi_greedy_spreads_picks():
    rng = np.random.default_rng(0)
    X = rng.random((30, 3))
    Y = np.stack([X[:, 0], 1.0 - X[:, 0] + 0.2 * X[:, 1]], axis=1)
    gp = GP(seed=0).fit(X, Y)
    Xc = rng.random((64, 3))
    front = Y[non_dominated_mask(Y)]
    idx = qehvi_sequential_greedy(gp, Xc, front, np.zeros(2), rng, q=4)
    assert len(idx) == 4 and len(set(idx)) == 4


# ---------------------------------------------------------------------------
# GP fantasy conditioning
# ---------------------------------------------------------------------------
def test_gp_condition_on_shrinks_uncertainty_and_keeps_original():
    rng = np.random.default_rng(0)
    X = rng.random((20, 3))
    Y = np.stack([X[:, 0] * 2, -X[:, 1]], axis=1)
    gp = GP(seed=0).fit(X, Y)
    xq = rng.random((5, 3))
    mean0, std0 = gp.predict(xq)
    gp2 = gp.condition_on(xq[:1], mean0[:1])
    mean1, std1 = gp2.predict(xq)
    assert (std1[0] < std0[0]).all()  # fantasy collapses uncertainty there
    assert np.allclose(mean1[0], mean0[0], atol=1e-2)
    _, std_again = gp.predict(xq)  # original posterior untouched
    assert np.allclose(std_again, std0)


def test_gp_condition_on_grows_past_pad_boundary():
    rng = np.random.default_rng(1)
    X = rng.random((32, 2))  # exactly one pad block: forces re-padding
    Y = X[:, :1] * 3.0
    gp = GP(seed=0).fit(X, Y)
    xn = rng.random((3, 2))
    mean0, std0 = gp.predict(xn)
    gp2 = gp.condition_on(xn, mean0)  # Kriging-believer fantasies
    mean1, std1 = gp2.predict(xn)
    assert mean1.shape == (3, 1) and std1.shape == (3, 1)
    assert np.allclose(mean1, mean0, atol=0.05)  # fantasy is self-consistent
    assert (std1 < std0).all()


# ---------------------------------------------------------------------------
# vectorized evaluation pool
# ---------------------------------------------------------------------------
def test_evaluate_batch_matches_sequential(small_dataset):
    space = make_space()
    base = space.default_config("IVF_FLAT")
    cfgs = [
        dict(base),                       # homogeneous same-shape group...
        dict(base, kmeans_iters=16),      # ...same shapes, different centroids
        dict(base, nprobe=16),            # different static -> separate program
        space.default_config("HNSW"),     # heterogeneous leftovers
        space.default_config("FLAT"),
        dict(base),                       # in-batch duplicate (deduped)
    ]
    env_b = VDMSTuningEnv(small_dataset, mode="analytic", seed=0)
    out_b = env_b.evaluate_batch(cfgs)
    env_s = VDMSTuningEnv(small_dataset, mode="analytic", seed=0)
    out_s = [env_s(c) for c in cfgs]
    for i, (b, s) in enumerate(zip(out_b, out_s)):
        assert not isinstance(b, Exception), (i, b)
        for k in ("speed", "recall", "mem_gib"):
            assert b[k] == s[k], (i, k)
    assert env_b.n_evals == env_s.n_evals  # duplicate deduped in both paths


def test_evaluate_batch_reports_failures_per_config(small_dataset):
    space = make_space()
    env = VDMSTuningEnv(small_dataset, mode="analytic", seed=0, build_timeout=0.0)
    out = env.evaluate_batch([space.default_config("FLAT"), space.default_config("HNSW")])
    assert all(isinstance(o, TuningFailure) for o in out)


def test_evaluate_batch_serves_cache_hits(small_dataset):
    space = make_space()
    env = VDMSTuningEnv(small_dataset, mode="analytic", seed=0)
    cfg = space.default_config("IVF_FLAT")
    first = env(cfg)
    n = env.n_evals
    again = env.evaluate_batch([cfg, cfg])
    assert env.n_evals == n
    assert again[0]["speed"] == first["speed"] == again[1]["speed"]
