"""Training substrate: loss decreases, checkpoint atomicity/resume/corruption
recovery, data-pipeline determinism and shard invariance, optimizer math,
gradient compression, fault-tolerance monitors."""
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.ft.monitor import PreemptionHandler, StragglerMonitor
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, compress_grads


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray(np.ones(4, np.float32) * 5)}
    state = adamw.init_state(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(120):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3
    assert int(state.step) == 120


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    huge = {"w": jnp.asarray([1e6, 0.0, 0.0], jnp.float32)}
    new, _ = adamw.apply_updates(params, huge, state, cfg)
    assert float(jnp.abs(new["w"]).max()) < 10.0


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_bf16_compression_close():
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)}
    out, _ = compress_grads(g, None, CompressionConfig("bf16"))
    assert float(jnp.abs(out["a"] - g["a"]).max()) < 0.01


def test_int8_ef_error_feedback_is_lossless_over_time():
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(128), jnp.float32)
    ef = {"a": jnp.zeros(128, jnp.float32)}
    cfg = CompressionConfig("int8_ef")
    acc = jnp.zeros(128, jnp.float32)
    for _ in range(50):
        out, ef = compress_grads({"a": g_true}, ef, cfg)
        acc = acc + out["a"]
    # accumulated compressed gradient converges to accumulated true gradient
    rel = float(jnp.abs(acc / 50 - g_true).max() / jnp.abs(g_true).max())
    assert rel < 0.02


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=7)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    assert np.array_equal(p1.batch_at(13), p2.batch_at(13))
    assert not np.array_equal(p1.batch_at(13), p1.batch_at(14))


def test_pipeline_shard_invariance():
    """Concatenating 2 shards' rows == the single-shard global batch."""
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=7)
    whole = TokenPipeline(cfg).batch_at(5)
    s0 = TokenPipeline(cfg, shard=0, num_shards=2).batch_at(5)
    s1 = TokenPipeline(cfg, shard=1, num_shards=2).batch_at(5)
    assert np.array_equal(np.concatenate([s0, s1]), whole)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"loss": float(step)})
    assert mgr.all_steps() == [2, 3]  # keep=2 garbage-collects step 1
    restored, extra = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert extra["loss"] == 3.0


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones(1000)}
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x + 1, tree))
    # corrupt the newest checkpoint's arrays
    d = mgr._step_dir(2)
    (d / "arrays.npz").write_bytes(b"garbage")
    restored, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4, dtype=np.float32))


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, {"w": jnp.zeros(8)})
    names = [p.name for p in tmp_path.iterdir()]
    assert all(not n.startswith(".tmp") for n in names)


# ---------------------------------------------------------------------------
# fault tolerance monitors
# ---------------------------------------------------------------------------
def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=16, threshold=2.0, patience=2)
    for i in range(12):
        mon.record(i, 0.1)
    s = mon.record(12, 0.5)
    assert s.flagged
    assert not mon.should_replace
    mon.record(13, 0.5)
    assert mon.should_replace


def test_preemption_handler_sets_flag():
    with PreemptionHandler(signals=(signal.SIGUSR1,)) as h:
        assert not h.preempted
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert h.preempted


# ---------------------------------------------------------------------------
# end-to-end trainer
# ---------------------------------------------------------------------------
def test_train_loss_decreases_and_resumes(tmp_path):
    from repro.launch.train import TrainConfig, run

    tcfg = TrainConfig(
        arch="mamba2-130m",
        smoke=True,
        steps=25,
        seq_len=64,
        global_batch=4,
        ckpt_dir=str(tmp_path),
        ckpt_every=10,
        async_ckpt=False,
        log_every=100,
    )
    out = run(tcfg)
    assert out["final_loss"] < out["losses"][0] - 0.05
    # resume continues from the saved step
    tcfg2 = TrainConfig(
        arch="mamba2-130m",
        smoke=True,
        steps=30,
        seq_len=64,
        global_batch=4,
        ckpt_dir=str(tmp_path),
        ckpt_every=10,
        async_ckpt=False,
        log_every=100,
    )
    out2 = run(tcfg2)
    assert len(out2["losses"]) == 5  # only the remaining 5 steps ran


def test_microbatched_grads_match_full_batch():
    from repro.launch.train import TrainConfig, make_train_step
    from repro.configs import get_arch, reduce
    from repro.models import build_model
    from repro.optim.compression import CompressionConfig

    cfg = reduce(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (4, 33)), jnp.int32)}
    opt_cfg = adamw.AdamWConfig()
    comp = CompressionConfig("none")
    full = make_train_step(model, TrainConfig(arch="x", global_batch=4, steps=1), opt_cfg, comp)
    micro = make_train_step(model, TrainConfig(arch="x", global_batch=4, microbatch=2, steps=1), opt_cfg, comp)
    st_ = adamw.init_state(params)
    l1, p1, _, _ = full(params, st_, batch, None)
    l2, p2, _, _ = micro(params, st_, batch, None)
    assert float(jnp.abs(l1 - l2)) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
