"""Optional GPipe-style pipeline parallelism (off by default — TP x FSDP
already fits every assigned model, see DESIGN.md §5).

``pipeline_apply`` runs a layer stack split into S stages over M microbatches
with the classic (S + M - 1)-slot schedule, expressed as a single lax.scan
whose carry holds one in-flight activation per stage. On a mesh with a
"stage" axis the per-stage params shard over it and the activation hand-off
between slots lowers to a collective-permute; on one device it degrades to
exactly the sequential computation (same math — tested against the plain
scan).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def pipeline_apply(
    layers_params: Any,
    x: jnp.ndarray,
    block_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    n_stages: int,
    n_micro: int,
):
    """Apply a stacked layer pytree (leading dim = n_layers) to x (b, ...).

    The layer stack is split into `n_stages` contiguous stages; the batch is
    split into `n_micro` microbatches. Returns the same value as sequentially
    scanning the layers.
    """
    n_layers = jax.tree.leaves(layers_params)[0].shape[0]
    assert n_layers % n_stages == 0, "layers must divide stages"
    per_stage = n_layers // n_stages
    b = x.shape[0]
    assert b % n_micro == 0, "batch must divide microbatches"
    mb = b // n_micro
    micro = x.reshape(n_micro, mb, *x.shape[1:])

    # stage s holds layers [s*per_stage, (s+1)*per_stage)
    stage_params = jax.tree.map(
        lambda a: a.reshape(n_stages, per_stage, *a.shape[1:]), layers_params
    )

    def run_stage(s_params, h):
        def body(h, layer_p):
            return block_fn(layer_p, h), None

        h, _ = jax.lax.scan(body, h, s_params)
        return h

    n_slots = n_stages + n_micro - 1
    buf = jnp.zeros((n_stages, mb, *x.shape[1:]), x.dtype)  # in-flight per stage
    out = jnp.zeros_like(micro)

    def slot(carry, t):
        buf, out = carry
        # shift: stage s consumes what stage s-1 produced last slot; stage 0
        # consumes microbatch t. (On a "stage" mesh axis this shift is a
        # collective-permute.)
        incoming = jnp.where(
            (t >= 0) & (t < n_micro),
            jax.lax.dynamic_index_in_dim(micro, jnp.clip(t, 0, n_micro - 1), 0, False),
            jnp.zeros_like(buf[0]),
        )
        shifted = jnp.concatenate([incoming[None], buf[:-1]], axis=0)
        # every stage computes on its current slot input
        new_buf = jax.vmap(run_stage)(stage_params, shifted)
        # stage S-1's output for microbatch (t - S + 1) is final
        done_idx = t - (n_stages - 1)
        out = jax.lax.cond(
            (done_idx >= 0) & (done_idx < n_micro),
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, new_buf[-1], jnp.clip(done_idx, 0, n_micro - 1), 0
            ),
            lambda o: o,
            out,
        )
        return (new_buf, out), None

    (buf, out), _ = jax.lax.scan(slot, (buf, out), jnp.arange(n_slots))
    return out.reshape(b, *x.shape[1:])
