"""Logical-axis sharding rules (DP / TP / FSDP / EP / SP + pod axis).

Model code annotates activations with *logical* axis names via ``constrain``;
parameters get specs inferred from their path + shape. The rules map logical
axes onto mesh axes with divisibility fallbacks (a dimension that does not
divide by its mesh axes is left replicated — recorded for the roofline notes).

Mapping (mesh axes ("pod", "data", "model") — "pod" optional):
  batch      -> (pod, data)     activations' batch dim (DP)
  seq        -> None            (train/prefill activations; SP uses "data")
  seq_sp     -> (data,)         sequence-parallel prefill for long contexts
  kv_seq     -> (model,)        decode KV cache sequence (flash-decoding style)
  embed      -> None            activation feature dim
  heads/ff/vocab/experts/ssm_inner -> (model,)   tensor parallel
  kv_heads   -> (model,) if divisible else None
  fsdp       -> (pod, data)     parameter & optimizer-state sharding

Serving-side (vector search) placement rides the same machinery: a sealed
VDMS segment stack carries its segment dim as the logical "segments" axis,
mapped onto the dedicated "shard" mesh axis (:func:`make_shard_mesh`). The
contract segment placement relies on is in :func:`segment_placement`:
contiguous blocks, dead padding at the tail, so flattening shards in axis
order preserves the unsharded segment order — the property that keeps the
sharded top-k merge tie-breaks identical to single-device results.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class ShardingRules:
    def __init__(self, mesh: Optional[Mesh], fsdp: bool = True, seq_parallel: bool = True):
        self.mesh = mesh
        self.fsdp = fsdp
        self.seq_parallel = seq_parallel
        self.fallbacks: list[str] = []
        axes = tuple(mesh.axis_names) if mesh is not None else ()
        dp = tuple(a for a in ("pod", "data") if a in axes)
        tp = ("model",) if "model" in axes else ()
        self.logical: Dict[str, Tuple[str, ...]] = {
            "batch": dp,
            "seq": (),
            "seq_sp": ("data",) if "data" in axes else (),
            # Megatron-style sequence parallelism for the residual stream
            # between blocks: the lax.scan saved carry shards its seq dim over
            # the model axis (16x smaller activation checkpoints; interior
            # compute re-gathers as needed).
            "seq_act": tp if seq_parallel else (),
            "kv_seq": tp,
            "embed": (),
            "heads": tp,
            "kv_heads": tp,
            "head_dim": (),
            "ff": tp,
            "vocab": tp,
            "experts": tp,
            "ssm_inner": tp,
            "ssm_state": (),
            "fsdp": dp if fsdp else (),
            "layers": (),
            "replicated": (),
            # serving: sealed-segment stacks shard their leading segment dim
            # over the dedicated "shard" axis (see make_shard_mesh); meshes
            # without that axis leave segment arrays replicated
            "segments": ("shard",) if "shard" in axes else (),
        }

    # ------------------------------------------------------------------
    def _axis_size(self, names: Tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in names], initial=1))

    def spec(self, axes: Sequence[Optional[str]], shape: Optional[Tuple[int, ...]] = None) -> P:
        """PartitionSpec from logical axis names, with divisibility fallback.
        A mesh axis may appear once per spec: later logical axes that resolve
        to an already-used mesh axis fall back to replicated (e.g. "experts"
        wins over "ff" when both map to the model axis and E divides it)."""
        parts = []
        used: set = set()
        for i, name in enumerate(axes):
            mesh_axes = tuple(
                a for a in self.logical.get(name or "replicated", ()) if a not in used
            )
            if not mesh_axes:
                parts.append(None)
                continue
            if shape is not None:
                sz = self._axis_size(mesh_axes)
                if shape[i] % sz != 0:
                    # fallback: try a prefix of the mesh axes, else replicate
                    for cut in range(len(mesh_axes) - 1, 0, -1):
                        if shape[i] % self._axis_size(mesh_axes[:cut]) == 0:
                            mesh_axes = mesh_axes[:cut]
                            break
                    else:
                        self.fallbacks.append(f"{name}:dim{shape[i]}")
                        parts.append(None)
                        continue
                    if shape[i] % self._axis_size(mesh_axes) != 0:
                        self.fallbacks.append(f"{name}:dim{shape[i]}")
                        parts.append(None)
                        continue
            used.update(mesh_axes)
            parts.append(mesh_axes[0] if len(mesh_axes) == 1 else mesh_axes)
        return P(*parts)

    def sharding(self, axes: Sequence[Optional[str]], shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))


# ---------------------------------------------------------------------------
# serving-side segment placement (sharded vector search)
# ---------------------------------------------------------------------------
def make_shard_mesh(n_shards: Optional[int] = None) -> Mesh:
    """1-D serving mesh over the "shard" axis. ``n_shards`` defaults to every
    available device; asking for more shards than devices is an error (use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to emulate a
    larger mesh on one host)."""
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards must be >= 1, got {n}")
    if n > len(devices):
        raise ValueError(
            f"n_shards={n} exceeds the {len(devices)} available devices; "
            "emulate more with XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return Mesh(np.asarray(devices[:n]), ("shard",))


def segment_placement(n_seg: int, n_shards: int) -> Tuple[int, int, np.ndarray]:
    """THE placement contract for sealed segments on a shard mesh.

    Returns ``(per_shard, n_pad, shard_of)``: segments are laid out in
    contiguous blocks of ``per_shard = ceil(n_seg / n_shards)`` — segment
    ``z`` lives on shard ``z // per_shard`` (``shard_of[z]``) — and the
    stack is padded with ``n_pad`` dead segments (gids all -1) so every
    shard holds exactly ``per_shard``. Contiguous blocks + tail padding mean
    concatenating shard-local stacks in shard order reproduces the original
    segment order, which is what keeps the merge tree's lowest-flat-index
    tie-break identical to the unsharded merge.
    """
    if n_seg < 0 or n_shards < 1:
        raise ValueError(f"invalid placement: n_seg={n_seg}, n_shards={n_shards}")
    per_shard = max(1, -(-n_seg // n_shards))
    n_pad = per_shard * n_shards - n_seg
    shard_of = np.arange(n_seg, dtype=np.int32) // per_shard
    return per_shard, n_pad, shard_of


# ---------------------------------------------------------------------------
# context for activation constraints inside model code
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active rules (no-op otherwise)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(axes, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# parameter axis inference by path convention
# ---------------------------------------------------------------------------
_PARAM_AXES_BY_NAME: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": ("vocab", "fsdp"),
    "head": ("fsdp", "vocab"),
    # attention
    "wq": ("fsdp", "heads"),
    "wk": ("fsdp", "kv_heads"),
    "wv": ("fsdp", "kv_heads"),
    "wo": ("heads", "fsdp"),
    "bq": ("heads",),
    "bk": ("kv_heads",),
    "bv": ("kv_heads",),
    # mlp
    "w_gate": ("fsdp", "ff"),
    "w_up": ("fsdp", "ff"),
    "w_down": ("ff", "fsdp"),
    # moe (leading expert dim)
    "we_gate": ("experts", "fsdp", "ff"),
    "we_up": ("experts", "fsdp", "ff"),
    "we_down": ("experts", "ff", "fsdp"),
    "router": ("fsdp", "experts"),
    # ssm
    "in_proj": ("fsdp", "ssm_inner"),
    "out_proj": ("ssm_inner", "fsdp"),
    "conv_w": (None, "ssm_inner"),
    "a_log": (None,),
    "ssm_d": (None,),
    "dt_bias": (None,),
    # norms / misc
    "scale": (None,),
    "bias": (None,),
}


def param_axes_for(path: Tuple[str, ...], shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    name = path[-1]
    axes = _PARAM_AXES_BY_NAME.get(name)
    if axes is None:
        axes = (None,) * len(shape)
    # layer-stacked params carry a leading "layers" dim
    if len(shape) == len(axes) + 1:
        axes = ("layers",) + axes
    elif len(shape) != len(axes):
        axes = (None,) * len(shape)
    return axes


def params_sharding(params_shape: Any, rules: ShardingRules) -> Any:
    """Pytree of NamedShardings matching a params(-shape) pytree."""

    def leaf(path, x):
        keys = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        return rules.sharding(param_axes_for(keys, tuple(x.shape)), tuple(x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)
