"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

cost_analysis() provides FLOPs and bytes. Collective bytes are NOT in
cost_analysis — we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\]{},\s]*?)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _result_bytes(line: str, op_name: str) -> int:
    """Sum result-shape sizes: HLO lines read `%name = TYPE op(...)`, so the
    result type(s) sit between '=' and the op mnemonic (tuples included)."""
    rhs = line.split("=", 1)[1] if "=" in line else line
    idx = rhs.find(f" {op_name}")
    seg = rhs[:idx] if idx >= 0 else rhs
    total = 0
    for dtype, dims in _SHAPE_RE.findall(seg):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind collective bytes (result-shape sizes of collective ops)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        out[kind] = out.get(kind, 0) + _result_bytes(line, kind)
    return out


def count_collectives(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m:
            out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out


@dataclasses.dataclass
class Roofline:
    """All quantities are GLOBAL (per-device HLO costs x chips): the compiled
    SPMD module is the per-device program, so cost_analysis() and the HLO text
    report per-device work; callers multiply by `chips` before construction."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    coll_counts: Dict[str, int]
    model_flops: float
    peak_mem_per_dev: float

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=lambda k: terms[k])

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline the useful work achieves:
        model_flops-time / max(term)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        dominant = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / dominant if dominant > 0 else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown, "coll_counts": self.coll_counts,
            "model_flops": self.model_flops,
            "peak_mem_per_dev": self.peak_mem_per_dev,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    compiled,
    lowered_text: Optional[str],
    model_flops: float,
) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text() if lowered_text is None else lowered_text
    cb = collective_bytes(text)
    cc = count_collectives(text)
    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byt,
        coll_bytes=float(sum(cb.values())), coll_breakdown=cb, coll_counts=cc,
        model_flops=model_flops, peak_mem_per_dev=peak,
    )
