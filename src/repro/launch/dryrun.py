import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove memory fits, and extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above executes before any
other import so jax sees 512 host devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs.base import SHAPES, get_arch, input_specs, list_archs, shape_applicable  # noqa: E402
from ..distributed.sharding import ShardingRules, params_sharding, use_rules  # noqa: E402
from ..launch import hlo_analysis  # noqa: E402
from ..launch.mesh import make_production_mesh  # noqa: E402
from ..models import build_model  # noqa: E402
from ..optim import adamw  # noqa: E402


# ---------------------------------------------------------------------------
def _cache_axes(path, shape):
    name = path[-1]
    rank = len(shape)
    if name in ("k", "v", "ck", "cv"):  # (L, b, s, kv, hd)
        return (None, "batch", "kv_seq", None, None)[:rank]
    if name == "h":  # (L, b, nh, hp, ns)
        return (None, "batch", "heads", None, None)[:rank]
    if name == "conv":  # (L, b, w, conv_dim)
        return (None, "batch", None, "ssm_inner")[:rank]
    return (None,) * rank


def cache_sharding(cache_specs, rules: ShardingRules):
    def leaf(path, x):
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        return rules.sharding(_cache_axes(keys, tuple(x.shape)), tuple(x.shape))

    return jax.tree_util.tree_map_with_path(leaf, cache_specs)


def batch_sharding(batch_specs, rules: ShardingRules):
    def leaf(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return rules.sharding(axes, tuple(x.shape))

    return jax.tree.map(leaf, batch_specs)


# ---------------------------------------------------------------------------
def model_flops_for(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    if cfg.family == "encdec":
        # encoder params see b*s source frames; decoder params see b*tgt
        d, ff = cfg.d_model, cfg.d_ff
        attn = (
            d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd
            + cfg.n_heads * cfg.hd * d
        )
        enc_params = cfg.enc_layers * (attn + 3 * d * ff)
        dec_params = cfg.n_layers * (2 * attn + 3 * d * ff) + 2 * cfg.vocab_padded * d
        b, s = shape.global_batch, shape.seq_len
        tgt = min(cfg.dec_target_len, max(s // 32, 16))
        if shape.kind == "decode":
            return mult * dec_params * b
        return mult * (enc_params * b * s + dec_params * b * tgt)
    if shape.kind == "decode":
        return mult * n_active * shape.global_batch  # one token per sequence
    return mult * n_active * shape.global_batch * shape.seq_len


def _with_depth(cfg, units: int):
    """Reduced-depth copy with fully unrolled scans (for exact cost counting).
    `units` is layers for most families, groups for the hybrid."""
    import dataclasses as dc

    if cfg.family == "hybrid":
        return dc.replace(cfg, n_layers=units * cfg.attn_every, scan_unroll=True)
    if cfg.family == "encdec":
        return dc.replace(cfg, n_layers=units, enc_layers=units, scan_unroll=True)
    return dc.replace(cfg, n_layers=units, scan_unroll=True)


def _depth_units(cfg) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.family == "hybrid" else cfg.n_layers


def _compile_step(cfg, shape, mesh, rules, remat):
    """Lower+compile the step for `cfg` on `mesh`; returns (lowered, compiled)."""
    model = build_model(cfg, remat=remat)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = params_sharding(params_shapes, rules)
    specs = input_specs(cfg, shape)
    b_shard = batch_sharding(specs, rules)
    mesh_obj = mesh

    with use_rules(rules):
        if shape.kind == "train":
            opt_shapes = adamw.state_specs(params_shapes)
            o_shard = adamw.AdamWState(
                step=NamedSharding(mesh_obj, P()),
                m=params_sharding(opt_shapes.m, rules),
                v=params_sharding(opt_shapes.v, rules),
            )
            opt_cfg = adamw.AdamWConfig()

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                new_params, new_opt = adamw.apply_updates(params, grads, opt_state, opt_cfg)
                return loss, new_params, new_opt

            fn = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(NamedSharding(mesh_obj, P()), p_shard, o_shard),
            )
            lowered = fn.lower(params_shapes, opt_shapes, specs)
        elif shape.kind == "prefill":
            fn = jax.jit(model.prefill, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(params_shapes, specs)
        else:  # decode
            cache_specs = model.init_cache(shape.global_batch, shape.seq_len, as_specs=True)
            c_shard = cache_sharding(cache_specs, rules)
            tok_spec = specs["tokens"]
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            logits_shard = rules.sharding(
                ("batch", "vocab"), (shape.global_batch, cfg.vocab_padded)
            )
            fn = jax.jit(
                model.decode,
                in_shardings=(
                    p_shard,
                    c_shard,
                    rules.sharding(("batch",), tuple(tok_spec.shape)),
                    NamedSharding(mesh_obj, P()),
                ),
                out_shardings=(logits_shard, c_shard),
            )
            lowered = fn.lower(params_shapes, cache_specs, tok_spec, pos_spec)
        compiled = lowered.compile()
    return lowered, compiled


def _costs_of(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    text = compiled.as_text()
    cb = hlo_analysis.collective_bytes(text)
    cc = hlo_analysis.count_collectives(text)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": cb,
        "coll_counts": cc,
    }


def measure_costs(cfg, shape, mesh, remat: str, units=(1, 2)) -> dict:
    """Exact HLO costs via two reduced-depth fully-unrolled compiles, linearly
    extrapolated to full depth (XLA cost analysis counts while bodies once, so
    the production scanned program cannot be measured directly)."""
    u1, u2 = units
    rules = ShardingRules(mesh)
    c1 = _costs_of(_compile_step(_with_depth(cfg, u1), shape, mesh, rules, remat)[1])
    c2 = _costs_of(_compile_step(_with_depth(cfg, u2), shape, mesh, rules, remat)[1])
    full = _depth_units(cfg)

    def extrap(a, b):
        per = (b - a) / (u2 - u1)
        return max(a + (full - u1) * per, 0.0)

    out = {
        "flops": extrap(c1["flops"], c2["flops"]),
        "bytes": extrap(c1["bytes"], c2["bytes"]),
    }
    kinds = set(c1["coll_bytes"]) | set(c2["coll_bytes"])
    out["coll_bytes"] = {
        k: int(extrap(c1["coll_bytes"].get(k, 0), c2["coll_bytes"].get(k, 0)))
        for k in kinds
    }
    kinds = set(c1["coll_counts"]) | set(c2["coll_counts"])
    out["coll_counts"] = {
        k: int(extrap(c1["coll_counts"].get(k, 0), c2["coll_counts"].get(k, 0)))
        for k in kinds
    }
    return out


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool, remat: str = "dots",
               skip_costs: bool = False):
    """Lower + compile one (arch, shape, mesh) cell. Returns (Roofline, meta)."""
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rules = ShardingRules(mesh)

    # 1) PRODUCTION compile: full depth, scanned — proves sharding coherence
    #    and per-device memory; this is deliverable (e).
    t0 = time.perf_counter()
    lowered, compiled = _compile_step(cfg, shape, mesh, rules, remat)
    prod_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    meta = {
        "prod_compile_s": prod_s,
        "fallbacks": sorted(set(rules.fallbacks)),
        "memory_analysis": {
            k: int(getattr(mem, k, 0))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
        },
    }

    # 2) COST measurement: reduced-depth unrolled compiles, extrapolated.
    if skip_costs:
        costs = _costs_of(compiled)  # lower bound (loop bodies counted once)
        meta["costs_exact"] = False
    else:
        t1 = time.perf_counter()
        costs = measure_costs(cfg, shape, mesh, remat)
        meta["cost_compile_s"] = time.perf_counter() - t1
        meta["costs_exact"] = True

    # cost_analysis / HLO text describe the PER-DEVICE program: scale to global
    roof = hlo_analysis.Roofline(
        arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=costs["flops"] * chips, hlo_bytes=costs["bytes"] * chips,
        coll_bytes=float(sum(costs["coll_bytes"].values())) * chips,
        coll_breakdown={k: int(v * chips) for k, v in costs["coll_bytes"].items()},
        coll_counts=costs["coll_counts"],
        model_flops=model_flops_for(cfg, shape),
        peak_mem_per_dev=float(meta["memory_analysis"]["temp_size_in_bytes"]),
    )
    return roof, meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-costs", action="store_true",
                    help="skip the reduced-depth cost compiles (faster)")
    args = ap.parse_args(argv)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'512' if mp else '256'}"
                t0 = time.perf_counter()
                try:
                    roof, meta = lower_cell(
                        arch, shape, mp, remat=args.remat, skip_costs=args.skip_costs
                    )
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
                    continue
                if roof is None:
                    print(f"[SKIP] {tag}: {meta['skipped']}", flush=True)
                    record = {"arch": arch, "shape": shape, "skipped": meta["skipped"]}
                else:
                    record = {**roof.to_dict(), **meta}
                    dom = roof.bottleneck
                    print(
                        f"[OK] {tag}: compute={roof.compute_s*1e3:.2f}ms "
                        f"memory={roof.memory_s*1e3:.2f}ms coll={roof.collective_s*1e3:.2f}ms "
                        f"bound={dom} useful={roof.useful_ratio:.2f} "
                        f"frac={roof.roofline_fraction:.3f} "
                        f"temp/dev={meta['memory_analysis']['temp_size_in_bytes']/2**30:.2f}GiB "
                        f"(prod {meta['prod_compile_s']:.0f}s costs "
                        f"{meta.get('cost_compile_s', 0):.0f}s)",
                        flush=True,
                    )
                record["wall_s"] = time.perf_counter() - t0
                (out_dir / f"{tag}.json").write_text(json.dumps(record, indent=1))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
