"""Batched serving driver: prefill + decode with a KV/SSM cache.

Serves a (reduced or full) architecture with batched requests; reports
prefill latency and decode throughput. This is the serve-side end-to-end
example and the harness behind the decode benchmarks.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_arch, reduce as reduce_cfg
from ..distributed.sharding import ShardingRules, use_rules
from ..models import build_model


def run(arch: str, smoke: bool, batch: int, prompt_len: int, gen: int,
        seed: int = 0, mesh=None, greedy: bool = True) -> dict:
    cfg = reduce_cfg(get_arch(arch)) if smoke else get_arch(arch)
    model = build_model(cfg)
    rules = ShardingRules(mesh)
    rng = np.random.default_rng(seed)

    with use_rules(rules):
        params = model.init(jax.random.PRNGKey(seed))
        if cfg.family == "encdec":
            batch_inputs = {
                "src_embeds": jnp.asarray(
                    rng.standard_normal((batch, prompt_len, cfg.d_model)),
                    jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32,
                ),
                "tgt_tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (batch, max(4, gen // 2))), jnp.int32
                ),
            }
            start_pos = batch_inputs["tgt_tokens"].shape[1]
        else:
            batch_inputs = {
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32
                )
            }
            start_pos = prompt_len

        decode_fn = jax.jit(model.decode)
        prefill_fn = jax.jit(model.prefill)

        t0 = time.perf_counter()
        logits, cache = prefill_fn(params, batch_inputs)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens = [np.asarray(tokens)]
        t0 = time.perf_counter()
        for i in range(gen):
            pos = jnp.asarray(start_pos + i, jnp.int32)
            logits, cache = decode_fn(params, cache, tokens, pos)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(np.asarray(tokens))
        jax.block_until_ready(logits)
        decode_s = time.perf_counter() - t0

    toks_per_s = batch * gen / max(decode_s, 1e-9)
    return {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "decode_tokens_per_s": toks_per_s,
        "tokens": np.stack(out_tokens, axis=1),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)
    out = run(args.arch, args.smoke, args.batch, args.prompt_len, args.gen)
    print(
        f"[serve] {args.arch} prefill={out['prefill_s']*1e3:.0f}ms "
        f"decode={out['decode_tokens_per_s']:.1f} tok/s "
        f"(batch={args.batch}, gen={args.gen})"
    )


if __name__ == "__main__":
    main()
