"""End-to-end training driver: real data pipeline, AdamW, checkpointing with
auto-resume, preemption handling, straggler monitoring, optional gradient
compression — runs a ~100M model on this host and the assigned architectures
on the production mesh unchanged (the mesh/sharding layer is the only knob).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager
from ..configs.base import get_arch, reduce as reduce_cfg
from ..data.pipeline import DataConfig, TokenPipeline
from ..distributed.sharding import ShardingRules, params_sharding, use_rules
from ..ft.monitor import PreemptionHandler, StragglerMonitor
from ..models import build_model
from ..optim import adamw
from ..optim.compression import CompressionConfig, compress_grads


@dataclasses.dataclass
class TrainConfig:
    arch: str
    smoke: bool = True
    steps: int = 200
    seq_len: int = 128
    global_batch: int = 8
    microbatch: Optional[int] = None  # gradient accumulation
    lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    async_ckpt: bool = True
    seed: int = 0
    remat: str = "dots"
    compression: str = "none"  # none | bf16 | int8_ef
    log_every: int = 10


def lr_schedule(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup, 1), 1.0)
    prog = jnp.clip(
        (step.astype(jnp.float32) - cfg.warmup) / max(cfg.steps - cfg.warmup, 1), 0.0, 1.0
    )
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * (0.1 + 0.9 * cosine)


def make_train_step(model, tcfg: TrainConfig, opt_cfg: adamw.AdamWConfig,
                    comp: CompressionConfig):
    nmicro = 1
    if tcfg.microbatch:
        assert tcfg.global_batch % tcfg.microbatch == 0
        nmicro = tcfg.global_batch // tcfg.microbatch

    def grads_of(params, batch):
        if nmicro == 1:
            return jax.value_and_grad(model.loss)(params, batch)
        tokens = batch["tokens"].reshape(nmicro, tcfg.microbatch, -1)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(model.loss)(params, {"tokens": mb})
            return (loss_acc + l, jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), tokens)
        scale = 1.0 / nmicro
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    @jax.jit
    def train_step(params, opt_state, batch, ef_state):
        loss, grads = grads_of(params, batch)
        grads, ef_state = compress_grads(grads, ef_state, comp)
        params, opt_state = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr_scale=lr_schedule(tcfg, opt_state.step)
        )
        return loss, params, opt_state, ef_state

    return train_step


def run(tcfg: TrainConfig, mesh=None) -> dict:
    arch = get_arch(tcfg.arch)
    cfg = reduce_cfg(arch) if tcfg.smoke else arch
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for the token-LM trainer")
    model = build_model(cfg, remat=tcfg.remat)
    rules = ShardingRules(mesh)
    opt_cfg = adamw.AdamWConfig(lr=tcfg.lr)
    comp = CompressionConfig(kind=tcfg.compression)

    pipeline = TokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=tcfg.seq_len, global_batch=tcfg.global_batch,
                   seed=tcfg.seed)
    )
    ckpt = CheckpointManager(tcfg.ckpt_dir) if tcfg.ckpt_dir else None

    with use_rules(rules):
        params = model.init(jax.random.PRNGKey(tcfg.seed))
        opt_state = adamw.init_state(params)
        ef_state = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if comp.kind == "int8_ef" else None
        start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            # elastic auto-resume: shardings recomputed for the CURRENT mesh
            like = {"params": params, "opt": opt_state}
            shardings = (
                {"params": params_sharding(params, rules),
                 "opt": adamw.AdamWState(step=None, m=params_sharding(opt_state.m, rules),
                                         v=params_sharding(opt_state.v, rules))}
                if mesh is not None else None
            )
            restored, _ = ckpt.restore(like, shardings=shardings)
            params, opt_state = restored["params"], restored["opt"]
            start_step = ckpt.latest_step()
            print(f"[train] resumed from step {start_step}")

        train_step = make_train_step(model, tcfg, opt_cfg, comp)
        monitor = StragglerMonitor()
        losses = []
        with PreemptionHandler() as pre:
            for step in range(start_step, tcfg.steps):
                t0 = time.perf_counter()
                batch = {"tokens": jnp.asarray(pipeline.batch_at(step))}
                loss, params, opt_state, ef_state = train_step(
                    params, opt_state, batch, ef_state
                )
                loss = float(loss)
                losses.append(loss)
                stat = monitor.record(step, time.perf_counter() - t0)
                if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                    print(
                        f"[train] step={step} loss={loss:.4f} "
                        f"dt={stat.seconds*1e3:.0f}ms"
                        + (" STRAGGLER" if stat.flagged else ""),
                        flush=True,
                    )
                if ckpt is not None and (step + 1) % tcfg.ckpt_every == 0:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state},
                              extra={"loss": loss}, blocking=not tcfg.async_ckpt)
                if pre.preempted:
                    print("[train] preemption requested -> final checkpoint")
                    break
        if ckpt is not None and losses:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"loss": losses[-1]}, blocking=True)
            ckpt.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else float("nan"),
            "median_step_s": monitor.median_step()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none")
    ap.add_argument("--remat", default="dots")
    args = ap.parse_args(argv)
    tcfg = TrainConfig(
        arch=args.arch, smoke=args.smoke, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, microbatch=args.microbatch, lr=args.lr,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        compression=args.compression, remat=args.remat,
    )
    out = run(tcfg)
    print(f"[train] done: first={out['losses'][0]:.4f} final={out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
