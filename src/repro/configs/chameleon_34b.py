"""chameleon-34b [vlm] — early-fusion decoder-only; VQ image tokens live in
the unified vocab so the modality frontend stub is just token ids
[arXiv:2405.09818]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, rope_theta=1e4,
))
