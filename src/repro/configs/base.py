"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four input-shape
suites are ``ShapeConfig``s. ``reduce()`` produces the small-family variant
used by CPU smoke tests; ``input_specs()`` produces ShapeDtypeStruct stand-ins
for the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None  # sliding-window attention (Mixtral)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # hybrid (Zamba2): one shared attention block applied every k SSM layers
    attn_every: int = 0
    # enc-dec (Seamless backbone): n_layers = decoder layers
    enc_layers: int = 0
    dec_target_len: int = 1024  # max decoder length for enc-dec shapes
    # numerics
    param_dtype: str = "bfloat16"
    # analysis: fully unroll lax.scan loops so HLO cost_analysis counts every
    # iteration (XLA counts while-loop bodies once); used by the roofline path
    scan_unroll: bool = False
    # technique applicability / notes (DESIGN.md §6)
    subquadratic: bool = False  # can run long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_padded, self.n_layers
        n = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe"):
            attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd + self.n_heads * self.hd * d
            mlp = 3 * d * ff
            if self.family == "moe":
                mlp = mlp * self.n_experts + d * self.n_experts
            n += L * (attn + mlp)
        elif self.family == "ssm":
            di, ns, hh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * ns + hh) + self.ssm_conv * (di + 2 * ns) + di * d + hh * 2
            n += L * per
        elif self.family == "hybrid":
            di, ns, hh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * ns + hh) + self.ssm_conv * (di + 2 * ns) + di * d + hh * 2
            n += L * per
            # one shared attention+mlp block (input is concat[x, residual] -> 2d)
            n += 2 * d * self.n_heads * self.hd + 2 * 2 * d * self.n_kv_heads * self.hd + self.n_heads * self.hd * d
            n += 3 * d * ff if ff else 0
        elif self.family == "encdec":
            attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd + self.n_heads * self.hd * d
            mlp = 3 * d * ff
            n += self.enc_layers * (attn + mlp)  # encoder
            n += L * (2 * attn + mlp)  # decoder has self + cross attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_padded, self.n_layers
        n = v * d * 2
        attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd + self.n_heads * self.hd * d
        n += L * (attn + 3 * d * ff * self.top_k + d * self.n_experts)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_ARCH_MODULES = (
    "deepseek_67b", "internlm2_20b", "glm4_9b", "qwen2_5_32b", "mamba2_130m",
    "mixtral_8x7b", "mixtral_8x22b", "seamless_m4t_large_v2", "zamba2_2_7b",
    "chameleon_34b",
)

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        list_archs()
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    return tuple(sorted(_REGISTRY))


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs (DESIGN.md §6 skip rules)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention architecture: no sub-quadratic path at 512k"
    return True, ""


def reduce(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests."""
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = 4 if cfg.n_heads >= 4 else cfg.n_heads
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv if cfg.n_kv_heads != cfg.n_heads else heads,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        window=min(cfg.window, 64) if cfg.window else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=16,
        attn_every=2 if cfg.attn_every else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        dec_target_len=32,
        param_dtype="float32",
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for a given shape suite, as ShapeDtypeStructs.

    [audio]/[vlm] modality frontends are stubs: for the enc-dec backbone the
    spec supplies precomputed frame embeddings; Chameleon's VQ image tokens
    are ordinary ids inside its unified vocab.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    sds = jax.ShapeDtypeStruct
    if cfg.family == "encdec":
        tgt = min(cfg.dec_target_len, max(s // 32, 16))
        if shape.kind == "train":
            return {
                "src_embeds": sds((b, s, cfg.d_model), act),
                "tgt_tokens": sds((b, tgt + 1), i32),
            }
        if shape.kind == "prefill":
            return {
                "src_embeds": sds((b, s, cfg.d_model), act),
                "tgt_tokens": sds((b, tgt), i32),
            }
        return {  # decode: one decoder step; cross-KV over s source frames
            "tokens": sds((b,), i32),
            "pos": sds((b,), i32),
        }
    if shape.kind == "train":
        return {"tokens": sds((b, s + 1), i32)}
    if shape.kind == "prefill":
        return {"tokens": sds((b, s), i32)}
    return {"tokens": sds((b,), i32), "pos": sds((b,), i32)}
