"""seamless-m4t-large-v2 [audio] — enc-dec backbone [arXiv:2308.11596; hf].

Modality frontend (speech feature extractor) is a STUB: input_specs supplies
precomputed frame embeddings (b, frames, d_model)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, enc_layers=24, dec_target_len=1024,
))
