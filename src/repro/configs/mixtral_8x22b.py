"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, rope_theta=1e6, window=4096, n_experts=8, top_k=2,
    subquadratic=True,
    notes="SWA ring KV cache (window=4096) makes long_500k decode O(window)",
))
