"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, rope_theta=1e6, window=4096, n_experts=8, top_k=2,
    subquadratic=True,  # sliding window bounds the KV cache
    notes="SWA ring KV cache (window=4096) makes long_500k decode O(window)",
))
