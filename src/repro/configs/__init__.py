"""Assigned architecture configs + shape suites."""
from .base import (
    SHAPES, ArchConfig, ShapeConfig, get_arch, input_specs, list_archs,
    reduce, register, shape_applicable,
)

__all__ = [
    "SHAPES", "ArchConfig", "ShapeConfig", "get_arch", "input_specs",
    "list_archs", "reduce", "register", "shape_applicable",
]
