"""Shared model components: RMSNorm, RoPE, GQA attention (train / prefill /
decode-with-cache), SwiGLU MLP, embeddings, cross-entropy.

Pure-JAX functional style: params are nested dicts of arrays; layer stacks
carry a leading ``n_layers`` dim and are scanned. Activation sharding is
annotated through ``distributed.sharding.constrain`` with logical axis names.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import constrain
from ..kernels import ops


def act_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., s, h, dh); positions (..., s) int."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., s, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (.., s, 1, half)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ArchConfig, d_in: Optional[int] = None) -> Dict[str, Any]:
    d = d_in or cfg.d_model
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = act_dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, kv * hd), dt),
        "wv": dense_init(ks[2], (d, kv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _project_qkv(p, x, cfg: ArchConfig, positions, use_rope: bool = True):
    b, s, _ = x.shape
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    return q, k, v


def attention(
    p: Dict[str, Any],
    x: jnp.ndarray,
    cfg: ArchConfig,
    *,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). kv_override = cross-attention."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions, use_rope)
    if kv_override is not None:
        k, v = kv_override
    out = ops.flash_attention(q, k, v, causal=causal, window=cfg.window)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    out = out @ p["wo"]
    return constrain(out, "batch", None, None)


def attention_prefill(
    p, x, cfg: ArchConfig, cache_len: int
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Prefill: returns output and a KV cache of length cache_len (>= s)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = ops.flash_attention(q, k, v, causal=True, window=cfg.window)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"]
    ck = jnp.zeros((b, cache_len, cfg.n_kv_heads, cfg.hd), k.dtype)
    cv = jnp.zeros_like(ck)
    if cfg.window is not None and cache_len <= cfg.window:
        take = min(s, cache_len)
        ck = jax.lax.dynamic_update_slice(ck, k[:, -take:], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v[:, -take:], (0, 0, 0, 0))
    else:
        take = min(s, cache_len)
        ck = jax.lax.dynamic_update_slice(ck, k[:, :take], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v[:, :take], (0, 0, 0, 0))
    cache = {"k": constrain(ck, "batch", "kv_seq", None, None),
             "v": constrain(cv, "batch", "kv_seq", None, None)}
    return constrain(out, "batch", None, None), cache


def attention_decode(
    p,
    x: jnp.ndarray,  # (b, d) single-token hidden
    cache: Dict[str, jnp.ndarray],
    cfg: ArchConfig,
    pos: jnp.ndarray,  # scalar current position
    *,
    update_cache: bool = True,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step against a (possibly ring-buffered SWA) KV cache.

    The cache seq dim is sharded over the model axis (flash-decoding layout);
    softmax over the sharded axis lowers to an all-reduce of (max, sum).
    """
    b, d = x.shape
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ck, cv = cache["k"], cache["v"]
    s_cache = ck.shape[1]
    q = (x @ p["wq"])
    k_new = (x @ p["wk"])
    v_new = (x @ p["wv"])
    if cfg.qkv_bias:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    q = q.reshape(b, 1, h, hd)
    k_new = k_new.reshape(b, 1, kv, hd)
    v_new = v_new.reshape(b, 1, kv, hd)
    posb = jnp.broadcast_to(pos[None], (b, 1))
    if use_rope:
        q = rope(q, posb, cfg.rope_theta)
        k_new = rope(k_new, posb, cfg.rope_theta)
    slot = pos % s_cache if cfg.window is not None else jnp.minimum(pos, s_cache - 1)
    if update_cache:
        ck = jax.lax.dynamic_update_slice(ck, k_new, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_new, (0, slot, 0, 0))
    ck = constrain(ck, "batch", "kv_seq", None, None)
    cv = constrain(cv, "batch", "kv_seq", None, None)
    # grouped-head attention over the cache
    g = h // kv
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32)
    kf = ck.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, kf) / (hd**0.5)  # (b, kv, g, S)
    # valid positions: ring buffer is fully valid once pos >= s_cache
    idx = jnp.arange(s_cache)
    valid = jnp.where(pos >= s_cache, jnp.ones_like(idx, bool), idx <= pos)
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv.astype(jnp.float32))
    out = out.reshape(b, h * hd).astype(x.dtype) @ p["wo"]
    return constrain(out, "batch", None), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = act_dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), dt),
        "w_up": dense_init(ks[1], (d, ff), dt),
        "w_down": dense_init(ks[2], (ff, d), dt),
    }


def mlp(p, x):
    mid = (None,) * (x.ndim - 2)
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, "batch", *mid, "ff")
    return constrain(h @ p["w_down"], "batch", *mid, None)


# ---------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ArchConfig) -> Dict[str, Any]:
    dt = act_dtype(cfg)
    ks = jax.random.split(key, 2)
    p = {"embed": dense_init(ks[0], (cfg.vocab_padded, cfg.d_model), dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_padded), dt)
    return p


def embed(p, tokens, cfg: ArchConfig):
    x = p["embed"][tokens]
    return constrain(x, "batch", None, None) if x.ndim == 3 else constrain(x, "batch", None)


def lm_logits(p, x, cfg: ArchConfig):
    w = p["head"] if not cfg.tie_embeddings else p["embed"].T
    logits = x @ w
    return constrain(logits, "batch", None, "vocab") if logits.ndim == 3 else constrain(
        logits, "batch", "vocab"
    )


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE; logits (..., V) any float dtype, reductions in f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    true = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


def lm_loss(params, x: jnp.ndarray, labels: jnp.ndarray, cfg: ArchConfig,
            chunk_tokens: int = 8192) -> jnp.ndarray:
    """Token-chunked LM cross-entropy: the (tokens, vocab) logits tensor is
    only ever materialized one chunk at a time (forward AND backward — the
    chunk body is checkpointed so the backward recomputes its logits). This
    keeps the loss region O(chunk * vocab/TP) instead of O(seq * vocab/TP).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    lt = labels.reshape(t)
    n_chunks = max(1, t // chunk_tokens)
    while t % n_chunks:
        n_chunks -= 1
    xc = xt.reshape(n_chunks, t // n_chunks, d)
    lc = lt.reshape(n_chunks, t // n_chunks)

    @jax.checkpoint
    def chunk_loss(xi, li):
        logits = lm_logits(params, xi, cfg)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        true = jnp.take_along_axis(lf, li[:, None], axis=1)[:, 0]
        return jnp.sum(lse - true)

    def body(acc, xs):
        xi, li = xs
        return acc + chunk_loss(xi, li), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / t
