"""Mixtral-style MoE transformer: GQA + sliding-window attention, and a
top-2-of-8 expert SwiGLU MLP with capacity-based dropless-ish dispatch.

TPU-native dispatch: tokens are routed by sorting the (token, slot) pairs by
expert id and packing them into a fixed (E, capacity) buffer — the expert
computation is then a dense batched einsum on the MXU; gather/scatter are the
only data movements. When ``n_experts`` divides the model axis, the rules map
the expert dim onto it (true EP); otherwise experts are replicated and the ff
dim is tensor-parallel (TP-within-expert, the standard fallback).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm
from .transformer import REMAT_POLICIES, cache_len_for


# ---------------------------------------------------------------------------
def init_moe_mlp(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cm.act_dtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": cm.dense_init(ks[0], (d, e), dt),
        "we_gate": cm.dense_init(ks[1], (e, d, ff), dt),
        "we_up": cm.dense_init(ks[2], (e, d, ff), dt),
        "we_down": cm.dense_init(ks[3], (e, ff, d), dt),
    }


def moe_mlp(p, x: jnp.ndarray, cfg: ArchConfig, groups: int = 32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., d). Returns (output, aux_load_balance_loss).

    Grouped (shard-local) dispatch: tokens are reshaped to (G, T/G) with G on
    the data mesh axes, and each group routes into its OWN (E, capacity)
    buffer. All gathers/scatters are then *batched* ops over a sharded leading
    dim — shard-local under GSPMD, no data-dependent cross-shard indexing —
    and the expert einsum is a clean (G, E, cap, d) x (E, d, f) contraction
    (EP over the model axis when E divides it, TP over f otherwise).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)  # (T, d)
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    g = min(groups, t)
    while t % g:
        g -= 1
    tl = t // g
    cap = max(int(math.ceil(cfg.capacity_factor * tl * k / e)), 1)
    cap = min(cap, tl * k)

    xg = cm.constrain(xt.reshape(g, tl, d), "batch", None, None)
    logits = (xg @ p["router"]).astype(jnp.float32)  # (G, TL, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (G, TL, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style), computed globally
    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # pack (token, slot) pairs into per-group per-expert buffers of size cap
    eid = topi.reshape(g, tl * k)  # (G, TLk)
    w = topw.reshape(g, tl * k).astype(xt.dtype)
    order = jnp.argsort(eid, axis=1)  # stable within group
    sorted_eid = jnp.take_along_axis(eid, order, axis=1)
    seg_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(
        sorted_eid
    )  # (G, E)
    rank = jnp.arange(tl * k)[None, :] - jnp.take_along_axis(seg_start, sorted_eid, axis=1)
    keep = rank < cap
    dest = jnp.where(keep, sorted_eid * cap + rank, e * cap)  # (G, TLk)
    gidx = jnp.arange(g)[:, None]
    buf_tok = jnp.zeros((g, e * cap + 1), jnp.int32).at[gidx, dest].set(
        (order // k).astype(jnp.int32)
    )[:, : e * cap]
    buf_valid = jnp.zeros((g, e * cap + 1), bool).at[gidx, dest].set(keep)[:, : e * cap]
    w_sorted = jnp.take_along_axis(w, order, axis=1)
    buf_w = jnp.zeros((g, e * cap + 1), xt.dtype).at[gidx, dest].set(
        jnp.where(keep, w_sorted, 0)
    )[:, : e * cap]

    # batched (shard-local) gather -> (G, E, cap, d)
    xe = jnp.take_along_axis(xg, buf_tok[..., None], axis=1)
    xe = xe * buf_valid[..., None]
    xe = cm.constrain(xe.reshape(g, e, cap, d), "batch", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["we_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["we_up"]
    )
    h = cm.constrain(h, "batch", "experts", None, "ff")
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"])  # (G, E, cap, d)
    ye = cm.constrain(ye, "batch", "experts", None, None).reshape(g, e * cap, d)
    ye = ye * buf_w[..., None]

    # batched (shard-local) scatter-add back to token order
    out = jnp.zeros_like(xg).at[gidx[..., None], buf_tok[..., None], jnp.arange(d)[None, None, :]].add(
        jnp.where(buf_valid[..., None], ye, 0)
    )
    return out.reshape(orig_shape), aux


# ---------------------------------------------------------------------------
def init_params(key: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    l = cfg.n_layers
    ks = jax.random.split(key, 4)

    def stacked(initializer, rng):
        return jax.vmap(initializer)(jax.random.split(rng, l))

    layers = {
        "attn": stacked(lambda k: cm.init_attention(k, cfg), ks[0]),
        "moe": stacked(lambda k: init_moe_mlp(k, cfg), ks[1]),
        "attn_norm": {"scale": jnp.ones((l, cfg.d_model), cm.act_dtype(cfg))},
        "mlp_norm": {"scale": jnp.ones((l, cfg.d_model), cm.act_dtype(cfg))},
    }
    p = {"layers": layers, "final_norm": {"scale": jnp.ones((cfg.d_model,), cm.act_dtype(cfg))}}
    p.update(cm.init_embed(ks[2], cfg))
    return p


def _block(layer_p, carry, cfg: ArchConfig):
    x, aux = carry
    h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
    x = x + cm.attention(layer_p["attn"], h, cfg, causal=True)
    h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
    y, a = moe_mlp(layer_p["moe"], h, cfg)
    return cm.constrain(x + y, "batch", "seq_act", None), aux + a


def forward(params, tokens, cfg: ArchConfig, remat: str = "dots"):
    x = cm.embed(params, tokens, cfg)
    body = _block
    if remat != "everything":
        body = jax.checkpoint(
            _block, policy=REMAT_POLICIES[remat], static_argnums=(2,), prevent_cse=True
        )

    def scan_fn(carry, layer_p):
        return body(layer_p, carry, cfg), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), params["layers"], unroll=cfg.scan_unroll)
    return cm.rms_norm(x, params["final_norm"]["scale"]), aux / cfg.n_layers


def loss_fn(params, batch, cfg: ArchConfig, remat: str = "dots", aux_weight: float = 0.01):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x, aux = forward(params, inp, cfg, remat=remat)
    return cm.lm_loss(params, x, labels, cfg) + aux_weight * aux


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, as_specs: bool = False):
    s = cache_len_for(cfg, seq_len)
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.hd)
    dt = cm.act_dtype(cfg)
    if as_specs:
        return {"k": jax.ShapeDtypeStruct(shape, dt), "v": jax.ShapeDtypeStruct(shape, dt)}
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def prefill(params, batch, cfg: ArchConfig, cache_len: Optional[int] = None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    cl = cache_len or cache_len_for(cfg, s)
    x = cm.embed(params, tokens, cfg)

    def scan_fn(x, layer_p):
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        a, cache = cm.attention_prefill(layer_p["attn"], h, cfg, cl)
        x = x + a
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        y, _ = moe_mlp(layer_p["moe"], h, cfg)
        return cm.constrain(x + y, "batch", None, None), cache

    x, caches = jax.lax.scan(scan_fn, x, params["layers"], unroll=cfg.scan_unroll)
    x = cm.rms_norm(x[:, -1:], params["final_norm"]["scale"])
    return cm.lm_logits(params, x, cfg)[:, 0], caches


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    x = cm.embed(params, tokens, cfg)  # (b, d)

    def scan_fn(x, scanned):
        layer_p, layer_cache = scanned
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        a, new_cache = cm.attention_decode(layer_p["attn"], h, layer_cache, cfg, pos)
        x = x + a
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        y, _ = moe_mlp(layer_p["moe"], h, cfg)
        return cm.constrain(x + y, "batch", None), new_cache

    x, new_caches = jax.lax.scan(scan_fn, x, (params["layers"], cache), unroll=cfg.scan_unroll)
    x = cm.rms_norm(x, params["final_norm"]["scale"])
    return cm.lm_logits(params, x, cfg), new_caches
