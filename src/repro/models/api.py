"""Unified model API: one entry point per family.

``build_model(cfg)`` returns a ``Model`` whose methods are plain functions
(jit/pjit-ready):
    init(key) -> params
    loss(params, batch) -> scalar                    (train objective)
    prefill(params, batch) -> (last-token logits, cache)
    decode(params, cache, tokens, pos) -> (logits, cache)
    init_cache(batch, seq, as_specs) -> pytree       (decode-state stand-ins)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from ..configs.base import ArchConfig
from . import encdec, mamba2, moe, transformer, zamba2

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": zamba2,
    "encdec": encdec,
}


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    module: Any
    remat: str = "dots"

    def init(self, key: jax.Array):
        return self.module.init_params(key, self.cfg)

    def loss(self, params, batch):
        return self.module.loss_fn(params, batch, self.cfg, remat=self.remat)

    def prefill(self, params, batch):
        return self.module.prefill(params, batch, self.cfg)

    def decode(self, params, cache, tokens, pos):
        return self.module.decode_step(params, cache, tokens, pos, self.cfg)

    def init_cache(self, batch: int, seq_len: int, as_specs: bool = False):
        return self.module.init_cache(self.cfg, batch, seq_len, as_specs=as_specs)


def build_model(cfg: ArchConfig, remat: str = "dots") -> Model:
    return Model(cfg=cfg, module=_FAMILIES[cfg.family], remat=remat)
