"""Zamba2 hybrid: Mamba2 backbone with ONE shared attention+MLP block applied
every ``attn_every`` SSM layers (arXiv:2411.15242). The shared block consumes
concat(hidden, original embedding) — 2*d input — and its weights are reused at
every application site (9 sites for the 54-layer config).

Structure: python loop over the (few) groups; within each group the mamba
layers are lax.scan'd, then the shared block is applied.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm
from . import mamba2 as mb
from .transformer import REMAT_POLICIES


def n_shared_sites(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init_params(key: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    l = cfg.n_layers
    ks = jax.random.split(key, 5)
    layers = {
        "mamba": jax.vmap(lambda k: mb.init_layer(k, cfg))(jax.random.split(ks[0], l)),
        "norm": {"scale": jnp.ones((l, cfg.d_model), cm.act_dtype(cfg))},
    }
    shared = {
        "attn": cm.init_attention(ks[1], cfg, d_in=2 * cfg.d_model),
        "mlp": cm.init_mlp(ks[2], cfg),
        "attn_norm": {"scale": jnp.ones((2 * cfg.d_model,), cm.act_dtype(cfg))},
        "mlp_norm": {"scale": jnp.ones((cfg.d_model,), cm.act_dtype(cfg))},
    }
    p = {
        "layers": layers,
        "shared": shared,
        "final_norm": {"scale": jnp.ones((cfg.d_model,), cm.act_dtype(cfg))},
    }
    p.update(cm.init_embed(ks[3], cfg))
    return p


def _group_slices(cfg: ArchConfig):
    k = cfg.attn_every
    return [(g * k, min((g + 1) * k, cfg.n_layers)) for g in range(n_shared_sites(cfg))]


def _mamba_group(layers_p, x, cfg: ArchConfig, lo: int, hi: int, remat: str):
    sub = jax.tree.map(lambda a: a[lo:hi], layers_p)
    body = mb._block
    if remat != "everything":
        body = jax.checkpoint(
            mb._block, policy=REMAT_POLICIES[remat], static_argnums=(2,), prevent_cse=True
        )

    def scan_fn(x, layer_p):
        return body(layer_p, x, cfg), None

    x, _ = jax.lax.scan(scan_fn, x, sub, unroll=cfg.scan_unroll)
    return x


def _shared_block(p, x, x0, cfg: ArchConfig, causal: bool = True):
    inp = jnp.concatenate([x, x0], axis=-1)  # (b, s, 2d)
    h = cm.rms_norm(inp, p["attn_norm"]["scale"])
    x = x + cm.attention(p["attn"], h, cfg, causal=causal)
    h = cm.rms_norm(x, p["mlp_norm"]["scale"])
    x = x + cm.mlp(p["mlp"], h)
    return cm.constrain(x, "batch", "seq_act", None)


def forward(params, tokens, cfg: ArchConfig, remat: str = "dots"):
    x = cm.embed(params, tokens, cfg)
    x0 = x
    for lo, hi in _group_slices(cfg):
        x = _mamba_group(params["layers"], x, cfg, lo, hi, remat)
        x = _shared_block(params["shared"], x, x0, cfg)
    return cm.rms_norm(x, params["final_norm"]["scale"])


def loss_fn(params, batch, cfg: ArchConfig, remat: str = "dots"):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x = forward(params, inp, cfg, remat=remat)
    return cm.lm_loss(params, x, labels, cfg)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, as_specs: bool = False):
    """SSM states for every mamba layer + a KV cache per shared-attn site."""
    ssm = mb.init_cache(cfg, batch, seq_len, as_specs=as_specs)
    sites = n_shared_sites(cfg)
    kv_shape = (sites, batch, seq_len, cfg.n_kv_heads, cfg.hd)
    dt = cm.act_dtype(cfg)
    if as_specs:
        kv = {"k": jax.ShapeDtypeStruct(kv_shape, dt), "v": jax.ShapeDtypeStruct(kv_shape, dt)}
    else:
        kv = {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)}
    return {"ssm": ssm, "attn": kv}


def prefill(params, batch, cfg: ArchConfig, cache_len: Optional[int] = None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    cl = cache_len or s
    x = cm.embed(params, tokens, cfg)
    x0 = x
    ssm_hs, ssm_convs, kv_ks, kv_vs = [], [], [], []
    for lo, hi in _group_slices(cfg):
        sub = jax.tree.map(lambda a: a[lo:hi], params["layers"])

        def scan_fn(x, layer_p):
            h = cm.rms_norm(x, layer_p["norm"]["scale"])
            zxbcdt = h @ layer_p["mamba"]["in_proj"]
            di, nh, ns, conv_dim, _ = mb._dims(cfg)
            z, xbc, dt_raw = mb._split_proj(layer_p["mamba"], zxbcdt, cfg)
            conv_tail = xbc[:, -(cfg.ssm_conv - 1) :, :]
            xbc = mb._causal_conv(xbc, layer_p["mamba"]["conv_w"], layer_p["mamba"]["conv_bias"])
            xin = xbc[..., :di]
            b_in = xbc[..., di : di + ns].astype(jnp.float32)
            c_in = xbc[..., di + ns :].astype(jnp.float32)
            dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + layer_p["mamba"]["dt_bias"])
            a = -jnp.exp(layer_p["mamba"]["a_log"])
            xh = xin.reshape(*xin.shape[:-1], nh, cfg.ssm_head_dim)
            y, h_final = mb._ssd_scan(xh, dtv, a, b_in, c_in, cfg)
            y = y + xh * layer_p["mamba"]["ssm_d"][None, None, :, None].astype(xh.dtype)
            y = y.reshape(*xin.shape)
            y = cm.rms_norm(y * jax.nn.silu(z), layer_p["mamba"]["norm"]["scale"])
            x = x + y @ layer_p["mamba"]["out_proj"]
            return cm.constrain(x, "batch", None, None), {"h": h_final, "conv": conv_tail}

        x, st = jax.lax.scan(scan_fn, x, sub, unroll=cfg.scan_unroll)
        ssm_hs.append(st["h"])
        ssm_convs.append(st["conv"])
        # shared attention with cache capture
        inp = jnp.concatenate([x, x0], axis=-1)
        h = cm.rms_norm(inp, params["shared"]["attn_norm"]["scale"])
        a_out, kv = cm.attention_prefill(params["shared"]["attn"], h, cfg, cl)
        x = x + a_out
        h = cm.rms_norm(x, params["shared"]["mlp_norm"]["scale"])
        x = x + cm.mlp(params["shared"]["mlp"], h)
        kv_ks.append(kv["k"])
        kv_vs.append(kv["v"])
    x = cm.rms_norm(x[:, -1:], params["final_norm"]["scale"])
    logits = cm.lm_logits(params, x, cfg)[:, 0]
    cache = {
        "ssm": {"h": jnp.concatenate(ssm_hs, 0), "conv": jnp.concatenate(ssm_convs, 0)},
        "attn": {"k": jnp.stack(kv_ks), "v": jnp.stack(kv_vs)},
    }
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    x = cm.embed(params, tokens, cfg)
    x0 = x
    new_h, new_conv, new_k, new_v = [], [], [], []
    for g, (lo, hi) in enumerate(_group_slices(cfg)):
        sub = jax.tree.map(lambda a: a[lo:hi], params["layers"])
        sub_cache = {
            "h": cache["ssm"]["h"][lo:hi],
            "conv": cache["ssm"]["conv"][lo:hi],
        }

        def scan_fn(x, scanned):
            layer_p, layer_cache = scanned
            h = cm.rms_norm(x, layer_p["norm"]["scale"])
            y, st = mb.mamba_decode(layer_p["mamba"], h, layer_cache, cfg)
            return cm.constrain(x + y, "batch", None), st

        x, st = jax.lax.scan(scan_fn, x, (sub, sub_cache), unroll=cfg.scan_unroll)
        new_h.append(st["h"])
        new_conv.append(st["conv"])
        inp = jnp.concatenate([x, x0], axis=-1)
        h = cm.rms_norm(inp, params["shared"]["attn_norm"]["scale"])
        site_cache = {"k": cache["attn"]["k"][g], "v": cache["attn"]["v"][g]}
        a_out, kv = cm.attention_decode(params["shared"]["attn"], h, site_cache, cfg, pos)
        x = x + a_out
        h = cm.rms_norm(x, params["shared"]["mlp_norm"]["scale"])
        x = x + cm.mlp(params["shared"]["mlp"], h)
        new_k.append(kv["k"])
        new_v.append(kv["v"])
    x = cm.rms_norm(x, params["final_norm"]["scale"])
    logits = cm.lm_logits(params, x, cfg)
    cache = {
        "ssm": {"h": jnp.concatenate(new_h, 0), "conv": jnp.concatenate(new_conv, 0)},
        "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
    }
    return logits, cache
