"""Encoder–decoder transformer backbone (Seamless-M4T v2 scale).

The speech/modality frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings (b, frames, d_model). The decoder is a
causal transformer with cross-attention; decode keeps a self-attention KV
cache plus precomputed cross-attention K/V over the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm
from .transformer import REMAT_POLICIES


def init_params(key: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    dt = cm.act_dtype(cfg)
    ks = jax.random.split(key, 6)

    def stacked(initializer, rng, n):
        return jax.vmap(initializer)(jax.random.split(rng, n))

    enc = {
        "attn": stacked(lambda k: cm.init_attention(k, cfg), ks[0], cfg.enc_layers),
        "mlp": stacked(lambda k: cm.init_mlp(k, cfg), ks[1], cfg.enc_layers),
        "attn_norm": {"scale": jnp.ones((cfg.enc_layers, cfg.d_model), dt)},
        "mlp_norm": {"scale": jnp.ones((cfg.enc_layers, cfg.d_model), dt)},
    }
    dec = {
        "attn": stacked(lambda k: cm.init_attention(k, cfg), ks[2], cfg.n_layers),
        "cross": stacked(lambda k: cm.init_attention(k, cfg), ks[3], cfg.n_layers),
        "mlp": stacked(lambda k: cm.init_mlp(k, cfg), ks[4], cfg.n_layers),
        "attn_norm": {"scale": jnp.ones((cfg.n_layers, cfg.d_model), dt)},
        "cross_norm": {"scale": jnp.ones((cfg.n_layers, cfg.d_model), dt)},
        "mlp_norm": {"scale": jnp.ones((cfg.n_layers, cfg.d_model), dt)},
    }
    p = {
        "encoder": enc,
        "decoder": dec,
        "enc_norm": {"scale": jnp.ones((cfg.d_model,), dt)},
        "final_norm": {"scale": jnp.ones((cfg.d_model,), dt)},
    }
    p.update(cm.init_embed(ks[5], cfg))
    return p


def encode(params, src_embeds: jnp.ndarray, cfg: ArchConfig, remat: str = "dots"):
    """src_embeds (b, s_src, d) -> encoder memory (b, s_src, d)."""
    x = cm.constrain(src_embeds, "batch", None, None)

    def block(layer_p, x, _cfg):
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        x = x + cm.attention(layer_p["attn"], h, _cfg, causal=False)
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        x = x + cm.mlp(layer_p["mlp"], h)
        return cm.constrain(x, "batch", "seq_act", None)

    body = block
    if remat != "everything":
        body = jax.checkpoint(block, policy=REMAT_POLICIES[remat], static_argnums=(2,), prevent_cse=True)

    def scan_fn(x, layer_p):
        return body(layer_p, x, cfg), None

    x, _ = jax.lax.scan(scan_fn, x, params["encoder"], unroll=cfg.scan_unroll)
    return cm.rms_norm(x, params["enc_norm"]["scale"])


def _cross_kv(layer_p, memory, cfg: ArchConfig):
    b, s, _ = memory.shape
    k = (memory @ layer_p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (memory @ layer_p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return k, v


def decode_train(params, memory, tgt_tokens, cfg: ArchConfig, remat: str = "dots"):
    """Teacher-forced decoder forward. tgt_tokens (b, t)."""
    x = cm.embed(params, tgt_tokens, cfg)

    def block(layer_p, x, _cfg):
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        x = x + cm.attention(layer_p["attn"], h, _cfg, causal=True)
        h = cm.rms_norm(x, layer_p["cross_norm"]["scale"])
        kv = _cross_kv(layer_p["cross"], memory, _cfg)
        x = x + cm.attention(layer_p["cross"], h, _cfg, causal=False, kv_override=kv, use_rope=False)
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        x = x + cm.mlp(layer_p["mlp"], h)
        return cm.constrain(x, "batch", "seq_act", None)

    body = block
    if remat != "everything":
        body = jax.checkpoint(block, policy=REMAT_POLICIES[remat], static_argnums=(2,), prevent_cse=True)

    def scan_fn(x, layer_p):
        return body(layer_p, x, cfg), None

    x, _ = jax.lax.scan(scan_fn, x, params["decoder"], unroll=cfg.scan_unroll)
    return cm.rms_norm(x, params["final_norm"]["scale"])


def loss_fn(params, batch, cfg: ArchConfig, remat: str = "dots"):
    memory = encode(params, batch["src_embeds"], cfg, remat=remat)
    tgt = batch["tgt_tokens"]
    inp, labels = tgt[:, :-1], tgt[:, 1:]
    x = decode_train(params, memory, inp, cfg, remat=remat)
    return cm.lm_loss(params, x, labels, cfg)


def init_cache(cfg: ArchConfig, batch: int, src_len: int, as_specs: bool = False):
    """Self cache over dec_target_len + cross K/V over src_len, per layer."""
    dt = cm.act_dtype(cfg)
    l = cfg.n_layers
    self_shape = (l, batch, cfg.dec_target_len, cfg.n_kv_heads, cfg.hd)
    cross_shape = (l, batch, src_len, cfg.n_kv_heads, cfg.hd)
    if as_specs:
        sds = jax.ShapeDtypeStruct
        return {
            "k": sds(self_shape, dt), "v": sds(self_shape, dt),
            "ck": sds(cross_shape, dt), "cv": sds(cross_shape, dt),
        }
    return {
        "k": jnp.zeros(self_shape, dt), "v": jnp.zeros(self_shape, dt),
        "ck": jnp.zeros(cross_shape, dt), "cv": jnp.zeros(cross_shape, dt),
    }


def prefill(params, batch, cfg: ArchConfig, cache_len: Optional[int] = None):
    """Encode source + run the decoder prefix, building both caches."""
    memory = encode(params, batch["src_embeds"], cfg)
    tgt = batch["tgt_tokens"]
    b, t = tgt.shape
    cl = cache_len or cfg.dec_target_len
    x = cm.embed(params, tgt, cfg)

    def scan_fn(x, layer_p):
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        a, cache = cm.attention_prefill(layer_p["attn"], h, cfg, cl)
        x = x + a
        h = cm.rms_norm(x, layer_p["cross_norm"]["scale"])
        ck, cv = _cross_kv(layer_p["cross"], memory, cfg)
        x = x + cm.attention(layer_p["cross"], h, cfg, causal=False, kv_override=(ck, cv), use_rope=False)
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        x = x + cm.mlp(layer_p["mlp"], h)
        cache["ck"] = cm.constrain(ck, "batch", "kv_seq", None, None)
        cache["cv"] = cm.constrain(cv, "batch", "kv_seq", None, None)
        return cm.constrain(x, "batch", None, None), cache

    x, caches = jax.lax.scan(scan_fn, x, params["decoder"], unroll=cfg.scan_unroll)
    x = cm.rms_norm(x[:, -1:], params["final_norm"]["scale"])
    return cm.lm_logits(params, x, cfg)[:, 0], caches


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    x = cm.embed(params, tokens, cfg)  # (b, d)

    def scan_fn(x, scanned):
        layer_p, layer_cache = scanned
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        self_cache = {"k": layer_cache["k"], "v": layer_cache["v"]}
        a, new_self = cm.attention_decode(layer_p["attn"], h, self_cache, cfg, pos)
        x = x + a
        h = cm.rms_norm(x, layer_p["cross_norm"]["scale"])
        cross_cache = {"k": layer_cache["ck"], "v": layer_cache["cv"]}
        c, _ = cm.attention_decode(
            layer_p["cross"], h, cross_cache, cfg, jnp.asarray(cross_cache["k"].shape[1] - 1),
            update_cache=False, use_rope=False,
        )
        x = x + c
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        x = x + cm.mlp(layer_p["mlp"], h)
        new_cache = {"k": new_self["k"], "v": new_self["v"], "ck": layer_cache["ck"], "cv": layer_cache["cv"]}
        return cm.constrain(x, "batch", None), new_cache

    x, new_caches = jax.lax.scan(scan_fn, x, (params["decoder"], cache), unroll=cfg.scan_unroll)
    x = cm.rms_norm(x, params["final_norm"]["scale"])
    return cm.lm_logits(params, x, cfg), new_caches
