"""Dense decoder-only transformer (GQA, RoPE, SwiGLU, optional QKV bias,
optional sliding window). Covers deepseek-67b, internlm2-20b, glm4-9b,
qwen2.5-32b and chameleon-34b (early fusion = VQ tokens in the unified vocab).

The layer stack is ``lax.scan``'d over stacked parameters (HLO size is
depth-independent) with a configurable remat policy.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}


def init_params(key: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    l = cfg.n_layers
    ks = jax.random.split(key, 4)

    def stacked(initializer, rng):
        return jax.vmap(initializer)(jax.random.split(rng, l))

    layers = {
        "attn": stacked(lambda k: cm.init_attention(k, cfg), ks[0]),
        "mlp": stacked(lambda k: cm.init_mlp(k, cfg), ks[1]),
        "attn_norm": {"scale": jnp.ones((l, cfg.d_model), cm.act_dtype(cfg))},
        "mlp_norm": {"scale": jnp.ones((l, cfg.d_model), cm.act_dtype(cfg))},
    }
    p = {"layers": layers, "final_norm": {"scale": jnp.ones((cfg.d_model,), cm.act_dtype(cfg))}}
    p.update(cm.init_embed(ks[2], cfg))
    return p


def _block(layer_p, x, cfg: ArchConfig):
    h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
    x = x + cm.attention(layer_p["attn"], h, cfg, causal=True)
    h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
    x = x + cm.mlp(layer_p["mlp"], h)
    return cm.constrain(x, "batch", "seq_act", None)


def forward(params, tokens: jnp.ndarray, cfg: ArchConfig, remat: str = "dots") -> jnp.ndarray:
    """tokens (b, s) -> final hidden states (b, s, d)."""
    x = cm.embed(params, tokens, cfg)
    policy = REMAT_POLICIES[remat]
    body = _block
    if remat != "everything":
        body = jax.checkpoint(
            _block, policy=policy, static_argnums=(2,), prevent_cse=True
        )

    def scan_fn(x, layer_p):
        return body(layer_p, x, cfg), None

    x, _ = jax.lax.scan(scan_fn, x, params["layers"], unroll=cfg.scan_unroll)
    return cm.rms_norm(x, params["final_norm"]["scale"])


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig, remat: str = "dots"):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x = forward(params, inp, cfg, remat=remat)
    return cm.lm_loss(params, x, labels, cfg)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window is not None else seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, as_specs: bool = False):
    s = cache_len_for(cfg, seq_len)
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.hd)
    dt = cm.act_dtype(cfg)
    if as_specs:
        return {"k": jax.ShapeDtypeStruct(shape, dt), "v": jax.ShapeDtypeStruct(shape, dt)}
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def prefill(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig, cache_len: Optional[int] = None):
    """Returns (last-token logits, stacked KV cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cl = cache_len or cache_len_for(cfg, s)
    x = cm.embed(params, tokens, cfg)

    def scan_fn(x, layer_p):
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        a, cache = cm.attention_prefill(layer_p["attn"], h, cfg, cl)
        x = x + a
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        x = x + cm.mlp(layer_p["mlp"], h)
        return cm.constrain(x, "batch", None, None), cache

    x, caches = jax.lax.scan(scan_fn, x, params["layers"], unroll=cfg.scan_unroll)
    x = cm.rms_norm(x[:, -1:], params["final_norm"]["scale"])
    logits = cm.lm_logits(params, x, cfg)[:, 0]
    return logits, caches


def decode_step(params, cache, tokens: jnp.ndarray, pos: jnp.ndarray, cfg: ArchConfig):
    """One token for the whole batch. tokens (b,), pos scalar."""
    x = cm.embed(params, tokens, cfg)  # (b, d)

    def scan_fn(x, scanned):
        layer_p, layer_cache = scanned
        h = cm.rms_norm(x, layer_p["attn_norm"]["scale"])
        a, new_cache = cm.attention_decode(layer_p["attn"], h, layer_cache, cfg, pos)
        x = x + a
        h = cm.rms_norm(x, layer_p["mlp_norm"]["scale"])
        x = x + cm.mlp(layer_p["mlp"], h)
        return cm.constrain(x, "batch", None), new_cache

    x, new_caches = jax.lax.scan(scan_fn, x, (params["layers"], cache), unroll=cfg.scan_unroll)
    x = cm.rms_norm(x, params["final_norm"]["scale"])
    logits = cm.lm_logits(params, x, cfg)
    return logits, new_caches
