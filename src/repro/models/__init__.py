"""Model families for the assigned architectures."""
from .api import Model, build_model

__all__ = ["Model", "build_model"]
