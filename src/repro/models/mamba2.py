"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD forward: within a chunk the recurrence is expanded into
attention-like matmuls (MXU-friendly); chunks are linked by a sequential
``lax.scan`` carrying the (b, h, p, n) state. Per-chunk intermediates only —
the (q, q) decay matrix never materializes for the whole sequence.

Decode is the O(1) recurrence: h <- h * exp(dt*A) + dt * (B outer x).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm
from .transformer import REMAT_POLICIES


def _dims(cfg: ArchConfig):
    di = cfg.d_inner
    nh = cfg.ssm_heads
    ns = cfg.ssm_state
    conv_dim = di + 2 * ns  # x, B, C go through the causal conv
    d_in_proj = 2 * di + 2 * ns + nh  # z, x, B, C, dt
    return di, nh, ns, conv_dim, d_in_proj


def init_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    di, nh, ns, conv_dim, d_in_proj = _dims(cfg)
    dt = cm.act_dtype(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": cm.dense_init(ks[0], (d, d_in_proj), dt),
        "conv_w": cm.dense_init(ks[1], (cfg.ssm_conv, conv_dim), dt, scale=0.5),
        "conv_bias": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "ssm_d": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dt)},
        "out_proj": cm.dense_init(ks[2], (di, d), dt),
    }


def _split_proj(p, zxbcdt, cfg: ArchConfig):
    di, nh, ns, conv_dim, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_dim]
    dt_raw = zxbcdt[..., di + conv_dim :]  # (..., nh)
    return z, xbc, dt_raw


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq: xbc (b, l, c), w (width, c)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + bias)


def _ssd_scan(x, dt, a, b_in, c_in, cfg: ArchConfig, h0=None):
    """Chunked SSD. x (b, l, nh, hp); dt (b, l, nh); a (nh,) negative;
    b_in/c_in (b, l, ns). Returns (y (b, l, nh, hp), final state (b, nh, hp, ns))."""
    bsz, l, nh, hp = x.shape
    ns = b_in.shape[-1]
    q = min(cfg.ssm_chunk, l)
    n_chunks = (l + q - 1) // q
    if l % q:
        padn = n_chunks * q - l
        x = jnp.pad(x, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padn), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, padn), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, padn), (0, 0)))
    xc = x.reshape(bsz, n_chunks, q, nh, hp)
    dtc = dt.reshape(bsz, n_chunks, q, nh)
    bc = b_in.reshape(bsz, n_chunks, q, ns)
    cc = c_in.reshape(bsz, n_chunks, q, ns)

    def chunk_step(h, inputs):
        xq, dtq, bq, cq = inputs  # (b, q, nh, hp), (b, q, nh), (b, q, ns) x2
        adt = dtq * a[None, None, :]  # (b, q, nh) negative
        cum = jnp.cumsum(adt, axis=1)  # (b, q, nh)
        # intra-chunk: y[i] = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (b, q, q, nh)
        tri = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: exp of masked (positive) entries would overflow and
        # poison the gradient through jnp.where
        decay = jnp.exp(jnp.where(tri[None, :, :, None], seg, -1e30))
        cb = jnp.einsum("bin,bjn->bij", cq, bq)  # (b, q, q)
        w = cb[..., None] * decay  # (b, q, q, nh)
        xdt = xq * dtq[..., None]  # (b, q, nh, hp)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xdt)
        # inter-chunk: y[i] += C_i . h exp(cum_i)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, h, jnp.exp(cum))
        # state update: h' = h*exp(cum_last) + sum_j exp(cum_last - cum_j) B_j (dt_j x_j)
        last = cum[:, -1:, :]  # (b, 1, nh)
        sdecay = jnp.exp(last - cum)  # (b, q, nh)
        h_new = h * jnp.exp(last)[:, 0, :, None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhpn", bq, sdecay, xdt
        )
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, hp, ns), jnp.float32)
    xc_t = jnp.moveaxis(xc, 1, 0)
    dtc_t = jnp.moveaxis(dtc, 1, 0)
    bc_t = jnp.moveaxis(bc, 1, 0)
    cc_t = jnp.moveaxis(cc, 1, 0)
    h_final, ys = jax.lax.scan(chunk_step, h0, (xc_t, dtc_t, bc_t, cc_t), unroll=cfg.scan_unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, n_chunks * q, nh, hp)[:, :l]
    return y, h_final


def mamba_block(p, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Full-sequence mamba2 block. x (b, l, d) -> (b, l, d)."""
    di, nh, ns, conv_dim, _ = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(p, zxbcdt, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_bias"])
    xin = xbc[..., :di]
    b_in = xbc[..., di : di + ns]
    c_in = xbc[..., di + ns :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b, l, nh)
    a = -jnp.exp(p["a_log"])  # (nh,)
    xh = xin.reshape(*xin.shape[:-1], nh, cfg.ssm_head_dim)
    y, _ = _ssd_scan(xh, dt, a, b_in.astype(jnp.float32), c_in.astype(jnp.float32), cfg)
    y = y + xh * p["ssm_d"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(*xin.shape)
    y = cm.rms_norm(y * jax.nn.silu(z), p["norm"]["scale"])
    out = y @ p["out_proj"]
    return cm.constrain(out, "batch", None, None)


# --- single-token decode ---------------------------------------------------
def mamba_decode(p, x: jnp.ndarray, state: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """x (b, d); state {'h': (b, nh, hp, ns), 'conv': (b, width-1, conv_dim)}."""
    di, nh, ns, conv_dim, _ = _dims(cfg)
    zxbcdt = x @ p["in_proj"]  # (b, d_in_proj)
    z, xbc, dt_raw = _split_proj(p, zxbcdt, cfg)
    # conv cache update
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (b, w, c)
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w) + p["conv_bias"])
    new_conv = window[:, 1:]
    xin = conv_out[..., :di]
    b_in = conv_out[..., di : di + ns].astype(jnp.float32)
    c_in = conv_out[..., di + ns :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b, nh)
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(-1, nh, cfg.ssm_head_dim).astype(jnp.float32)  # (b, nh, hp)
    h = state["h"]
    decay = jnp.exp(dt * a[None, :])  # (b, nh)
    h_new = h * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", b_in, dt, xh
    )
    y = jnp.einsum("bn,bhpn->bhp", c_in, h_new) + xh * p["ssm_d"][None, :, None]
    y = y.reshape(-1, di).astype(x.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z), p["norm"]["scale"])
    out = y @ p["out_proj"]
    return cm.constrain(out, "batch", None), {"h": h_new, "conv": new_conv}


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def init_params(key: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    l = cfg.n_layers
    ks = jax.random.split(key, 3)
    layers = {
        "mamba": jax.vmap(lambda k: init_layer(k, cfg))(jax.random.split(ks[0], l)),
        "norm": {"scale": jnp.ones((l, cfg.d_model), cm.act_dtype(cfg))},
    }
    p = {"layers": layers, "final_norm": {"scale": jnp.ones((cfg.d_model,), cm.act_dtype(cfg))}}
    p.update(cm.init_embed(ks[1], cfg))
    return p


def _block(layer_p, x, cfg: ArchConfig):
    h = cm.rms_norm(x, layer_p["norm"]["scale"])
    return cm.constrain(x + mamba_block(layer_p["mamba"], h, cfg), "batch", "seq_act", None)


def forward(params, tokens, cfg: ArchConfig, remat: str = "dots"):
    x = cm.embed(params, tokens, cfg)
    body = _block
    if remat != "everything":
        body = jax.checkpoint(
            _block, policy=REMAT_POLICIES[remat], static_argnums=(2,), prevent_cse=True
        )

    def scan_fn(x, layer_p):
        return body(layer_p, x, cfg), None

    x, _ = jax.lax.scan(scan_fn, x, params["layers"], unroll=cfg.scan_unroll)
    return cm.rms_norm(x, params["final_norm"]["scale"])


def loss_fn(params, batch, cfg: ArchConfig, remat: str = "dots"):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x = forward(params, inp, cfg, remat=remat)
    return cm.lm_loss(params, x, labels, cfg)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, as_specs: bool = False):
    di, nh, ns, conv_dim, _ = _dims(cfg)
    l = cfg.n_layers
    h_shape = (l, batch, nh, cfg.ssm_head_dim, ns)
    c_shape = (l, batch, cfg.ssm_conv - 1, conv_dim)
    dt = cm.act_dtype(cfg)
    if as_specs:
        return {
            "h": jax.ShapeDtypeStruct(h_shape, jnp.float32),
            "conv": jax.ShapeDtypeStruct(c_shape, dt),
        }
    return {"h": jnp.zeros(h_shape, jnp.float32), "conv": jnp.zeros(c_shape, dt)}


def prefill(params, batch, cfg: ArchConfig, cache_len: Optional[int] = None):
    tokens = batch["tokens"]
    x = cm.embed(params, tokens, cfg)

    def scan_fn(x, layer_p):
        h = cm.rms_norm(x, layer_p["norm"]["scale"])
        # run block and capture final ssm state + conv tail
        di, nh, ns, conv_dim, _ = _dims(cfg)
        zxbcdt = h @ layer_p["mamba"]["in_proj"]
        z, xbc, dt_raw = _split_proj(layer_p["mamba"], zxbcdt, cfg)
        conv_tail = xbc[:, -(cfg.ssm_conv - 1) :, :]
        xbc = _causal_conv(xbc, layer_p["mamba"]["conv_w"], layer_p["mamba"]["conv_bias"])
        xin = xbc[..., :di]
        b_in = xbc[..., di : di + ns].astype(jnp.float32)
        c_in = xbc[..., di + ns :].astype(jnp.float32)
        dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + layer_p["mamba"]["dt_bias"])
        a = -jnp.exp(layer_p["mamba"]["a_log"])
        xh = xin.reshape(*xin.shape[:-1], nh, cfg.ssm_head_dim)
        y, h_final = _ssd_scan(xh, dtv, a, b_in, c_in, cfg)
        y = y + xh * layer_p["mamba"]["ssm_d"][None, None, :, None].astype(xh.dtype)
        y = y.reshape(*xin.shape)
        y = cm.rms_norm(y * jax.nn.silu(z), layer_p["mamba"]["norm"]["scale"])
        x = x + y @ layer_p["mamba"]["out_proj"]
        return cm.constrain(x, "batch", None, None), {"h": h_final, "conv": conv_tail}

    x, caches = jax.lax.scan(scan_fn, x, params["layers"], unroll=cfg.scan_unroll)
    x = cm.rms_norm(x[:, -1:], params["final_norm"]["scale"])
    return cm.lm_logits(params, x, cfg)[:, 0], caches


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    x = cm.embed(params, tokens, cfg)

    def scan_fn(x, scanned):
        layer_p, layer_cache = scanned
        h = cm.rms_norm(x, layer_p["norm"]["scale"])
        y, new_state = mamba_decode(layer_p["mamba"], h, layer_cache, cfg)
        return cm.constrain(x + y, "batch", None), new_state

    x, new_caches = jax.lax.scan(scan_fn, x, (params["layers"], cache), unroll=cfg.scan_unroll)
    x = cm.rms_norm(x, params["final_norm"]["scale"])
    return cm.lm_logits(params, x, cfg), new_caches
