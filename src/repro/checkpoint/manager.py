"""Mesh-agnostic, atomic, fault-tolerant checkpointing.

Design goals (the large-scale runnability story):
* **Atomic**: write to a temp dir, fsync, then rename — a crash mid-save never
  corrupts the latest checkpoint.
* **Mesh-agnostic / elastic**: arrays are saved as full logical arrays plus a
  manifest; on restore they are placed under the *new* mesh's shardings, so a
  job may resume with a different pod count / DP width.
* **Self-verifying**: the manifest stores a checksum per array; restore
  validates and falls back to the previous step on corruption.
* **Async**: `save(..., blocking=False)` snapshots to host then writes in a
  background thread so the train loop keeps stepping.
* **Bounded**: keeps the newest `keep` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else f"[{p.idx}]" if hasattr(p, "idx") else str(p)
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            flat[key + "::bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()[:1_000_000]).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def all_steps(self):
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "MANIFEST.json").exists():
                out.append(int(d.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict):
        tmp = self.dir / f".tmp_step_{step:010d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "extra": extra, "arrays": {}}
        np.savez(tmp / "arrays.npz", **flat)
        for k, v in flat.items():
            manifest["arrays"][k] = {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "checksum": _checksum(v),
            }
        with open(tmp / "MANIFEST.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None, blocking: bool = True):
        """Snapshot `tree` (device -> host) and persist it."""
        self.wait()  # one in-flight async save at a time
        flat = _flatten(tree)  # host copy happens here
        if blocking:
            self._write(step, flat, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def restore(
        self,
        like: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, Dict]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). If `shardings` (matching pytree of NamedSharding)
        is given, arrays are placed under the NEW mesh — elastic restart."""
        self.wait()
        candidates = self.all_steps() if step is None else [step]
        last_err: Optional[Exception] = None
        for s in reversed(candidates):
            try:
                return self._restore_step(s, like, shardings)
            except Exception as e:  # corrupted -> try previous
                last_err = e
                continue
        raise FileNotFoundError(f"no restorable checkpoint in {self.dir}: {last_err}")

    def _restore_step(self, step: int, like: Any, shardings) -> Tuple[Any, Dict]:
        d = self._step_dir(step)
        manifest = json.loads((d / "MANIFEST.json").read_text())
        data = np.load(d / "arrays.npz")
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else None
        )
        out = []
        for i, (path, leaf) in enumerate(leaves_like):
            key = "/".join(
                str(p.key) if hasattr(p, "key") else f"[{p.idx}]" if hasattr(p, "idx") else str(p)
                for p in path
            )
            stored_key = key + "::bf16" if key + "::bf16" in data else key
            arr = data[stored_key]
            meta = manifest["arrays"][stored_key]
            if _checksum(arr) != meta["checksum"]:
                raise IOError(f"checksum mismatch for {key} at step {step}")
            if stored_key.endswith("::bf16"):
                arr = arr.view(jax.numpy.bfloat16)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            if shard_leaves is not None and shard_leaves[i] is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return treedef.unflatten(out), manifest.get("extra", {})
