"""Deterministic fault injection for the live VDMS engine.

A :class:`FaultPlan` is a seeded, JSON-serializable schedule of faults —
segment loss/corruption, flaky index builds with fail-count schedules,
per-query latency storms, shadow-build OOMs. A :class:`FaultInjector`
replays one plan against a :class:`~repro.vdms.engine.LiveVDMS`: the engine
calls ``advance()`` once per operation (its *fault clock*), ``on_build()``
on every segment build, and ``latency_shape()`` after timing each search
call. All hooks are gated behind ``LiveVDMS._faults is not None``, so the
no-fault fast path is byte-identical to an engine that never imported this
module.

Fault semantics (the degraded-mode contract the engine implements):

* ``segment_loss`` / ``segment_corruption`` — a sealed segment becomes
  unusable (corruption is *detected* via checksum and handled identically:
  the engine must never serve results from a corrupt index). The engine
  quarantines the segment — searches keep serving partial results from the
  surviving segments + growing tail, reporting a per-query ``coverage``
  fraction — and rebuilds it in the background from the authoritative
  vector store with bounded retry + exponential backoff.
* ``build_crash`` — arms a fail-count budget: the next ``fails`` segment
  builds (seals, compactions, or quarantine rebuilds) raise
  :class:`BuildCrashFault`. Failed seals retry with backoff instead of
  raising; a seal whose retries exhaust ``max_seal_retries`` raises
  :class:`TransientEngineFault` (the engine's "give up" signal, classified
  transient by the tuning taxonomy).
* ``latency_storm`` — every search inside ``[at_tick, at_tick +
  duration_ticks)`` has its measured chunk seconds scaled by
  ``latency_mult`` and padded by ``latency_add_s`` per query. Results are
  untouched: storms lie about time, never about answers.
* ``shadow_oom`` — the ``at_tick``-th bootstrap attempt in the injector's
  scope raises :class:`ShadowBuildOOM` (the serving controller aborts the
  canary and rolls back checkpoint-exact).

Determinism: a plan is fully materialized data; an injector's behavior is a
pure function of (plan, the engine's operation sequence), so replaying the
same trace against the same plan twice is bit-identical — property-tested
in ``tests/test_faults.py``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.objectives import TuningFailure

#: Engine health states (ordered by severity; ledger gauge codes).
HEALTH_STATES: Tuple[str, ...] = ("healthy", "rebuilding", "degraded")
HEALTH_CODE: Dict[str, int] = {s: i for i, s in enumerate(HEALTH_STATES)}

FAULT_KINDS: Tuple[str, ...] = (
    "segment_loss",
    "segment_corruption",
    "build_crash",
    "latency_storm",
    "shadow_oom",
)


class FaultError(RuntimeError):
    """Base class of every injected fault raised by a :class:`FaultInjector`."""


class BuildCrashFault(FaultError):
    """An injected segment-build crash (seal, compaction, or rebuild)."""


class ShadowBuildOOM(FaultError):
    """An injected out-of-memory during a shadow instance bootstrap."""


class TransientEngineFault(RuntimeError):
    """The degraded-mode engine exhausted its bounded repair budget.

    Raised (e.g.) when a seal keeps crashing past ``max_seal_retries`` —
    the environment classifies it as a *transient* :class:`TuningFailure`
    so the session retries the evaluation instead of poisoning the GP.
    """


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. Unused fields stay at their defaults (the JSON
    round-trip keeps every field, so plans are self-describing)."""

    kind: str
    at_tick: int = 0  # engine op tick the event arms (shadow_oom: bootstrap ordinal)
    segment: int = -1  # segment_loss/corruption: sealed segment (mod n_sealed at fire)
    fails: int = 1  # build_crash: consecutive build attempts to fail
    duration_ticks: int = 0  # latency_storm: window length in ticks
    latency_mult: float = 1.0  # latency_storm: chunk-seconds multiplier
    latency_add_s: float = 0.0  # latency_storm: added seconds per query
    note: str = ""

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; choose from {FAULT_KINDS}")
        if self.at_tick < 0:
            raise ValueError(f"at_tick must be >= 0, got {self.at_tick}")
        if self.kind == "build_crash" and self.fails < 1:
            raise ValueError(f"build_crash needs fails >= 1, got {self.fails}")
        if self.kind == "latency_storm" and (
            self.duration_ticks < 1 or self.latency_mult < 1.0 or self.latency_add_s < 0.0
        ):
            raise ValueError(
                "latency_storm needs duration_ticks >= 1, latency_mult >= 1, latency_add_s >= 0"
            )


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, JSON-serializable fault schedule + the repair-policy knobs
    the degraded-mode engine honors while the plan is armed."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    max_seal_retries: int = 6  # failed-seal retries before TransientEngineFault
    max_rebuild_retries: int = 4  # quarantine rebuild attempts before permanent degraded
    backoff_base_ticks: int = 4  # first retry delay; doubles per attempt

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        if self.max_seal_retries < 0 or self.max_rebuild_retries < 0:
            raise ValueError("retry budgets must be >= 0")
        if self.backoff_base_ticks < 1:
            raise ValueError("backoff_base_ticks must be >= 1")

    # --- serialization (JSON round-trip is exact) ----------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "seed": int(self.seed),
            "max_seal_retries": int(self.max_seal_retries),
            "max_rebuild_retries": int(self.max_rebuild_retries),
            "backoff_base_ticks": int(self.backoff_base_ticks),
            "events": [dataclasses.asdict(e) for e in self.events],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultPlan":
        return cls(
            events=tuple(FaultEvent(**e) for e in d.get("events", [])),
            seed=int(d.get("seed", 0)),
            max_seal_retries=int(d.get("max_seal_retries", 6)),
            max_rebuild_retries=int(d.get("max_rebuild_retries", 4)),
            backoff_base_ticks=int(d.get("backoff_base_ticks", 4)),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls.from_dict(json.loads(s))

    # --- seeded generation ---------------------------------------------
    @classmethod
    def generate(
        cls,
        seed: int,
        horizon_ticks: int,
        n_events: int = 3,
        kinds: Tuple[str, ...] = ("segment_loss", "build_crash", "latency_storm"),
    ) -> "FaultPlan":
        """A random-but-reproducible plan: ``n_events`` faults of the given
        kinds, uniformly placed over ``horizon_ticks``. Same arguments →
        identical plan (the rng is derived from ``seed`` alone)."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for _ in range(int(n_events)):
            kind = str(kinds[int(rng.integers(len(kinds)))])
            at = int(rng.integers(1, max(horizon_ticks, 2)))
            if kind in ("segment_loss", "segment_corruption"):
                events.append(FaultEvent(kind=kind, at_tick=at, segment=int(rng.integers(8))))
            elif kind == "build_crash":
                events.append(FaultEvent(kind=kind, at_tick=at, fails=int(rng.integers(1, 3))))
            elif kind == "latency_storm":
                events.append(
                    FaultEvent(
                        kind=kind,
                        at_tick=at,
                        duration_ticks=int(rng.integers(4, max(horizon_ticks // 4, 5))),
                        latency_mult=float(2 + 6 * rng.random()),
                        latency_add_s=float(1e-4 * rng.random()),
                    )
                )
            else:  # shadow_oom
                events.append(FaultEvent(kind=kind, at_tick=int(rng.integers(2))))
        events.sort(key=lambda e: (e.at_tick, e.kind))
        return cls(events=tuple(events), seed=int(seed))


def canned_fault_plans(horizon_ticks: int) -> Dict[str, FaultPlan]:
    """The three chaos schedules ``bench_chaos`` replays (scaled to the
    trace's op count): pure segment loss, flaky builds + a loss, and a
    latency storm + a shadow-build OOM striking the first canary."""
    h = max(int(horizon_ticks), 16)
    return {
        "segment_loss": FaultPlan(
            events=(
                FaultEvent(kind="segment_loss", at_tick=h // 4, segment=0),
                FaultEvent(kind="segment_corruption", at_tick=(3 * h) // 5, segment=2),
            ),
            seed=1,
        ),
        "flaky_builds": FaultPlan(
            events=(
                FaultEvent(kind="build_crash", at_tick=h // 6, fails=2),
                FaultEvent(kind="segment_loss", at_tick=h // 2, segment=1),
                FaultEvent(kind="build_crash", at_tick=(2 * h) // 3, fails=1),
            ),
            seed=2,
        ),
        "latency_storm": FaultPlan(
            events=(
                FaultEvent(
                    kind="latency_storm",
                    at_tick=h // 3,
                    duration_ticks=max(h // 6, 8),
                    latency_mult=8.0,
                    latency_add_s=2e-4,
                ),
                FaultEvent(kind="shadow_oom", at_tick=0),
                FaultEvent(kind="segment_loss", at_tick=(4 * h) // 5, segment=1),
            ),
            seed=3,
        ),
    }


class FaultInjector:
    """Replays one :class:`FaultPlan` against a live engine.

    ``scope`` selects which events this injector serves: ``"primary"``
    handles everything except ``shadow_oom``; ``"shadow"`` handles only
    ``shadow_oom`` (keyed by bootstrap ordinal, not ticks) — the serving
    controller arms one injector per role from the same plan.
    """

    def __init__(self, plan: FaultPlan, scope: str = "primary"):
        if scope not in ("primary", "shadow"):
            raise ValueError(f"scope must be 'primary' or 'shadow', got {scope!r}")
        self.plan = plan
        self.scope = scope
        self.tick = 0
        self.n_builds = 0
        self.n_bootstraps = 0
        self.n_injected = 0  # faults actually applied (crashes, losses, storms, ooms)
        self.fired: List[Dict[str, Any]] = []  # applied-event log (diagnostics)
        self._crash_budget = 0
        self._storm_until = -1
        self._storm_mult = 1.0
        self._storm_add = 0.0
        if scope == "shadow":
            self._oom_ordinals = {
                e.at_tick for e in plan.events if e.kind == "shadow_oom"
            }
            self._pending: List[FaultEvent] = []
        else:
            self._oom_ordinals = set()
            self._pending = sorted(
                (e for e in plan.events if e.kind != "shadow_oom"),
                key=lambda e: (e.at_tick, FAULT_KINDS.index(e.kind)),
            )
        self._next = 0  # index into _pending

    # ------------------------------------------------------------------
    def advance(self) -> List[FaultEvent]:
        """Advance the fault clock one engine operation; apply newly-due
        build-crash / latency-storm events and return the due segment
        loss/corruption events for the engine to quarantine."""
        self.tick += 1
        losses: List[FaultEvent] = []
        while self._next < len(self._pending) and self._pending[self._next].at_tick <= self.tick:
            e = self._pending[self._next]
            self._next += 1
            self.n_injected += 1
            self.fired.append({"tick": self.tick, "kind": e.kind, "note": e.note})
            if e.kind == "build_crash":
                self._crash_budget += e.fails
            elif e.kind == "latency_storm":
                self._storm_until = self.tick + e.duration_ticks
                self._storm_mult = float(e.latency_mult)
                self._storm_add = float(e.latency_add_s)
            else:  # segment_loss / segment_corruption
                losses.append(e)
        return losses

    @property
    def storm_active(self) -> bool:
        return self.tick < self._storm_until

    def latency_shape(self) -> Tuple[float, float]:
        """(multiplier, added seconds per query) for searches at this tick."""
        if self.storm_active:
            return self._storm_mult, self._storm_add
        return 1.0, 0.0

    def on_build(self, context: str = "seal") -> None:
        """Called by the engine before every segment build; raises
        :class:`BuildCrashFault` while the fail-count budget lasts."""
        self.n_builds += 1
        if self._crash_budget > 0:
            self._crash_budget -= 1
            self.fired.append({"tick": self.tick, "kind": "build_crash_hit", "note": context})
            raise BuildCrashFault(f"injected build crash during {context} (tick {self.tick})")

    def on_bootstrap(self, n_vectors: int) -> None:
        """Called before a bulk-load; the ``at_tick``-th bootstrap in a
        shadow-scoped injector raises :class:`ShadowBuildOOM`."""
        ordinal = self.n_bootstraps
        self.n_bootstraps += 1
        if ordinal in self._oom_ordinals:
            self.n_injected += 1
            self.fired.append({"tick": self.tick, "kind": "shadow_oom", "note": f"n={n_vectors}"})
            raise ShadowBuildOOM(
                f"injected OOM bootstrapping {n_vectors} vectors (attempt {ordinal})"
            )


# ---------------------------------------------------------------------------
# failure taxonomy (the tuning env routes evaluation errors through this)
# ---------------------------------------------------------------------------
def classify_eval_error(e: BaseException) -> Optional[TuningFailure]:
    """Map an evaluation-time exception to the honest failure taxonomy.

    * :class:`TuningFailure` passes through unchanged (already classified);
    * injected/engine faults (:class:`TransientEngineFault`,
      :class:`FaultError`) become *transient* failures — the session retries
      them instead of feeding the GP worst-value feedback;
    * config-dependent numeric/shape crashes (``ValueError``,
      ``ZeroDivisionError``, ``FloatingPointError``) and device-runtime
      errors (``XlaRuntimeError`` — bad configs OOMing the accelerator)
      become genuine config failures;
    * anything else — programmer errors — returns ``None``: the caller must
      re-raise rather than swallow it into the GP.
    """
    if isinstance(e, TuningFailure):
        return e
    if isinstance(e, (TransientEngineFault, FaultError)):
        return TuningFailure(str(e), transient=True)
    if isinstance(e, (ValueError, ZeroDivisionError, FloatingPointError)):
        return TuningFailure(str(e))
    if type(e).__name__ == "XlaRuntimeError":
        return TuningFailure(str(e))
    return None
