"""IVF_PQR: a DiskANN-style index family registered through the PUBLIC hook.

PQ candidate generation + exact re-ranking: the scan walks the probed
clusters with the ADC lookup table (like IVF_PQ), keeps the best
``reorder_k`` candidates, and re-scores exactly those against the raw stored
vectors — the graph-less core of the DiskANN/Vamana serving recipe (compressed
codes decide *where* to look, full-precision vectors decide *what* to return).
The memory/recall trade sits between IVF_PQ (codes only) and SCANN (int8
codes): PQ compression for the scan plus one raw copy for the re-rank.

This module is deliberately NOT imported by ``repro.vdms`` — it exists to
prove the registry API: calling :func:`register` is the ONLY integration
step, after which ``make_space()`` exposes the family's parameters, the
engine builds/searches/seals it, and both static and streaming tuning runs
work end-to-end with zero edits to ``core/space.py``, ``tuning_env.py``, or
the session layer. The README "Extending" section walks through this file.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.space import Param
from .fused import fused_search_ivf_pqr, shard_search_ivf_pqr
from .indexes import (
    _NLIST,
    _NPROBE,
    IndexBundle,
    _build_cost_ivf_pq,
    _gather_candidates,
    _storage,
    build_ivf_pq,
)
from .registry import REGISTRY, IndexFamily, register_family


def build_ivf_pqr(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    """PQ bundle (codes + shared codebooks, frozen-calibration reuse included)
    plus the raw vectors the re-rank stage scores against."""
    base = build_ivf_pq(key, segs, gids, params, sys, frozen=frozen)
    arrays = dict(base.arrays)
    arrays["data"] = _storage(segs, sys["storage_bf16"])
    static = dict(base.static)
    static["reorder_k"] = int(max(params["reorder_k"], 1))
    return IndexBundle(kind="IVF_PQR", arrays=arrays, static=static)


def search_ivf_pqr(q, arrays, *, k_seg: int, nprobe: int, m: int, c: int, reorder_k: int):
    b, d = q.shape
    dsub = d // m
    # ADC similarity LUT (higher is better), shared across segments
    lut = jnp.einsum("bmd,mcd->bmc", q.reshape(b, m, dsub), arrays["codebooks"])

    def per_seg(seg):
        codes, data, gids, cents, members = seg
        cand = _gather_candidates(q, cents, members, nprobe=nprobe)  # (B, P)
        safe = jnp.maximum(cand, 0)
        ccodes = codes[safe].astype(jnp.int32)  # (B, P, m)
        g = jnp.take_along_axis(lut[:, None, :, :], ccodes[..., None], axis=3)
        approx = jnp.sum(g[..., 0], axis=-1)
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        r = min(reorder_k, approx.shape[1])
        _, top_r = jax.lax.top_k(approx, r)  # (B, r)
        rcand = jnp.take_along_axis(cand, top_r, axis=1)
        rsafe = jnp.maximum(rcand, 0)
        exact = jnp.einsum("brd,bd->br", data[rsafe].astype(jnp.float32), q)
        exact = jnp.where(rcand >= 0, exact, -jnp.inf)
        k = min(k_seg, exact.shape[1])
        top_s, top_i = jax.lax.top_k(exact, k)
        lids = jnp.take_along_axis(rcand, top_i, axis=1)
        ids = jnp.where(lids >= 0, gids[jnp.maximum(lids, 0)], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:
            pad = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(
        per_seg,
        (
            arrays["codes"],
            arrays["data"],
            arrays["gids"],
            arrays["centroids"],
            arrays["members"],
        ),
    )


def _chunk_cost_ivf_pqr(st, arrays, n_sealed, seg_size, dim):
    """ADC scan (centroid probe + LUT + code adds) plus the exact re-rank."""
    nlist = arrays["centroids"].shape[1]
    cap = arrays["members"].shape[2]
    flops = n_sealed * (
        nlist * dim * 2
        + st["m"] * st["c"] * (dim // st["m"]) * 2
        + st["nprobe"] * cap * st["m"]
        + st["reorder_k"] * dim * 2
    )
    return flops, 0


FAMILY = IndexFamily(
    name="IVF_PQR",
    params=(
        Param("nlist", "grid", choices=_NLIST, default=128),
        Param("m", "grid", choices=(4, 8, 16, 32), default=8),
        Param("nbits", "grid", choices=(4, 6, 8), default=8),
        Param("nprobe", "grid", choices=_NPROBE, default=8),
        Param("reorder_k", "grid", choices=(32, 64, 128, 256, 512), default=64),
    ),
    build=build_ivf_pqr,
    search=search_ivf_pqr,
    shared_arrays=("codebooks",),
    fused_search=fused_search_ivf_pqr,
    shard_search=shard_search_ivf_pqr,
    supports_frozen=True,
    chunk_cost=_chunk_cost_ivf_pqr,
    build_cost=_build_cost_ivf_pq,  # re-rank stores raw vectors; build cost is PQ's
    description="DiskANN-style IVF: PQ candidate scan + exact re-rank (reorder_k)",
)


def register() -> IndexFamily:
    """Register IVF_PQR via the public hook (idempotent)."""
    if FAMILY.name not in REGISTRY:
        register_family(FAMILY)
    return FAMILY
