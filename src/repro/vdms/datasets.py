"""Synthetic vector datasets mirroring the paper's three workloads (Table III).

All datasets use angular distance (vectors are L2-normalized; similarity =
inner product). Structure is chosen so that the paper's observed phenomena
survive the scale-down:

* glove_like    — clustered Gaussian mixture (word embeddings cluster):
                  IVF-family indexes work well at modest nprobe.
* keyword_like  — nearly-independent heavy-tailed dimensions (the paper calls
                  out its low inter-dimension correlation and the consequent
                  need for large nprobe).
* georadius_like— high-dimensional (2048-d in the paper; 256-d here), few
                  vectors, smooth manifold structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VectorDataset:
    name: str
    data: np.ndarray  # (n, d) float32, L2-normalized
    queries: np.ndarray  # (q, d) float32, L2-normalized
    ground_truth: np.ndarray  # (q, k) int32 exact top-k ids
    k: int

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]


def _normalize(x: np.ndarray) -> np.ndarray:
    return (x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)).astype(np.float32)


def exact_topk(data: np.ndarray, queries: np.ndarray, k: int, chunk: int = 1024) -> np.ndarray:
    """Brute-force top-k by inner product (chunked to bound memory)."""
    out = np.empty((queries.shape[0], k), dtype=np.int32)
    for i in range(0, queries.shape[0], chunk):
        sim = queries[i : i + chunk] @ data.T
        part = np.argpartition(-sim, k - 1, axis=1)[:, :k]
        row = np.take_along_axis(sim, part, axis=1)
        order = np.argsort(-row, axis=1, kind="stable")
        out[i : i + chunk] = np.take_along_axis(part, order, axis=1).astype(np.int32)
    return out


def exact_topk_masked(
    data: np.ndarray, queries: np.ndarray, dead: np.ndarray, k: int, chunk: int = 1024
) -> np.ndarray:
    """Exact top-k over the *visible* rows of ``data`` only.

    ``dead`` is a boolean mask over ``data`` rows; masked rows can never be
    returned. Rows short of ``k`` visible vectors are padded with ``-1`` —
    this is the time-aware ground-truth primitive for streaming replays,
    where visibility at a query's timestamp excludes not-yet-inserted and
    tombstoned vectors.
    """
    n = data.shape[0]
    k_eff = min(k, max(int(n - dead.sum()), 0))
    out = -np.ones((queries.shape[0], k), dtype=np.int32)
    if k_eff == 0:
        return out
    for i in range(0, queries.shape[0], chunk):
        sim = queries[i : i + chunk] @ data.T
        sim[:, dead] = -np.inf
        part = np.argpartition(-sim, k_eff - 1, axis=1)[:, :k_eff]
        row = np.take_along_axis(sim, part, axis=1)
        order = np.argsort(-row, axis=1, kind="stable")
        out[i : i + chunk, :k_eff] = np.take_along_axis(part, order, axis=1).astype(np.int32)
    return out


def _glove_like(rng: np.random.Generator, n: int, dim: int) -> np.ndarray:
    n_clusters = max(32, n // 256)
    centers = rng.standard_normal((n_clusters, dim)) * 2.0
    assign = rng.integers(0, n_clusters, size=n)
    scale = 0.6 + 0.8 * rng.random(n_clusters)  # clusters of varying tightness
    return centers[assign] + rng.standard_normal((n, dim)) * scale[assign, None]


def _keyword_like(rng: np.random.Generator, n: int, dim: int) -> np.ndarray:
    # independent heavy-tailed dims: hard for coarse quantizers
    return rng.standard_t(df=3, size=(n, dim))


def _georadius_like(rng: np.random.Generator, n: int, dim: int) -> np.ndarray:
    # smooth low-intrinsic-dimension manifold embedded in high dim
    latent = rng.standard_normal((n, 8))
    proj = rng.standard_normal((8, dim))
    return latent @ proj + 0.1 * rng.standard_normal((n, dim))


_GENERATORS = {
    "glove_like": (_glove_like, 96),
    "keyword_like": (_keyword_like, 96),
    "georadius_like": (_georadius_like, 256),
}


def make_dataset(
    name: str,
    n: int = 8192,
    n_queries: int = 128,
    k: int = 10,
    seed: int = 0,
    dim: int | None = None,
) -> VectorDataset:
    gen, default_dim = _GENERATORS[name]
    dim = dim or default_dim
    rng = np.random.default_rng(seed)
    raw = gen(rng, n + n_queries, dim)
    raw = _normalize(raw)
    data, queries = raw[:n], raw[n:]
    gt = exact_topk(data, queries, k)
    return VectorDataset(name=name, data=data, queries=queries, ground_truth=gt, k=k)


def recall_at_k(pred_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Mean fraction of true top-k retrieved (order-insensitive, paper §II-A)."""
    q, k = gt_ids.shape
    hits = 0
    for i in range(q):
        hits += len(set(pred_ids[i].tolist()) & set(gt_ids[i].tolist()))
    return hits / (q * k)


def recall_at_k_masked(pred_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Order-insensitive recall where ``-1`` ground-truth slots (fewer than k
    visible vectors at the query's timestamp) shrink the denominator."""
    total = 0
    hits = 0
    for p_row, g_row in zip(pred_ids, gt_ids):
        g = {int(g) for g in g_row.tolist() if g >= 0}
        if not g:
            continue
        total += len(g)
        hits += len({int(p) for p in p_row.tolist() if p >= 0} & g)
    return hits / total if total else 1.0


# ---------------------------------------------------------------------------
# streaming sources: raw (pre-normalization) draws + drift blending
# ---------------------------------------------------------------------------
def dataset_names() -> tuple:
    """Names of the three Table-III-style workloads."""
    return tuple(_GENERATORS)


def raw_vectors(name: str, rng: np.random.Generator, n: int, dim: int) -> np.ndarray:
    """Un-normalized draws from a named generator (streaming trace source)."""
    gen, _ = _GENERATORS[name]
    return gen(rng, n, dim)


def default_dim(name: str) -> int:
    return _GENERATORS[name][1]


def blend_vectors(a: np.ndarray, b: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Per-row convex blend of two raw sources, re-normalized.

    ``w`` in [0, 1] per row is the drift weight: 0 = pure source ``a``
    (the base distribution), 1 = pure source ``b`` (the drift target). Used
    by workload traces so the *distribution* of inserted vectors and queries
    moves smoothly (or abruptly, per the schedule) during a replay.
    """
    w = np.asarray(w, np.float64).reshape(-1, 1)
    return _normalize((1.0 - w) * a + w * b)
