"""JAX-native vector data management system (the system under tune)."""
from .datasets import (
    VectorDataset,
    blend_vectors,
    dataset_names,
    exact_topk,
    exact_topk_masked,
    make_dataset,
    recall_at_k,
    recall_at_k_masked,
)
from .engine import LiveVDMS, VDMSInstance, batch_signature, measure_batch
from .indexes import (
    INDEX_TYPES,
    IndexBundle,
    build_index,
    concat_bundles,
    frozen_state,
    search_index,
)
from .segments import SegmentPlan, live_seg_size, plan_segments, stack_sealed
from .tuning_env import VDMSTuningEnv, make_space
from .workload import (
    DRIFT_SCHEDULES,
    WorkloadTrace,
    make_trace,
    replay_trace,
    time_aware_ground_truth,
)

__all__ = [
    "DRIFT_SCHEDULES", "INDEX_TYPES", "IndexBundle", "LiveVDMS", "SegmentPlan",
    "VDMSInstance", "VDMSTuningEnv", "VectorDataset", "WorkloadTrace",
    "batch_signature", "blend_vectors", "build_index", "concat_bundles",
    "dataset_names", "exact_topk", "exact_topk_masked", "frozen_state",
    "live_seg_size", "make_dataset", "make_space", "make_trace", "measure_batch",
    "plan_segments", "recall_at_k", "recall_at_k_masked", "replay_trace",
    "search_index", "stack_sealed", "time_aware_ground_truth",
]
