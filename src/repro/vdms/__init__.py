"""JAX-native vector data management system (the system under tune)."""
from .datasets import (
    VectorDataset,
    blend_vectors,
    dataset_names,
    exact_topk,
    exact_topk_masked,
    make_dataset,
    recall_at_k,
    recall_at_k_masked,
)
from .engine import (
    LiveVDMS,
    VDMSInstance,
    batch_signature,
    get_search_pipeline,
    measure_batch,
    set_search_pipeline,
)
from .faults import (
    BuildCrashFault,
    FaultError,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    ShadowBuildOOM,
    TransientEngineFault,
    canned_fault_plans,
    classify_eval_error,
)
from .indexes import (
    IndexBundle,
    build_index,
    concat_bundles,
    frozen_state,
    replace_segment,
    search_index,
)
from .merge import merge_topk
from .registry import (
    IndexFamily,
    fused_pipeline_table,
    get_family,
    register_family,
    registered_families,
    registered_names,
    registry_table,
    shard_pipeline_table,
    temporary_family,
    unregister_family,
)
from .segments import SegmentPlan, live_seg_size, plan_segments, stack_sealed
from .sharded import ShardedVDMS, shard_invariants_table
from .tuning_env import VDMSTuningEnv, make_space
from .workload import (
    DRIFT_SCHEDULES,
    WorkloadTrace,
    make_query_streams,
    make_trace,
    poisson_arrivals,
    replay_query_streams,
    replay_trace,
    time_aware_ground_truth,
)


def __getattr__(name: str):
    if name == "INDEX_TYPES":
        # always the registry keys — never a snapshot that can drift
        return registered_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BuildCrashFault", "DRIFT_SCHEDULES", "FaultError", "FaultEvent",
    "FaultInjector", "FaultPlan", "INDEX_TYPES", "IndexBundle", "IndexFamily",
    "LiveVDMS",
    "SegmentPlan", "ShadowBuildOOM", "TransientEngineFault", "VDMSInstance",
    "VDMSTuningEnv", "VectorDataset",
    "WorkloadTrace", "batch_signature", "blend_vectors", "build_index",
    "canned_fault_plans", "classify_eval_error",
    "concat_bundles", "dataset_names", "exact_topk", "exact_topk_masked",
    "frozen_state", "fused_pipeline_table", "get_family", "get_search_pipeline",
    "live_seg_size", "make_dataset", "make_query_streams", "make_space",
    "make_trace", "measure_batch", "merge_topk", "plan_segments",
    "poisson_arrivals", "recall_at_k",
    "recall_at_k_masked", "register_family", "registered_families",
    "registered_names", "registry_table", "replace_segment",
    "replay_query_streams", "replay_trace",
    "search_index", "set_search_pipeline", "shard_invariants_table",
    "shard_pipeline_table", "ShardedVDMS",
    "stack_sealed", "temporary_family", "time_aware_ground_truth",
    "unregister_family",
]
