"""JAX-native vector data management system (the system under tune)."""
from .datasets import VectorDataset, exact_topk, make_dataset, recall_at_k
from .engine import VDMSInstance, batch_signature, measure_batch
from .indexes import INDEX_TYPES, IndexBundle, build_index, search_index
from .segments import SegmentPlan, plan_segments, stack_sealed
from .tuning_env import VDMSTuningEnv, make_space

__all__ = [
    "INDEX_TYPES", "IndexBundle", "SegmentPlan", "VDMSInstance", "VDMSTuningEnv",
    "VectorDataset", "batch_signature", "build_index", "exact_topk", "make_dataset",
    "make_space", "measure_batch", "plan_segments", "recall_at_k", "search_index",
    "stack_sealed",
]
