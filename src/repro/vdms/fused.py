"""Fused per-family search pipelines (the registry ``fused_search`` hooks).

Each hook replaces one family's *entire* per-chunk hot path — IVF probe,
candidate scoring, per-segment top-k, global-id mapping, and the merge with
the growing tail — with a single call into the fused kernel layer
(:mod:`repro.kernels.fused_scan` / :mod:`repro.kernels.fused_adc` via the
``ops`` impl switch: XLA reference on CPU, Pallas on TPU). The engine
dispatches here whenever the family registered a hook and the session
pipeline mode is ``"fused"``; families without a hook transparently fall
back to their composed ``search`` callable.

Result contract (what the engine relies on):

* the returned ``(B, topk)`` global ids are SET-identical per query to the
  composed path's output — same candidates survive, same growing-tail merge,
  same -1 padding — with slot order among *tied* scores impl-defined;
* under the XLA impl the IVF_PQ and IVF_PQR scores are bit-identical to the
  composed scan (the flat-LUT lookup sums subquantizers in the same order),
  while IVF_SQ8 may differ in the last ulp (full-tile matmul vs gathered
  einsum associate the d-reduction differently);
* ``clamp=True`` (static instances whose sealed segments carry no ``-1``
  padding, see ``VDMSInstance._clamp_ok``) narrows the per-segment width to
  ``min(k_seg, topk)`` — exact because only ``topk`` results survive the
  merge and no dead slot can consume width; live searches never clamp;
* ``alive`` selects the merge flavor: ``None`` runs the static
  ``_pipeline_impl`` chunk merge, a mask runs ``_live_chunk``'s tombstone
  filtering (sentinel slot, masked growing gids, -1 on -inf) — both are the
  SAME code the engine calls (``repro.vdms.merge.merge_topk``), not copies.

The module also hosts the per-family **shard hooks** (``shard_search``): the
candidate-generation stage of the sharded engine's merge tree. A shard hook
runs the family's fused kernels over one shard's local segment stack and
returns per-segment ``(global ids, sims)`` with composed masking semantics
(dead slots stay -1/-inf and keep their width, never clamped) — the merge
itself stays in ``ShardedVDMS``, which feeds every shard's partial top-k
through the same ``repro.vdms.merge`` arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .merge import merge_topk


def _map_gids(gids, lids):
    """Map per-segment local ids (n_seg, B, k) to global ids via each
    segment's gid row; empty slots (lid < 0) map to -1."""
    ids = jax.vmap(lambda g, l: g[jnp.maximum(l, 0)])(gids, lids)
    return jnp.where(lids >= 0, ids, -1)


def _finish(lids, sims, gids, q, growing, growing_gids, alive, topk):
    """Shared epilogue: local→global ids, dead-slot masking (gid < 0 slots
    keep their width but turn -1/-inf, mirroring the composed post-top-k
    mask), then the shared static/live merge (``repro.vdms.merge``)."""
    ids = _map_gids(gids, lids)
    sims = jnp.where(ids >= 0, sims, -jnp.inf)
    return merge_topk(ids, sims, q, growing, growing_gids, topk, alive=alive)


# ---------------------------------------------------------------------------
# per-family hooks
# ---------------------------------------------------------------------------
def fused_search_ivf_sq8(
    q, arrays, growing, growing_gids, *, k_seg, topk, clamp=False, alive=None, nprobe
):
    """IVF_SQ8: fused probe → int8 dequant scan → in-kernel top-k."""
    clamped = clamp and alive is None
    k_eff = min(k_seg, topk) if clamped else k_seg
    lids, sims = ops.fused_ivf_sq8_topk(
        q,
        arrays["codes"],
        arrays["scale"],
        arrays["centroids"],
        arrays["members"],
        arrays["gids"],
        nprobe=nprobe,
        k=k_eff,
        mask_dead=clamped,
    )
    return _finish(lids, sims, arrays["gids"], q, growing, growing_gids, alive, topk)


fused_search_ivf_sq8.stages = "probe → int8 dequant scan → top-k"


def fused_search_ivf_pq(
    q, arrays, growing, growing_gids, *, k_seg, topk, clamp=False, alive=None, nprobe, m, c
):
    """IVF_PQ: fused probe → flat-LUT ADC scan → in-kernel top-k."""
    clamped = clamp and alive is None
    k_eff = min(k_seg, topk) if clamped else k_seg
    b, d = q.shape
    lut = jnp.einsum("bmd,mcd->bmc", q.reshape(b, m, d // m), arrays["codebooks"])
    lids, sims = ops.fused_ivf_pq_topk(
        q,
        lut,
        arrays["codes"],
        arrays["centroids"],
        arrays["members"],
        arrays["gids"],
        nprobe=nprobe,
        k=k_eff,
        mask_dead=clamped,
    )
    return _finish(lids, sims, arrays["gids"], q, growing, growing_gids, alive, topk)


fused_search_ivf_pq.stages = "probe → PQ ADC scan → top-k"


def fused_search_ivf_pqr(
    q,
    arrays,
    growing,
    growing_gids,
    *,
    k_seg,
    topk,
    clamp=False,
    alive=None,
    nprobe,
    m,
    c,
    reorder_k,
):
    """IVF_PQR: fused PQ candidate scan (width ``reorder_k``, never clamped —
    dead slots consume reorder width exactly as composed) → exact re-rank
    against the raw vectors → clamped per-segment top-k."""
    clamped = clamp and alive is None
    k_eff = min(k_seg, topk) if clamped else k_seg
    b, d = q.shape
    lut = jnp.einsum("bmd,mcd->bmc", q.reshape(b, m, d // m), arrays["codebooks"])
    lids, _ = ops.fused_ivf_pq_topk(
        q,
        lut,
        arrays["codes"],
        arrays["centroids"],
        arrays["members"],
        arrays["gids"],
        nprobe=nprobe,
        k=reorder_k,
        mask_dead=False,
    )  # (n_seg, B, r): the PQ stage only ranks; its scores are discarded

    def rerank(data_z, lids_z):
        vecs = data_z[jnp.maximum(lids_z, 0)].astype(jnp.float32)  # (B, r, d)
        exact = jnp.einsum("brd,bd->br", vecs, q)
        return jnp.where(lids_z >= 0, exact, -jnp.inf)

    exact = jax.vmap(rerank)(arrays["data"], lids)  # (n_seg, B, r)
    kk = min(k_eff, exact.shape[-1])
    top_s, top_i = jax.lax.top_k(exact, kk)
    lids2 = jnp.take_along_axis(lids, top_i, axis=2)
    if kk < k_eff:
        pad = ((0, 0), (0, 0), (0, k_eff - kk))
        lids2 = jnp.pad(lids2, pad, constant_values=-1)
        top_s = jnp.pad(top_s, pad, constant_values=-jnp.inf)
    return _finish(lids2, top_s, arrays["gids"], q, growing, growing_gids, alive, topk)


fused_search_ivf_pqr.stages = "probe → PQ ADC scan → exact re-rank → top-k"


# ---------------------------------------------------------------------------
# per-family shard hooks (candidate stage of the sharded merge tree)
# ---------------------------------------------------------------------------
def shard_search_ivf_sq8(q, arrays, *, k_seg, nprobe):
    """IVF_SQ8 per-shard candidates via the fused kernel (composed masking:
    dead slots -1/-inf, full ``k_seg`` width)."""
    lids, sims = ops.fused_ivf_sq8_topk(
        q,
        arrays["codes"],
        arrays["scale"],
        arrays["centroids"],
        arrays["members"],
        arrays["gids"],
        nprobe=nprobe,
        k=k_seg,
        mask_dead=False,
    )
    ids = _map_gids(arrays["gids"], lids)
    return ids, jnp.where(ids >= 0, sims, -jnp.inf)


shard_search_ivf_sq8.stages = "probe → int8 dequant scan → shard top-k"


def shard_search_ivf_pq(q, arrays, *, k_seg, nprobe, m, c):
    """IVF_PQ per-shard candidates via the fused ADC kernel."""
    b, d = q.shape
    lut = jnp.einsum("bmd,mcd->bmc", q.reshape(b, m, d // m), arrays["codebooks"])
    lids, sims = ops.fused_ivf_pq_topk(
        q,
        lut,
        arrays["codes"],
        arrays["centroids"],
        arrays["members"],
        arrays["gids"],
        nprobe=nprobe,
        k=k_seg,
        mask_dead=False,
    )
    ids = _map_gids(arrays["gids"], lids)
    return ids, jnp.where(ids >= 0, sims, -jnp.inf)


shard_search_ivf_pq.stages = "probe → PQ ADC scan → shard top-k"


def shard_search_ivf_pqr(q, arrays, *, k_seg, nprobe, m, c, reorder_k):
    """IVF_PQR per-shard candidates: fused PQ scan picks ``reorder_k``
    candidates per segment, the exact re-rank scores them against the raw
    vectors, then the per-segment top-k (all inside the shard)."""
    b, d = q.shape
    lut = jnp.einsum("bmd,mcd->bmc", q.reshape(b, m, d // m), arrays["codebooks"])
    lids, _ = ops.fused_ivf_pq_topk(
        q,
        lut,
        arrays["codes"],
        arrays["centroids"],
        arrays["members"],
        arrays["gids"],
        nprobe=nprobe,
        k=reorder_k,
        mask_dead=False,
    )

    def rerank(data_z, lids_z):
        vecs = data_z[jnp.maximum(lids_z, 0)].astype(jnp.float32)  # (B, r, d)
        exact = jnp.einsum("brd,bd->br", vecs, q)
        return jnp.where(lids_z >= 0, exact, -jnp.inf)

    exact = jax.vmap(rerank)(arrays["data"], lids)  # (n_seg, B, r)
    kk = min(k_seg, exact.shape[-1])
    top_s, top_i = jax.lax.top_k(exact, kk)
    lids2 = jnp.take_along_axis(lids, top_i, axis=2)
    if kk < k_seg:
        pad = ((0, 0), (0, 0), (0, k_seg - kk))
        lids2 = jnp.pad(lids2, pad, constant_values=-1)
        top_s = jnp.pad(top_s, pad, constant_values=-jnp.inf)
    ids = _map_gids(arrays["gids"], lids2)
    return ids, jnp.where(ids >= 0, top_s, -jnp.inf)


shard_search_ivf_pqr.stages = "probe → PQ ADC scan → exact re-rank → shard top-k"
