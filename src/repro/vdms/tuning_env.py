"""The VDMS tuning environment: the Milvus-like 16-dimensional search space
(index type + 8 index parameters + 7 system parameters, paper §V-A) and the
expensive black-box objective the tuners optimize.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.space import Param, SearchSpace
from ..core.tuner import TuningFailure
from .datasets import VectorDataset
from .engine import VDMSInstance

# ---------------------------------------------------------------------------
# Search space (16 dims: 1 index type + 8 index params + 7 system params)
# ---------------------------------------------------------------------------
_NLIST = (16, 32, 64, 128, 256, 512)
_NPROBE = (1, 2, 4, 8, 16, 32, 64, 128)


def make_space() -> SearchSpace:
    index_types = {
        "FLAT": [],
        "IVF_FLAT": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ],
        "IVF_SQ8": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ],
        "IVF_PQ": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("m", "grid", choices=(4, 8, 16, 32), default=8),
            Param("nbits", "grid", choices=(4, 6, 8), default=8),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ],
        "HNSW": [
            Param("M", "grid", choices=(8, 16, 32, 48), default=16),
            Param("efConstruction", "grid", choices=(32, 64, 128, 256), default=128),
            Param("ef", "grid", choices=(16, 32, 64, 128, 256), default=64),
        ],
        "SCANN": [
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
            Param("reorder_k", "grid", choices=(32, 64, 128, 256, 512), default=64),
        ],
        "AUTOINDEX": [],
    }
    system = [
        Param("segment_max_size", "grid", choices=(1024, 2048, 4096, 8192), default=4096),
        Param("seal_proportion", "float", 0.1, 1.0, default=0.75),
        Param("graceful_time", "float", 0.0, 0.9, default=0.2),
        Param("search_batch_size", "grid", choices=(8, 16, 32, 64, 128), default=32),
        Param("topk_merge_width", "grid", choices=(16, 32, 64, 128), default=64),
        Param("kmeans_iters", "grid", choices=(4, 8, 16, 25), default=8),
        Param("storage_bf16", "cat", choices=(False, True), default=False),
    ]
    return SearchSpace(index_types=index_types, system_params=system)


# ---------------------------------------------------------------------------
# Objective
# ---------------------------------------------------------------------------
class VDMSTuningEnv:
    """Callable black-box: config -> {'speed', 'recall', 'mem_gib', ...}.

    ``mode="wall"`` measures real QPS; ``mode="analytic"`` uses the engine's
    deterministic cost model (recall is always real). Results are cached by
    canonical config so repeated samples are free (and the replay-time ledger
    still reflects first-evaluation cost, like a real tuning session).
    """

    def __init__(
        self,
        dataset: VectorDataset,
        mode: str = "wall",
        seed: int = 0,
        build_timeout: float = 120.0,
        repeats: int = 3,
    ):
        self.dataset = dataset
        self.mode = mode
        self.seed = seed
        self.build_timeout = build_timeout
        self.repeats = repeats
        self.cache: Dict[Tuple, Dict[str, float]] = {}
        self.n_evals = 0
        self.total_replay_time = 0.0

    @staticmethod
    def _canon(cfg: Dict[str, Any]) -> Tuple:
        items = []
        for k in sorted(cfg):
            v = cfg[k]
            if isinstance(v, float):
                v = round(v, 4)
            items.append((k, v))
        return tuple(items)

    def __call__(self, cfg: Dict[str, Any]) -> Dict[str, float]:
        key = self._canon(cfg)
        if key in self.cache:
            return dict(self.cache[key])
        t0 = time.perf_counter()
        try:
            inst = VDMSInstance(self.dataset, cfg, seed=self.seed)
            if inst.build_time > self.build_timeout:
                raise TuningFailure(f"index build exceeded {self.build_timeout}s")
            result = inst.measure(repeats=self.repeats, mode=self.mode)
            del inst
        except TuningFailure:
            raise
        except (ValueError, ZeroDivisionError, RuntimeError) as e:
            raise TuningFailure(str(e)) from e
        finally:
            self.total_replay_time += time.perf_counter() - t0
            self.n_evals += 1
        self.cache[key] = dict(result)
        return result
