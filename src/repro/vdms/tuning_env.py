"""The VDMS tuning environment: the expensive black-box objective the tuners
optimize over the Milvus-like search space (index type + per-family index
parameters + 7 system parameters, paper §V-A).

The space itself is no longer hand-coded here: :func:`make_space` (re-exported
from :mod:`~repro.vdms.registry`) derives it from the declarative index-family
registry, so a family registered through the public hook is tunable with zero
edits to this module.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.objectives import TuningFailure
from .datasets import VectorDataset
from .engine import VDMSInstance, batch_signature, measure_batch
from .faults import FaultInjector, FaultPlan, classify_eval_error
from .registry import make_space  # noqa: F401  (registry-derived; re-exported)
from .workload import (
    OP_DELETE,
    OP_INSERT,
    OP_SEARCH,
    WorkloadTrace,
    replay_trace,
    time_aware_ground_truth,
)


# ---------------------------------------------------------------------------
# Objective
# ---------------------------------------------------------------------------
class VDMSTuningEnv:
    """Callable black-box: config -> {'speed', 'recall', 'mem_gib', ...}.

    Implements the full ``repro.core.objectives.EvalBackend`` protocol: the
    per-config ``__call__`` plus a genuinely vectorized ``evaluate_batch``
    (cache dedupe, threaded index builds, batched measurement), so a
    ``TuningSession`` with the batch executor exploits batch structure here.

    ``mode="wall"`` measures real QPS; ``mode="analytic"`` uses the engine's
    deterministic cost model (recall is always real). Results are cached by
    canonical config so repeated samples are free (and the replay-time ledger
    still reflects first-evaluation cost, like a real tuning session).

    The ``workload`` axis selects the evaluation regime:

    * ``"static"`` (default) — the original frozen-snapshot evaluation: one
      ``VDMSInstance`` per config over ``dataset``; bit-identical to the
      pre-streaming environment.
    * ``"streaming"`` — each config replays a :class:`WorkloadTrace` through
      a live instance (``LiveVDMS``): growing-tail ingestion, incremental
      seal-and-index builds, tombstone deletes with compaction, time-aware
      recall. ``trace`` is required; ``n_phases`` splits it into equal-op
      windows and :meth:`set_phase` moves the drifting workload forward —
      the cache is phase-keyed, so re-measuring a config after the workload
      moved is a fresh evaluation.
    """

    def __init__(
        self,
        dataset: Optional[VectorDataset] = None,
        mode: str = "wall",
        seed: int = 0,
        build_timeout: float = 120.0,
        repeats: int = 3,
        batch_workers: Optional[int] = None,
        workload: str = "static",
        trace: Optional[WorkloadTrace] = None,
        n_phases: int = 1,
        compact_threshold: float = 0.3,
        faults: Union[FaultPlan, FaultInjector, None] = None,
    ):
        if workload not in ("static", "streaming"):
            raise ValueError(f"workload must be 'static' or 'streaming', got {workload!r}")
        if workload == "static" and dataset is None:
            raise ValueError("static workload requires dataset=")
        if workload == "streaming" and trace is None:
            raise ValueError("streaming workload requires trace=")
        if faults is not None and workload != "streaming":
            raise ValueError("fault injection requires the streaming workload")
        self.dataset = dataset
        self.mode = mode
        self.seed = seed
        self.build_timeout = build_timeout
        self.repeats = repeats
        self.batch_workers = batch_workers  # thread pool size for evaluate_batch
        self.workload = workload
        self.trace = trace
        self.compact_threshold = compact_threshold
        self._phases = trace.split(n_phases) if workload == "streaming" else []
        self._phase_gt: List[Optional[Any]] = [None] * len(self._phases)
        self._phase = 0
        # one PERSISTENT injector across evaluations: a fail-count schedule
        # (e.g. "the next 2 builds crash") exhausts across session retries,
        # so a transiently-faulted config recovers on re-evaluation — the
        # semantics the RetryPolicy taxonomy is built around. Faulted evals
        # raise before caching, so retries genuinely re-run the replay.
        self._fault_injector: Optional[FaultInjector] = (
            faults
            if faults is None or isinstance(faults, FaultInjector)
            else FaultInjector(faults, scope="primary")
        )
        self.cache: Dict[Tuple, Dict[str, float]] = {}
        self.n_evals = 0
        self.total_replay_time = 0.0

    # ------------------------------------------------------------------
    # streaming phases (the drifting workload's time axis)
    # ------------------------------------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self._phases)

    @property
    def phase(self) -> int:
        return self._phase

    def set_phase(self, phase: int) -> None:
        """Advance the streaming workload to phase ``phase`` (a window of the
        trace whose base corpus is the visible state at the window start)."""
        if self.workload != "streaming":
            raise ValueError("set_phase is only meaningful for streaming workloads")
        if not 0 <= phase < len(self._phases):
            raise ValueError(f"phase must be in [0, {len(self._phases)}), got {phase}")
        self._phase = int(phase)

    # ------------------------------------------------------------------
    # fleet descriptor view (what an evaluation right now would measure)
    # ------------------------------------------------------------------
    def current_workload(self) -> Tuple[str, Union[WorkloadTrace, VectorDataset]]:
        """``("streaming", active-phase trace)`` or ``("static", dataset)``.

        Fleet :class:`~repro.fleet.descriptor.WorkloadDescriptor`s are
        computed from this view, so tenant similarity tracks the workload the
        tuner is *currently* being scored against (phase-advanced streaming
        tenants re-describe automatically).
        """
        if self.workload == "streaming":
            return "streaming", self._phases[self._phase]
        return "static", self.dataset

    def workload_stats(self) -> Dict[str, float]:
        """Scalar statistics of the current workload view: dimensionality,
        corpus size, top-k, and the operation arrival mix — the raw
        ingredients of a fleet workload descriptor."""
        kind, w = self.current_workload()
        if kind == "streaming":
            n_ops = max(w.n_ops, 1)
            return {
                "dim": float(w.dim),
                "k": float(w.k),
                "corpus": float(w.capacity),
                "n_queries": float(w.n_searches),
                "insert_frac": float(np.sum(w.kinds == OP_INSERT)) / n_ops,
                "search_frac": float(np.sum(w.kinds == OP_SEARCH)) / n_ops,
                "delete_frac": float(np.sum(w.kinds == OP_DELETE)) / n_ops,
            }
        return {
            "dim": float(w.dim),
            "k": float(w.k),
            "corpus": float(w.n),
            "n_queries": float(w.queries.shape[0]),
            "insert_frac": 0.0,
            "search_frac": 1.0,
            "delete_frac": 0.0,
        }

    def _cache_key(self, cfg: Dict[str, Any]) -> Tuple:
        key = self._canon(cfg)
        if self.workload == "streaming":
            key = (("__phase__", self._phase),) + key
        return key

    @staticmethod
    def _canon(cfg: Dict[str, Any]) -> Tuple:
        items = []
        for k in sorted(cfg):
            v = cfg[k]
            if isinstance(v, float):
                v = round(v, 4)
            items.append((k, v))
        return tuple(items)

    def _measure_one(self, cfg: Dict[str, Any]) -> Dict[str, float]:
        """Build + measure one config in the active workload regime (raises
        :class:`TuningFailure` for crashed / timed-out configurations)."""
        if self.workload == "streaming":
            phase = self._phases[self._phase]
            if self._phase_gt[self._phase] is None:
                self._phase_gt[self._phase] = time_aware_ground_truth(phase)
            result = replay_trace(
                phase,
                cfg,
                seed=self.seed,
                mode=self.mode,
                ground_truth=self._phase_gt[self._phase],
                compact_threshold=self.compact_threshold,
                fault_injector=self._fault_injector,
            )
            if result["build_time"] + result["seal_build_s"] > self.build_timeout:
                raise TuningFailure(f"index builds exceeded {self.build_timeout}s")
            return result
        inst = VDMSInstance(self.dataset, cfg, seed=self.seed)
        if inst.build_time > self.build_timeout:
            raise TuningFailure(f"index build exceeded {self.build_timeout}s")
        result = inst.measure(repeats=self.repeats, mode=self.mode)
        del inst
        return result

    def __call__(self, cfg: Dict[str, Any]) -> Dict[str, float]:
        key = self._cache_key(cfg)
        if key in self.cache:
            return dict(self.cache[key])
        t0 = time.perf_counter()
        try:
            result = self._measure_one(cfg)
        except Exception as e:
            # honest taxonomy: config-dependent crashes become TuningFailure
            # (injected/engine faults as *transient* ones); anything else is
            # a programmer error and propagates instead of poisoning the GP
            tf = classify_eval_error(e)
            if tf is None or tf is e:
                raise
            raise tf from e
        finally:
            self.total_replay_time += time.perf_counter() - t0
            self.n_evals += 1
        self.cache[key] = dict(result)
        return result

    # ------------------------------------------------------------------
    # batch evaluation
    # ------------------------------------------------------------------
    def evaluate_batch(
        self, cfgs: Sequence[Dict[str, Any]], max_workers: Optional[int] = None
    ) -> List[Union[Dict[str, float], TuningFailure]]:
        """Evaluate a batch of configurations, exploiting batch structure.

        Pipeline: cache hits and in-batch duplicates are deduplicated; index
        builds for the misses run in a thread pool (analytic mode only —
        ``build_timeout`` is checked against wall-clock build time, so under
        the pool it is approximate; wall mode builds sequentially to keep
        build_time/timeout semantics exact); shape-identical instances (same
        :func:`batch_signature`) are then measured in ONE vectorized dispatch
        via :func:`measure_batch` (analytic mode, where the amortized path is
        exact), while heterogeneous leftovers fall back to per-instance
        measurement — threaded in analytic mode, sequential in wall mode so
        wall-clock timings stay honest.

        Returns one entry per input config, aligned with ``cfgs``: the raw
        result dict, or the ``TuningFailure`` for configs that crashed/timed
        out (this method never raises per-config — callers decide failure
        semantics, e.g. the tuner's worst-value feedback).
        """
        results: List[Any] = [None] * len(cfgs)
        pending: Dict[Tuple, List[int]] = {}
        for i, cfg in enumerate(cfgs):
            key = self._cache_key(cfg)
            if key in self.cache:
                results[i] = dict(self.cache[key])
            else:
                pending.setdefault(key, []).append(i)
        if not pending:
            return results
        keys = list(pending)
        miss_cfgs = [cfgs[pending[k][0]] for k in keys]
        t0 = time.perf_counter()
        try:
            outs = self._evaluate_misses(miss_cfgs, max_workers)
        finally:
            self.total_replay_time += time.perf_counter() - t0
            self.n_evals += len(miss_cfgs)
        for key, out in zip(keys, outs):
            if not isinstance(out, Exception):
                self.cache[key] = dict(out)
            for pos in pending[key]:
                results[pos] = out if isinstance(out, Exception) else dict(out)
        return results

    def _evaluate_misses(
        self, cfgs: Sequence[Dict[str, Any]], max_workers: Optional[int]
    ) -> List[Union[Dict[str, float], TuningFailure]]:
        if self.workload == "streaming":
            # replays are stateful trace walks: no cross-config vectorization,
            # evaluated sequentially (dedupe/caching still applied above)
            outs: List[Any] = []
            for cfg in cfgs:
                try:
                    outs.append(self._measure_one(cfg))
                except Exception as e:
                    tf = classify_eval_error(e)
                    if tf is None:
                        raise  # programmer error — never laundered into feedback
                    outs.append(tf)
            return outs

        def build(cfg: Dict[str, Any]) -> Union[VDMSInstance, TuningFailure]:
            try:
                inst = VDMSInstance(self.dataset, cfg, seed=self.seed)
                if inst.build_time > self.build_timeout:
                    raise TuningFailure(f"index build exceeded {self.build_timeout}s")
                return inst
            except Exception as e:
                tf = classify_eval_error(e)
                if tf is None:
                    raise
                return tf

        def measure_one(inst: VDMSInstance) -> Union[Dict[str, float], TuningFailure]:
            try:
                return inst.measure(repeats=self.repeats, mode=self.mode)
            except Exception as e:
                tf = classify_eval_error(e)
                if tf is None:
                    raise
                return tf

        workers = max_workers or self.batch_workers or min(len(cfgs), os.cpu_count() or 4)
        # Wall mode builds sequentially: each instance's build_time is compared
        # against build_timeout, and concurrent builds inflate wall-clock under
        # contention, spuriously failing configs a sequential run would accept.
        if len(cfgs) == 1 or workers == 1 or self.mode != "analytic":
            built = [build(c) for c in cfgs]
        else:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                built = list(ex.map(build, cfgs))

        outs: List[Any] = [None] * len(cfgs)
        groups: Dict[Tuple, List[int]] = {}
        singles: List[int] = []
        for i, inst in enumerate(built):
            if isinstance(inst, Exception):
                outs[i] = inst
            elif self.mode == "analytic":
                groups.setdefault(batch_signature(inst), []).append(i)
            else:
                singles.append(i)
        for idxs in groups.values():
            if len(idxs) == 1:
                singles.append(idxs[0])
                continue
            try:
                rs = measure_batch(
                    [built[i] for i in idxs], repeats=self.repeats, mode=self.mode
                )
                for i, r in zip(idxs, rs):
                    outs[i] = r
            except (ValueError, ZeroDivisionError, RuntimeError):
                # defensive, not swallowing: the vectorized dispatch failed as
                # a whole, so re-measure per instance — where measure_one's
                # taxonomy assigns (or propagates) each config's own error
                singles.extend(idxs)
        if singles:
            if self.mode == "analytic" and len(singles) > 1 and workers > 1:
                with ThreadPoolExecutor(max_workers=workers) as ex:
                    for i, r in zip(singles, ex.map(lambda i: measure_one(built[i]), singles)):
                        outs[i] = r
            else:
                for i in singles:
                    outs[i] = measure_one(built[i])
        return outs
