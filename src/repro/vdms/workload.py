"""Streaming workload traces: timestamped insert/delete/search streams.

A :class:`WorkloadTrace` is the replayable unit: a pre-replay corpus plus an
operation stream with configurable arrival mixes and a *drift schedule* — a
map from normalized time to a blend weight that moves the distribution of
inserted vectors (and queries) from the base dataset toward a drift target
(by default a different Table-III-style generator, the hardest kind of shift
for a tuned index configuration).

:func:`replay_trace` drives a :class:`~repro.vdms.engine.LiveVDMS` through a
trace — growing-tail appends, seal-and-index events, tombstone deletes with
compaction — and scores recall against *time-aware* ground truth: the exact
top-k over the vectors visible (inserted and not deleted) at each query's
timestamp, computed by :func:`time_aware_ground_truth`.

:func:`replay_query_streams` is the serving-side driver: many concurrent
query streams with Poisson arrivals (:func:`poisson_arrivals`) offered at a
target aggregate rate against any engine exposing the ``search(queries,
topk, mode) -> (ids, elapsed)`` contract (``LiveVDMS``, ``ShardedVDMS``) —
arrivals queue, dispatch in engine-batch-sized multi-stream micro-batches,
and every query is charged its full sojourn (queue wait + service), which is
what makes saturation visible: offered rates above capacity show up as
unbounded sojourn growth, not as a flattering served-QPS number.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .datasets import (
    blend_vectors,
    default_dim,
    exact_topk_masked,
    raw_vectors,
    recall_at_k_masked,
)
from .engine import LiveVDMS

OP_INSERT, OP_SEARCH, OP_DELETE = 0, 1, 2

#: Named drift schedules: normalized time in [0, 1] -> blend weight in [0, 1].
DRIFT_SCHEDULES: Dict[str, Callable[[np.ndarray], np.ndarray]] = {
    "none": lambda t: np.zeros_like(t),
    "ramp": lambda t: t,
    "step": lambda t: (t >= 0.5).astype(np.float64),
    "sine": lambda t: 0.5 - 0.5 * np.cos(2.0 * np.pi * t),
}


@dataclasses.dataclass(frozen=True)
class WorkloadTrace:
    """A replayable operation stream over a live VDMS.

    Global vector ids are assignment-ordered: the pre-replay corpus occupies
    ``0..n_base-1`` and the j-th insert op creates id ``n_base + j``.
    ``payload[i]`` is the row of :attr:`inserts` / :attr:`queries` for
    insert/search ops, and the victim *global id* for delete ops.
    """

    name: str
    dim: int
    k: int
    base: np.ndarray  # (n_base, d) float32, L2-normalized
    kinds: np.ndarray  # (n_ops,) int8 in {OP_INSERT, OP_SEARCH, OP_DELETE}
    payload: np.ndarray  # (n_ops,) int32
    times: np.ndarray  # (n_ops,) float64, nondecreasing, normalized to [0, 1]
    inserts: np.ndarray  # (n_inserts, d) float32, L2-normalized
    queries: np.ndarray  # (n_searches, d) float32, L2-normalized

    @property
    def n_base(self) -> int:
        return self.base.shape[0]

    @property
    def n_ops(self) -> int:
        return self.kinds.shape[0]

    @property
    def n_inserts(self) -> int:
        return self.inserts.shape[0]

    @property
    def n_searches(self) -> int:
        return self.queries.shape[0]

    @property
    def capacity(self) -> int:
        return self.n_base + self.n_inserts

    # ------------------------------------------------------------------
    def all_vectors(self) -> np.ndarray:
        """(capacity, d) vectors in global-id order."""
        return np.concatenate([self.base, self.inserts], axis=0)

    def window(self, lo: int, hi: int) -> "WorkloadTrace":
        """The sub-trace covering ops ``[lo, hi)``: the prefix's inserts and
        deletes are folded into the new base corpus (global ids re-assigned
        densely), so replaying the window starts from exactly the visible
        state at op ``lo``."""
        if not 0 <= lo <= hi <= self.n_ops:
            raise ValueError(f"bad window [{lo}, {hi}) for {self.n_ops} ops")
        all_vec = self.all_vectors()
        dead = np.zeros(self.capacity, dtype=bool)
        n_vis = self.n_base
        for i in range(lo):
            if self.kinds[i] == OP_INSERT:
                n_vis += 1
            elif self.kinds[i] == OP_DELETE:
                dead[self.payload[i]] = True
        vis_ids = np.flatnonzero(~dead[:n_vis])
        new_gid = np.full(self.capacity, -1, np.int64)
        new_gid[vis_ids] = np.arange(vis_ids.size)
        n_base2 = vis_ids.size

        kinds2, payload2, times2 = [], [], []
        ins_rows: List[int] = []
        q_rows: List[int] = []
        for i in range(lo, hi):
            kind = int(self.kinds[i])
            p = int(self.payload[i])
            if kind == OP_INSERT:
                # insert op number within the full trace is recoverable from
                # its global id; here we only need the source row order
                new_gid[self.n_base + p] = n_base2 + len(ins_rows)
                payload2.append(len(ins_rows))
                ins_rows.append(p)
            elif kind == OP_SEARCH:
                payload2.append(len(q_rows))
                q_rows.append(p)
            else:
                mapped = int(new_gid[p])
                if mapped < 0:  # victim already gone before the window
                    continue
                payload2.append(mapped)
            kinds2.append(kind)
            times2.append(float(self.times[i]))
        return WorkloadTrace(
            name=f"{self.name}[{lo}:{hi}]",
            dim=self.dim,
            k=self.k,
            base=all_vec[vis_ids],
            kinds=np.asarray(kinds2, np.int8),
            payload=np.asarray(payload2, np.int32),
            times=np.asarray(times2, np.float64),
            inserts=self.inserts[ins_rows] if ins_rows else np.empty((0, self.dim), np.float32),
            queries=self.queries[q_rows] if q_rows else np.empty((0, self.dim), np.float32),
        )

    def split(self, n_phases: int) -> List["WorkloadTrace"]:
        """Equal-op-count phase windows (the drifting workload's time axis)."""
        if n_phases < 1:
            raise ValueError("n_phases must be >= 1")
        bounds = np.linspace(0, self.n_ops, n_phases + 1).astype(int)
        return [self.window(int(a), int(b)) for a, b in zip(bounds[:-1], bounds[1:])]


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------
def _norm_mix(mix, label: str) -> np.ndarray:
    arr = np.asarray(mix, np.float64)
    if arr.shape != (3,) or (arr < 0).any() or arr.sum() <= 0:
        raise ValueError(f"{label} must be 3 nonnegative weights, got {mix!r}")
    return arr / arr.sum()


def make_trace(
    name: str,
    n_base: int = 4096,
    n_ops: int = 1024,
    mix: Tuple[float, float, float] = (0.25, 0.70, 0.05),
    drift: str = "none",
    drift_to: Optional[str] = None,
    mix_to: Optional[Tuple[float, float, float]] = None,
    k: int = 10,
    dim: Optional[int] = None,
    seed: int = 0,
) -> WorkloadTrace:
    """Generate a streaming trace over a Table-III-style dataset.

    ``mix`` is the (insert, search, delete) arrival mix; op kinds are drawn
    iid and timestamps from a Poisson-like arrival process (normalized to
    [0, 1]). ``drift`` names a :data:`DRIFT_SCHEDULES` entry driving two
    drift axes with the schedule's weight at each op's timestamp:

    * *distribution* drift — inserted vectors and queries blend toward
      ``drift_to`` (default: a different generator family, the shift that
      moves which index parameters work);
    * *arrival-mix* drift — with ``mix_to`` given, the op-kind probabilities
      interpolate from ``mix`` to ``mix_to`` (e.g. search-heavy to
      insert-heavy: the insert-pressure shift that moves the seal-policy /
      graceful-window optimum, paper Fig. 1–2).
    """
    if drift not in DRIFT_SCHEDULES:
        raise ValueError(f"unknown drift {drift!r}; choose from {sorted(DRIFT_SCHEDULES)}")
    mix_arr = _norm_mix(mix, "mix")
    mix_to_arr = _norm_mix(mix_to, "mix_to") if mix_to is not None else mix_arr
    if n_base < 1:
        raise ValueError("n_base must be >= 1 (deletes need a victim pool)")
    rng = np.random.default_rng(seed)
    dim = dim or default_dim(name)
    if drift_to is None:
        drift_to = "keyword_like" if name != "keyword_like" else "glove_like"
    schedule = DRIFT_SCHEDULES[drift]

    gaps = rng.exponential(1.0, size=n_ops)
    times = np.cumsum(gaps)
    times = times / times[-1] if n_ops else times
    w_ops = schedule(times)[:, None]
    p = (1.0 - w_ops) * mix_arr[None, :] + w_ops * mix_to_arr[None, :]
    u = rng.random(n_ops)
    kinds = np.where(u < p[:, 0], OP_INSERT, np.where(u < p[:, 0] + p[:, 1], OP_SEARCH, OP_DELETE)).astype(np.int8)

    base = blend_vectors(raw_vectors(name, rng, n_base, dim), np.zeros((n_base, dim)), np.zeros(n_base))

    ins_idx = np.flatnonzero(kinds == OP_INSERT)
    q_idx = np.flatnonzero(kinds == OP_SEARCH)
    n_ins, n_q = ins_idx.size, q_idx.size
    a_ins = raw_vectors(name, rng, n_ins, dim) if n_ins else np.empty((0, dim))
    b_ins = raw_vectors(drift_to, rng, n_ins, dim) if n_ins else np.empty((0, dim))
    a_q = raw_vectors(name, rng, n_q, dim) if n_q else np.empty((0, dim))
    b_q = raw_vectors(drift_to, rng, n_q, dim) if n_q else np.empty((0, dim))
    inserts = (blend_vectors(a_ins, b_ins, schedule(times[ins_idx])) if n_ins else np.empty((0, dim), np.float32))
    queries = (blend_vectors(a_q, b_q, schedule(times[q_idx])) if n_q else np.empty((0, dim), np.float32))

    # payloads: sequential rows for inserts/searches; sampled victims for
    # deletes (uniform over the currently-alive ids, never repeated)
    payload = np.zeros(n_ops, np.int32)
    payload[ins_idx] = np.arange(n_ins, dtype=np.int32)
    payload[q_idx] = np.arange(n_q, dtype=np.int32)
    alive: List[int] = list(range(n_base))
    n_inserted = 0
    dropped: List[int] = []
    for i in np.flatnonzero(kinds != OP_SEARCH):
        if kinds[i] == OP_INSERT:
            alive.append(n_base + n_inserted)
            n_inserted += 1
        elif alive:
            j = int(rng.integers(len(alive)))
            payload[i] = alive.pop(j)
        else:  # victim pool exhausted under a delete-heavy mix: drop the op
            dropped.append(int(i))
    if dropped:
        keep = np.ones(n_ops, dtype=bool)
        keep[dropped] = False
        kinds, payload, times = kinds[keep], payload[keep], times[keep]
    return WorkloadTrace(
        name=f"{name}/{drift}->{drift_to}",
        dim=dim,
        k=k,
        base=base,
        kinds=kinds,
        payload=payload,
        times=times,
        inserts=inserts,
        queries=queries,
    )


# ---------------------------------------------------------------------------
# time-aware ground truth
# ---------------------------------------------------------------------------
def time_aware_ground_truth(trace: WorkloadTrace, k: Optional[int] = None) -> np.ndarray:
    """Exact top-k for every search op over the vectors *visible at its
    timestamp*: inserted before it and not yet deleted. Rows are ordered by
    search op (aligned with ``trace.queries``); short visible sets pad with
    -1. This is the oracle the engine's bounded-consistency searches are
    scored against.
    """
    k = k or trace.k
    all_vec = trace.all_vectors()
    dead = np.zeros(trace.capacity, dtype=bool)
    n_vis = trace.n_base
    out = -np.ones((trace.n_searches, k), np.int32)
    pending: List[int] = []  # search payload rows awaiting the current state

    def flush():
        if not pending:
            return
        rows = np.asarray(pending, np.int64)
        out[rows] = exact_topk_masked(all_vec[:n_vis], trace.queries[rows], dead[:n_vis], k)
        pending.clear()

    for i in range(trace.n_ops):
        kind = int(trace.kinds[i])
        if kind == OP_SEARCH:
            pending.append(int(trace.payload[i]))
            continue
        flush()
        if kind == OP_INSERT:
            n_vis += 1
        else:
            dead[trace.payload[i]] = True
    flush()
    return out


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------
def replay_trace(
    trace: WorkloadTrace,
    config: Dict[str, Any],
    seed: int = 0,
    mode: str = "analytic",
    topk: Optional[int] = None,
    ground_truth: Optional[np.ndarray] = None,
    compact_threshold: float = 0.3,
    with_live: bool = False,
    search_hooks: Sequence[Callable] = (),
    fault_injector=None,
):
    """Replay a trace under one configuration and measure the paper's
    objectives in the streaming regime.

    Returns a flat float dict (an ``EvalBackend`` raw result): ``speed`` is
    search throughput (consecutive searches are micro-batched, insert/delete
    barriers respected), ``recall`` is time-aware recall@k against
    :func:`time_aware_ground_truth`, ``mem_gib`` is the peak footprint,
    ``lat_p50_s``/``lat_p95_s``/``lat_p99_s`` are per-query wall-latency
    percentiles over the whole replay, and the ingest side reports
    ``seal_build_s`` (incremental seal + compaction builds), ``n_seals`` and
    ``n_compactions``. ``search_hooks`` are attached to the live instance's
    per-search instrumentation (``fn(n_queries, latencies, elapsed)`` — the
    serving metrics ledger's feed). With ``with_live=True`` also returns the
    finished :class:`LiveVDMS` (diagnostics: seal history, visible ids) as a
    second value. ``fault_injector`` arms a
    :class:`~repro.vdms.faults.FaultInjector` on the live instance *after*
    bootstrap (the fault clock ticks over replayed ops, not bulk-load
    inserts); the result then additionally reports ``coverage_min``,
    ``n_quarantines`` and ``n_rebuilds`` — absent without an injector, so
    fault-free results stay byte-identical.
    """
    k = topk or trace.k
    gt = ground_truth if ground_truth is not None else time_aware_ground_truth(trace, k)
    live = LiveVDMS(config, trace.dim, trace.capacity, seed=seed, compact_threshold=compact_threshold)
    live.search_hooks.extend(search_hooks)
    live.bootstrap(trace.base)
    if fault_injector is not None:
        live.arm_faults(fault_injector)
    coverage_min = 1.0
    preds = -np.ones((trace.n_searches, k), np.int32)
    lat_all: List[np.ndarray] = []
    search_s = 0.0
    peak_mem = live.memory_gib()
    pending: List[int] = []

    def flush():
        nonlocal search_s, coverage_min
        if not pending:
            return
        rows = np.asarray(pending, np.int64)
        ids, secs = live.search(trace.queries[rows], k, mode=mode)
        preds[rows] = ids
        lat_all.append(live.last_latencies)
        search_s += secs
        coverage_min = min(coverage_min, live.last_coverage)
        pending.clear()

    for i in range(trace.n_ops):
        kind = int(trace.kinds[i])
        if kind == OP_SEARCH:
            pending.append(int(trace.payload[i]))
            continue
        flush()
        if kind == OP_INSERT:
            live.insert(trace.inserts[trace.payload[i]])
        else:
            live.delete(int(trace.payload[i]))
        peak_mem = max(peak_mem, live.memory_gib())
    flush()
    peak_mem = max(peak_mem, live.memory_gib())

    n_searches = trace.n_searches
    stats = live.stats()
    lats = np.concatenate(lat_all) if lat_all else np.empty(0, np.float64)
    p50, p95, p99 = (
        np.percentile(lats, (50.0, 95.0, 99.0)) if lats.size else (0.0, 0.0, 0.0)
    )
    # analytic mode charges the deterministic build model for ingest overhead
    # (wall-clock build noise would leak into the tuning objective otherwise)
    seal_build = stats["seal_build_model_s"] if mode == "analytic" else stats["seal_build_s"]
    result = {
        "speed": float(n_searches / max(search_s, 1e-9)),
        "recall": float(recall_at_k_masked(preds[:, : trace.k], gt[:, : trace.k])),
        "mem_gib": float(peak_mem),
        "build_time": float(stats["build_time"]),
        "compile_time": float(stats["compile_s"]),
        "seal_build_s": float(seal_build),
        "search_s": float(search_s),
        "n_searches": float(n_searches),
        "n_seals": float(stats["n_seals"]),
        "n_compactions": float(stats["n_compactions"]),
        "tombstone_fraction": float(stats["tombstone_fraction"]),
        "lat_p50_s": float(p50),
        "lat_p95_s": float(p95),
        "lat_p99_s": float(p99),
    }
    if fault_injector is not None:
        result["coverage_min"] = float(coverage_min)
        result["n_quarantines"] = float(stats["n_quarantines"])
        result["n_rebuilds"] = float(stats["n_rebuilds"])
    return (result, live) if with_live else result


# ---------------------------------------------------------------------------
# high-rate multi-stream Poisson serving driver
# ---------------------------------------------------------------------------
def poisson_arrivals(
    rate: float, n: int, seed: int = 0, t0: float = 0.0
) -> np.ndarray:
    """Arrival timestamps of a Poisson process: ``n`` events at ``rate``
    events/second starting after ``t0`` (exponential i.i.d. gaps)."""
    if rate <= 0.0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    return t0 + np.cumsum(rng.exponential(1.0 / rate, size=int(n)))


def make_query_streams(
    queries: np.ndarray,
    n_streams: int,
    rate: float,
    n_per_stream: int,
    seed: int = 0,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """``n_streams`` independent Poisson query streams at ``rate / n_streams``
    each (their superposition is Poisson at the aggregate ``rate``). Each
    stream cycles through its round-robin slice of ``queries``. Returns
    ``[(arrival_times, query_row_indices), ...]`` per stream."""
    if n_streams < 1:
        raise ValueError(f"n_streams must be >= 1, got {n_streams}")
    nq = queries.shape[0]
    streams = []
    for s in range(n_streams):
        times = poisson_arrivals(rate / n_streams, n_per_stream, seed=seed * 1000 + s)
        rows = (s + np.arange(n_per_stream, dtype=np.int64) * n_streams) % nq
        streams.append((times, rows.astype(np.int32)))
    return streams


def replay_query_streams(
    engine,
    queries: np.ndarray,
    *,
    rate: float,
    n_streams: int = 8,
    n_per_stream: int = 64,
    topk: int = 10,
    mode: str = "analytic",
    seed: int = 0,
) -> Dict[str, float]:
    """Offer multi-stream Poisson load to an engine and measure sustained
    serving behavior.

    The merged arrival sequence drains through a single batching server:
    when the engine frees up, every queued arrival (capped at the engine's
    ``search_batch_size``) dispatches as ONE multi-stream micro-batch —
    padded to the full batch width so the compiled chunk shape never churns,
    exactly the shape the engine would serve in production. Service time is
    the engine's measured ``elapsed`` (deterministic under
    ``mode="analytic"``); each query's latency is its full sojourn
    (queue wait + service).

    Returns offered vs served QPS, sojourn percentiles, utilization, and a
    ``saturated`` flag (mean sojourn of the last quarter more than 4x the
    first quarter — the queue is growing without bound).
    """
    queries = np.asarray(queries, np.float32)
    streams = make_query_streams(queries, n_streams, rate, n_per_stream, seed=seed)
    arr = np.concatenate([t for t, _ in streams])
    rows = np.concatenate([r for _, r in streams])
    stream_of = np.concatenate(
        [np.full(t.size, s, np.int32) for s, (t, _) in enumerate(streams)]
    )
    order = np.argsort(arr, kind="stable")
    arr, rows, stream_of = arr[order], rows[order], stream_of[order]
    n = arr.size
    batch = int(getattr(engine, "batch", 32))
    sojourn = np.zeros(n, np.float64)
    t_free = 0.0
    busy = 0.0
    n_batches = 0
    i = 0
    while i < n:
        start = max(t_free, float(arr[i]))
        j = i + 1
        while j < n and arr[j] <= start and j - i < batch:
            j += 1
        idx = np.arange(i, j)
        qrows = queries[rows[idx]]
        if qrows.shape[0] < batch:  # pad to the production chunk shape
            wrap = np.tile(qrows, (-(-batch // qrows.shape[0]), 1))[:batch]
            qrows = wrap
        _, service = engine.search(qrows, topk, mode=mode)
        done = start + service
        sojourn[idx] = done - arr[idx]
        t_free = done
        busy += service
        n_batches += 1
        i = j
    makespan = max(t_free - float(arr[0]), 1e-9)
    q1 = sojourn[: max(n // 4, 1)].mean()
    q4 = sojourn[-max(n // 4, 1) :].mean()
    per_stream = np.bincount(stream_of, minlength=n_streams)
    return {
        "offered_qps": float(rate),
        "served_qps": float(n / makespan),
        "n_queries": float(n),
        "n_streams": float(n_streams),
        "n_batches": float(n_batches),
        "mean_batch_occupancy": float(n / max(n_batches, 1)),
        "utilization": float(busy / makespan),
        "sojourn_p50_s": float(np.percentile(sojourn, 50.0)),
        "sojourn_p95_s": float(np.percentile(sojourn, 95.0)),
        "sojourn_p99_s": float(np.percentile(sojourn, 99.0)),
        "saturated": float(q4 > 4.0 * max(q1, 1e-9)),
        "min_stream_queries": float(per_stream.min()),
    }
