"""Jittable spherical k-means (Lloyd) used by the IVF-family indexes.

Centroids are re-normalized every iteration (angular metric). Empty clusters
keep their previous centroid. Shapes are static so repeated builds with
grid-quantized (k, iters) hit the jit cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: jax.Array, x: jnp.ndarray, k: int, iters: int):
    """x: (n, d) normalized. Returns (centroids (k, d), assign (n,))."""
    n, d = x.shape
    init_idx = jax.random.choice(key, n, shape=(k,), replace=False)
    cent = x[init_idx]

    def body(cent, _):
        sim = x @ cent.T  # (n, k)
        assign = jnp.argmax(sim, axis=1)
        one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (n, k)
        sums = one_hot.T @ x  # (k, d)
        counts = one_hot.sum(axis=0)[:, None]  # (k, 1)
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), cent)
        new = new / (jnp.linalg.norm(new, axis=1, keepdims=True) + 1e-12)
        return new, None

    cent, _ = jax.lax.scan(body, cent, None, length=iters)
    assign = jnp.argmax(x @ cent.T, axis=1)
    return cent, assign


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_l2(key: jax.Array, x: jnp.ndarray, k: int, iters: int):
    """Plain (non-spherical) Lloyd for PQ sub-codebooks."""
    n, d = x.shape
    init_idx = jax.random.choice(key, n, shape=(k,), replace=False)
    cent = x[init_idx]

    def body(cent, _):
        d2 = (
            jnp.sum(x * x, 1)[:, None]
            - 2.0 * x @ cent.T
            + jnp.sum(cent * cent, 1)[None, :]
        )
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)
        sums = one_hot.T @ x
        counts = one_hot.sum(axis=0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(body, cent, None, length=iters)
    d2 = (
        jnp.sum(x * x, 1)[:, None]
        - 2.0 * x @ cent.T
        + jnp.sum(cent * cent, 1)[None, :]
    )
    assign = jnp.argmin(d2, axis=1)
    return cent, assign
