"""Top-k merge arithmetic — the ONE implementation every search path shares.

Historically the engine's static chunk merge (``_pipeline_impl``), the live
tombstone merge (``_live_chunk``) and the fused hooks' epilogues
(``fused._merge_static`` / ``fused._merge_live``) each carried a line-for-line
copy of the same arithmetic. This module is the extraction: the composed and
fused pipelines call :func:`merge_topk`, and the sharded engine's two-level
merge tree is built from the same primitives (:func:`flatten_candidates`,
:func:`partial_topk`, :func:`merge_flat`), so a change to the merge semantics
lands everywhere at once — there is no second copy left to drift.

Semantics (unchanged from the original engine code, bitwise):

* per-segment candidates ``(n_seg, B, k_seg)`` flatten query-major to
  ``(B, n_seg * k_seg)`` — flat position = ``segment * k_seg + slot``, which
  is the tie-break order (``lax.top_k`` keeps the lowest index among equal
  scores);
* ``alive`` (live merge only) gates every candidate through the global alive
  mask; id ``-1`` maps to the always-dead sentinel slot ``alive[-1]``;
* the growing tail is brute-forced and its best ``min(topk, len)`` candidates
  are appended AFTER all segment candidates (ties lose to sealed results);
  the live flavor additionally masks tail pad rows (gid < 0) to ``-inf``;
* the final ``top_k`` keeps ``min(topk, width)`` winners; the live flavor
  reports ``-inf`` survivors as id ``-1``; missing width pads with ``-1``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops


def flatten_candidates(ids, sims, alive=None):
    """Flatten per-segment candidates (n_seg, B, k) to flat per-query lists
    (B, n_seg * k), optionally gating scores through the global ``alive``
    mask (id -1 hits the always-dead sentinel slot ``alive[-1]``)."""
    n_seg, b, ks = ids.shape
    ids2 = jnp.moveaxis(ids, 0, 1).reshape(b, n_seg * ks)
    sims2 = jnp.moveaxis(sims, 0, 1).reshape(b, n_seg * ks)
    if alive is not None:
        sentinel = alive.shape[0] - 1
        ok = alive[jnp.where(ids2 >= 0, ids2, sentinel)]
        sims2 = jnp.where(ok, sims2, -jnp.inf)
    return ids2, sims2


def partial_topk(ids, sims, k, alive=None):
    """One leaf of the merge tree: flatten a shard's per-segment candidates
    and keep its best ``min(k, width)`` — scores included, so a root merge
    can finish the reduction. Tie-break and alive gating are identical to
    the full merge; prefiltering a flat list to its top-k preserves the
    global winners because at most ``k`` of them can come from one shard."""
    ids2, sims2 = flatten_candidates(ids, sims, alive=alive)
    return ops.topk_by_score(ids2, sims2, min(k, sims2.shape[1]))


def merge_flat(ids2, sims2, q, growing, growing_gids, topk, *, live: bool,
               return_scores: bool = False):
    """Root of the merge: append the growing-tail candidates to flat
    per-query lists (B, W) and keep the global top-k. ``live`` selects the
    tombstone flavor (masked tail gids, -inf survivors become id -1)."""
    if growing.shape[0] > 0:
        gs = jnp.dot(q, growing.T.astype(q.dtype), preferred_element_type=jnp.float32)
        if live:
            gs = jnp.where(growing_gids[None, :] >= 0, gs, -jnp.inf)
        gk = min(topk, growing.shape[0])
        gtop_s, gtop_i = jax.lax.top_k(gs, gk)
        ids2 = jnp.concatenate([ids2, growing_gids[gtop_i]], axis=1)
        sims2 = jnp.concatenate([sims2, gtop_s], axis=1)
    k = min(topk, sims2.shape[1])
    out, top_s = ops.topk_by_score(ids2, sims2, k)
    if live:
        out = jnp.where(jnp.isfinite(top_s), out, -1)
    if k < topk:
        out = jnp.pad(out, ((0, 0), (0, topk - k)), constant_values=-1)
        if return_scores:
            top_s = jnp.pad(top_s, ((0, 0), (0, topk - k)), constant_values=-jnp.inf)
    if return_scores:
        return out, top_s
    return out


def merge_topk(ids, sims, q, growing, growing_gids, topk, alive=None,
               return_scores: bool = False):
    """Merge per-segment candidates (n_seg, B, k_seg) with the growing tail
    into (B, topk) global ids. ``alive=None`` is the static merge
    (``_pipeline_impl``); a mask selects the live merge (``_live_chunk``)."""
    ids2, sims2 = flatten_candidates(ids, sims, alive=alive)
    return merge_flat(
        ids2, sims2, q, growing, growing_gids, topk,
        live=alive is not None, return_scores=return_scores,
    )
