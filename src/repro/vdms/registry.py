"""Declarative index-family registry: ONE spec per family drives everything.

An :class:`IndexFamily` carries the complete tuning-facing knowledge about
one ANNS index family — its tunable :class:`~repro.core.space.Param` specs
(with defaults), its build/search callables, the calibration arrays frozen
across incremental builds, capability flags, and the analytic cost-model
hooks. Every consumer derives from the registry instead of hand-coding
per-family tables:

* :func:`make_space` derives the holistic ``SearchSpace`` (the paper's
  non-fixed parameter space, §II-B Table I) from the registered families;
* ``indexes.build_index`` / ``indexes.search_index`` and the bundle
  lifecycle ops (``frozen_state`` / ``concat_bundles`` /
  ``replace_segment``) dispatch through the registry;
* the engine's analytic search/build cost models ask the family for its
  FLOP formulas;
* ``LiveVDMS`` gates the streaming seal path on ``supports_incremental``.

Adding a family is therefore ONE :func:`register_family` call — no edits to
``core/space.py``, ``tuning_env.py``, or the session layer (see
``repro.vdms.ivf_pqr`` for a complete worked example, and the README
"Extending" section).

The seven built-in families register themselves when ``repro.vdms.indexes``
imports; public lookups here trigger that import lazily so the registry is
never observed half-populated.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional, Sequence, Tuple

from ..core.space import Param, SearchSpace

#: build(key, segs, gids, params, sys, frozen=None) -> IndexBundle
BuildFn = Callable[..., Any]
#: search(q, arrays, *, k_seg, **static) -> (ids, sims), each (n_seg, B, k_seg)
SearchFn = Callable[..., Tuple[Any, Any]]
#: chunk_cost(static, arrays, n_sealed, seg_size, dim) -> (flops, seq_steps)
ChunkCostFn = Callable[[Dict[str, Any], Dict[str, Any], int, int, int], Tuple[float, int]]
#: build_cost(config, seg_size, dim, first_build) -> flops beyond the storage pass
BuildCostFn = Callable[[Dict[str, Any], int, int, bool], float]
#: fused_search(q, arrays, growing, growing_gids, *, k_seg, topk,
#:              clamp=False, alive=None, **static) -> (B, topk) global ids
FusedSearchFn = Callable[..., Any]
#: shard_search(q, arrays, *, k_seg, **static) -> (ids, sims), each
#: (n_seg_local, B, k_seg) with GLOBAL ids and composed masking (-1/-inf)
ShardSearchFn = Callable[..., Tuple[Any, Any]]


@dataclasses.dataclass(frozen=True)
class IndexFamily:
    """One declarative index-family spec (the unit of registration).

    ``build`` must accept ``(key, segs, gids, params, sys, frozen=None)`` and
    return an ``IndexBundle`` whose ``kind`` equals :attr:`name` (or
    :attr:`builds_kind` when the family delegates to another family's bundle
    layout, like AUTOINDEX building IVF_FLAT bundles). ``search`` receives
    the bundle's arrays and statics as keyword arguments.

    ``shared_arrays`` names the bundle arrays that hold segment-shared
    calibration state (quantizer scales, PQ codebooks): ``frozen_state``
    extracts exactly these, incremental builds re-inject them via
    ``frozen=``, and the bundle lifecycle ops never concatenate them.

    ``chunk_cost`` / ``build_cost`` back the engine's deterministic analytic
    mode; a family may omit them (``None``) and analytic search cost falls
    back to an exhaustive-scan estimate while build cost charges only the
    storage pass.

    ``fused_search`` is the OPTIONAL fused-pipeline hook: a callable
    replacing the whole per-chunk hot path (probe, scan, per-segment top-k,
    gid mapping, growing-tail merge) in one fused call — see
    ``repro.vdms.fused`` and ``docs/KERNELS.md``. Families that omit it
    (``None``) always run their composed ``search`` through the engine's
    generic merge; the engine falls back automatically, so registering a
    hook is purely a performance opt-in with identical result sets.

    ``shard_search`` is the OPTIONAL sharded-serving hook: the candidate
    stage the sharded engine runs per shard under ``shard_map`` (fused
    kernels over the shard's local segment stack, returning per-segment
    GLOBAL ids + sims with composed masking). Families that omit it fall
    back to their composed ``search`` inside each shard — same results,
    sharding works for every family either way. See ``docs/SHARDING.md``.
    """

    name: str
    params: Tuple[Param, ...]
    build: BuildFn
    search: SearchFn
    shared_arrays: Tuple[str, ...] = ()
    fused_search: Optional[FusedSearchFn] = None
    shard_search: Optional[ShardSearchFn] = None
    supports_frozen: bool = False
    supports_incremental: bool = True
    builds_kind: Optional[str] = None  # bundle kind produced by build (default: name)
    chunk_cost: Optional[ChunkCostFn] = None
    build_cost: Optional[BuildCostFn] = None
    description: str = ""

    def __post_init__(self):
        if not self.name or not self.name.replace("_", "").isalnum():
            raise ValueError(f"invalid family name {self.name!r}")
        if not callable(self.build) or not callable(self.search):
            raise TypeError(f"{self.name}: build and search must be callable")
        object.__setattr__(self, "params", tuple(self.params))
        for p in self.params:
            if not isinstance(p, Param):
                raise TypeError(f"{self.name}: params must be Param specs, got {p!r}")
        object.__setattr__(self, "shared_arrays", tuple(self.shared_arrays))
        if self.supports_frozen and not self.shared_arrays:
            raise ValueError(
                f"{self.name}: supports_frozen=True requires shared_arrays naming "
                "the calibration state to freeze"
            )
        if self.fused_search is not None and not callable(self.fused_search):
            raise TypeError(f"{self.name}: fused_search must be callable or None")
        if self.shard_search is not None and not callable(self.shard_search):
            raise TypeError(f"{self.name}: shard_search must be callable or None")

    @property
    def kind(self) -> str:
        """Bundle ``kind`` this family's build produces."""
        return self.builds_kind or self.name


class IndexFamilyRegistry:
    """Ordered name -> :class:`IndexFamily` mapping with a public hook."""

    def __init__(self):
        self._families: Dict[str, IndexFamily] = {}

    # -- registration ---------------------------------------------------
    def register(self, family: IndexFamily, *, replace: bool = False) -> IndexFamily:
        if not isinstance(family, IndexFamily):
            raise TypeError(f"expected an IndexFamily, got {type(family).__name__}")
        if family.name in self._families and not replace:
            raise ValueError(
                f"index family {family.name!r} is already registered "
                "(pass replace=True to override)"
            )
        if family.builds_kind is not None and family.builds_kind not in self._families:
            raise ValueError(
                f"{family.name}: builds_kind={family.builds_kind!r} is not a "
                f"registered family; registered: {sorted(self._families)}"
            )
        self._families[family.name] = family
        return family

    def unregister(self, name: str) -> IndexFamily:
        if name not in self._families:
            raise ValueError(self._unknown(name))
        return self._families.pop(name)

    @contextlib.contextmanager
    def temporary(self, family: IndexFamily) -> Iterator[IndexFamily]:
        """Register ``family`` for the duration of a ``with`` block (tests)."""
        self.register(family)
        try:
            yield family
        finally:
            self._families.pop(family.name, None)

    # -- lookup ---------------------------------------------------------
    def _unknown(self, name: str) -> str:
        return (
            f"unknown index family {name!r}; registered families: "
            f"{sorted(self._families)}"
        )

    def get(self, name: str) -> IndexFamily:
        try:
            return self._families[name]
        except KeyError:
            raise ValueError(self._unknown(name)) from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._families)

    def families(self) -> Tuple[IndexFamily, ...]:
        return tuple(self._families.values())

    def __contains__(self, name: object) -> bool:
        return name in self._families

    def __iter__(self) -> Iterator[str]:
        return iter(self._families)

    def __len__(self) -> int:
        return len(self._families)


#: The process-wide registry every dispatch path consults.
REGISTRY = IndexFamilyRegistry()


def _ensure_builtins() -> None:
    # the built-in families register on repro.vdms.indexes import; lazy so
    # `import repro.vdms.registry` alone never sees a half-populated registry
    from . import indexes  # noqa: F401


# ---------------------------------------------------------------------------
# public hook
# ---------------------------------------------------------------------------
def register_family(family: IndexFamily, *, replace: bool = False) -> IndexFamily:
    """THE extension point: one call makes a family tunable end-to-end
    (search space, engine dispatch, streaming seal path, analytic mode)."""
    _ensure_builtins()
    return REGISTRY.register(family, replace=replace)


def unregister_family(name: str) -> IndexFamily:
    _ensure_builtins()
    return REGISTRY.unregister(name)


def temporary_family(family: IndexFamily):
    """Context manager registering ``family`` only inside a ``with`` block."""
    _ensure_builtins()
    return REGISTRY.temporary(family)


def get_family(name: str) -> IndexFamily:
    _ensure_builtins()
    return REGISTRY.get(name)


def registered_families() -> Tuple[IndexFamily, ...]:
    _ensure_builtins()
    return REGISTRY.families()


def registered_names() -> Tuple[str, ...]:
    _ensure_builtins()
    return REGISTRY.names()


# ---------------------------------------------------------------------------
# registry-derived search space
# ---------------------------------------------------------------------------
_SEGMENT_SIZES = (1024, 2048, 4096, 8192)

#: System parameters shared by every index family (paper §V-A): these are
#: engine-level knobs, so they live with the registry rather than any family.
SYSTEM_PARAMS: Tuple[Param, ...] = (
    Param("segment_max_size", "grid", choices=_SEGMENT_SIZES, default=4096),
    Param("seal_proportion", "float", 0.1, 1.0, default=0.75),
    Param("graceful_time", "float", 0.0, 0.9, default=0.2),
    Param("search_batch_size", "grid", choices=(8, 16, 32, 64, 128), default=32),
    Param("topk_merge_width", "grid", choices=(16, 32, 64, 128), default=64),
    Param("kmeans_iters", "grid", choices=(4, 8, 16, 25), default=8),
    Param("storage_bf16", "cat", choices=(False, True), default=False),
)


def make_space(include: Optional[Sequence[str]] = None) -> SearchSpace:
    """Derive the holistic search space from the registry.

    With ``include=None`` every registered family contributes its declared
    ``Param`` specs, in registration order — for the seven built-ins this is
    bit-identical to the historical hand-coded space (same params, defaults,
    and encoding-column order, so existing GP checkpoints restore unchanged).
    ``include`` restricts the space to a subset of families (validated
    against the registry; registration order is preserved regardless of the
    order given).
    """
    _ensure_builtins()
    families = REGISTRY.families()
    if include is not None:
        wanted = tuple(include)
        unknown = sorted(set(wanted) - set(REGISTRY.names()))
        if unknown:
            raise ValueError(
                f"unknown index families {unknown}; registered families: "
                f"{sorted(REGISTRY.names())}"
            )
        families = tuple(f for f in families if f.name in wanted)
        if not families:
            raise ValueError("include= selected no families")
    return SearchSpace.from_families(families, SYSTEM_PARAMS)


# ---------------------------------------------------------------------------
# documentation
# ---------------------------------------------------------------------------
def registry_table(families: Optional[Sequence[IndexFamily]] = None) -> str:
    """Markdown table of families (name -> params -> capabilities); the
    README embeds it between ``registry-table`` markers and a doc-sync test
    keeps the two in lockstep."""
    families = tuple(families) if families is not None else registered_families()
    rows = [
        "| Family | Index params (default) | Frozen calibration | Incremental |",
        "|---|---|---|---|",
    ]
    for f in families:
        params = ", ".join(f"`{p.name}`={p.default}" for p in f.params) or "—"
        frozen = ", ".join(f"`{a}`" for a in f.shared_arrays) if f.supports_frozen else "—"
        incr = "yes" if f.supports_incremental else "no"
        rows.append(f"| `{f.name}` | {params} | {frozen} | {incr} |")
    return "\n".join(rows)


def fused_pipeline_table(families: Optional[Sequence[IndexFamily]] = None) -> str:
    """Markdown table of per-family search pipelines (fused vs composed);
    the README embeds it between ``fused-table`` markers and a doc-sync test
    keeps the two in lockstep. ``Fused stages`` comes from the hook's
    ``stages`` attribute so the table always reflects the registered code."""
    families = tuple(families) if families is not None else registered_families()
    rows = [
        "| Family | Search pipeline | Fused stages | Frozen calibration |",
        "|---|---|---|---|",
    ]
    for f in families:
        fused = f.fused_search is not None
        pipe = "fused (composed fallback)" if fused else "composed"
        stages = getattr(f.fused_search, "stages", "—") if fused else "—"
        frozen = ", ".join(f"`{a}`" for a in f.shared_arrays) if f.supports_frozen else "—"
        rows.append(f"| `{f.name}` | {pipe} | {stages} | {frozen} |")
    return "\n".join(rows)


def shard_pipeline_table(families: Optional[Sequence[IndexFamily]] = None) -> str:
    """Markdown table of per-family sharded candidate stages (the
    ``shard_search`` hooks); ``docs/SHARDING.md`` embeds it between
    ``shard-pipeline`` markers and a doc-sync test keeps the two in
    lockstep. Families without a hook run their composed ``search`` inside
    each shard — the merge tree above is family-independent either way."""
    families = tuple(families) if families is not None else registered_families()
    rows = [
        "| Family | Per-shard candidate stage | Stages |",
        "|---|---|---|",
    ]
    for f in families:
        hooked = f.shard_search is not None
        pipe = "fused shard hook" if hooked else "composed `search` fallback"
        stages = getattr(f.shard_search, "stages", "—") if hooked else "—"
        rows.append(f"| `{f.name}` | {pipe} | {stages} |")
    return "\n".join(rows)
