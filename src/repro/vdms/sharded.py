"""Sharded multi-device segment serving: sealed segments across a mesh.

``ShardedVDMS`` takes the engine's segment-native layout to its logical
scaling conclusion: sealed segments are *embarrassingly parallel* — each is
searched independently and only the per-segment top-k lists meet at the
merge — so a corpus that outgrows one device is placed across a 1-D
``("shard",)`` mesh (``distributed.make_shard_mesh``) via the existing
:class:`~repro.distributed.sharding.ShardingRules` machinery and searched
under ``shard_map`` with a two-level on-device top-k merge tree:

* **leaf (per shard)**: the family's fused ``shard_search`` hook (or its
  composed ``search`` fallback) scores the shard's local segment stack, then
  ``merge.partial_topk`` folds the per-segment candidates — alive-mask
  gating included — into one ``(B, k_shard)`` partial list;
* **root (replicated)**: the partial lists concatenate in shard order and
  ``merge.merge_flat`` finishes the reduction together with the replicated
  growing tail — literally the same arithmetic the single-device engine
  runs (``repro.vdms.merge``), which is why ``n_shards=1`` results are
  bit-identical and any shard count returns the same (gid, score) sets.

Placement (``distributed.segment_placement``) is contiguous blocks with dead
tail padding, so concatenating shard-local stacks in shard order reproduces
the unsharded segment order and every tie-break (lowest flat ``(segment,
slot)`` index) lands exactly where the unsharded merge puts it.

Dispatch modes: ``shard_map`` (real devices, or host-emulated via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), ``vmap`` (shard
axis batched on one device — same math, no parallelism; what the test suite
uses when the mesh is bigger than the machine), and a direct single-device
path for ``n_shards=1``. See ``docs/SHARDING.md`` for the full contract.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import ShardingRules, make_shard_mesh, segment_placement
from .datasets import VectorDataset, recall_at_k
from .engine import (
    VDMSInstance,
    _bucket,
    analytic_chunk_seconds,
    get_search_pipeline,
)
from .merge import merge_flat, merge_topk, partial_topk
from .registry import get_family

# sharded additions to the analytic cost model (same convention as the
# engine's calibration constants: documented, deterministic)
_SHARD_MERGE_OVERHEAD = 8.0e-5  # one partial list folded at the root, per chunk (s)
_SHARD_DISPATCH_OVERHEAD = 1.5e-4  # collective dispatch per chunk, n_shards > 1 (s)

#: CI gate: minimum analytic QPS scaling from 1 to 4 shards at bench scale.
MIN_QPS_SCALING_1_TO_4 = 1.5

#: The invariants the sharded engine guarantees (and the bench/CI gate).
#: ``docs/SHARDING.md`` embeds :func:`shard_invariants_table` between
#: ``shard-invariants`` markers; a doc-sync test keeps them in lockstep.
SHARD_INVARIANTS: Tuple[Tuple[str, str, str], ...] = (
    (
        "placement",
        "contiguous blocks",
        "segment `z` lives on shard `z // ceil(n_seg / n_shards)`; the stack "
        "pads with dead segments (gids all `-1`) so every shard holds the "
        "same count",
    ),
    (
        "result sets",
        "shard-count-invariant",
        "the per-query `(gid, score)` set is identical for every `n_shards` "
        "(gated by `bench_sharded --check-invariants`)",
    ),
    (
        "single shard",
        "bit-identical",
        "`n_shards=1` returns byte-identical ids to the unsharded engine — "
        "same kernels, same `merge_topk`",
    ),
    (
        "tie-break",
        "lowest flat index",
        "equal scores resolve to the lowest `(segment, slot)` flat position "
        "at every merge level (`lax.top_k` order)",
    ),
    (
        "growing tail",
        "replicated",
        "the tail is brute-forced once at the merge root, after all sealed "
        "candidates — never sharded, never stale across shards",
    ),
    (
        "recall",
        "oracle-exact accounting",
        "bench recall is scored against the brute-force oracle and must "
        "match the unsharded engine exactly",
    ),
    (
        "QPS scaling",
        f">= {MIN_QPS_SCALING_1_TO_4}x at 4 shards",
        "1→4 shard throughput scaling gated in CI at n_base >= 1M "
        "(analytic mode; wall mode reports alongside)",
    ),
)


def shard_invariants_table() -> str:
    """Markdown table of :data:`SHARD_INVARIANTS` (doc-synced)."""
    rows = ["| Invariant | Rule | Detail |", "|---|---|---|"]
    for name, rule, detail in SHARD_INVARIANTS:
        rows.append(f"| {name} | {rule} | {detail} |")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# the jitted sharded pipeline
# ---------------------------------------------------------------------------
def _shard_stage(kind: str, statics: Tuple, k_seg: int, use_hook: bool) -> Callable:
    """Per-shard candidate stage: the family's fused ``shard_search`` hook
    when registered (and the pipeline mode is fused), else its composed
    ``search`` — both return per-segment (n_seg_local, B, k_seg) GLOBAL ids
    and sims with identical masking semantics."""
    family = get_family(kind)
    st = dict(statics)
    if use_hook and family.shard_search is not None:
        return lambda q, arrays: family.shard_search(q, arrays, k_seg=k_seg, **st)
    return lambda q, arrays: family.search(q, arrays, k_seg=k_seg, **st)


@partial(
    jax.jit,
    static_argnames=(
        "mesh", "dispatch", "kind", "statics", "k_seg", "topk",
        "n_shards", "use_hook", "live", "return_scores",
    ),
)
def _sharded_chunk(
    q, arrays, alive, growing, growing_gids, *,
    mesh, dispatch, kind, statics, k_seg, topk,
    n_shards, use_hook, live, return_scores,
):
    """One query chunk through the two-level merge tree.

    ``arrays`` carry a flat leading segment axis of ``n_shards * per``;
    ``alive`` is the global mask (+ sentinel) for the live flavor or a dummy
    when ``live=False``; ``growing`` / ``growing_gids`` are the replicated
    tail, merged once at the root.
    """
    stage = _shard_stage(kind, statics, k_seg, use_hook)
    alive_arg = alive if live else None

    if n_shards == 1:
        # direct path: exactly the single-device engine pipeline
        ids, sims = stage(q, arrays)
        return merge_topk(
            q=q, ids=ids, sims=sims, growing=growing, growing_gids=growing_gids,
            topk=topk, alive=alive_arg, return_scores=return_scores,
        )

    n_seg_p = arrays["gids"].shape[0]
    per = n_seg_p // n_shards
    k_shard = min(topk, per * k_seg)
    family = get_family(kind)
    shared = set(family.shared_arrays)

    def leaf(q_l, arrays_l, alive_l):
        ids, sims = stage(q_l, arrays_l)  # (per, B, k_seg)
        pid, psc = partial_topk(ids, sims, k_shard, alive=alive_l if live else None)
        return pid, psc

    if dispatch == "shard_map":
        specs_in = (
            P(),  # queries replicated
            {k: (P() if k in shared else P("shard")) for k in arrays},
            P(),  # alive mask replicated
        )
        def leaf_sm(q_l, arrays_l, alive_l):
            pid, psc = leaf(q_l, arrays_l, alive_l)
            return pid[None], psc[None]  # local leading shard axis of 1
        parts_i, parts_s = shard_map(
            leaf_sm, mesh=mesh, in_specs=specs_in,
            out_specs=(P("shard"), P("shard")), check_rep=False,
        )(q, arrays, alive)
    else:  # "vmap": shard axis batched on one device — same math
        arrays_v = {
            k: (v if k in shared else v.reshape((n_shards, per) + v.shape[1:]))
            for k, v in arrays.items()
        }
        def leaf_v(arrays_l):
            full = {k: (arrays_v[k] if k in shared else arrays_l[k]) for k in arrays}
            return leaf(q, full, alive)
        parts_i, parts_s = jax.vmap(leaf_v)(
            {k: v for k, v in arrays_v.items() if k not in shared}
        )

    # root merge: concatenate partial lists in shard order (shard-major flat
    # position keeps the global tie-break order) and finish with the shared
    # merge arithmetic + the replicated growing tail
    b = parts_i.shape[1]
    ids2 = jnp.moveaxis(parts_i, 0, 1).reshape(b, n_shards * k_shard)
    sims2 = jnp.moveaxis(parts_s, 0, 1).reshape(b, n_shards * k_shard)
    return merge_flat(
        ids2, sims2, q, growing, growing_gids, topk,
        live=live, return_scores=return_scores,
    )


# ---------------------------------------------------------------------------
# the sharded serving instance
# ---------------------------------------------------------------------------
class ShardedVDMS:
    """Sealed segments placed across a device mesh, serving batched
    multi-stream queries through the two-level top-k merge tree.

    Build it three ways:

    * ``ShardedVDMS(dataset, config, n_shards=4)`` — bulk build (via
      :class:`VDMSInstance`) then place;
    * ``ShardedVDMS.from_instance(inst, n_shards=4)`` — place an existing
      static instance (shares its arrays; nothing is rebuilt);
    * ``ShardedVDMS.from_live(live, n_shards=4)`` — snapshot a streaming
      :class:`LiveVDMS` (sealed bundle + tombstone mask + visible tail) for
      sharded serving with the live merge semantics.
    """

    def __init__(
        self,
        dataset: Optional[VectorDataset] = None,
        config: Optional[Dict[str, Any]] = None,
        *,
        n_shards: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        dispatch: str = "auto",
        seed: int = 0,
        pipeline: Optional[str] = None,
        _state: Optional[Dict[str, Any]] = None,
    ):
        if _state is None:
            if dataset is None or config is None:
                raise ValueError("ShardedVDMS needs (dataset, config) or a _state")
            inst = VDMSInstance(dataset, config, seed=seed)
            _state = _state_from_instance(inst)
        self.dataset = _state.get("dataset")
        self.config = _state.get("config")
        self.kind = _state["kind"]
        self.static = dict(_state["static"])
        self.k_seg = int(_state["k_seg"])
        self.batch = int(_state["batch"])
        self.dim = int(_state["dim"])
        self.seg_size = int(_state["seg_size"])
        self.n_sealed = int(_state["n_sealed"])
        self.build_time = float(_state.get("build_time", 0.0))
        self.live = _state["alive"] is not None
        self.pipeline = pipeline  # None -> follow the engine's global mode
        if self.n_sealed <= 0:
            raise ValueError("nothing sealed to shard: the corpus has no sealed segments")

        # --- mesh + dispatch resolution --------------------------------
        if mesh is not None:
            if tuple(mesh.axis_names) != ("shard",):
                raise ValueError(f"expected a ('shard',) mesh, got {mesh.axis_names}")
            self.n_shards = int(mesh.devices.size) if n_shards is None else int(n_shards)
            if self.n_shards != mesh.devices.size:
                raise ValueError("n_shards must match the mesh size when both are given")
        else:
            self.n_shards = 1 if n_shards is None else int(n_shards)
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if dispatch == "auto":
            if self.n_shards == 1:
                dispatch = "direct"
            elif mesh is not None or self.n_shards <= len(jax.devices()):
                dispatch = "shard_map"
            else:
                dispatch = "vmap"  # mesh bigger than the machine: emulate
        if dispatch not in ("direct", "shard_map", "vmap"):
            raise ValueError(f"unknown dispatch {dispatch!r}")
        if dispatch == "direct" and self.n_shards != 1:
            raise ValueError("dispatch='direct' requires n_shards=1")
        self.dispatch = dispatch
        self.mesh = mesh
        if dispatch == "shard_map" and self.mesh is None:
            self.mesh = make_shard_mesh(self.n_shards)
        self.rules = ShardingRules(self.mesh) if self.mesh is not None else None

        # --- placement: contiguous blocks, dead tail padding ------------
        self.per_shard, self.n_pad, self.shard_of = segment_placement(
            self.n_sealed, self.n_shards
        )
        family = get_family(self.kind)
        self.shared_arrays = tuple(family.shared_arrays)
        arrays = dict(_state["arrays"])
        if self.n_pad:
            arrays = {
                k: (v if k in self.shared_arrays else _pad_segments(k, v, self.n_pad))
                for k, v in arrays.items()
            }
        if self.rules is not None:
            # place through the ShardingRules machinery: the segment dim is
            # the logical "segments" axis, everything else replicated
            arrays = {
                k: jax.device_put(v, self._named_sharding(k, v))
                for k, v in arrays.items()
            }
        self.arrays = arrays
        self.growing = _replicate(self.mesh, _state["growing"])
        self.growing_gids = _replicate(self.mesh, _state["growing_gids"])
        alive = _state["alive"]
        if alive is None:  # static merge: the jit still wants an operand
            alive = jnp.zeros((1,), bool)
        self.alive = _replicate(self.mesh, alive)
        self.coverage = float(_state.get("coverage", 1.0))

        # serving instrumentation (the metrics ledger attaches here, same
        # contract as LiveVDMS.search_hooks)
        self.queries_served = 0
        self.last_latencies: np.ndarray = np.empty(0, np.float64)
        self.search_hooks: List[Callable[[int, np.ndarray, float], None]] = []
        self._warmed: set = set()
        self.compile_s = 0.0

    # ------------------------------------------------------------------
    @classmethod
    def from_instance(cls, inst: VDMSInstance, **kw) -> "ShardedVDMS":
        return cls(_state=_state_from_instance(inst), **kw)

    @classmethod
    def from_live(cls, live, **kw) -> "ShardedVDMS":
        """Snapshot a :class:`LiveVDMS` for sharded serving: sealed bundle,
        tombstone/quarantine-masked alive mask, and the bucketed visible
        tail — the exact operands ``_live_chunk`` would see, so a 1-shard
        snapshot serves bit-identical results to ``live.search``."""
        return cls(_state=_state_from_live(live), **kw)

    # ------------------------------------------------------------------
    def _named_sharding(self, name: str, v) -> NamedSharding:
        axes: Tuple[Optional[str], ...]
        if name in self.shared_arrays:
            axes = (None,) * v.ndim
        else:
            axes = ("segments",) + (None,) * (v.ndim - 1)
        return self.rules.sharding(axes, tuple(v.shape))

    def _use_hook(self) -> bool:
        mode = self.pipeline or get_search_pipeline()
        return mode == "fused"

    def _dispatch_chunk(self, q, topk: int, return_scores: bool = False):
        return _sharded_chunk(
            q, self.arrays, self.alive, self.growing, self.growing_gids,
            mesh=self.mesh, dispatch=self.dispatch, kind=self.kind,
            statics=tuple(sorted(self.static.items())), k_seg=self.k_seg,
            topk=topk, n_shards=self.n_shards, use_hook=self._use_hook(),
            live=self.live, return_scores=return_scores,
        )

    # ------------------------------------------------------------------
    def search(
        self, queries: np.ndarray, topk: int, mode: str = "analytic",
        return_scores: bool = False,
    ):
        """Search the sharded state. Returns ``(ids (Q, topk), elapsed)`` —
        or ``(ids, scores, elapsed)`` with ``return_scores=True``. Analytic
        mode charges the deterministic sharded cost model (max-over-shards
        leaf work + root merge overhead); wall mode times the dispatch with
        compile kept apart, mirroring ``LiveVDMS.search``."""
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        b = min(self.batch, max(nq, 1))
        n_chunks = (nq + b - 1) // b
        out = np.empty((n_chunks * b, topk), np.int32)
        scores = np.empty((n_chunks * b, topk), np.float32) if return_scores else None
        chunk_s = np.zeros(n_chunks, np.float64)
        shape_key = (b, topk, self._use_hook(), return_scores)
        for c in range(n_chunks):
            lo = c * b
            chunk = queries[lo : lo + b]
            if chunk.shape[0] < b:  # pad the final chunk by wrapping
                chunk = np.concatenate([chunk, queries[: b - chunk.shape[0]]], axis=0)
            qj = jnp.asarray(chunk)
            if mode != "analytic" and shape_key not in self._warmed:
                t0 = time.perf_counter()
                jax.block_until_ready(self._dispatch_chunk(qj, topk, return_scores))
                self.compile_s += time.perf_counter() - t0
                self._warmed.add(shape_key)
            t0 = time.perf_counter()
            r = jax.block_until_ready(self._dispatch_chunk(qj, topk, return_scores))
            chunk_s[c] = time.perf_counter() - t0
            if return_scores:
                out[lo : lo + b] = np.asarray(r[0])
                scores[lo : lo + b] = np.asarray(r[1])
            else:
                out[lo : lo + b] = np.asarray(r)
        if mode == "analytic":
            chunk_s[:] = self._analytic_seconds_per_chunk(b)
        counts = np.minimum(b, nq - b * np.arange(n_chunks))
        elapsed = float(chunk_s.sum())
        lat = np.repeat(chunk_s / np.maximum(counts, 1), counts)
        self.last_latencies = lat
        self.queries_served += nq
        for hook in self.search_hooks:
            hook(nq, lat, elapsed)
        if return_scores:
            return out[:nq], scores[:nq], elapsed
        return out[:nq], elapsed

    def search_streams(
        self, streams: Sequence[np.ndarray], topk: int, mode: str = "analytic"
    ) -> Tuple[List[np.ndarray], float]:
        """Batched multi-stream dispatch: concatenate the per-stream query
        batches, run ONE sharded search over the union (amortizing dispatch
        and the merge tree across streams), split results back per stream."""
        streams = [np.asarray(s, np.float32).reshape(-1, self.dim) for s in streams]
        if not streams:
            return [], 0.0
        allq = np.concatenate(streams, axis=0)
        ids, elapsed = self.search(allq, topk, mode=mode)
        outs, lo = [], 0
        for s in streams:
            outs.append(ids[lo : lo + s.shape[0]])
            lo += s.shape[0]
        return outs, elapsed

    # --- analytic cost model ------------------------------------------
    def _analytic_seconds_per_chunk(self, batch: Optional[int] = None) -> float:
        """Deterministic per-chunk cost: shards run their leaves in
        parallel, so the leaf term charges the (padded) per-shard segment
        count — the critical shard — plus the root-merge terms that grow
        with the shard count. ``n_shards=1`` reduces exactly to the
        unsharded engine model."""
        base = analytic_chunk_seconds(
            self.kind,
            self.static,
            self.arrays,
            self.per_shard if self.n_shards > 1 else self.n_sealed,
            self.seg_size,
            int(self.growing.shape[0]),
            self.dim,
            self.batch if batch is None else batch,
        )
        if self.n_shards == 1:
            return base
        return base + self.n_shards * _SHARD_MERGE_OVERHEAD + _SHARD_DISPATCH_OVERHEAD

    def memory_gib(self) -> float:
        b = sum(int(v.size) * v.dtype.itemsize for v in self.arrays.values())
        b += int(self.growing.size) * self.growing.dtype.itemsize
        return b / (1024.0**3)

    def measure(
        self, topk: Optional[int] = None, repeats: int = 3, mode: str = "analytic"
    ) -> Dict[str, float]:
        """Objectives at the current shard count (dataset-built instances):
        QPS / recall@K / memory, same contract as ``VDMSInstance.measure``."""
        if self.dataset is None:
            raise ValueError("measure() needs a dataset-built ShardedVDMS")
        ds = self.dataset
        topk = topk or ds.k
        t0 = time.perf_counter()
        ids, _ = self.search(ds.queries, topk, mode="analytic")
        compile_time = time.perf_counter() - t0
        recall = recall_at_k(ids[:, : ds.k], ds.ground_truth)
        nq = ds.queries.shape[0]
        if mode == "analytic":
            b = min(self.batch, nq)
            n_chunks = (nq + b - 1) // b
            elapsed = self._analytic_seconds_per_chunk(b) * n_chunks
        else:
            times = []
            for _ in range(repeats):
                _, e = self.search(ds.queries, topk, mode="wall")
                times.append(e)
            elapsed = min(times)
        return {
            "speed": float(nq / max(elapsed, 1e-9)),
            "recall": float(recall),
            "mem_gib": float(self.memory_gib()),
            "build_time": float(self.build_time),
            "compile_time": float(compile_time),
            "n_shards": float(self.n_shards),
        }

    # --- serving telemetry --------------------------------------------
    def shard_segments(self) -> np.ndarray:
        """Real (non-padding) sealed segments per shard."""
        counts = np.zeros(self.n_shards, np.int64)
        np.add.at(counts, self.shard_of, 1)
        return counts

    def shard_coverage(self) -> np.ndarray:
        """Alive fraction of each shard's sealed vectors (1.0 for shards of
        a static instance; padding-only shards serve an empty slice and
        report 0 coverage honestly)."""
        gids = np.asarray(self.arrays["gids"]).reshape(self.n_shards, -1)
        alive = np.asarray(self.alive)
        cov = np.zeros(self.n_shards, np.float64)
        for s in range(self.n_shards):
            g = gids[s]
            g = g[g >= 0]
            if g.size == 0:
                cov[s] = 0.0
            elif self.live:
                cov[s] = float(alive[g].mean())
            else:
                cov[s] = 1.0
        return cov

    def stats(self) -> Dict[str, float]:
        """JSON-safe serving snapshot (the sharded metrics ledger input)."""
        cov = self.shard_coverage()
        segs = self.shard_segments()
        populated = segs > 0
        return {
            "n_shards": int(self.n_shards),
            "n_sealed": int(self.n_sealed),
            "per_shard": int(self.per_shard),
            "n_pad_segments": int(self.n_pad),
            "shard_skew": float(segs.max() / max(segs[populated].mean(), 1e-9))
            if populated.any()
            else 0.0,
            "min_shard_coverage": float(cov[populated].min()) if populated.any() else 0.0,
            "mean_shard_coverage": float(cov[populated].mean()) if populated.any() else 0.0,
            "growing_size": int(self.growing.shape[0]),
            "coverage": float(self.coverage),
            "queries_served": int(self.queries_served),
            "mem_gib": float(self.memory_gib()),
            "dispatch": self.dispatch,
        }


# ---------------------------------------------------------------------------
# state snapshots
# ---------------------------------------------------------------------------
def _pad_segments(name: str, v, n_pad: int):
    """Append dead padding segments: id-like arrays pad with -1 (gids map
    them to the dead slot / -inf), everything else with zeros."""
    pad_shape = (n_pad,) + tuple(v.shape[1:])
    fill = -1 if name in ("gids", "members") else 0
    pad = jnp.full(pad_shape, fill, v.dtype)
    return jnp.concatenate([v, pad], axis=0)


def _replicate(mesh: Optional[Mesh], v):
    v = jnp.asarray(v)
    if mesh is None:
        return v
    return jax.device_put(v, NamedSharding(mesh, P(*([None] * v.ndim))))


def _state_from_instance(inst: VDMSInstance) -> Dict[str, Any]:
    return {
        "dataset": inst.dataset,
        "config": dict(inst.config),
        "kind": inst.bundle.kind,
        "static": dict(inst.bundle.static),
        "arrays": dict(inst.bundle.arrays),
        "growing": inst.growing,
        "growing_gids": inst.growing_gids,
        "alive": None,  # static merge semantics
        "k_seg": inst.k_seg,
        "batch": inst.batch,
        "dim": inst.dataset.dim,
        "seg_size": inst.plan.seg_size,
        "n_sealed": inst.plan.n_sealed,
        "build_time": inst.build_time,
    }


def _state_from_live(live) -> Dict[str, Any]:
    if live.bundle is None:
        raise ValueError("nothing sealed to shard: LiveVDMS has no sealed segments")
    vis = live._visible_tail()
    nb = _bucket(vis.size)
    growing = np.zeros((nb, live.dim), np.float32)
    growing[: vis.size] = live.store[vis]
    ggids = np.full(nb, -1, np.int32)
    ggids[: vis.size] = vis
    alive_arr = live.alive
    coverage = 1.0
    if live.quarantined:
        # same degraded-mode masking live.search applies: quarantined
        # segments drop out of the merge, coverage reports the visible share
        alive_arr = live.alive.copy()
        sealed_alive = int((live.alive[: live.capacity] & (live.gid_seg >= 0)).sum())
        lost = 0
        for z in live.quarantined:
            row = live.seg_gids[z]
            valid = row[row >= 0]
            lost += int(live.alive[valid].sum())
            alive_arr[valid] = False
        total = sealed_alive + int(vis.size)
        coverage = float((total - lost) / max(total, 1))
    return {
        "dataset": None,
        "config": dict(live.config),
        "kind": live.bundle.kind,
        "static": dict(live.bundle.static),
        "arrays": dict(live.bundle.arrays),
        "growing": jnp.asarray(growing),
        "growing_gids": jnp.asarray(ggids),
        "alive": jnp.asarray(alive_arr),
        "k_seg": live.k_seg,
        "batch": live.batch,
        "dim": live.dim,
        "seg_size": live.seg_size,
        "n_sealed": live.n_sealed,
        "build_time": live.build_time,
        "coverage": coverage,
    }
