"""ANNS index implementations (Milvus Table I): FLAT, IVF_FLAT, IVF_SQ8,
IVF_PQ, HNSW, SCANN, AUTOINDEX — all with jittable search paths.

Every family here is declared to the :mod:`~repro.vdms.registry` as one
:class:`~repro.vdms.registry.IndexFamily` spec (tunable params, build/search
callables, frozen-calibration keys, analytic cost hooks) at the bottom of
this module; ``build_index`` / ``search_index`` and the bundle lifecycle ops
dispatch through that registry, so an externally-registered family (see
``repro.vdms.ivf_pqr``) flows through every path below without edits.

Conventions
-----------
* Angular metric: all vectors L2-normalized, similarity = inner product
  (higher is better); returned "sims" follow that convention.
* Sealed segments are stacked into (n_seg, S, d); each segment has its own
  index; searches run per segment via ``lax.map`` and the engine merges.
* Every search returns (global_ids (Q, n_seg * k_seg), sims) with -1/-inf on
  padded slots.
* Build runs on host (numpy + jitted JAX pieces) and is timed by the engine —
  index build cost is part of the tuning cost the paper measures.
* Arrays named in a family's ``shared_arrays`` hold calibration state shared
  across segments (quantizer scales, PQ codebooks), not per-segment stacks.
  Incremental builds freeze these after the first sealed segment — like real
  systems that train quantizers once and reuse them for every later segment —
  so per-segment bundles stay concatenable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.space import Param
from ..kernels import ops
from .fused import (
    fused_search_ivf_pq,
    fused_search_ivf_sq8,
    shard_search_ivf_pq,
    shard_search_ivf_sq8,
)
from .kmeans import kmeans, kmeans_l2
from .registry import REGISTRY, IndexFamily, get_family


def __getattr__(name: str):
    if name == "INDEX_TYPES":
        # derived, never a second source of truth: always == registry keys
        return tuple(REGISTRY.names())
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class IndexBundle:
    kind: str
    arrays: Dict[str, jnp.ndarray]  # stacked over segments (leading dim n_seg)
    static: Dict[str, Any]  # static search params (k_seg etc. added by engine)

    def memory_bytes(self) -> int:
        return int(sum(np.prod(a.shape) * a.dtype.itemsize for a in self.arrays.values()))


# =========================================================================
# helpers
# =========================================================================
def _storage(x: np.ndarray, bf16: bool) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.bfloat16 if bf16 else jnp.float32)


def _member_lists(assign: np.ndarray, nlist: int, cap: int) -> np.ndarray:
    """(nlist, cap) local-id lists, -1 padded; overflow beyond cap is dropped
    (mirrors real systems' bounded per-cluster scan). Fully vectorized: one
    stable argsort + a rank-within-cluster scatter, no per-cluster loop."""
    out = -np.ones((nlist, cap), dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    sa = assign[order]
    starts = np.searchsorted(sa, np.arange(nlist), "left")
    pos = np.arange(sa.shape[0]) - starts[sa]  # rank within own cluster
    keep = pos < cap
    out[sa[keep], pos[keep]] = order[keep]
    return out


def _ivf_cap(seg_size: int, nlist: int, nprobe: int) -> int:
    cap = int(2.5 * seg_size / nlist) + 8
    if nprobe * cap > seg_size + 8 * nprobe:
        cap = max(8, seg_size // max(nprobe, 1) + 8)
    return cap


def _mask_pad(sims: jnp.ndarray, gids: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(gids >= 0, sims, -jnp.inf)


# =========================================================================
# FLAT — exhaustive
# =========================================================================
def build_flat(key, segs: np.ndarray, gids: np.ndarray, params, sys, frozen=None) -> IndexBundle:
    return IndexBundle(
        kind="FLAT",
        arrays={"data": _storage(segs, sys["storage_bf16"]), "gids": jnp.asarray(gids)},
        static={},
    )


def _search_flat(q: jnp.ndarray, arrays, *, k_seg: int):
    def per_seg(seg):
        data, gids = seg
        sims = ops.batched_ip(q, data)  # (B, S)
        sims = _mask_pad(sims, gids[None, :])
        top_s, top_i = jax.lax.top_k(sims, k_seg)
        return gids[top_i], top_s

    ids, sims = jax.lax.map(per_seg, (arrays["data"], arrays["gids"]))
    return ids, sims  # (n_seg, B, k_seg)


# =========================================================================
# IVF family
# =========================================================================
def _build_ivf_common(key, segs, gids, nlist, kmeans_iters):
    n_seg, s, d = segs.shape
    nlist = int(min(max(nlist, 4), max(s // 8, 4)))
    keys = jax.random.split(key, n_seg)
    cents, assigns = jax.vmap(lambda k, x: kmeans(k, x, nlist, kmeans_iters))(
        keys, jnp.asarray(segs)
    )
    return nlist, np.asarray(cents), np.asarray(assigns)


def build_ivf_flat(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    nlist, cents, assigns = _build_ivf_common(
        key, segs, gids, params["nlist"], sys["kmeans_iters"]
    )
    nprobe = int(min(params["nprobe"], nlist))
    cap = _ivf_cap(segs.shape[1], nlist, nprobe)
    members = np.stack([_member_lists(assigns[z], nlist, cap) for z in range(len(segs))])
    return IndexBundle(
        kind="IVF_FLAT",
        arrays={
            "data": _storage(segs, sys["storage_bf16"]),
            "gids": jnp.asarray(gids),
            "centroids": jnp.asarray(cents),
            "members": jnp.asarray(members),
        },
        static={"nprobe": nprobe},
    )


def _gather_candidates(q, centroids, members, *, nprobe):
    """Probe top-nprobe clusters; return flattened candidate local ids (B, P)."""
    csim = jnp.dot(q, centroids.T, preferred_element_type=jnp.float32)  # (B, nlist)
    _, probe = jax.lax.top_k(csim, nprobe)  # (B, nprobe)
    cand = members[probe]  # (B, nprobe, cap)
    return cand.reshape(q.shape[0], -1)  # (B, P)


def _search_ivf_flat(q, arrays, *, k_seg: int, nprobe: int):
    def per_seg(seg):
        data, gids, cents, members = seg
        cand = _gather_candidates(q, cents, members, nprobe=nprobe)  # (B, P)
        safe = jnp.maximum(cand, 0)
        vecs = data[safe]  # (B, P, d)
        sims = jnp.einsum("bpd,bd->bp", vecs.astype(jnp.float32), q)
        sims = jnp.where(cand >= 0, sims, -jnp.inf)
        k = min(k_seg, sims.shape[1])
        top_s, top_i = jax.lax.top_k(sims, k)
        lids = jnp.take_along_axis(cand, top_i, axis=1)
        ids = jnp.where(lids >= 0, gids[jnp.maximum(lids, 0)], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:  # pad to fixed k_seg
            pad = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(
        per_seg,
        (arrays["data"], arrays["gids"], arrays["centroids"], arrays["members"]),
    )


def build_ivf_sq8(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    nlist, cents, assigns = _build_ivf_common(
        key, segs, gids, params["nlist"], sys["kmeans_iters"]
    )
    nprobe = int(min(params["nprobe"], nlist))
    cap = _ivf_cap(segs.shape[1], nlist, nprobe)
    members = np.stack([_member_lists(assigns[z], nlist, cap) for z in range(len(segs))])
    if frozen is None:
        scale = np.abs(segs).max(axis=(0, 1)) / 127.0 + 1e-12  # (d,) shared scale
    else:
        scale = np.asarray(frozen["scale"], np.float32)
    codes = np.clip(np.round(segs / scale), -127, 127).astype(np.int8)
    return IndexBundle(
        kind="IVF_SQ8",
        arrays={
            "codes": jnp.asarray(codes),
            "scale": jnp.asarray(scale.astype(np.float32)),
            "gids": jnp.asarray(gids),
            "centroids": jnp.asarray(cents),
            "members": jnp.asarray(members),
        },
        static={"nprobe": nprobe},
    )


def _search_ivf_sq8(q, arrays, *, k_seg: int, nprobe: int):
    scale = arrays["scale"]

    def per_seg(seg):
        codes, gids, cents, members = seg
        cand = _gather_candidates(q, cents, members, nprobe=nprobe)
        safe = jnp.maximum(cand, 0)
        vecs = codes[safe].astype(jnp.float32) * scale[None, None, :]
        sims = jnp.einsum("bpd,bd->bp", vecs, q)
        sims = jnp.where(cand >= 0, sims, -jnp.inf)
        k = min(k_seg, sims.shape[1])
        top_s, top_i = jax.lax.top_k(sims, k)
        lids = jnp.take_along_axis(cand, top_i, axis=1)
        ids = jnp.where(lids >= 0, gids[jnp.maximum(lids, 0)], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:
            pad = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(
        per_seg,
        (arrays["codes"], arrays["gids"], arrays["centroids"], arrays["members"]),
    )


def build_ivf_pq(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    n_seg, s, d = segs.shape
    m = int(params["m"])
    while d % m != 0:  # snap to a divisor of d
        m -= 1
    nbits = int(params["nbits"])
    c = 2**nbits
    nlist, cents, assigns = _build_ivf_common(
        key, segs, gids, params["nlist"], sys["kmeans_iters"]
    )
    nprobe = int(min(params["nprobe"], nlist))
    cap = _ivf_cap(s, nlist, nprobe)
    members = np.stack([_member_lists(assigns[z], nlist, cap) for z in range(n_seg)])
    dsub = d // m
    if frozen is None:
        # shared codebooks across segments (trained on the pooled sample)
        pool = segs.reshape(-1, m, dsub)
        sample = pool[:: max(1, pool.shape[0] // 8192)]
        keys = jax.random.split(jax.random.fold_in(key, 7), m)
        cb, _ = jax.vmap(
            lambda kk, xs: kmeans_l2(kk, xs, c, sys["kmeans_iters"])
        )(keys, jnp.asarray(sample.transpose(1, 0, 2)))  # (m, c, dsub)
        cb = np.asarray(cb)
    else:
        cb = np.asarray(frozen["codebooks"], np.float32)
    # encode: nearest codeword per subspace
    codes = np.empty((n_seg, s, m), dtype=np.uint8)
    x = segs.reshape(n_seg * s, m, dsub)
    for j in range(m):
        d2 = (
            np.sum(x[:, j] ** 2, 1)[:, None]
            - 2.0 * x[:, j] @ cb[j].T
            + np.sum(cb[j] ** 2, 1)[None, :]
        )
        codes[..., j] = np.argmin(d2, axis=1).astype(np.uint8).reshape(n_seg, s)
    return IndexBundle(
        kind="IVF_PQ",
        arrays={
            "codes": jnp.asarray(codes),
            "codebooks": jnp.asarray(cb.astype(np.float32)),
            "gids": jnp.asarray(gids),
            "centroids": jnp.asarray(cents),
            "members": jnp.asarray(members),
        },
        static={"nprobe": nprobe, "m": m, "c": c},
    )


def _search_ivf_pq(q, arrays, *, k_seg: int, nprobe: int, m: int, c: int):
    b, d = q.shape
    dsub = d // m
    qs = q.reshape(b, m, dsub)
    # similarity LUT: higher is better (IP of query sub-vector with codeword)
    lut = jnp.einsum("bmd,mcd->bmc", qs, arrays["codebooks"])  # (B, m, c)

    def per_seg(seg):
        codes, gids, cents, members = seg
        cand = _gather_candidates(q, cents, members, nprobe=nprobe)  # (B, P)
        safe = jnp.maximum(cand, 0)
        ccodes = codes[safe].astype(jnp.int32)  # (B, P, m)
        g = jnp.take_along_axis(
            lut[:, None, :, :], ccodes[..., None], axis=3
        )  # (B, P, m, 1)
        sims = jnp.sum(g[..., 0], axis=-1)
        sims = jnp.where(cand >= 0, sims, -jnp.inf)
        k = min(k_seg, sims.shape[1])
        top_s, top_i = jax.lax.top_k(sims, k)
        lids = jnp.take_along_axis(cand, top_i, axis=1)
        ids = jnp.where(lids >= 0, gids[jnp.maximum(lids, 0)], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:
            pad = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(
        per_seg, (arrays["codes"], arrays["gids"], arrays["centroids"], arrays["members"])
    )


# =========================================================================
# HNSW (NSW-style kNN graph + diversity pruning + shortcut links)
# =========================================================================
@partial(jax.jit, static_argnames=("m_links", "ef_construction", "row_chunk"))
def _build_graph(data: jnp.ndarray, m_links: int, ef_construction: int, row_chunk: int = 512):
    """Graph build: exact kNN candidates (chunked) + HNSW diversity heuristic."""
    s, d = data.shape
    efc = min(ef_construction, s - 1)

    def knn_rows(rows):
        sims = jnp.dot(data[rows], data.T, preferred_element_type=jnp.float32)
        sims = sims.at[jnp.arange(rows.shape[0]), rows].set(-jnp.inf)  # no self
        top_s, top_i = jax.lax.top_k(sims, efc)
        return top_i, top_s

    n_chunks = (s + row_chunk - 1) // row_chunk
    pad_s = n_chunks * row_chunk
    rows = jnp.arange(pad_s) % s
    cand_i, cand_s = jax.lax.map(
        knn_rows, rows.reshape(n_chunks, row_chunk)
    )
    cand_i = cand_i.reshape(pad_s, efc)[:s]
    cand_s = cand_s.reshape(pad_s, efc)[:s]

    # diversity pruning (per-node, vectorized over node chunks):
    # iteratively select the best remaining candidate; discard candidates that
    # are closer to the selected neighbor than to the node itself.
    def prune_chunk(args):
        ci, cs, rows = args  # (C, efc), (C, efc), (C,)
        alive = jnp.isfinite(cs)

        def step(carry, t):
            alive, sel = carry
            score = jnp.where(alive, cs, -jnp.inf)
            j = jnp.argmax(score, axis=1)  # (C,)
            ok = jnp.take_along_axis(alive, j[:, None], 1)[:, 0]
            pick = jnp.take_along_axis(ci, j[:, None], 1)[:, 0]  # (C,)
            pick = jnp.where(ok, pick, rows)  # degenerate: self-link
            sel = sel.at[:, t].set(pick)
            # drop candidates nearer to `pick` than to the node
            pv = data[pick]  # (C, d)
            cv = data[ci]  # (C, efc, d)
            sim_to_pick = jnp.einsum("ced,cd->ce", cv, pv)
            alive = alive & (sim_to_pick <= cs) & (
                jnp.arange(efc)[None, :] != j[:, None]
            )
            return (alive, sel), None

        sel0 = jnp.broadcast_to(rows[:, None], (rows.shape[0], m_links)).astype(jnp.int32)
        (alive, sel), _ = jax.lax.scan(step, (alive, sel0), jnp.arange(m_links))
        return sel

    sel = jax.lax.map(
        prune_chunk,
        (
            cand_i.reshape(n_chunks, row_chunk, efc)
            if s == pad_s
            else jnp.pad(cand_i, ((0, pad_s - s), (0, 0))).reshape(n_chunks, row_chunk, efc),
            jnp.pad(cand_s, ((0, pad_s - s), (0, 0)), constant_values=-jnp.inf).reshape(
                n_chunks, row_chunk, efc
            )
            if s != pad_s
            else cand_s.reshape(n_chunks, row_chunk, efc),
            rows.reshape(n_chunks, row_chunk),
        ),
    )
    graph = sel.reshape(pad_s, m_links)[:s]
    # small-world shortcut links in the last columns (keeps the graph connected)
    n_rand = max(1, m_links // 8)
    key = jax.random.PRNGKey(s * 7 + m_links)
    shortcuts = jax.random.randint(key, (s, n_rand), 0, s, dtype=jnp.int32)
    graph = graph.at[:, -n_rand:].set(shortcuts)
    return graph


def build_hnsw(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    n_seg, s, d = segs.shape
    m_links = int(max(4, min(params["M"], 64)))
    efc = int(min(max(params["efConstruction"], 16), s - 1))
    graphs = jnp.stack(
        [_build_graph(jnp.asarray(segs[z]), m_links, efc) for z in range(n_seg)]
    )
    ef = int(min(max(params["ef"], 8), s))
    return IndexBundle(
        kind="HNSW",
        arrays={
            "data": _storage(segs, sys["storage_bf16"]),
            "gids": jnp.asarray(gids),
            "graph": graphs,
        },
        static={"ef": ef, "m_links": m_links},
    )


def _search_hnsw(q, arrays, *, k_seg: int, ef: int, m_links: int):
    b, d = q.shape

    def per_seg(seg):
        data, gids, graph = seg
        s = data.shape[0]
        dataf = data.astype(jnp.float32)
        # entry points: strided samples across the segment
        n_entry = min(4, ef)
        entries = (jnp.arange(n_entry) * (s // max(n_entry, 1))).astype(jnp.int32)
        beam_ids = jnp.broadcast_to(entries, (b, n_entry))
        beam_sims = jnp.einsum("bed,bd->be", dataf[beam_ids], q)
        pad = ef - n_entry
        beam_ids = jnp.pad(beam_ids, ((0, 0), (0, pad)), constant_values=0)
        beam_sims = jnp.pad(beam_sims, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        expanded = jnp.zeros((b, ef), dtype=bool)
        visited = jnp.zeros((b, s), dtype=bool)
        visited = visited.at[jnp.arange(b)[:, None], beam_ids].set(True)

        def step(carry, _):
            beam_ids, beam_sims, expanded, visited = carry
            score = jnp.where(expanded | ~jnp.isfinite(beam_sims), -jnp.inf, beam_sims)
            j = jnp.argmax(score, axis=1)  # (b,)
            has = jnp.isfinite(jnp.take_along_axis(score, j[:, None], 1)[:, 0])
            expanded = expanded.at[jnp.arange(b), j].set(True)
            node = jnp.take_along_axis(beam_ids, j[:, None], 1)[:, 0]  # (b,)
            nbrs = graph[node]  # (b, M)
            seen = jnp.take_along_axis(visited, nbrs, axis=1)  # (b, M)
            visited = visited.at[jnp.arange(b)[:, None], nbrs].set(True)
            nsims = jnp.einsum("bmd,bd->bm", dataf[nbrs], q)
            nsims = jnp.where(seen | ~has[:, None], -jnp.inf, nsims)
            all_ids = jnp.concatenate([beam_ids, nbrs], axis=1)
            all_sims = jnp.concatenate([beam_sims, nsims], axis=1)
            all_exp = jnp.concatenate([expanded, jnp.zeros_like(seen)], axis=1)
            top_s, top_i = jax.lax.top_k(all_sims, ef)
            beam_ids = jnp.take_along_axis(all_ids, top_i, axis=1)
            expanded = jnp.take_along_axis(all_exp, top_i, axis=1)
            return (beam_ids, top_s, expanded, visited), None

        (beam_ids, beam_sims, _, _), _ = jax.lax.scan(
            step, (beam_ids, beam_sims, expanded, visited), None, length=ef
        )
        k = min(k_seg, ef)
        top_s, top_i = jax.lax.top_k(beam_sims, k)
        lids = jnp.take_along_axis(beam_ids, top_i, axis=1)
        ids = jnp.where(jnp.isfinite(top_s), gids[lids], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:
            padk = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, padk)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, padk)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(per_seg, (arrays["data"], arrays["gids"], arrays["graph"]))


# =========================================================================
# SCANN — IVF + int8 score-aware quantized scan + exact re-ranking
# =========================================================================
def build_scann(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    nlist, cents, assigns = _build_ivf_common(
        key, segs, gids, params["nlist"], sys["kmeans_iters"]
    )
    nprobe = int(min(params["nprobe"], nlist))
    cap = _ivf_cap(segs.shape[1], nlist, nprobe)
    members = np.stack([_member_lists(assigns[z], nlist, cap) for z in range(len(segs))])
    if frozen is None:
        scale = np.abs(segs).max(axis=(0, 1)) / 127.0 + 1e-12
    else:
        scale = np.asarray(frozen["scale"], np.float32)
    codes = np.clip(np.round(segs / scale), -127, 127).astype(np.int8)
    reorder_k = int(max(params["reorder_k"], 1))
    return IndexBundle(
        kind="SCANN",
        arrays={
            "codes": jnp.asarray(codes),
            "scale": jnp.asarray(scale.astype(np.float32)),
            "data": _storage(segs, sys["storage_bf16"]),
            "gids": jnp.asarray(gids),
            "centroids": jnp.asarray(cents),
            "members": jnp.asarray(members),
        },
        static={"nprobe": nprobe, "reorder_k": reorder_k},
    )


def _search_scann(q, arrays, *, k_seg: int, nprobe: int, reorder_k: int):
    scale = arrays["scale"]

    def per_seg(seg):
        codes, data, gids, cents, members = seg
        cand = _gather_candidates(q, cents, members, nprobe=nprobe)
        safe = jnp.maximum(cand, 0)
        approx = jnp.einsum(
            "bpd,bd->bp", codes[safe].astype(jnp.float32) * scale[None, None, :], q
        )
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        r = min(reorder_k, approx.shape[1])
        _, top_r = jax.lax.top_k(approx, r)  # (B, r)
        rcand = jnp.take_along_axis(cand, top_r, axis=1)
        rsafe = jnp.maximum(rcand, 0)
        exact = jnp.einsum("brd,bd->br", data[rsafe].astype(jnp.float32), q)
        exact = jnp.where(rcand >= 0, exact, -jnp.inf)
        k = min(k_seg, exact.shape[1])
        top_s, top_i = jax.lax.top_k(exact, k)
        lids = jnp.take_along_axis(rcand, top_i, axis=1)
        ids = jnp.where(lids >= 0, gids[jnp.maximum(lids, 0)], -1)
        top_s = jnp.where(ids >= 0, top_s, -jnp.inf)
        if k < k_seg:
            pad = k_seg - k
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        return ids, top_s

    return jax.lax.map(
        per_seg,
        (
            arrays["codes"],
            arrays["data"],
            arrays["gids"],
            arrays["centroids"],
            arrays["members"],
        ),
    )


# =========================================================================
# AUTOINDEX — delegated IVF_FLAT build with derived parameters
# =========================================================================
def build_autoindex(key, segs, gids, params, sys, frozen=None) -> IndexBundle:
    s = segs.shape[1]
    auto = {"nlist": max(4, int(np.sqrt(s) * 2)), "nprobe": 16}
    return build_ivf_flat(key, segs, gids, auto, sys)


# =========================================================================
# analytic cost hooks (the engine's deterministic search/build model asks
# each family for its FLOP count; the shared rate/overhead arithmetic stays
# in engine.py — identical numbers to the historical per-kind if-chains)
# =========================================================================
def _chunk_cost_flat(st, arrays, n_sealed, seg_size, dim):
    return n_sealed * seg_size * dim * 2, 0


def _chunk_cost_ivf(bytes_scale: float):
    def cost(st, arrays, n_sealed, seg_size, dim):
        nlist = arrays["centroids"].shape[1]
        cap = arrays["members"].shape[2]
        return n_sealed * (nlist * dim + st["nprobe"] * cap * dim * bytes_scale) * 2, 0

    return cost


def _chunk_cost_ivf_pq(st, arrays, n_sealed, seg_size, dim):
    nlist = arrays["centroids"].shape[1]
    cap = arrays["members"].shape[2]
    flops = n_sealed * (
        nlist * dim * 2 + st["m"] * st["c"] * (dim // st["m"]) * 2 + st["nprobe"] * cap * st["m"]
    )
    return flops, 0


def _chunk_cost_hnsw(st, arrays, n_sealed, seg_size, dim):
    return n_sealed * st["ef"] * st["m_links"] * dim * 2, st["ef"]


def _chunk_cost_scann(st, arrays, n_sealed, seg_size, dim):
    nlist = arrays["centroids"].shape[1]
    cap = arrays["members"].shape[2]
    flops = n_sealed * (nlist * dim * 2 + st["nprobe"] * cap * dim + st["reorder_k"] * dim * 2)
    return flops, 0


def _build_cost_ivf_common(config, seg_size, dim):
    it = int(config.get("kmeans_iters", 8))
    nlist = int(config.get("nlist", max(4, int(np.sqrt(seg_size) * 2))))
    nlist = int(min(max(nlist, 4), max(seg_size // 8, 4)))
    return it * nlist * seg_size * dim * 2


def _build_cost_ivf_flat(config, seg_size, dim, first_build):
    return _build_cost_ivf_common(config, seg_size, dim)


def _build_cost_sq(config, seg_size, dim, first_build):
    return _build_cost_ivf_common(config, seg_size, dim) + seg_size * dim * 2


def _build_cost_ivf_pq(config, seg_size, dim, first_build):
    flops = _build_cost_ivf_common(config, seg_size, dim)
    it = int(config.get("kmeans_iters", 8))
    m = int(config.get("m", 8))
    while dim % m != 0:
        m -= 1
    c = 2 ** int(config.get("nbits", 8))
    dsub = dim // m
    flops += seg_size * m * c * dsub * 2  # encode
    if first_build:
        flops += it * m * c * min(seg_size, 8192) * dsub * 2  # codebook training
    return flops


def _build_cost_hnsw(config, seg_size, dim, first_build):
    efc = int(min(max(int(config.get("efConstruction", 128)), 16), max(seg_size - 1, 1)))
    m_links = int(max(4, min(int(config.get("M", 16)), 64)))
    return seg_size * seg_size * dim * 2 + seg_size * m_links * efc * dim


# =========================================================================
# registry dispatch — the ONLY way index builds/searches are reached
# =========================================================================
def build_index(
    key, segs, gids, index_type: str, params: Dict, sys: Dict, frozen: Dict | None = None
) -> IndexBundle:
    """Build per-segment indexes for the stacked segments ``(n_seg, S, d)``.

    Dispatches to the registered :class:`~repro.vdms.registry.IndexFamily`
    (unknown types raise with the sorted list of registered families).
    ``frozen`` (from :func:`frozen_state`) reuses a previous build's shared
    calibration (SQ8/SCANN scales, PQ codebooks) instead of re-training —
    the incremental-build path for live instances sealing one segment at a
    time. ``frozen=None`` reproduces the original from-scratch build exactly.
    """
    return get_family(index_type).build(key, segs, gids, params, sys, frozen=frozen)


def _family_of(bundle: IndexBundle) -> IndexFamily:
    return get_family(bundle.kind)


def frozen_state(bundle: IndexBundle) -> Dict[str, np.ndarray]:
    """Extract the segment-shared calibration arrays (the family's declared
    ``shared_arrays``) to freeze for incremental builds — empty for index
    families without shared state."""
    family = _family_of(bundle)
    if not family.supports_frozen:
        return {}
    return {
        k: np.asarray(bundle.arrays[k]) for k in family.shared_arrays if k in bundle.arrays
    }


def concat_bundles(a: IndexBundle, b: IndexBundle) -> IndexBundle:
    """Concatenate two bundles of the same kind/statics along the segment
    axis. Shared calibration arrays must be frozen-compatible and are taken
    from ``a`` (the incremental-build contract)."""
    if a.kind != b.kind or a.static != b.static:
        raise ValueError(
            f"cannot concat bundles: kind/static mismatch "
            f"({a.kind}/{a.static} vs {b.kind}/{b.static})"
        )
    shared = _family_of(a).shared_arrays
    arrays = {}
    for k, av in a.arrays.items():
        arrays[k] = av if k in shared else jnp.concatenate([av, b.arrays[k]], axis=0)
    return IndexBundle(kind=a.kind, arrays=arrays, static=dict(a.static))


def replace_segment(bundle: IndexBundle, z: int, seg_bundle: IndexBundle) -> IndexBundle:
    """Splice a freshly rebuilt single-segment bundle into position ``z`` —
    the compaction path (tombstoned vectors dropped, shapes preserved)."""
    if bundle.kind != seg_bundle.kind or bundle.static != seg_bundle.static:
        raise ValueError("cannot splice: kind/static mismatch")
    shared = _family_of(bundle).shared_arrays
    arrays = {}
    for k, av in bundle.arrays.items():
        if k in shared:
            arrays[k] = av
        else:
            arrays[k] = av.at[z].set(seg_bundle.arrays[k][0])
    return IndexBundle(kind=bundle.kind, arrays=arrays, static=dict(bundle.static))


def search_index(bundle: IndexBundle, q: jnp.ndarray, k_seg: int):
    """Returns (ids, sims) of shape (n_seg, B, k_seg) — merged by the engine.

    Dispatches on ``bundle.kind`` through the registry; the bundle's static
    params are passed to the family's search callable as keyword arguments.
    """
    return _family_of(bundle).search(q, bundle.arrays, k_seg=k_seg, **bundle.static)


# =========================================================================
# built-in family registrations (declaration order == historical space
# order, so the registry-derived SearchSpace stays bit-identical)
# =========================================================================
_NLIST = (16, 32, 64, 128, 256, 512)
_NPROBE = (1, 2, 4, 8, 16, 32, 64, 128)

REGISTRY.register(
    IndexFamily(
        name="FLAT",
        params=(),
        build=build_flat,
        search=_search_flat,
        chunk_cost=_chunk_cost_flat,
        description="exhaustive inner-product scan",
    )
)
REGISTRY.register(
    IndexFamily(
        name="IVF_FLAT",
        params=(
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ),
        build=build_ivf_flat,
        search=_search_ivf_flat,
        chunk_cost=_chunk_cost_ivf(1.0),
        build_cost=_build_cost_ivf_flat,
        description="inverted file over kmeans cells, raw vectors",
    )
)
REGISTRY.register(
    IndexFamily(
        name="IVF_SQ8",
        params=(
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ),
        build=build_ivf_sq8,
        search=_search_ivf_sq8,
        shared_arrays=("scale",),
        fused_search=fused_search_ivf_sq8,
        shard_search=shard_search_ivf_sq8,
        supports_frozen=True,
        chunk_cost=_chunk_cost_ivf(0.5),
        build_cost=_build_cost_sq,
        description="IVF over int8 scalar-quantized codes",
    )
)
REGISTRY.register(
    IndexFamily(
        name="IVF_PQ",
        params=(
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("m", "grid", choices=(4, 8, 16, 32), default=8),
            Param("nbits", "grid", choices=(4, 6, 8), default=8),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
        ),
        build=build_ivf_pq,
        search=_search_ivf_pq,
        shared_arrays=("codebooks",),
        fused_search=fused_search_ivf_pq,
        shard_search=shard_search_ivf_pq,
        supports_frozen=True,
        chunk_cost=_chunk_cost_ivf_pq,
        build_cost=_build_cost_ivf_pq,
        description="IVF + product quantization (ADC lookup scan)",
    )
)
REGISTRY.register(
    IndexFamily(
        name="HNSW",
        params=(
            Param("M", "grid", choices=(8, 16, 32, 48), default=16),
            Param("efConstruction", "grid", choices=(32, 64, 128, 256), default=128),
            Param("ef", "grid", choices=(16, 32, 64, 128, 256), default=64),
        ),
        build=build_hnsw,
        search=_search_hnsw,
        chunk_cost=_chunk_cost_hnsw,
        build_cost=_build_cost_hnsw,
        description="NSW-style kNN graph with beam search",
    )
)
REGISTRY.register(
    IndexFamily(
        name="SCANN",
        params=(
            Param("nlist", "grid", choices=_NLIST, default=128),
            Param("nprobe", "grid", choices=_NPROBE, default=8),
            Param("reorder_k", "grid", choices=(32, 64, 128, 256, 512), default=64),
        ),
        build=build_scann,
        search=_search_scann,
        shared_arrays=("scale",),
        supports_frozen=True,
        chunk_cost=_chunk_cost_scann,
        build_cost=_build_cost_sq,
        description="IVF + int8 quantized scan + exact re-ranking",
    )
)
REGISTRY.register(
    IndexFamily(
        name="AUTOINDEX",
        params=(),
        build=build_autoindex,
        # builds_kind delegation: build_autoindex emits IVF_FLAT-kind bundles,
        # so bundle-keyed dispatch (search_index, analytic_chunk_seconds) uses
        # the IVF_FLAT family's hooks at runtime. search/chunk_cost here only
        # serve hand-constructed kind="AUTOINDEX" bundles (legacy contract);
        # build_cost IS live — the seal/build model dispatches on index_type.
        search=_search_ivf_flat,
        builds_kind="IVF_FLAT",
        chunk_cost=_chunk_cost_ivf(1.0),
        build_cost=_build_cost_ivf_flat,
        description="auto-derived IVF_FLAT (nlist ~ 2*sqrt(S), nprobe=16)",
    )
)
