"""Segment planning: executable semantics for the Milvus-like system params.

* ``segment_max_size`` — vectors per sealed segment. Each sealed segment gets
  its *own* index build (smaller segments → more per-segment index builds,
  more merge overhead, different nlist balance — the interdependence shown in
  the paper's Fig. 1–2).
* ``seal_proportion``  — the trailing partial segment is sealed (indexed) only
  if it reached this fraction of ``segment_max_size``; otherwise it stays
  *growing* and is searched by brute force.
* ``graceful_time``    — bounded-consistency window: the fraction of the
  growing tail a query may *skip*. Small values scan (almost) the whole
  unindexed tail (slow, complete — the paper notes small gracefulTime causes
  request blocking); large values skip recent inserts (fast, may miss them).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    n: int
    seg_size: int  # S (padded size of every sealed segment)
    n_sealed: int
    sealed_valid: np.ndarray  # (n_sealed,) number of real vectors per segment
    growing_start: int  # first id of the growing tail
    growing_searched: int  # how many tail vectors a query actually scans

    @property
    def growing_size(self) -> int:
        return self.n - self.growing_start


def plan_segments(
    n: int, segment_max_size: int, seal_proportion: float, graceful_time: float
) -> SegmentPlan:
    s = int(min(max(segment_max_size, 64), n))
    n_full = n // s
    rem = n - n_full * s
    seal_rem = rem > 0 and rem >= seal_proportion * s
    n_sealed = n_full + (1 if seal_rem else 0)
    if n_sealed == 0:  # everything growing: force at least one sealed segment
        n_sealed, s = 1, n
        rem, seal_rem = 0, False
    sealed_valid = np.full((n_sealed,), s, dtype=np.int64)
    if seal_rem:
        sealed_valid[-1] = rem
    growing_start = int(sealed_valid.sum())
    growing = n - growing_start
    searched = int(np.ceil((1.0 - float(np.clip(graceful_time, 0.0, 1.0))) * growing))
    return SegmentPlan(
        n=n,
        seg_size=s,
        n_sealed=n_sealed,
        sealed_valid=sealed_valid,
        growing_start=growing_start,
        growing_searched=searched,
    )


def live_seg_size(segment_max_size: int, seal_proportion: float) -> int:
    """Sealed-segment size under *streaming* ingestion.

    A growing segment seals (and gets its own index build) the moment it
    crosses ``seal_proportion * segment_max_size`` — the live counterpart of
    the static plan's trailing-remainder rule. Clamped to >= 64 like the
    static plan so degenerate configurations cannot produce per-vector
    segments.
    """
    s = max(int(segment_max_size), 64)
    return int(min(max(int(np.ceil(float(seal_proportion) * s)), 64), s))


def stack_sealed(data: np.ndarray, plan: SegmentPlan) -> tuple[np.ndarray, np.ndarray]:
    """Pack sealed vectors into (n_sealed, S, d) with -1-id padding.

    Returns (segments, global_ids); padded slots have id -1 and zero vectors.
    """
    s, d = plan.seg_size, data.shape[1]
    segs = np.zeros((plan.n_sealed, s, d), dtype=data.dtype)
    gids = -np.ones((plan.n_sealed, s), dtype=np.int32)
    off = 0
    for z in range(plan.n_sealed):
        v = int(plan.sealed_valid[z])
        segs[z, :v] = data[off : off + v]
        gids[z, :v] = np.arange(off, off + v, dtype=np.int32)
        off += v
    return segs, gids
