"""VDMS query engine: builds a configured instance and measures the paper's
objectives — search speed (QPS), recall@K, and memory footprint.

Two measurement modes:
* ``wall``     — real wall-clock over the jitted search pipeline (the paper's
                 workload replay). Compile/build time is tracked separately as
                 the index-building cost.
* ``analytic`` — deterministic cost model counting the distance evaluations the
                 pipeline performs (used by tests and fast benchmark configs;
                 recall is still measured by actually running the search).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .datasets import VectorDataset, recall_at_k
from .faults import HEALTH_CODE, BuildCrashFault, FaultInjector, TransientEngineFault
from .indexes import (
    IndexBundle,
    build_index,
    concat_bundles,
    frozen_state,
    replace_segment,
    search_index,
)
from .merge import merge_topk
from .registry import get_family
from .segments import live_seg_size, plan_segments, stack_sealed

# analytic-mode calibration constants (documented, deterministic)
_FLOPS_RATE = 5.0e9  # effective CPU distance-eval rate (FLOP/s)
_CHUNK_OVERHEAD = 2.0e-4  # dispatch overhead per query chunk (s)
_SEG_OVERHEAD = 5.0e-5  # per-segment merge overhead per chunk (s)
_STEP_OVERHEAD = 6.0e-6  # per sequential graph-walk step (s)


def analytic_chunk_seconds(
    kind: str,
    st: Dict[str, Any],
    arrays: Dict[str, Any],
    n_sealed: int,
    seg_size: int,
    growing_searched: int,
    dim: int,
    batch: int,
) -> float:
    """Deterministic cost (seconds) of one query chunk — the shared analytic
    model behind static ``VDMSInstance.measure`` and live replays. The
    per-family FLOP count comes from the registered family's ``chunk_cost``
    hook (families without one are charged an exhaustive-scan estimate); the
    rate/overhead arithmetic here is identical to the original model."""
    d, b = dim, batch
    family = get_family(kind)
    if family.chunk_cost is not None:
        flops, steps = family.chunk_cost(st, arrays, n_sealed, seg_size, d)
    else:  # conservative default: brute-force scan of every sealed vector
        flops, steps = n_sealed * seg_size * d * 2, 0
    flops += growing_searched * d * 2  # growing-tail brute force
    flops *= b  # per chunk of b queries
    return (
        flops / _FLOPS_RATE
        + _CHUNK_OVERHEAD
        + n_sealed * _SEG_OVERHEAD
        + steps * _STEP_OVERHEAD
    )


# analytic index-build cost model (deterministic, like the search model):
# counts the dominant FLOPs of one per-segment build so streaming objectives
# can charge ingest overhead without wall-clock noise
_BUILD_RATE = 2.0e10  # effective build FLOP/s (batched kmeans / graph matmuls)
_BUILD_OVERHEAD = 5.0e-3  # per-build dispatch + allocation overhead (s)


def analytic_build_seconds(
    index_type: str, config: Dict[str, Any], seg_size: int, dim: int, first_build: bool
) -> float:
    """Deterministic cost (seconds) of sealing + indexing one segment.

    ``first_build`` additionally charges the one-off shared-calibration
    training (PQ codebooks) that incremental builds freeze afterwards. The
    per-family term comes from the registered family's ``build_cost`` hook
    (families without one are charged only the storage pass).
    """
    s, d = int(seg_size), int(dim)
    family = get_family(index_type)
    flops = float(s * d)  # storage pass
    if family.build_cost is not None:
        flops += family.build_cost(config, s, d, bool(first_build))
    return flops / _BUILD_RATE + _BUILD_OVERHEAD


# ---------------------------------------------------------------------------
# search-pipeline mode (fused vs composed)
# ---------------------------------------------------------------------------
#: process-wide pipeline selector, read OUTSIDE jit and passed as a static
#: argument (a module global read inside a traced function would not retrace)
_SEARCH_PIPELINE = "fused"


def set_search_pipeline(mode: str) -> None:
    """Select the search hot path: ``"fused"`` (default) routes chunks through
    a family's registered ``fused_search`` hook when it has one, ``"composed"``
    always runs the per-family ``search`` + generic merge. Families without a
    hook run composed either way, so "fused" is always safe to leave on."""
    global _SEARCH_PIPELINE
    if mode not in ("fused", "composed"):
        raise ValueError(f"unknown search pipeline {mode!r}; use 'fused' or 'composed'")
    _SEARCH_PIPELINE = mode


def get_search_pipeline() -> str:
    return _SEARCH_PIPELINE


def _pipeline_impl(
    qc, arrays, growing, growing_gids, kind, statics, k_seg, topk, fused=False, clamp=False
):
    """qc: (n_chunks, B, d) queries; returns (n_chunks, B, topk) global ids.

    ``fused=True`` dispatches through the family's registered ``fused_search``
    hook (all chunks flattened into one batched call); families without a
    hook — and segment-less instances — fall back to the composed path below,
    whose results are unchanged by this routing. ``clamp=True`` (set only when
    the instance's sealed segments carry no -1 padding) lets the hook narrow
    per-segment width to ``min(k_seg, topk)``; see ``repro.vdms.fused``.
    """
    family = get_family(kind)
    if fused and family.fused_search is not None and arrays["gids"].shape[0] > 0:
        n_chunks, b, d = qc.shape
        out = family.fused_search(
            qc.reshape(n_chunks * b, d),
            arrays,
            growing,
            growing_gids,
            k_seg=k_seg,
            topk=topk,
            clamp=clamp,
            **dict(statics),
        )
        return out.reshape(n_chunks, b, topk)
    bundle = IndexBundle(kind=kind, arrays=arrays, static=dict(statics))

    def chunk_fn(q):
        ids, sims = search_index(bundle, q, k_seg)  # (n_seg, B, k_seg)
        return merge_topk(ids, sims, q, growing, growing_gids, topk)

    return jax.lax.map(chunk_fn, qc)


_pipeline = partial(
    jax.jit, static_argnames=("kind", "statics", "k_seg", "topk", "fused", "clamp")
)(_pipeline_impl)


@partial(jax.jit, static_argnames=("kind", "statics", "k_seg", "topk"))
def _pipeline_batch(qc, arrays, growing, growing_gids, kind, statics, k_seg, topk):
    """Vectorized multi-config dispatch: every per-instance operand carries a
    leading batch axis (arrays values, growing, growing_gids); the query chunks
    are shared. Returns (B, n_chunks, b, topk) global ids in ONE compiled
    program, amortizing dispatch + compile across the batch. Always runs the
    composed pipeline: fused hooks are a single-instance fast path and the
    vmapped stack is already one fused program."""

    def one(arrays_i, growing_i, gids_i):
        return _pipeline_impl(qc, arrays_i, growing_i, gids_i, kind, statics, k_seg, topk)

    return jax.vmap(one)(arrays, growing, growing_gids)


class VDMSInstance:
    """A built VDMS under one configuration."""

    def __init__(self, dataset: VectorDataset, config: Dict[str, Any], seed: int = 0):
        self.dataset = dataset
        self.config = dict(config)
        t0 = time.perf_counter()
        self.plan = plan_segments(
            dataset.n,
            int(config["segment_max_size"]),
            float(config["seal_proportion"]),
            float(config["graceful_time"]),
        )
        segs, gids = stack_sealed(dataset.data, self.plan)
        key = jax.random.PRNGKey(seed)
        sys = {
            "kmeans_iters": int(config["kmeans_iters"]),
            "storage_bf16": bool(config["storage_bf16"]),
        }
        self.bundle = build_index(key, segs, gids, config["index_type"], config, sys)
        g0 = self.plan.growing_start
        g_searched = self.plan.growing_searched
        self.growing = jnp.asarray(dataset.data[g0 : g0 + g_searched])
        self.growing_gids = jnp.asarray(np.arange(g0, g0 + g_searched, dtype=np.int32))
        jax.block_until_ready(list(self.bundle.arrays.values()))
        self.build_time = time.perf_counter() - t0
        self.k_seg = int(config["topk_merge_width"])
        self.batch = int(config["search_batch_size"])
        # the fused top-k clamp is exact only when every sealed slot is real:
        # a trailing partial seal pads with -1 gids, whose dead slots must
        # keep consuming merge width to match the composed path bit-for-bit
        self._clamp_ok = bool(
            np.all(np.asarray(self.plan.sealed_valid) == self.plan.seg_size)
        )

    # ------------------------------------------------------------------
    def _chunked_queries(self, queries: np.ndarray) -> jnp.ndarray:
        q, d = queries.shape
        b = min(self.batch, q)
        n_chunks = (q + b - 1) // b
        pad = n_chunks * b - q
        if pad:
            queries = np.concatenate([queries, queries[:pad]], axis=0)
        return jnp.asarray(queries.reshape(n_chunks, b, d))

    def search(self, queries: np.ndarray, topk: int) -> np.ndarray:
        qc = self._chunked_queries(queries)
        out = _pipeline(
            qc,
            self.bundle.arrays,
            self.growing,
            self.growing_gids,
            self.bundle.kind,
            tuple(sorted(self.bundle.static.items())),
            self.k_seg,
            topk,
            get_search_pipeline() == "fused",
            self._clamp_ok,
        )
        out = np.asarray(out).reshape(-1, topk)[: queries.shape[0]]
        return out

    def memory_gib(self) -> float:
        b = self.bundle.memory_bytes() + self.growing.size * self.growing.dtype.itemsize
        return b / (1024.0**3)

    # --- analytic cost model ------------------------------------------
    def _analytic_seconds_per_chunk(self) -> float:
        return analytic_chunk_seconds(
            self.bundle.kind,
            self.bundle.static,
            self.bundle.arrays,
            self.plan.n_sealed,
            self.plan.seg_size,
            self.plan.growing_searched,
            self.dataset.dim,
            self.batch,
        )

    # ------------------------------------------------------------------
    def measure(
        self, topk: int | None = None, repeats: int = 3, mode: str = "wall"
    ) -> Dict[str, float]:
        ds = self.dataset
        topk = topk or ds.k
        queries = ds.queries
        # one measured-apart warmup run → compile time + recall
        t0 = time.perf_counter()
        ids = self.search(queries, topk)
        compile_time = time.perf_counter() - t0
        recall = recall_at_k(ids[:, : ds.k], ds.ground_truth)
        n_chunks = (queries.shape[0] + self.batch - 1) // self.batch
        if mode == "analytic":
            elapsed = self._analytic_seconds_per_chunk() * n_chunks
        else:
            times = []
            qc = self._chunked_queries(queries)
            args = (
                qc,
                self.bundle.arrays,
                self.growing,
                self.growing_gids,
                self.bundle.kind,
                tuple(sorted(self.bundle.static.items())),
                self.k_seg,
                topk,
                get_search_pipeline() == "fused",
                self._clamp_ok,
            )
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(_pipeline(*args))
                times.append(time.perf_counter() - t0)
            elapsed = min(times)
        qps = queries.shape[0] / max(elapsed, 1e-9)
        return {
            "speed": float(qps),
            "recall": float(recall),
            "mem_gib": float(self.memory_gib()),
            "build_time": float(self.build_time),
            "compile_time": float(compile_time),
        }


# ---------------------------------------------------------------------------
# live (streaming) instance
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("kind", "statics", "k_seg", "topk", "fused"))
def _live_chunk(
    q, arrays, alive_g, growing, growing_gids, kind, statics, k_seg, topk, fused=False
):
    """One query chunk against the live state: sealed segments searched via
    their indexes, the visible growing tail brute-forced, tombstones and
    padded slots filtered through the global ``alive_g`` mask at merge time
    (index -1 maps to the always-dead sentinel slot ``alive_g[-1]``).

    ``fused=True`` routes through the family's ``fused_search`` hook with
    ``alive=alive_g`` (the hook replicates this merge); live searches never
    clamp — compacted segments carry -1 padding that must consume width."""
    family = get_family(kind)
    if fused and family.fused_search is not None and arrays["gids"].shape[0] > 0:
        return family.fused_search(
            q,
            arrays,
            growing,
            growing_gids,
            k_seg=k_seg,
            topk=topk,
            clamp=False,
            alive=alive_g,
            **dict(statics),
        )
    bundle = IndexBundle(kind=kind, arrays=arrays, static=dict(statics))
    ids, sims = search_index(bundle, q, k_seg)  # (n_seg, B, k_seg)
    return merge_topk(ids, sims, q, growing, growing_gids, topk, alive=alive_g)


@partial(jax.jit, static_argnames=("topk",))
def _live_chunk_unsealed(q, growing, growing_gids, topk):
    """Chunk search before the first seal: brute force over the visible tail."""
    gs = jnp.dot(q, growing.T.astype(q.dtype), preferred_element_type=jnp.float32)
    gs = jnp.where(growing_gids[None, :] >= 0, gs, -jnp.inf)
    k = min(topk, growing.shape[0])
    top_s, top_i = jax.lax.top_k(gs, k)
    out = jnp.where(jnp.isfinite(top_s), growing_gids[top_i], -1)
    if k < topk:
        out = jnp.pad(out, ((0, 0), (0, topk - k)), constant_values=-1)
    return out


def _bucket(n: int) -> int:
    """Pad count for the visible growing tail: next power of two >= n (min
    64), so tail churn recompiles the chunk program only O(log) times."""
    if n <= 0:
        return 0
    b = 64
    while b < n:
        b *= 2
    return b


class LiveVDMS:
    """A *live* VDMS instance: bulk-loaded once, then ingesting timestamped
    inserts/deletes while serving searches — the streaming regime the paper's
    system parameters exist for.

    Lifecycle (Milvus-like):

    * inserts append to the growing tail; when the tail reaches the seal
      size ``ceil(seal_proportion * segment_max_size)`` it is sealed into a
      fixed-shape segment and indexed *incrementally* (one per-segment build;
      SQ8/SCANN scales and PQ codebooks are frozen after the first build,
      like real systems that train quantizers once);
    * deletes tombstone ids anywhere; a sealed segment whose dead fraction
      crosses ``compact_threshold`` is compacted — rebuilt in place from its
      survivors with ``-1``-id padding;
    * ``graceful_time`` is the bounded-consistency window over the *current*
      tail: each search scans only the oldest ``(1 - graceful_time)``
      fraction of the growing tail, so the freshest inserts may be invisible
      (fast but stale). Recall under that staleness is scored by the
      replayer against time-aware ground truth.
    """

    def __init__(
        self,
        config: Dict[str, Any],
        dim: int,
        capacity: int,
        seed: int = 0,
        compact_threshold: float = 0.3,
    ):
        self.config = dict(config)
        # the seal path is registry-dispatched: resolve the family up front so
        # unknown types and non-incremental families fail loudly at creation
        self._family = get_family(config["index_type"])
        if not self._family.supports_incremental:
            raise ValueError(
                f"index family {self._family.name!r} does not support "
                "incremental (streaming) builds"
            )
        self.dim = int(dim)
        self.capacity = int(capacity)
        self.seed = int(seed)
        self.compact_threshold = float(compact_threshold)
        self.seg_size = live_seg_size(
            int(config["segment_max_size"]), float(config["seal_proportion"])
        )
        self.graceful = float(np.clip(float(config["graceful_time"]), 0.0, 1.0))
        self.k_seg = int(config["topk_merge_width"])
        self.batch = int(config["search_batch_size"])
        self._sys = {
            "kmeans_iters": int(config["kmeans_iters"]),
            "storage_bf16": bool(config["storage_bf16"]),
        }
        self._key = jax.random.PRNGKey(seed)
        self.store = np.zeros((self.capacity, self.dim), np.float32)
        # +1 sentinel slot (always dead): merge maps id -1 there
        self.alive = np.zeros(self.capacity + 1, dtype=bool)
        self.gid_seg = np.full(self.capacity, -1, np.int32)  # gid -> sealed segment
        self.n_total = 0
        self.tail: List[int] = []
        self.bundle: IndexBundle | None = None
        self.seg_gids: List[np.ndarray] = []
        self._frozen: Dict[str, np.ndarray] | None = None
        # lifecycle diagnostics
        self.build_time = 0.0  # bootstrap (bulk-load) build seconds
        self.bootstrap_build_model_s = 0.0  # bootstrap builds, analytic model
        self.seal_build_s = 0.0  # incremental seal + compaction builds (wall)
        self.seal_build_model_s = 0.0  # same, under the analytic build model
        self.n_seals = 0
        self.n_compactions = 0
        self.n_deletes = 0
        self.seal_history: List[int] = []  # n_sealed after every lifecycle event
        self._warmed: set = set()  # compiled (n_sealed, bucket, b, topk) shapes
        self.compile_s = 0.0  # wall-mode warmup (compile) seconds, kept apart
        # search instrumentation: per-query latencies of the last search call
        # (a query is charged its chunk's elapsed / chunk width) plus hooks
        # ``fn(n_queries, latencies, elapsed)`` the metrics ledger attaches to
        self.queries_served = 0
        self.last_latencies: np.ndarray = np.empty(0, np.float64)
        self.search_hooks: List[Callable[[int, np.ndarray, float], None]] = []
        # fault-injection + degraded mode. Everything below is inert until
        # ``arm_faults`` installs an injector: every fault branch is gated on
        # ``self._faults is not None`` so the unarmed engine is byte-identical
        # to one that never heard of faults.
        self._faults: FaultInjector | None = None
        # sealed segment -> repair state while quarantined
        self.quarantined: Dict[int, Dict[str, Any]] = {}
        # per-sealed-segment build provenance ({"salt", "first"}) so a
        # quarantined segment can be rebuilt bitwise-identically: the same
        # fold_in salt + frozen-calibration choice replays the same build
        self._seg_meta: List[Dict[str, Any]] = []
        self._pending_seal: Dict[str, int] | None = None  # crashed-seal backoff
        self.last_coverage = 1.0  # visible fraction served by the last search
        self.n_quarantines = 0
        self.n_rebuilds = 0
        self.n_rebuild_failures = 0  # rebuilds whose retry budget exhausted
        self.n_seal_retries = 0  # crashed incremental builds (seal/compact)

    # --- state views ---------------------------------------------------
    @property
    def n_sealed(self) -> int:
        return len(self.seg_gids)

    @property
    def n_alive(self) -> int:
        return int(self.alive[: self.capacity].sum())

    def visible_ids(self) -> np.ndarray:
        """Sorted global ids of every alive vector (sealed + whole tail)."""
        return np.flatnonzero(self.alive[: self.capacity]).astype(np.int32)

    def memory_gib(self) -> float:
        b = len(self.tail) * self.dim * 4
        if self.bundle is not None:
            b += self.bundle.memory_bytes()
        return b / (1024.0**3)

    def stats(self) -> Dict[str, float]:
        """One structured snapshot of the instance's lifecycle state — the
        dict the serving metrics ledger (and ``bench_streaming``) consumes
        instead of poking at scattered attributes. All values are plain
        Python ints/floats (JSON-safe)."""
        n_total = int(self.n_total)
        n_alive = self.n_alive
        return {
            "n_total": n_total,
            "n_alive": n_alive,
            "tombstone_fraction": float((n_total - n_alive) / max(n_total, 1)),
            "n_sealed": int(self.n_sealed),
            "tail_size": len(self.tail),
            "visible_tail": int(self._visible_tail().size),
            "n_seals": int(self.n_seals),
            "n_compactions": int(self.n_compactions),
            "n_deletes": int(self.n_deletes),
            "seal_build_s": float(self.seal_build_s),
            "seal_build_model_s": float(self.seal_build_model_s),
            "bootstrap_build_model_s": float(self.bootstrap_build_model_s),
            "build_time": float(self.build_time),
            "compile_s": float(self.compile_s),
            "mem_gib": float(self.memory_gib()),
            "queries_served": int(self.queries_served),
            # degraded-mode / fault-injection telemetry (all zero when no
            # FaultPlan has ever been armed)
            "coverage": float(self.last_coverage),
            "quarantined_segments": len(self.quarantined),
            "n_quarantines": int(self.n_quarantines),
            "n_rebuilds": int(self.n_rebuilds),
            "n_rebuild_failures": int(self.n_rebuild_failures),
            "n_seal_retries": int(self.n_seal_retries),
            "n_faults_injected": int(self._faults.n_injected if self._faults else 0),
            "health_code": HEALTH_CODE[self.health()],
        }

    # --- ingestion -----------------------------------------------------
    def bootstrap(self, base: np.ndarray) -> None:
        """Bulk-load the pre-replay corpus (sealing as segments fill); the
        time spent is the initial ``build_time`` (index-building cost), not
        replay-time ingest overhead — the seal counters reset afterwards."""
        if self._faults is not None:
            # shadow-scoped injectors fail the matching bootstrap ordinal
            # (injected OOM) before any vector lands
            self._faults.on_bootstrap(int(np.asarray(base).shape[0]))
        t0 = time.perf_counter()
        self.insert(base)
        self.build_time += time.perf_counter() - t0
        self.bootstrap_build_model_s += self.seal_build_model_s
        self.seal_build_s = 0.0
        self.seal_build_model_s = 0.0

    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Append vectors (d,) or (n, d); seals segments as the tail fills.
        Returns the assigned global ids."""
        if self._faults is not None:
            self._fault_tick()
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        n = vecs.shape[0]
        if self.n_total + n > self.capacity:
            raise ValueError(
                f"capacity exceeded: {self.n_total}+{n} > {self.capacity}"
            )
        gids = np.arange(self.n_total, self.n_total + n, dtype=np.int32)
        self.store[gids] = vecs
        self.alive[gids] = True
        self.n_total += n
        self.tail.extend(int(g) for g in gids)
        while len(self.tail) >= self.seg_size:
            if self._pending_seal is not None:
                break  # a crashed seal is backing off; the fault clock retries it
            if not self._try_seal():
                break
        return gids

    def _build_one(
        self,
        ids_row: np.ndarray,
        salt: int | None = None,
        use_frozen: bool | None = None,
        context: str = "seal",
    ) -> IndexBundle:
        """Incremental index build for one packed segment (gid -1 = padding).

        ``salt``/``use_frozen`` default to the live counters (normal seal /
        compaction path); a quarantine rebuild passes the segment's recorded
        provenance instead, replaying the original deterministic build —
        same key, same calibration choice — bitwise-identically."""
        seg = np.zeros((1, self.seg_size, self.dim), np.float32)
        valid = ids_row >= 0
        seg[0, valid] = self.store[ids_row[valid]]
        if salt is None:
            salt = self.n_seals + self.n_compactions
        key = jax.random.fold_in(self._key, salt)
        first = (self._frozen is None) if use_frozen is None else (not use_frozen)
        self.seal_build_model_s += analytic_build_seconds(
            self.config["index_type"], self.config, self.seg_size, self.dim, first
        )
        if self._faults is not None:
            # after the analytic charge: crashed attempts still cost build time
            self._faults.on_build(context)
        b = build_index(
            key, seg, ids_row[None], self.config["index_type"], self.config,
            self._sys, frozen=None if first else self._frozen,
        )
        jax.block_until_ready(list(b.arrays.values()))
        if self._frozen is None:
            self._frozen = frozen_state(b)
        return b

    def _try_seal(self) -> bool:
        """Seal one full tail slice. Returns False if the build crashed (the
        tail stays intact and a backoff retry is scheduled on the fault
        clock); raises :class:`TransientEngineFault` once the retry budget
        is exhausted."""
        t0 = time.perf_counter()
        ids = np.asarray(self.tail[: self.seg_size], np.int32)
        salt = self.n_seals + self.n_compactions
        first = self._frozen is None
        try:
            b = self._build_one(ids, context="seal")
        except BuildCrashFault:
            self.seal_build_s += time.perf_counter() - t0
            self.n_seal_retries += 1
            attempts = 1 if self._pending_seal is None else self._pending_seal["attempts"] + 1
            plan = self._faults.plan
            if attempts > plan.max_seal_retries:
                self._pending_seal = None
                raise TransientEngineFault(
                    f"seal crashed {attempts} times (budget {plan.max_seal_retries})"
                ) from None
            self._pending_seal = {
                "attempts": attempts,
                "next_tick": self._faults.tick + plan.backoff_base_ticks * 2 ** (attempts - 1),
            }
            return False
        self.tail = self.tail[self.seg_size :]
        self.bundle = b if self.bundle is None else concat_bundles(self.bundle, b)
        self.gid_seg[ids] = len(self.seg_gids)
        self.seg_gids.append(ids)
        self._seg_meta.append({"salt": salt, "first": first})
        self.n_seals += 1
        self.seal_build_s += time.perf_counter() - t0
        self.seal_history.append(self.n_sealed)
        self._pending_seal = None
        return True

    def delete(self, gid: int) -> bool:
        """Tombstone one vector; compacts its sealed segment if the dead
        fraction crosses the threshold. Returns False for already-dead ids."""
        if self._faults is not None:
            self._fault_tick()
        gid = int(gid)
        if gid < 0 or gid >= self.n_total or not self.alive[gid]:
            return False
        self.alive[gid] = False
        self.n_deletes += 1
        z = int(self.gid_seg[gid])
        if z >= 0 and z not in self.quarantined:
            row = self.seg_gids[z]
            valid = row[row >= 0]
            dead_frac = 1.0 - float(self.alive[valid].mean()) if valid.size else 1.0
            if dead_frac > self.compact_threshold:
                self._compact(z)
        return True

    def _compact(self, z: int) -> None:
        t0 = time.perf_counter()
        row = self.seg_gids[z]
        valid = row[row >= 0]
        survivors = valid[self.alive[valid]]
        new_row = np.full(self.seg_size, -1, np.int32)
        new_row[: survivors.size] = survivors
        salt = self.n_seals + self.n_compactions
        try:
            b = self._build_one(new_row, context="compact")
        except BuildCrashFault:
            # the old index still serves (tombstones filter at merge); skip —
            # the next delete past the threshold re-triggers compaction
            self.seal_build_s += time.perf_counter() - t0
            self.n_seal_retries += 1
            return
        self.bundle = replace_segment(self.bundle, z, b)
        self.seg_gids[z] = new_row
        self._seg_meta[z] = {"salt": salt, "first": False}
        self.gid_seg[survivors] = z
        self.n_compactions += 1
        self.seal_build_s += time.perf_counter() - t0
        self.seal_history.append(self.n_sealed)

    # --- fault injection + degraded mode -------------------------------
    def arm_faults(self, injector: FaultInjector | None) -> None:
        """Install (or clear, with ``None``) the fault injector driving this
        engine's fault clock. Arm after ``bootstrap`` so plan ticks line up
        with replayed operations rather than bulk-load inserts."""
        self._faults = injector

    def _fault_tick(self) -> None:
        """One step of the fault clock: apply newly-due events, then service
        scheduled repairs (crashed-seal retries, quarantine rebuilds)."""
        inj = self._faults
        for e in inj.advance():
            if self.n_sealed > 0:
                self._quarantine(e.segment % self.n_sealed, e.kind)
        self._service_repairs()

    def _quarantine(self, z: int, reason: str) -> None:
        if z in self.quarantined:
            return
        self.quarantined[z] = {
            "retries": 0,
            "next_tick": self._faults.tick + self._faults.plan.backoff_base_ticks,
            "reason": reason,
            "permanent": False,
        }
        self.n_quarantines += 1

    def _service_repairs(self) -> None:
        inj = self._faults
        tick, plan = inj.tick, inj.plan
        if self._pending_seal is not None and tick >= self._pending_seal["next_tick"]:
            while len(self.tail) >= self.seg_size:
                if not self._try_seal():
                    break
        for z in sorted(self.quarantined):
            st = self.quarantined[z]
            if st["permanent"] or tick < st["next_tick"]:
                continue
            t0 = time.perf_counter()
            meta = self._seg_meta[z]
            try:
                b = self._build_one(
                    self.seg_gids[z],
                    salt=meta["salt"],
                    use_frozen=not meta["first"],
                    context="rebuild",
                )
            except BuildCrashFault:
                self.seal_build_s += time.perf_counter() - t0
                st["retries"] += 1
                if st["retries"] >= plan.max_rebuild_retries:
                    st["permanent"] = True  # -> health() == "degraded"
                    self.n_rebuild_failures += 1
                else:
                    st["next_tick"] = tick + plan.backoff_base_ticks * 2 ** st["retries"]
                continue
            self.bundle = replace_segment(self.bundle, z, b)
            del self.quarantined[z]
            self.n_rebuilds += 1
            self.seal_build_s += time.perf_counter() - t0

    def searchable_ids(self) -> np.ndarray:
        """Sorted gids a search can actually return *right now*: alive, not
        in a quarantined segment, and not hidden behind the graceful-time
        consistency window — the visible set that honest (partial-coverage)
        recall accounting is scored against."""
        mask = self.alive[: self.capacity].copy()
        m = int(np.ceil((1.0 - self.graceful) * len(self.tail)))
        hidden = np.asarray(self.tail[m:], np.int32)
        if hidden.size:
            mask[hidden] = False
        for z in self.quarantined:
            row = self.seg_gids[z]
            mask[row[row >= 0]] = False
        return np.flatnonzero(mask).astype(np.int32)

    def health(self) -> str:
        """``healthy`` | ``rebuilding`` (repairs scheduled and within budget)
        | ``degraded`` (some quarantined segment exhausted its rebuilds)."""
        if any(st["permanent"] for st in self.quarantined.values()):
            return "degraded"
        if self.quarantined or self._pending_seal is not None:
            return "rebuilding"
        return "healthy"

    # --- search --------------------------------------------------------
    def _visible_tail(self) -> np.ndarray:
        """Alive gids of the tail slice a query may scan: the oldest
        ``(1 - graceful_time)`` fraction (newest inserts are skipped —
        the bounded-consistency window)."""
        m = int(np.ceil((1.0 - self.graceful) * len(self.tail)))
        if m == 0:
            return np.empty(0, np.int32)
        vis = np.asarray(self.tail[:m], np.int32)
        return vis[self.alive[vis]]

    def search(
        self, queries: np.ndarray, topk: int, mode: str = "analytic"
    ) -> Tuple[np.ndarray, float]:
        """Search the current visible state. Returns ``(global ids (Q, topk),
        elapsed seconds)`` — analytic mode charges the deterministic cost
        model for the live segment state; wall mode times the dispatch."""
        if self._faults is not None:
            self._fault_tick()
        queries = np.asarray(queries, np.float32)
        nq = queries.shape[0]
        b = min(self.batch, max(nq, 1))
        n_chunks = (nq + b - 1) // b
        vis = self._visible_tail()
        nb = _bucket(vis.size)
        growing = np.zeros((nb, self.dim), np.float32)
        growing[: vis.size] = self.store[vis]
        ggids = np.full(nb, -1, np.int32)
        ggids[: vis.size] = vis
        growing_j, ggids_j = jnp.asarray(growing), jnp.asarray(ggids)
        alive_arr = self.alive
        coverage = 1.0
        if self._faults is not None and self.quarantined:
            # degraded mode: mask quarantined segments out of the merge (same
            # array shape -> no recompile) and report the visible fraction
            alive_arr = self.alive.copy()
            sealed_alive = int((self.alive[: self.capacity] & (self.gid_seg >= 0)).sum())
            lost = 0
            for z in self.quarantined:
                row = self.seg_gids[z]
                valid = row[row >= 0]
                lost += int(self.alive[valid].sum())
                alive_arr[valid] = False
            total = sealed_alive + int(vis.size)
            coverage = float((total - lost) / max(total, 1))
        self.last_coverage = coverage
        alive_j = jnp.asarray(alive_arr)
        use_fused = get_search_pipeline() == "fused"

        def dispatch(chunk: np.ndarray) -> np.ndarray:
            if self.bundle is None:
                if nb == 0:
                    return np.full((b, topk), -1, np.int32)
                return np.asarray(
                    jax.block_until_ready(
                        _live_chunk_unsealed(jnp.asarray(chunk), growing_j, ggids_j, topk)
                    )
                )
            return np.asarray(
                jax.block_until_ready(
                    _live_chunk(
                        jnp.asarray(chunk),
                        self.bundle.arrays,
                        alive_j,
                        growing_j,
                        ggids_j,
                        self.bundle.kind,
                        tuple(sorted(self.bundle.static.items())),
                        self.k_seg,
                        topk,
                        use_fused,
                    )
                )
            )

        shape_key = (
            self.n_sealed if self.bundle is not None else -1, nb, b, topk, use_fused
        )
        out = np.empty((n_chunks * b, topk), np.int32)
        chunk_s = np.zeros(n_chunks, np.float64)
        for c in range(n_chunks):
            lo = c * b
            chunk = queries[lo : lo + b]
            if chunk.shape[0] < b:  # pad the final chunk by wrapping
                chunk = np.concatenate([chunk, queries[: b - chunk.shape[0]]], axis=0)
            if mode != "analytic" and shape_key not in self._warmed:
                # wall mode keeps compilation apart from the measured region,
                # mirroring the static path's measured-apart warmup run
                t0 = time.perf_counter()
                dispatch(chunk)
                self.compile_s += time.perf_counter() - t0
                self._warmed.add(shape_key)
            t0 = time.perf_counter()
            ids = dispatch(chunk)
            chunk_s[c] = time.perf_counter() - t0
            out[lo : lo + b] = ids
        if mode == "analytic":
            chunk_s[:] = analytic_chunk_seconds(
                self.bundle.kind if self.bundle is not None else "FLAT",
                self.bundle.static if self.bundle is not None else {},
                self.bundle.arrays if self.bundle is not None else {},
                self.n_sealed,
                self.seg_size,
                int(vis.size),
                self.dim,
                b,
            )
        counts = np.minimum(b, nq - b * np.arange(n_chunks))
        if self._faults is not None:
            # a latency storm distorts measured time only — never results
            mult, add = self._faults.latency_shape()
            if mult != 1.0 or add != 0.0:
                chunk_s = chunk_s * mult + add * counts
        elapsed = float(chunk_s.sum())
        # per-query wall latency: each chunk's elapsed is split over the real
        # queries it served (the final chunk's padding burden falls on them),
        # so latencies always sum to the batch elapsed — this is what makes
        # serving percentiles and throughput accounting consistent
        lat = np.repeat(chunk_s / np.maximum(counts, 1), counts)
        self.last_latencies = lat
        self.queries_served += nq
        for hook in self.search_hooks:
            hook(nq, lat, elapsed)
        return out[:nq], elapsed


# ---------------------------------------------------------------------------
# vectorized multi-config evaluation
# ---------------------------------------------------------------------------
def batch_signature(inst: VDMSInstance, topk: int | None = None) -> Tuple:
    """Static-shape fingerprint of an instance's compiled search program.

    Instances with equal signatures run the same XLA program modulo array
    contents, so their pipelines can be stacked and dispatched together via
    ``_pipeline_batch``.
    """
    topk = topk or inst.dataset.k
    return (
        inst.bundle.kind,
        tuple(sorted(inst.bundle.static.items())),
        tuple((k, a.shape, str(a.dtype)) for k, a in sorted(inst.bundle.arrays.items())),
        (inst.growing.shape, str(inst.growing.dtype)),
        inst.k_seg,
        inst.batch,
        topk,
    )


def measure_batch(
    instances: List[VDMSInstance],
    topk: int | None = None,
    repeats: int = 3,
    mode: str = "analytic",
) -> List[Dict[str, float]]:
    """Measure shape-identical instances in one vectorized dispatch.

    All instances must share one dataset and one :func:`batch_signature`;
    their arrays are stacked on a leading axis and searched by a single
    vmapped program, so compile and dispatch cost is paid once per batch
    instead of once per config. Recall is exact per config. In ``analytic``
    mode speed comes from each instance's deterministic cost model (identical
    to sequential ``measure``); in ``wall`` mode the batch is timed as one
    program and each config is charged an equal share of the wall time
    (amortized throughput — prefer per-instance measurement when single-config
    latency fidelity matters).
    """
    if not instances:
        return []
    inst0 = instances[0]
    ds = inst0.dataset
    topk = topk or ds.k
    if any(i.dataset is not ds for i in instances):
        raise ValueError("measure_batch requires a single shared dataset")
    if len({batch_signature(i, topk) for i in instances}) != 1:
        raise ValueError("measure_batch requires shape-identical instances")
    queries = ds.queries
    qc = inst0._chunked_queries(queries)
    arrays = {
        k: jnp.stack([i.bundle.arrays[k] for i in instances]) for k in inst0.bundle.arrays
    }
    growing = jnp.stack([i.growing for i in instances])
    gids = jnp.stack([i.growing_gids for i in instances])
    args = (
        qc,
        arrays,
        growing,
        gids,
        inst0.bundle.kind,
        tuple(sorted(inst0.bundle.static.items())),
        inst0.k_seg,
        topk,
    )
    t0 = time.perf_counter()
    out = np.asarray(jax.block_until_ready(_pipeline_batch(*args)))
    compile_time = time.perf_counter() - t0
    n_chunks = (queries.shape[0] + inst0.batch - 1) // inst0.batch
    if mode == "analytic":
        elapsed = [inst._analytic_seconds_per_chunk() * n_chunks for inst in instances]
    else:
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(_pipeline_batch(*args))
            times.append(time.perf_counter() - t0)
        elapsed = [min(times) / len(instances)] * len(instances)
    results = []
    for i, inst in enumerate(instances):
        ids = out[i].reshape(-1, topk)[: queries.shape[0]]
        recall = recall_at_k(ids[:, : ds.k], ds.ground_truth)
        qps = queries.shape[0] / max(elapsed[i], 1e-9)
        results.append(
            {
                "speed": float(qps),
                "recall": float(recall),
                "mem_gib": float(inst.memory_gib()),
                "build_time": float(inst.build_time),
                "compile_time": float(compile_time),
            }
        )
    return results
