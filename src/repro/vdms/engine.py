"""VDMS query engine: builds a configured instance and measures the paper's
objectives — search speed (QPS), recall@K, and memory footprint.

Two measurement modes:
* ``wall``     — real wall-clock over the jitted search pipeline (the paper's
                 workload replay). Compile/build time is tracked separately as
                 the index-building cost.
* ``analytic`` — deterministic cost model counting the distance evaluations the
                 pipeline performs (used by tests and fast benchmark configs;
                 recall is still measured by actually running the search).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .datasets import VectorDataset, recall_at_k
from .indexes import IndexBundle, build_index, search_index
from .segments import plan_segments, stack_sealed

# analytic-mode calibration constants (documented, deterministic)
_FLOPS_RATE = 5.0e9  # effective CPU distance-eval rate (FLOP/s)
_CHUNK_OVERHEAD = 2.0e-4  # dispatch overhead per query chunk (s)
_SEG_OVERHEAD = 5.0e-5  # per-segment merge overhead per chunk (s)
_STEP_OVERHEAD = 6.0e-6  # per sequential graph-walk step (s)


def _pipeline_impl(qc, arrays, growing, growing_gids, kind, statics, k_seg, topk):
    """qc: (n_chunks, B, d) queries; returns (n_chunks, B, topk) global ids."""
    bundle = IndexBundle(kind=kind, arrays=arrays, static=dict(statics))

    def chunk_fn(q):
        ids, sims = search_index(bundle, q, k_seg)  # (n_seg, B, k_seg)
        n_seg, b, ks = ids.shape
        ids2 = jnp.moveaxis(ids, 0, 1).reshape(b, n_seg * ks)
        sims2 = jnp.moveaxis(sims, 0, 1).reshape(b, n_seg * ks)
        if growing.shape[0] > 0:
            gs = jnp.dot(q, growing.T.astype(q.dtype), preferred_element_type=jnp.float32)
            gk = min(topk, growing.shape[0])
            gtop_s, gtop_i = jax.lax.top_k(gs, gk)
            ids2 = jnp.concatenate([ids2, growing_gids[gtop_i]], axis=1)
            sims2 = jnp.concatenate([sims2, gtop_s], axis=1)
        k = min(topk, sims2.shape[1])
        top_s, top_i = jax.lax.top_k(sims2, k)
        out = jnp.take_along_axis(ids2, top_i, axis=1)
        if k < topk:
            out = jnp.pad(out, ((0, 0), (0, topk - k)), constant_values=-1)
        return out

    return jax.lax.map(chunk_fn, qc)


_pipeline = partial(jax.jit, static_argnames=("kind", "statics", "k_seg", "topk"))(
    _pipeline_impl
)


@partial(jax.jit, static_argnames=("kind", "statics", "k_seg", "topk"))
def _pipeline_batch(qc, arrays, growing, growing_gids, kind, statics, k_seg, topk):
    """Vectorized multi-config dispatch: every per-instance operand carries a
    leading batch axis (arrays values, growing, growing_gids); the query chunks
    are shared. Returns (B, n_chunks, b, topk) global ids in ONE compiled
    program, amortizing dispatch + compile across the batch."""

    def one(arrays_i, growing_i, gids_i):
        return _pipeline_impl(qc, arrays_i, growing_i, gids_i, kind, statics, k_seg, topk)

    return jax.vmap(one)(arrays, growing, growing_gids)


class VDMSInstance:
    """A built VDMS under one configuration."""

    def __init__(self, dataset: VectorDataset, config: Dict[str, Any], seed: int = 0):
        self.dataset = dataset
        self.config = dict(config)
        t0 = time.perf_counter()
        self.plan = plan_segments(
            dataset.n,
            int(config["segment_max_size"]),
            float(config["seal_proportion"]),
            float(config["graceful_time"]),
        )
        segs, gids = stack_sealed(dataset.data, self.plan)
        key = jax.random.PRNGKey(seed)
        sys = {
            "kmeans_iters": int(config["kmeans_iters"]),
            "storage_bf16": bool(config["storage_bf16"]),
        }
        self.bundle = build_index(key, segs, gids, config["index_type"], config, sys)
        g0 = self.plan.growing_start
        g_searched = self.plan.growing_searched
        self.growing = jnp.asarray(dataset.data[g0 : g0 + g_searched])
        self.growing_gids = jnp.asarray(np.arange(g0, g0 + g_searched, dtype=np.int32))
        jax.block_until_ready(list(self.bundle.arrays.values()))
        self.build_time = time.perf_counter() - t0
        self.k_seg = int(config["topk_merge_width"])
        self.batch = int(config["search_batch_size"])

    # ------------------------------------------------------------------
    def _chunked_queries(self, queries: np.ndarray) -> jnp.ndarray:
        q, d = queries.shape
        b = min(self.batch, q)
        n_chunks = (q + b - 1) // b
        pad = n_chunks * b - q
        if pad:
            queries = np.concatenate([queries, queries[:pad]], axis=0)
        return jnp.asarray(queries.reshape(n_chunks, b, d))

    def search(self, queries: np.ndarray, topk: int) -> np.ndarray:
        qc = self._chunked_queries(queries)
        out = _pipeline(
            qc,
            self.bundle.arrays,
            self.growing,
            self.growing_gids,
            self.bundle.kind,
            tuple(sorted(self.bundle.static.items())),
            self.k_seg,
            topk,
        )
        out = np.asarray(out).reshape(-1, topk)[: queries.shape[0]]
        return out

    def memory_gib(self) -> float:
        b = self.bundle.memory_bytes() + self.growing.size * self.growing.dtype.itemsize
        return b / (1024.0**3)

    # --- analytic cost model ------------------------------------------
    def _analytic_seconds_per_chunk(self) -> float:
        st = self.bundle.static
        plan, d = self.plan, self.dataset.dim
        b = self.batch
        s = plan.seg_size
        kind = self.bundle.kind
        flops = 0.0
        steps = 0
        if kind == "FLAT":
            flops = plan.n_sealed * s * d * 2
        elif kind in ("IVF_FLAT", "IVF_SQ8", "AUTOINDEX"):
            nlist = self.bundle.arrays["centroids"].shape[1]
            cap = self.bundle.arrays["members"].shape[2]
            bytes_scale = 0.5 if kind == "IVF_SQ8" else 1.0
            flops = plan.n_sealed * (nlist * d + st["nprobe"] * cap * d * bytes_scale) * 2
        elif kind == "IVF_PQ":
            nlist = self.bundle.arrays["centroids"].shape[1]
            cap = self.bundle.arrays["members"].shape[2]
            flops = plan.n_sealed * (
                nlist * d * 2 + st["m"] * st["c"] * (d // st["m"]) * 2 + st["nprobe"] * cap * st["m"]
            )
        elif kind == "HNSW":
            flops = plan.n_sealed * st["ef"] * st["m_links"] * d * 2
            steps = st["ef"]
        elif kind == "SCANN":
            nlist = self.bundle.arrays["centroids"].shape[1]
            cap = self.bundle.arrays["members"].shape[2]
            flops = plan.n_sealed * (
                nlist * d * 2 + st["nprobe"] * cap * d + st["reorder_k"] * d * 2
            )
        flops += self.plan.growing_searched * d * 2  # growing-tail brute force
        flops *= b  # per chunk of b queries
        return (
            flops / _FLOPS_RATE
            + _CHUNK_OVERHEAD
            + plan.n_sealed * _SEG_OVERHEAD
            + steps * _STEP_OVERHEAD
        )

    # ------------------------------------------------------------------
    def measure(
        self, topk: int | None = None, repeats: int = 3, mode: str = "wall"
    ) -> Dict[str, float]:
        ds = self.dataset
        topk = topk or ds.k
        queries = ds.queries
        # one measured-apart warmup run → compile time + recall
        t0 = time.perf_counter()
        ids = self.search(queries, topk)
        compile_time = time.perf_counter() - t0
        recall = recall_at_k(ids[:, : ds.k], ds.ground_truth)
        n_chunks = (queries.shape[0] + self.batch - 1) // self.batch
        if mode == "analytic":
            elapsed = self._analytic_seconds_per_chunk() * n_chunks
        else:
            times = []
            qc = self._chunked_queries(queries)
            args = (
                qc,
                self.bundle.arrays,
                self.growing,
                self.growing_gids,
                self.bundle.kind,
                tuple(sorted(self.bundle.static.items())),
                self.k_seg,
                topk,
            )
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(_pipeline(*args))
                times.append(time.perf_counter() - t0)
            elapsed = min(times)
        qps = queries.shape[0] / max(elapsed, 1e-9)
        return {
            "speed": float(qps),
            "recall": float(recall),
            "mem_gib": float(self.memory_gib()),
            "build_time": float(self.build_time),
            "compile_time": float(compile_time),
        }


# ---------------------------------------------------------------------------
# vectorized multi-config evaluation
# ---------------------------------------------------------------------------
def batch_signature(inst: VDMSInstance, topk: int | None = None) -> Tuple:
    """Static-shape fingerprint of an instance's compiled search program.

    Instances with equal signatures run the same XLA program modulo array
    contents, so their pipelines can be stacked and dispatched together via
    ``_pipeline_batch``.
    """
    topk = topk or inst.dataset.k
    return (
        inst.bundle.kind,
        tuple(sorted(inst.bundle.static.items())),
        tuple((k, a.shape, str(a.dtype)) for k, a in sorted(inst.bundle.arrays.items())),
        (inst.growing.shape, str(inst.growing.dtype)),
        inst.k_seg,
        inst.batch,
        topk,
    )


def measure_batch(
    instances: List[VDMSInstance],
    topk: int | None = None,
    repeats: int = 3,
    mode: str = "analytic",
) -> List[Dict[str, float]]:
    """Measure shape-identical instances in one vectorized dispatch.

    All instances must share one dataset and one :func:`batch_signature`;
    their arrays are stacked on a leading axis and searched by a single
    vmapped program, so compile and dispatch cost is paid once per batch
    instead of once per config. Recall is exact per config. In ``analytic``
    mode speed comes from each instance's deterministic cost model (identical
    to sequential ``measure``); in ``wall`` mode the batch is timed as one
    program and each config is charged an equal share of the wall time
    (amortized throughput — prefer per-instance measurement when single-config
    latency fidelity matters).
    """
    if not instances:
        return []
    inst0 = instances[0]
    ds = inst0.dataset
    topk = topk or ds.k
    if any(i.dataset is not ds for i in instances):
        raise ValueError("measure_batch requires a single shared dataset")
    if len({batch_signature(i, topk) for i in instances}) != 1:
        raise ValueError("measure_batch requires shape-identical instances")
    queries = ds.queries
    qc = inst0._chunked_queries(queries)
    arrays = {
        k: jnp.stack([i.bundle.arrays[k] for i in instances]) for k in inst0.bundle.arrays
    }
    growing = jnp.stack([i.growing for i in instances])
    gids = jnp.stack([i.growing_gids for i in instances])
    args = (
        qc,
        arrays,
        growing,
        gids,
        inst0.bundle.kind,
        tuple(sorted(inst0.bundle.static.items())),
        inst0.k_seg,
        topk,
    )
    t0 = time.perf_counter()
    out = np.asarray(jax.block_until_ready(_pipeline_batch(*args)))
    compile_time = time.perf_counter() - t0
    n_chunks = (queries.shape[0] + inst0.batch - 1) // inst0.batch
    if mode == "analytic":
        elapsed = [inst._analytic_seconds_per_chunk() * n_chunks for inst in instances]
    else:
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(_pipeline_batch(*args))
            times.append(time.perf_counter() - t0)
        elapsed = [min(times) / len(instances)] * len(instances)
    results = []
    for i, inst in enumerate(instances):
        ids = out[i].reshape(-1, topk)[: queries.shape[0]]
        recall = recall_at_k(ids[:, : ds.k], ds.ground_truth)
        qps = queries.shape[0] / max(elapsed[i], 1e-9)
        results.append(
            {
                "speed": float(qps),
                "recall": float(recall),
                "mem_gib": float(inst.memory_gib()),
                "build_time": float(inst.build_time),
                "compile_time": float(compile_time),
            }
        )
    return results
