"""PQ asymmetric-distance (ADC) scan as an MXU kernel.

GPU implementations gather per-byte from a shared-memory LUT. TPUs have no
shared-memory gather, so we ADAPT rather than port: the per-subquantizer
lookup  lut[q, m, codes[n, m]]  is algebraically a matmul against the one-hot
expansion of the codes,

    out[q, n] = sum_m  lut[q, m, :] . onehot(codes[n, m])

and the one-hot matrix is materialized tile-by-tile in VMEM, turning the
whole scan into MXU work. Grid: (Q/bq, N/bn, m) accumulating over the
subquantizer axis in a VMEM scratch.

VMEM per step (defaults bq=128, bn=512, c<=256): onehot 512x256 f32 (512 KB)
+ lut 128x256 (128 KB) + acc 128x512 (256 KB) — well inside v5e VMEM.

Memory-layout contract (shared by every kernel in this package, see
``docs/KERNELS.md``): row-major operands, zero-padded to block multiples by
the host-side wrapper. Padded code rows are zero-filled and select LUT entry
0 — their garbage scores live only in rows the wrapper slices off; padded
LUT columns are never selected because real codes are < c. Accumulation is
f32 in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _adc_kernel(lut_ref, codes_ref, o_ref, acc_ref, *, m_steps: int, c: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]  # (bn, 1) int32 for this subquantizer
    onehot = (
        codes == jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], c), 1)
    ).astype(jnp.float32)  # (bn, c)
    lut = lut_ref[...][:, 0, :]  # (bq, 1, c) -> (bq, c)
    acc_ref[...] += jax.lax.dot_general(
        lut, onehot, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)

    @pl.when(mi == m_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def pq_adc_pallas(
    lut: jnp.ndarray,
    codes: jnp.ndarray,
    bq: int = 128,
    bn: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """lut (q, m, c) f32, codes (n, m) integer -> (q, n) f32 summed distances."""
    q, m, c = lut.shape
    n = codes.shape[0]
    bq = min(bq, _round_up(q, 8))
    bn = min(bn, _round_up(n, 128))
    qp, np_ = _round_up(q, bq), _round_up(n, bn)
    lut_p = jnp.pad(lut.astype(jnp.float32), ((0, qp - q), (0, 0), (0, 0)))
    codes_p = jnp.pad(codes.astype(jnp.int32), ((0, np_ - n), (0, 0)))
    grid = (qp // bq, np_ // bn, m)

    out = pl.pallas_call(
        functools.partial(_adc_kernel, m_steps=m, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, c), lambda i, j, mi: (i, mi, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, mi: (j, mi)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, mi: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        interpret=interpret,
    )(lut_p, codes_p)
    return out[:q, :n]
