"""Block-scanned FlashAttention in pure jnp/lax with a custom VJP.

This is the memory-safe attention used on every backend where the Pallas TPU
kernel is unavailable (CPU dry-run, smoke tests) — and the semantics model
for the Pallas kernel itself. The (sq, sk) score matrix is never materialized:

* forward: scan over q blocks; inner scan over kv blocks carrying the online
  (max, normalizer, accumulator); residuals saved are only (out, lse) —
  O(b·s·h·dh), not O(b·h·s²);
* backward: flash backward — recompute block probabilities from (q, k, lse),
  accumulate dq per q block and dk/dv across q blocks.

Supports causal masking, GQA head grouping, sliding windows, and tail-aligned
query offsets (decode/prefill against a longer key axis).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_NEG = -1e30

# process-wide default block sizes (tunable — see tuning/serve_tuner.py)
DEFAULT_BQ = 512
DEFAULT_BK = 1024


def set_default_blocks(bq: int, bk: int) -> None:
    global DEFAULT_BQ, DEFAULT_BK
    DEFAULT_BQ, DEFAULT_BK = int(bq), int(bk)


def get_default_blocks() -> tuple[int, int]:
    """Current process-wide (bq, bk) — pair with ``set_default_blocks`` to
    save/restore around a scoped override."""
    return DEFAULT_BQ, DEFAULT_BK


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _mask(qpos, kpos, causal: bool, window, kv_len: int):
    m = kpos[None, :] < kv_len
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m  # (bq, bk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention_xla(q, k, v, causal: bool = True, window: Optional[int] = None,
                         bq: int = 512, bk: int = 1024):
    out, _ = _forward(q, k, v, causal, window, bq, bk)
    return out


def flash_attention_xla(q, k, v, causal: bool = True, window: Optional[int] = None,
                        bq: Optional[int] = None, bk: Optional[int] = None):
    return _flash_attention_xla(
        q, k, v, causal, window, bq or DEFAULT_BQ, bk or DEFAULT_BK
    )


def _blocks(q, k, v, bq, bk):
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, sk)
    sqp, skp = _round_up(sq, bq), _round_up(sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
    g = hq // hkv
    # (nqb, b, hkv, g, bq, dh) and (nkb, b, hkv, bk, dh)
    qb = jnp.moveaxis(
        qp.reshape(b, sqp // bq, bq, hkv, g, dh), (1, 3, 4, 2), (0, 2, 3, 4)
    )
    kb = jnp.moveaxis(kp.reshape(b, skp // bk, bk, hkv, dh), (1, 3, 2), (0, 2, 3))
    vb = jnp.moveaxis(vp.reshape(b, skp // bk, bk, hkv, dh), (1, 3, 2), (0, 2, 3))
    return qb, kb, vb, bq, bk


def _forward(q, k, v, causal, window, bq, bk):
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / float(dh) ** 0.5
    q_off = sk - sq
    qb, kb, vb, bq, bk = _blocks(q, k, v, bq, bk)
    nqb, nkb = qb.shape[0], kb.shape[0]

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk  # qblk (b, hkv, g, bq, dh)
        qf = qblk.astype(jnp.float32) * scale
        qpos = q_off + qi * bq + jnp.arange(bq)

        def kv_step(carry, ki_blk):
            m_run, l_run, acc = carry
            ki, kblk, vblk = ki_blk
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qf, kblk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            msk = _mask(qpos, kpos, causal, window, sk)
            s = jnp.where(msk[None, None, None], s, _NEG)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, bq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkb), kb, vb)
        )
        l_safe = jnp.maximum(l_f, 1e-30)
        out_blk = acc / l_safe[..., None]
        lse = m_f + jnp.log(l_safe)
        return None, (out_blk, lse)

    _, (out_b, lse_b) = jax.lax.scan(q_step, None, (jnp.arange(nqb), qb))
    # out_b (nqb, b, hkv, g, bq, dh) -> (b, sq, hq, dh)
    out = jnp.moveaxis(out_b, (0, 4), (1, 2)).reshape(b, -1, hq, dh)[:, :sq]
    lse = jnp.moveaxis(lse_b, (0, 4), (1, 2)).reshape(b, -1, hkv, g)[:, :sq]
    return out.astype(q.dtype), lse


def _fwd(q, k, v, causal, window, bq, bk):
    out, lse = _forward(q, k, v, causal, window, bq, bk)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, bq, bk, res, dout):
    q, k, v, out, lse = res
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / float(dh) ** 0.5
    q_off = sk - sq
    qb, kb, vb, bq, bk = _blocks(q, k, v, bq, bk)
    nqb, nkb = qb.shape[0], kb.shape[0]
    dob = _blocks(dout, k, v, bq, bk)[0]  # same layout as qb
    # lse/delta per q block: (nqb, b, hkv, g, bq)
    sqp = nqb * bq
    lse_p = jnp.pad(lse, ((0, 0), (0, sqp - sq), (0, 0), (0, 0)))
    lse_b = jnp.moveaxis(lse_p.reshape(b, nqb, bq, hkv, g), (1, 3, 4), (0, 2, 3))
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    delta = delta.reshape(b, sq, hkv, g)
    delta_p = jnp.pad(delta, ((0, 0), (0, sqp - sq), (0, 0), (0, 0)))
    delta_b = jnp.moveaxis(delta_p.reshape(b, nqb, bq, hkv, g), (1, 3, 4), (0, 2, 3))

    def q_step(carry, xs):
        dk_acc, dv_acc = carry  # (nkb, b, hkv, bk, dh) f32
        qi, qblk, doblk, lse_blk, delta_blk = xs
        qf = qblk.astype(jnp.float32) * scale
        dof = doblk.astype(jnp.float32)
        qpos = q_off + qi * bq + jnp.arange(bq)

        def kv_step(dq_acc, ys):
            ki, kblk, vblk, dk_i, dv_i = ys
            kf = kblk.astype(jnp.float32)
            vf = vblk.astype(jnp.float32)
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf, preferred_element_type=jnp.float32)
            msk = _mask(qpos, kpos, causal, window, sk)
            s = jnp.where(msk[None, None, None], s, _NEG)
            p = jnp.exp(s - lse_blk[..., None])  # (b,hkv,g,bq,bk)
            dv_i = dv_i + jnp.einsum("bhgqk,bhgqd->bhkd", p, dof)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dof, vf)
            ds = p * (dp - delta_blk[..., None])
            dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kf) * scale
            dk_i = dk_i + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qf)  # qf has scale
            return dq_acc, (dk_i, dv_i)

        dq0 = jnp.zeros((b, hkv, g, bq, dh), jnp.float32)
        dq_blk, (dk_acc, dv_acc) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nkb), kb, vb, dk_acc, dv_acc)
        )
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((nkb, b, hkv, bk, dh), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk_b, dv_b), dq_b = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nqb), qb, dob, lse_b, delta_b)
    )
    dq = jnp.moveaxis(dq_b, (0, 4), (1, 2)).reshape(b, -1, hq, dh)[:, :sq]
    dk = jnp.moveaxis(dk_b, (0, 3), (1, 2)).reshape(b, -1, hkv, dh)[:, :sk]
    dv = jnp.moveaxis(dv_b, (0, 3), (1, 2)).reshape(b, -1, hkv, dh)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention_xla.defvjp(_fwd, _bwd)
