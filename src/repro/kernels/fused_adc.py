"""Fused IVF probe → PQ ADC scan → in-kernel top-k (the IVF_PQ pipeline).

Same fusion contract as :mod:`repro.kernels.fused_scan` (which contributes
the in-kernel probe and running-top-k stages); the scoring stage differs:

* :func:`fused_ivf_pq_topk_xla` — reference path: the per-subquantizer LUT
  lookup runs as ONE flat ``take_along_axis`` over the (B, m*c) LUT
  (measured 6-8x faster than the nested per-subquantizer gather the
  composed path uses), summed over m in the same order so scores are
  bit-identical to the composed scan.
* :func:`fused_ivf_pq_topk_pallas` — TPU kernel: no gather on TPU, so each
  code tile scores via m one-hot matmuls against the VMEM-resident LUT
  (the :mod:`repro.kernels.pq_adc` adaptation), then flows through the
  shared membership-mask + running-top-k stages.

Memory-layout contract
----------------------
* Codes are passed TRANSPOSED to the kernel — (m, s) int32, row-major — so
  the tiled axis (s) is the lane axis; the LUT is padded per subquantizer to
  a 128-multiple code width and flattened to (B, m*cpad), zero-padded slots
  are never matched because codes < c.
* Everything else follows fused_scan: zero-pad to block multiples, padding
  masked via ``cluster_of == -1``, f32 accumulation, (B, k) outputs with
  -1/-inf empty slots and impl-defined tie ordering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_scan import (
    _round_up,
    merge_tile_topk,
    probe_and_init,
    probe_candidates,
    topk_candidates,
)


def _fused_pq_kernel(
    q_ref, c_ref, lut_ref, codes_ref, cl_ref, gid_ref, lid_out, sim_out,
    cmask_scr, vals_scr, lids_scr, *, nlist, nprobe, k, m, cpad, n_steps, mask_dead,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        probe_and_init(q_ref, c_ref, cmask_scr, vals_scr, lids_scr, nlist=nlist, nprobe=nprobe)

    bp = lut_ref.shape[0]
    bn = codes_ref.shape[1]

    def body(mi, acc):
        crow = codes_ref[pl.ds(mi, 1), :]  # (1, bn) int32
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (cpad, bn), 0) == crow
        ).astype(jnp.float32)  # (cpad, bn)
        lutm = lut_ref[:, pl.ds(mi * cpad, cpad)]  # (Bp, cpad)
        return acc + jax.lax.dot_general(
            lutm, onehot, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    scores = jax.lax.fori_loop(0, m, body, jnp.zeros((bp, bn), jnp.float32))
    merge_tile_topk(
        scores, j, cl_ref, gid_ref, cmask_scr, vals_scr, lids_scr, k=k, mask_dead=mask_dead
    )

    @pl.when(j == n_steps - 1)
    def _flush():
        lid_out[...] = lids_scr[...]
        sim_out[...] = vals_scr[...]


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "mask_dead", "bn", "interpret"))
def fused_ivf_pq_topk_pallas(
    q: jnp.ndarray,
    lut: jnp.ndarray,
    codes: jnp.ndarray,
    centroids: jnp.ndarray,
    cluster_of: jnp.ndarray,
    gids: jnp.ndarray,
    *,
    nprobe: int,
    k: int,
    mask_dead: bool = False,
    bn: int = 256,
    interpret: bool = False,
):
    """One segment: q (B, d) f32, lut (B, m, c) f32, codes (s, m) integer,
    centroids (nlist, d), cluster_of (s,), gids (s,) -> (lids, sims) (B, k)."""
    b, d = q.shape
    _, m, c = lut.shape
    s = codes.shape[0]
    nlist = centroids.shape[0]
    bp, dp, lp = _round_up(b, 8), _round_up(d, 128), _round_up(nlist, 128)
    cpad = _round_up(c, 128)
    bn = min(bn, _round_up(s, 128))
    np_ = _round_up(s, bn)
    kp = _round_up(k, 128)
    qp = jnp.pad(q.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    cp = jnp.pad(centroids.astype(jnp.float32), ((0, lp - nlist), (0, dp - d)))
    lutp = jnp.pad(lut.astype(jnp.float32), ((0, bp - b), (0, 0), (0, cpad - c)))
    lutp = lutp.reshape(bp, m * cpad)
    codes_t = jnp.pad(codes.astype(jnp.int32), ((0, np_ - s), (0, 0)), constant_values=-1).T
    clp = jnp.pad(cluster_of.astype(jnp.int32), (0, np_ - s), constant_values=-1)
    gp = jnp.pad(gids.astype(jnp.int32), (0, np_ - s), constant_values=-1)
    n_steps = np_ // bn

    lids, sims = pl.pallas_call(
        functools.partial(
            _fused_pq_kernel,
            nlist=nlist,
            nprobe=min(nprobe, nlist),
            k=k,
            m=m,
            cpad=cpad,
            n_steps=n_steps,
            mask_dead=mask_dead,
        ),
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((bp, dp), lambda j: (0, 0)),
            pl.BlockSpec((lp, dp), lambda j: (0, 0)),
            pl.BlockSpec((bp, m * cpad), lambda j: (0, 0)),
            pl.BlockSpec((m, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bp, kp), lambda j: (0, 0)),
            pl.BlockSpec((bp, kp), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, kp), jnp.int32),
            jax.ShapeDtypeStruct((bp, kp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bp, lp), jnp.float32),
            pltpu.VMEM((bp, kp), jnp.float32),
            pltpu.VMEM((bp, kp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, cp, lutp, codes_t, clp.reshape(1, np_), gp.reshape(1, np_))
    return lids[:b, :k], sims[:b, :k]


# ---------------------------------------------------------------------------
# XLA reference (production path on CPU)
# ---------------------------------------------------------------------------
def fused_ivf_pq_topk_xla(
    q, lut, codes, centroids, members, gids, *, nprobe: int, k: int, mask_dead: bool = False
):
    """One segment, XLA formulation: probe + flat-LUT ADC over the candidate
    codes + clamped top-k. The flat (B, m*c) lookup sums over m in the same
    order as the composed nested gather, so scores are bit-identical."""
    b, m, c = lut.shape
    cand = probe_candidates(q, centroids, members, nprobe)  # (B, P)
    ccodes = codes[jnp.maximum(cand, 0)].astype(jnp.int32)  # (B, P, m)
    lutf = lut.reshape(b, m * c)
    idx = ccodes + (jnp.arange(m, dtype=jnp.int32) * c)[None, None, :]
    sims = jnp.take_along_axis(lutf, idx.reshape(b, -1), axis=1)
    sims = sims.reshape(b, -1, m).sum(axis=-1)  # (B, P)
    return topk_candidates(cand, sims, gids, k=k, mask_dead=mask_dead)
