"""Fused IVF probe → quantized scan → in-kernel top-k (SQ8 int8 pipeline).

The eval hot path composes four XLA calls per sealed segment (centroid
probe, candidate gather, dequantized scoring, ``lax.top_k``); this module
fuses the whole per-segment pipeline so the score matrix never round-trips
HBM. Two implementations share one contract:

* :func:`fused_ivf_sq8_topk_xla` — the reference path (production on CPU):
  probes via ``lax.top_k``, scores the FULL segment with one dequantized
  int8 matmul, then gathers candidate scores — measured 2-4x faster than
  the composed path because the per-segment top-k width can be clamped and
  the matmul is batched over every chunk at once.
* :func:`fused_ivf_sq8_topk_pallas` — the TPU Pallas kernel. TPUs have no
  gather, so the candidate-list formulation is ADAPTED to a mask-scan: the
  probe runs in-kernel (iterative max-extraction into a cluster-mask VMEM
  scratch), each code tile is scored on the MXU against the resident query
  block, cluster membership is applied as a one-hot matmul mask, and a
  running top-k scratch is merged per tile by iterative argmax extraction
  (``k`` selection steps over ``[running, tile]``).

Memory-layout contract (shared by every fused kernel in this repo)
------------------------------------------------------------------
* All operands are row-major; the segment axis is tiled by ``bn`` and every
  other operand (queries, centroids, scale) stays VMEM-resident across the
  whole grid, so the embedding dim rides along padded to a multiple of 128.
* Inputs are zero-padded to block multiples; the padding is masked via
  ``cluster_of == -1`` (padded rows belong to no cluster), NEVER by score
  sentinels written into the input arrays.
* Accumulation and scores are f32 (``preferred_element_type``) regardless
  of storage dtype; int8 codes are dequantized in-register per tile.
* Outputs are (B, k) local ids (-1 = empty slot) + scores (-inf = empty);
  ordering among tied scores is implementation-defined — parity tests
  compare score-sorted sets, not raw slot order.

Candidate semantics match the composed path exactly: a point is a candidate
iff it appears in the (capacity-bounded) member list of a probed cluster;
``members_to_cluster_of`` derives the inverse map from the member lists
themselves, so list-overflow drops carry over to the mask-scan formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def members_to_cluster_of(members: jnp.ndarray, s: int) -> jnp.ndarray:
    """Invert one segment's (nlist, cap) member lists into a (s,) cluster id
    per point; points dropped by the capacity bound (or padded slots) map to
    -1 so the mask-scan sees exactly the composed path's candidate set."""
    nlist, cap = members.shape
    flat = members.reshape(-1)
    vals = jnp.repeat(jnp.arange(nlist, dtype=jnp.int32), cap)
    safe = jnp.where(flat >= 0, flat, s)  # park padding on a scratch slot
    return jnp.full((s + 1,), -1, jnp.int32).at[safe].set(vals)[:s]


# ---------------------------------------------------------------------------
# shared in-kernel stages (also used by fused_adc.py)
# ---------------------------------------------------------------------------
def probe_and_init(q_ref, c_ref, cmask_scr, vals_scr, lids_scr, *, nlist: int, nprobe: int):
    """Grid step 0: probe the top-``nprobe`` clusters per query into the
    cluster-mask scratch and reset the running top-k scratch.

    The probe is iterative max-extraction (ties → lowest cluster index),
    matching ``lax.top_k``'s stable tie-break in the XLA reference, so both
    impls probe identical cluster sets.
    """
    csim = jax.lax.dot_general(
        q_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Bp, Lp)
    col = jax.lax.broadcasted_iota(jnp.int32, csim.shape, 1)
    csim = jnp.where(col < nlist, csim, -jnp.inf)

    def body(_, carry):
        csim, cmask = carry
        m = jnp.max(csim, axis=1, keepdims=True)
        hit = (csim == m) & jnp.isfinite(m)
        idx = jnp.min(jnp.where(hit, col, csim.shape[1]), axis=1, keepdims=True)
        sel = (col == idx) & jnp.isfinite(m)
        cmask = jnp.where(sel, 1.0, cmask)
        csim = jnp.where(sel, -jnp.inf, csim)
        return csim, cmask

    _, cmask = jax.lax.fori_loop(0, nprobe, body, (csim, jnp.zeros_like(csim)))
    cmask_scr[...] = cmask
    vals_scr[...] = jnp.full(vals_scr.shape, -jnp.inf, jnp.float32)
    lids_scr[...] = jnp.full(lids_scr.shape, -1, jnp.int32)


def merge_tile_topk(
    scores, j, cl_ref, gid_ref, cmask_scr, vals_scr, lids_scr, *, k: int, mask_dead: bool
):
    """Mask one scored tile by probed-cluster membership and fold it into the
    running top-k scratch via ``k`` iterative argmax extractions (ties →
    lowest slot). ``mask_dead`` additionally drops gid<0 slots pre-top-k (the
    clamped static path); otherwise dead slots survive to the caller like the
    composed path's post-top-k masking."""
    bn = scores.shape[1]
    cl = cl_ref[...]  # (1, bn) cluster id per point, -1 = not a candidate
    lp = cmask_scr.shape[1]
    lio = jax.lax.broadcasted_iota(jnp.int32, (lp, bn), 0)
    onehot = (lio == cl).astype(jnp.float32)  # (Lp, bn)
    probed = jax.lax.dot_general(
        cmask_scr[...], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Bp, bn)
    ok = (probed > 0.5) & (cl >= 0)
    if mask_dead:
        ok = ok & (gid_ref[...] >= 0)
    scores = jnp.where(ok, scores, -jnp.inf)
    lid_tile = j * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    vals = jnp.concatenate([vals_scr[...], scores], axis=1)
    lids = jnp.concatenate([lids_scr[...], lid_tile], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    bp, kp = vals_scr.shape

    def body(t, carry):
        vals, out_v, out_l = carry
        m = jnp.max(vals, axis=1, keepdims=True)
        hit = (vals == m) & jnp.isfinite(m)
        idx = jnp.min(jnp.where(hit, col, vals.shape[1]), axis=1, keepdims=True)
        sel = col == idx
        pick = jnp.sum(jnp.where(sel, lids, 0), axis=1, keepdims=True)
        pick = jnp.where(jnp.isfinite(m), pick, -1).astype(jnp.int32)
        out_v = jax.lax.dynamic_update_slice(out_v, m, (0, t))
        out_l = jax.lax.dynamic_update_slice(out_l, pick, (0, t))
        vals = jnp.where(sel, -jnp.inf, vals)
        return vals, out_v, out_l

    init = (
        vals,
        jnp.full((bp, kp), -jnp.inf, jnp.float32),
        jnp.full((bp, kp), -1, jnp.int32),
    )
    _, out_v, out_l = jax.lax.fori_loop(0, k, body, init)
    vals_scr[...] = out_v
    lids_scr[...] = out_l


# ---------------------------------------------------------------------------
# SQ8 kernel
# ---------------------------------------------------------------------------
def _fused_sq8_kernel(
    q_ref, c_ref, scale_ref, codes_ref, cl_ref, gid_ref, lid_out, sim_out,
    cmask_scr, vals_scr, lids_scr, *, nlist, nprobe, k, n_steps, mask_dead,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        probe_and_init(q_ref, c_ref, cmask_scr, vals_scr, lids_scr, nlist=nlist, nprobe=nprobe)

    deq = codes_ref[...].astype(jnp.float32) * scale_ref[...]  # (bn, Dp) f32
    scores = jax.lax.dot_general(
        q_ref[...], deq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Bp, bn)
    merge_tile_topk(
        scores, j, cl_ref, gid_ref, cmask_scr, vals_scr, lids_scr, k=k, mask_dead=mask_dead
    )

    @pl.when(j == n_steps - 1)
    def _flush():
        lid_out[...] = lids_scr[...]
        sim_out[...] = vals_scr[...]


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "mask_dead", "bn", "interpret"))
def fused_ivf_sq8_topk_pallas(
    q: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    centroids: jnp.ndarray,
    cluster_of: jnp.ndarray,
    gids: jnp.ndarray,
    *,
    nprobe: int,
    k: int,
    mask_dead: bool = False,
    bn: int = 256,
    interpret: bool = False,
):
    """One segment: q (B, d) f32, codes (s, d) int8, scale (d,), centroids
    (nlist, d), cluster_of (s,) from :func:`members_to_cluster_of`, gids (s,)
    -> (lids, sims) each (B, k)."""
    b, d = q.shape
    s = codes.shape[0]
    nlist = centroids.shape[0]
    bp, dp, lp = _round_up(b, 8), _round_up(d, 128), _round_up(nlist, 128)
    bn = min(bn, _round_up(s, 128))
    np_ = _round_up(s, bn)
    kp = _round_up(k, 128)
    qp = jnp.pad(q.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    cp = jnp.pad(centroids.astype(jnp.float32), ((0, lp - nlist), (0, dp - d)))
    sp = jnp.pad(scale.astype(jnp.float32), (0, dp - d)).reshape(1, dp)
    codesp = jnp.pad(codes, ((0, np_ - s), (0, dp - d)))
    clp = jnp.pad(cluster_of.astype(jnp.int32), (0, np_ - s), constant_values=-1)
    gp = jnp.pad(gids.astype(jnp.int32), (0, np_ - s), constant_values=-1)
    n_steps = np_ // bn

    lids, sims = pl.pallas_call(
        functools.partial(
            _fused_sq8_kernel,
            nlist=nlist,
            nprobe=min(nprobe, nlist),
            k=k,
            n_steps=n_steps,
            mask_dead=mask_dead,
        ),
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((bp, dp), lambda j: (0, 0)),
            pl.BlockSpec((lp, dp), lambda j: (0, 0)),
            pl.BlockSpec((1, dp), lambda j: (0, 0)),
            pl.BlockSpec((bn, dp), lambda j: (j, 0)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bp, kp), lambda j: (0, 0)),
            pl.BlockSpec((bp, kp), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, kp), jnp.int32),
            jax.ShapeDtypeStruct((bp, kp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bp, lp), jnp.float32),
            pltpu.VMEM((bp, kp), jnp.float32),
            pltpu.VMEM((bp, kp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, cp, sp, codesp, clp.reshape(1, np_), gp.reshape(1, np_))
    return lids[:b, :k], sims[:b, :k]


# ---------------------------------------------------------------------------
# XLA reference (production path on CPU)
# ---------------------------------------------------------------------------
def probe_candidates(q, centroids, members, nprobe: int) -> jnp.ndarray:
    """Probe top-nprobe clusters and flatten their member lists: (B, P) local
    ids, -1 padded — identical to the composed path's candidate stage."""
    csim = jnp.dot(q, centroids.T, preferred_element_type=jnp.float32)
    _, probe = jax.lax.top_k(csim, min(nprobe, centroids.shape[0]))
    return members[probe].reshape(q.shape[0], -1)


def topk_candidates(cand, sims, gids, *, k: int, mask_dead: bool):
    """Shared epilogue: mask padded (and optionally dead-gid) candidates,
    take the top-k, and return (lids, sims) padded to width ``k``."""
    ok = cand >= 0
    if mask_dead:
        ok = ok & (gids[jnp.maximum(cand, 0)] >= 0)
    sims = jnp.where(ok, sims, -jnp.inf)
    kk = min(k, sims.shape[1])
    top_s, top_i = jax.lax.top_k(sims, kk)
    lids = jnp.take_along_axis(cand, top_i, axis=1)
    lids = jnp.where(jnp.isfinite(top_s), lids, -1)
    if kk < k:
        pad = k - kk
        lids = jnp.pad(lids, ((0, 0), (0, pad)), constant_values=-1)
        top_s = jnp.pad(top_s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return lids, top_s


def fused_ivf_sq8_topk_xla(
    q, codes, scale, centroids, members, gids, *, nprobe: int, k: int, mask_dead: bool = False
):
    """One segment, XLA formulation: full-segment dequantized int8 matmul +
    candidate-score gather + clamped top-k. Scores match the composed path's
    per-element arithmetic (codes·scale dequant, f32 contraction over d)."""
    cand = probe_candidates(q, centroids, members, nprobe)  # (B, P)
    deq = codes.astype(jnp.float32) * scale[None, :]
    sall = jnp.dot(q, deq.T, preferred_element_type=jnp.float32)  # (B, s)
    sims = jnp.take_along_axis(sall, jnp.maximum(cand, 0), axis=1)
    return topk_candidates(cand, sims, gids, k=k, mask_dead=mask_dead)
