"""Pallas TPU kernels for the compute hot spots + pure-jnp reference oracles."""
from . import ops, ref

__all__ = ["ops", "ref"]
