"""Tiled MXU distance kernel (inner-product / squared-L2).

The VDMS hot spot: similarity of a query block against a database shard.
TPU adaptation (vs. the GPU cuBLAS-GEMM + epilogue formulation):

* the (Q, D) x (D, N) contraction is tiled onto the MXU with 128-aligned
  BlockSpecs; the K (=D) dimension is the innermost grid axis with a VMEM
  f32 accumulator, so arbitrary embedding dims stream through VMEM;
* the L2 epilogue (||q||^2 - 2 q.x + ||x||^2) is fused into the flush step —
  the norms ride along as VMEM blocks and the distance matrix never
  round-trips HBM between GEMM and epilogue.

Grid: (Q/bq, N/bn, D/bk), accumulating over the last (arbitrary) axis.
VMEM working set per step: bq*bk + bn*bk + bq*bn floats — the default tile
(128, 512, 128) uses ~0.6 MB, comfortably inside a v5e core's ~16 MB VMEM
with double buffering.

Memory-layout contract (shared by every kernel in this package, see
``docs/KERNELS.md``): operands arrive row-major and are zero-padded up to
the block multiple on every tiled axis by the host-side wrapper — padded
query rows produce garbage rows that the wrapper slices off, padded D
columns contribute zero to the contraction, and padded N columns are cut by
the final slice. All accumulation is f32 in VMEM scratch regardless of the
storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dist_kernel(q_ref, x_ref, qn_ref, xn_ref, o_ref, acc_ref, *, kind: str, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        q_ref[...], x_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        if kind == "ip":
            o_ref[...] = acc_ref[...]
        else:  # fused L2 epilogue: ||q||^2 - 2 q.x + ||x||^2
            o_ref[...] = qn_ref[...] - 2.0 * acc_ref[...] + xn_ref[...]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("kind", "bq", "bn", "bk", "interpret"))
def distance_pallas(
    queries: jnp.ndarray,
    database: jnp.ndarray,
    kind: str = "ip",
    bq: int = 128,
    bn: int = 512,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """queries (q, d), database (n, d) -> (q, n) similarity/distance, f32."""
    assert kind in ("ip", "l2")
    q, d = queries.shape
    n, _ = database.shape
    bq, bn, bk = min(bq, _round_up(q, 8)), min(bn, _round_up(n, 128)), min(bk, _round_up(d, 128))
    qp, np_, dp = _round_up(q, bq), _round_up(n, bn), _round_up(d, bk)
    qpad = jnp.pad(queries, ((0, qp - q), (0, dp - d)))
    xpad = jnp.pad(database, ((0, np_ - n), (0, dp - d)))
    qn = jnp.sum(qpad.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (qp, 1)
    xn = jnp.sum(xpad.astype(jnp.float32) ** 2, axis=1, keepdims=True).T  # (1, np)
    k_steps = dp // bk
    grid = (qp // bq, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_dist_kernel, kind=kind, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bq, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        interpret=interpret,
    )(qpad, xpad, qn, xn)
    return out[:q, :n]
