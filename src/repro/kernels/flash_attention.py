"""FlashAttention (causal, GQA, optional sliding window) as a Pallas kernel.

Online-softmax tiling: for each (batch*q_head, q_tile) the kernel streams KV
tiles through VMEM keeping running max / normalizer / weighted accumulator in
VMEM scratch. GQA is handled in the K/V BlockSpec index maps (q-head ->
kv-head = h // group), so grouped heads reuse the same KV tiles without any
HBM duplication. Sliding windows additionally bound which KV tiles can
contribute — fully-masked tiles are skipped via pl.when (no MXU work).

Grid: (b * hq, sq/bq, sk/bk); the KV axis is innermost so the scratch carry
is valid across its steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window, bq: int, bk: int, k_steps: int,
    q_offset: int, kv_len: int,
):
    kv = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile visibility (static per (qi, kv) only via dynamic check)
    q_start = qi * bq + q_offset  # absolute position of first query row
    k_start = kv * bk
    # any key in this tile visible to any query in the q tile?
    visible = k_start < kv_len  # end-padded keys are never visible
    if causal:
        visible = jnp.logical_and(visible, k_start <= q_start + bq - 1)
    if window is not None:
        visible = jnp.logical_and(visible, k_start + bk - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[...][0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[...][0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[...][0].astype(jnp.float32)  # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len  # mask end padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kv == k_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l)[None].astype(o_ref.dtype)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q (b, sq, hq, dh); k/v (b, sk, hkv, dh) -> (b, sq, hq, dh)."""
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / float(dh) ** 0.5
    q_offset = sk - sq  # queries occupy the tail of the key axis

    bq = min(bq, _round_up(sq, 8))
    bk = min(bk, _round_up(sk, 128))
    sqp, skp = _round_up(sq, bq), _round_up(sk, bk)
    # layout (b*h, s, dh): fold batch and heads into the leading grid axis
    qt = jnp.moveaxis(q, 2, 1).reshape(b * hq, sq, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * hkv, sk, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * hkv, sk, dh)
    qt = jnp.pad(qt, ((0, 0), (0, sqp - sq), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, skp - sk), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, skp - sk), (0, 0)))
    k_steps = skp // bk
    grid = (b * hq, sqp // bq, k_steps)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        return (h // group, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            scale=scale,
            causal=causal,
            window=window,
            bq=bq,
            bk=bk,
            k_steps=k_steps,
            q_offset=q_offset,
            kv_len=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_map),
            pl.BlockSpec((1, bk, dh), kv_map),
            pl.BlockSpec((1, bk, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sqp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :sq].reshape(b, hq, sq, dh)
    return jnp.moveaxis(out, 1, 2)
