"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantics contracts: each Pallas kernel's test sweeps shapes and
dtypes and asserts allclose against the function here. They are also the
production implementation on non-TPU backends (``ops.py`` dispatches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_ip(queries: jnp.ndarray, database: jnp.ndarray) -> jnp.ndarray:
    """Inner-product similarity matrix. queries (q, d), database (n, d) -> (q, n)."""
    return jnp.dot(queries, database.T, preferred_element_type=jnp.float32)


def l2_distance(queries: jnp.ndarray, database: jnp.ndarray) -> jnp.ndarray:
    """Squared-L2 distance matrix via the ||q||^2 - 2qx + ||x||^2 expansion."""
    qn = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1)
    xn = jnp.sum(database.astype(jnp.float32) ** 2, axis=-1)
    ip = jnp.dot(queries, database.T, preferred_element_type=jnp.float32)
    return qn[:, None] - 2.0 * ip + xn[None, :]


def pq_adc(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """PQ asymmetric distance computation.

    lut:   (q, m, c) per-query lookup tables (distance of query sub-vector to
           each of the c codewords of each of the m sub-quantizers).
    codes: (n, m) uint8/int32 database codes.
    returns (q, n) summed distances:  out[q, n] = sum_m lut[q, m, codes[n, m]].
    """
    q, m, c = lut.shape
    n = codes.shape[0]
    codes = codes.astype(jnp.int32)
    gathered = jnp.take_along_axis(
        lut[:, None, :, :],  # (q, 1, m, c)
        jnp.broadcast_to(codes[None, :, :, None], (q, n, m, 1)),
        axis=3,
    )  # (q, n, m, 1)
    return jnp.sum(gathered[..., 0], axis=-1)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference multi-head attention with GQA and optional sliding window.

    q: (b, sq, hq, dh); k/v: (b, sk, hkv, dh); hq must be a multiple of hkv.
    Returns (b, sq, hq, dh). ``window`` = sliding-window size (keys within
    [i - window + 1, i] attend, Mistral convention).
    """
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, groups, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    # positions: queries occupy the last sq slots of the sk-long key axis
    qpos = jnp.arange(sq) + (sk - sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(b, sq, hq, dh).astype(q.dtype)
