"""Public jit'd wrappers for the compute hot-spot kernels.

``impl`` selects the backend:
  * "xla"              — the pure-jnp reference (production path on CPU and the
                          GSPMD dry-run path; XLA fuses these well),
  * "pallas"           — the TPU Pallas kernel (TARGET hardware),
  * "pallas_interpret" — the Pallas kernel executed in interpret mode (CPU
                          correctness validation; used by the test suite).

The global default is "xla" on CPU hosts and "pallas" when a TPU backend is
present, override per-call or via set_default_impl().
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref

_DEFAULT_IMPL = "pallas" if any(d.platform == "tpu" for d in jax.devices()) else "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str]) -> str:
    return impl or _DEFAULT_IMPL


# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl",))
def batched_ip(queries, database, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.batched_ip(queries, database)
    from .distance import distance_pallas

    return distance_pallas(queries, database, kind="ip", interpret=impl == "pallas_interpret")


@partial(jax.jit, static_argnames=("impl",))
def l2_distance(queries, database, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.l2_distance(queries, database)
    from .distance import distance_pallas

    return distance_pallas(queries, database, kind="l2", interpret=impl == "pallas_interpret")


@partial(jax.jit, static_argnames=("impl",))
def pq_adc(lut, codes, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.pq_adc(lut, codes)
    from .pq_adc import pq_adc_pallas

    return pq_adc_pallas(lut, codes, interpret=impl == "pallas_interpret")


@partial(jax.jit, static_argnames=("nprobe", "k", "mask_dead", "impl"))
def fused_ivf_sq8_topk(q, codes, scale, centroids, members, gids, *,
                       nprobe: int, k: int, mask_dead: bool = False,
                       impl: Optional[str] = None):
    """Fused IVF probe → int8 dequant scan → top-k over stacked segments.

    q (B, d); codes (n_seg, s, d) int8; scale (d,); centroids
    (n_seg, nlist, d); members (n_seg, nlist, cap); gids (n_seg, s)
    -> (lids, sims), each (n_seg, B, k) with -1/-inf empty slots.

    Candidate SETS and scores match across impls (and the composed
    per-family search); slot ORDER among tied scores is impl-defined.
    ``mask_dead`` drops gid<0 slots before the top-k (the clamped static
    merge); default keeps them, mirroring the composed post-top-k masking.
    """
    impl = _resolve(impl)
    from .fused_scan import (
        fused_ivf_sq8_topk_pallas,
        fused_ivf_sq8_topk_xla,
        members_to_cluster_of,
    )

    if impl == "xla":
        return jax.vmap(
            lambda c, ce, me, g: fused_ivf_sq8_topk_xla(
                q, c, scale, ce, me, g, nprobe=nprobe, k=k, mask_dead=mask_dead
            )
        )(codes, centroids, members, gids)
    interp = impl == "pallas_interpret"
    outs = [
        fused_ivf_sq8_topk_pallas(
            q, codes[z], scale, centroids[z],
            members_to_cluster_of(members[z], codes.shape[1]), gids[z],
            nprobe=nprobe, k=k, mask_dead=mask_dead, interpret=interp,
        )
        for z in range(codes.shape[0])
    ]
    return jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs])


@partial(jax.jit, static_argnames=("nprobe", "k", "mask_dead", "impl"))
def fused_ivf_pq_topk(q, lut, codes, centroids, members, gids, *,
                      nprobe: int, k: int, mask_dead: bool = False,
                      impl: Optional[str] = None):
    """Fused IVF probe → PQ ADC scan → top-k over stacked segments.

    q (B, d); lut (B, m, c) f32 ADC similarity table; codes (n_seg, s, m)
    integer; centroids (n_seg, nlist, d); members (n_seg, nlist, cap);
    gids (n_seg, s) -> (lids, sims), each (n_seg, B, k). Same set/order
    contract as :func:`fused_ivf_sq8_topk`.
    """
    impl = _resolve(impl)
    from .fused_adc import fused_ivf_pq_topk_pallas, fused_ivf_pq_topk_xla
    from .fused_scan import members_to_cluster_of

    if impl == "xla":
        return jax.vmap(
            lambda c, ce, me, g: fused_ivf_pq_topk_xla(
                q, lut, c, ce, me, g, nprobe=nprobe, k=k, mask_dead=mask_dead
            )
        )(codes, centroids, members, gids)
    interp = impl == "pallas_interpret"
    outs = [
        fused_ivf_pq_topk_pallas(
            q, lut, codes[z], centroids[z],
            members_to_cluster_of(members[z], codes.shape[1]), gids[z],
            nprobe=nprobe, k=k, mask_dead=mask_dead, interpret=interp,
        )
        for z in range(codes.shape[0])
    ]
    return jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs])


@partial(jax.jit, static_argnames=("k", "impl"))
def topk_by_score(ids, sims, k: int, impl: Optional[str] = None):
    """Top-k-by-score selection over flat candidate lists — the merge-tree
    primitive behind ``repro.vdms.merge`` (composed / fused / sharded paths).

    ids, sims (B, W) -> (ids_k, sims_k), each (B, k), score-descending with
    ``lax.top_k`` tie semantics: equal scores keep the lowest flat index, so
    blockwise prefiltering (per-shard partial top-k) composes with a root
    merge without reordering ties. ``k`` must be <= W.

    All impls share the XLA lowering today: ``lax.top_k`` already maps to the
    TPU sort unit, so a dedicated Pallas kernel buys nothing until the merge
    is fused into the scan epilogue (see docs/KERNELS.md).
    """
    del impl  # reserved for a fused Pallas merge epilogue
    top_s, top_i = jax.lax.top_k(sims, k)
    return jnp.take_along_axis(ids, top_i, axis=1), top_s


@partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        # block-scanned flash with custom VJP: never materializes (sq, sk);
        # ref.flash_attention remains the semantics oracle for tests.
        from .flash_xla import flash_attention_xla

        return flash_attention_xla(q, k, v, causal, window)
    from .flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, interpret=impl == "pallas_interpret"
    )
