"""Public jit'd wrappers for the compute hot-spot kernels.

``impl`` selects the backend:
  * "xla"              — the pure-jnp reference (production path on CPU and the
                          GSPMD dry-run path; XLA fuses these well),
  * "pallas"           — the TPU Pallas kernel (TARGET hardware),
  * "pallas_interpret" — the Pallas kernel executed in interpret mode (CPU
                          correctness validation; used by the test suite).

The global default is "xla" on CPU hosts and "pallas" when a TPU backend is
present, override per-call or via set_default_impl().
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from . import ref

_DEFAULT_IMPL = "pallas" if any(d.platform == "tpu" for d in jax.devices()) else "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str]) -> str:
    return impl or _DEFAULT_IMPL


# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl",))
def batched_ip(queries, database, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.batched_ip(queries, database)
    from .distance import distance_pallas

    return distance_pallas(queries, database, kind="ip", interpret=impl == "pallas_interpret")


@partial(jax.jit, static_argnames=("impl",))
def l2_distance(queries, database, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.l2_distance(queries, database)
    from .distance import distance_pallas

    return distance_pallas(queries, database, kind="l2", interpret=impl == "pallas_interpret")


@partial(jax.jit, static_argnames=("impl",))
def pq_adc(lut, codes, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.pq_adc(lut, codes)
    from .pq_adc import pq_adc_pallas

    return pq_adc_pallas(lut, codes, interpret=impl == "pallas_interpret")


@partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        # block-scanned flash with custom VJP: never materializes (sq, sk);
        # ref.flash_attention remains the semantics oracle for tests.
        from .flash_xla import flash_attention_xla

        return flash_attention_xla(q, k, v, causal, window)
    from .flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, interpret=impl == "pallas_interpret"
    )
