"""Workload descriptors: the fleet's notion of "these two tenants look alike".

A :class:`WorkloadDescriptor` is a fixed-length vector of workload statistics
computed from a tenant's dataset or :class:`~repro.vdms.workload.WorkloadTrace`
— dimensionality, corpus size, arrival mix, a drift statistic, and query-shape
moments that separate the Table-III dataset families (a keyword-style sparse
corpus and a GloVe-style dense one have very different coordinate kurtosis).

Similarity between tenants is measured in a learned low-dimensional space, the
LatentTune idea: :class:`DescriptorEmbedding` standardizes the descriptor
features (optionally concatenated with a summary of each tenant's good
configurations, encoded through the registry's uniform
:meth:`~repro.core.space.SearchSpace.encode`), projects onto the top principal
components of the fitted fleet, and scores ``exp(-||ea - eb||^2 / 2s^2)`` with
an *absolute* length scale ``s`` in :data:`FEATURE_SCALES` units — a fleet of
near-identical tenants scores all-high similarities instead of being forced
into a spread. Everything is deterministic and JSON-serializable, so
embeddings ride inside fleet checkpoints.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Descriptor feature names, in vector order. ``feature_table()`` renders the
#: documented schema from this single source of truth (doc-sync-tested).
FEATURES: Tuple[Tuple[str, str], ...] = (
    ("log_corpus", "log10 of total corpus size (base + inserts)"),
    ("log_dim", "log10 of vector dimensionality"),
    ("log_k", "log10 of the trace's top-k"),
    ("insert_frac", "fraction of trace operations that are inserts"),
    ("search_frac", "fraction of trace operations that are searches"),
    ("delete_frac", "fraction of trace operations that are deletes"),
    ("drift", "L2 shift of the mean query between trace halves"),
    ("dispersion", "mean distance of queries from their centroid"),
    ("centroid_align", "mean cosine of queries against the base centroid"),
    ("coord_kurtosis", "dim-scaled 4th moment of query coordinates (sparsity)"),
)

FEATURE_NAMES: Tuple[str, ...] = tuple(name for name, _ in FEATURES)

#: Characteristic scale per feature: the difference that counts as "one unit"
#: of workload dissimilarity. Fixed a priori (not fitted) so that seed-level
#: noise in a small fleet — e.g. ±0.05 arrival-mix jitter between two tenants
#: of the same family — is not amplified to the same footing as a genuine
#: family difference, the failure mode of per-feature z-scoring when the
#: fitted fleet is only a handful of tenants.
FEATURE_SCALES: Dict[str, float] = {
    "log_corpus": 1.0,  # a decade of corpus size
    "log_dim": 0.5,
    "log_k": 0.5,
    "insert_frac": 0.25,
    "search_frac": 0.25,
    "delete_frac": 0.25,
    "drift": 0.25,
    "dispersion": 0.1,
    "centroid_align": 0.25,
    "coord_kurtosis": 2.0,  # dense isotropic ~3; sparse corpora run 8+
}


def feature_table() -> str:
    """Markdown table of the descriptor schema (docs/FLEET.md sync source)."""
    lines = ["| feature | meaning |", "| --- | --- |"]
    for name, desc in FEATURES:
        lines.append(f"| `{name}` | {desc} |")
    return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class WorkloadDescriptor:
    """Fixed-length workload fingerprint for one tenant."""

    name: str
    features: Dict[str, float]

    def __post_init__(self):
        missing = [n for n in FEATURE_NAMES if n not in self.features]
        if missing:
            raise ValueError(f"descriptor {self.name!r} missing features {missing}")

    def vector(self) -> np.ndarray:
        return np.array([self.features[n] for n in FEATURE_NAMES], np.float64)

    # --- serialization (JSON-compatible) --------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "features": {k: float(v) for k, v in self.features.items()}}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkloadDescriptor":
        return cls(name=str(d["name"]), features={k: float(v) for k, v in d["features"].items()})


def _query_moments(queries: np.ndarray, base: np.ndarray) -> Dict[str, float]:
    if queries.shape[0] == 0:
        return {"drift": 0.0, "dispersion": 0.0, "centroid_align": 0.0, "coord_kurtosis": 0.0}
    q = np.asarray(queries, np.float64)
    centroid = q.mean(axis=0)
    half = q.shape[0] // 2
    drift = float(np.linalg.norm(q[half:].mean(axis=0) - q[:half].mean(axis=0))) if half else 0.0
    dispersion = float(np.linalg.norm(q - centroid, axis=1).mean())
    if base.shape[0]:
        c = np.asarray(base, np.float64).mean(axis=0)
        c = c / (np.linalg.norm(c) + 1e-12)
        align = float((q @ c).mean())
    else:
        align = 0.0
    # vectors are L2-normalized, so E[x^4] * d^2 is ~3 + excess kurtosis for a
    # dense isotropic corpus and grows with coordinate sparsity
    kurt = float(np.mean(q**4) * q.shape[1] ** 2)
    return {
        "drift": drift,
        "dispersion": dispersion,
        "centroid_align": align,
        "coord_kurtosis": kurt,
    }


def describe_trace(trace, name: Optional[str] = None) -> WorkloadDescriptor:
    """Descriptor from a :class:`~repro.vdms.workload.WorkloadTrace`."""
    from ..vdms.workload import OP_DELETE, OP_INSERT, OP_SEARCH

    n_ops = max(trace.n_ops, 1)
    features = {
        "log_corpus": float(np.log10(max(trace.capacity, 1))),
        "log_dim": float(np.log10(max(trace.dim, 1))),
        "log_k": float(np.log10(max(trace.k, 1))),
        "insert_frac": float(np.sum(trace.kinds == OP_INSERT)) / n_ops,
        "search_frac": float(np.sum(trace.kinds == OP_SEARCH)) / n_ops,
        "delete_frac": float(np.sum(trace.kinds == OP_DELETE)) / n_ops,
    }
    features.update(_query_moments(trace.queries, trace.base))
    return WorkloadDescriptor(name=name or trace.name, features=features)


def describe_dataset(dataset, name: Optional[str] = None) -> WorkloadDescriptor:
    """Descriptor from a static :class:`~repro.vdms.datasets.VectorDataset`
    (pure-search arrival mix, no drift axis)."""
    features = {
        "log_corpus": float(np.log10(max(dataset.n, 1))),
        "log_dim": float(np.log10(max(dataset.dim, 1))),
        "log_k": float(np.log10(max(dataset.k, 1))),
        "insert_frac": 0.0,
        "search_frac": 1.0,
        "delete_frac": 0.0,
    }
    features.update(_query_moments(dataset.queries, dataset.data))
    return WorkloadDescriptor(name=name or dataset.name, features=features)


def describe_env(env, name: Optional[str] = None) -> WorkloadDescriptor:
    """Descriptor from a :class:`~repro.vdms.tuning_env.VDMSTuningEnv`'s
    current workload view (the active phase for streaming tenants)."""
    kind, w = env.current_workload()
    if kind == "streaming":
        return describe_trace(w, name=name)
    return describe_dataset(w, name=name)


def config_summary(space, observations) -> Optional[np.ndarray]:
    """Mean encoded row of a tenant's non-dominated fresh configurations —
    the "which configs worked here" half of the LatentTune embedding input.
    Returns None when the tenant has no usable history yet."""
    from ..core.pareto import non_dominated_mask

    ok = [o for o in observations if not o.failed]
    if not ok:
        return None
    Y = np.stack([np.asarray(o.y, np.float64) for o in ok])
    nd = non_dominated_mask(Y)
    rows = [space.encode(o.config) for o, keep in zip(ok, nd) if keep]
    return np.mean(np.stack(rows), axis=0)


class DescriptorEmbedding:
    """Deterministic PCA embedding over scaled descriptor (+ optional
    config-summary) features, with a Gaussian-kernel similarity in [0, 1].

    Features are centered on the fitted fleet and divided by the fixed
    :data:`FEATURE_SCALES` (see its note on why fleet-std z-scoring is the
    wrong normalization for small fleets) before the PCA projection. Fit on
    the whole fleet's descriptors; ``similarity(a, b)`` then compares two
    tenants in the learned space. With fewer samples than components the
    rank is truncated automatically (PCA of a 2-tenant fleet is the line
    through both). State round-trips through JSON for fleet checkpoints.
    """

    def __init__(
        self, n_components: int = 4, config_weight: float = 0.5, length_scale: float = 1.0
    ):
        if n_components < 1:
            raise ValueError(f"n_components must be >= 1, got {n_components}")
        if length_scale <= 0:
            raise ValueError(f"length_scale must be > 0, got {length_scale}")
        self.n_components = int(n_components)
        self.config_weight = float(config_weight)
        self._mean: Optional[np.ndarray] = None
        self._std: Optional[np.ndarray] = None
        self._components: Optional[np.ndarray] = None  # (k, d)
        # absolute similarity length scale, in FEATURE_SCALES units: tenants
        # one characteristic unit apart score exp(-0.5) ~ 0.61; a family gap
        # of ~3 units lands near zero. Deliberately NOT fitted to the fleet —
        # a fleet of near-identical tenants should see all-high similarities,
        # not a forced spread.
        self._scale: float = float(length_scale)

    @property
    def fitted(self) -> bool:
        return self._components is not None

    def _feature_row(
        self, desc: WorkloadDescriptor, summary: Optional[np.ndarray], d_cfg: int
    ) -> np.ndarray:
        cfg = np.zeros(d_cfg, np.float64)
        if summary is not None:
            cfg[: summary.shape[0]] = self.config_weight * np.asarray(summary, np.float64)
        return np.concatenate([desc.vector(), cfg])

    def fit(
        self,
        descriptors: Sequence[WorkloadDescriptor],
        config_summaries: Optional[Sequence[Optional[np.ndarray]]] = None,
    ) -> "DescriptorEmbedding":
        if not descriptors:
            raise ValueError("need at least one descriptor to fit")
        summaries: List[Optional[np.ndarray]] = (
            list(config_summaries) if config_summaries is not None else [None] * len(descriptors)
        )
        if len(summaries) != len(descriptors):
            raise ValueError("config_summaries must align with descriptors")
        d_cfg = max((s.shape[0] for s in summaries if s is not None), default=0)
        X = np.stack([self._feature_row(d, s, d_cfg) for d, s in zip(descriptors, summaries)])
        self._mean = X.mean(axis=0)
        # fixed characteristic scales for descriptor features (see
        # FEATURE_SCALES); config-summary dims are already unit-interval
        self._std = np.concatenate(
            [
                np.array([FEATURE_SCALES[n] for n in FEATURE_NAMES], np.float64),
                np.ones(d_cfg, np.float64),
            ]
        )
        Xs = (X - self._mean) / self._std
        k = min(self.n_components, Xs.shape[1], max(Xs.shape[0] - 1, 1))
        # SVD sign convention: force each component's largest-|loading|
        # coordinate positive so the embedding is unique and deterministic
        _, _, vt = np.linalg.svd(Xs, full_matrices=False)
        comps = vt[:k]
        for i in range(comps.shape[0]):
            j = int(np.argmax(np.abs(comps[i])))
            if comps[i, j] < 0:
                comps[i] = -comps[i]
        self._components = comps
        return self

    def embed(
        self, desc: WorkloadDescriptor, summary: Optional[np.ndarray] = None
    ) -> np.ndarray:
        if not self.fitted:
            raise ValueError("fit() the embedding before embedding descriptors")
        d_cfg = self._mean.shape[0] - len(FEATURE_NAMES)
        row = self._feature_row(desc, summary, d_cfg)
        return (row - self._mean) / self._std @ self._components.T

    def similarity(
        self,
        a: WorkloadDescriptor,
        b: WorkloadDescriptor,
        summary_a: Optional[np.ndarray] = None,
        summary_b: Optional[np.ndarray] = None,
    ) -> float:
        ea, eb = self.embed(a, summary_a), self.embed(b, summary_b)
        d2 = float(np.sum((ea - eb) ** 2))
        return float(np.exp(-0.5 * d2 / self._scale**2))

    # --- serialization (JSON-compatible; exact f64 round-trip) ----------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "n_components": self.n_components,
            "config_weight": self.config_weight,
            "mean": self._mean.tolist() if self._mean is not None else None,
            "std": self._std.tolist() if self._std is not None else None,
            "components": (
                [row.tolist() for row in self._components]
                if self._components is not None
                else None
            ),
            "scale": float(self._scale),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "DescriptorEmbedding":
        self.n_components = int(state["n_components"])
        self.config_weight = float(state["config_weight"])
        self._mean = np.asarray(state["mean"], np.float64) if state["mean"] is not None else None
        self._std = np.asarray(state["std"], np.float64) if state["std"] is not None else None
        self._components = (
            np.asarray(state["components"], np.float64)
            if state["components"] is not None
            else None
        )
        self._scale = float(state["scale"])
        return self
