"""Cross-tenant prior transfer: seed a new tenant's GP from similar tenants.

The policy (ML-Powered Index Tuning survey, §"workload similarity"): rank the
fleet's other tenants by embedding similarity to the target, take the top-K
above a floor, and import a capped selection of each source's observations —
its Pareto front first, then best knee-score fill — as §IV-F-style *bootstrap*
entries with per-source noise inflation (``noise_scale = base / similarity``,
clipped), so a near-identical tenant's measurements are trusted almost like
local ones while a marginal match merely biases the prior. Imports are gated
on :meth:`SearchSpace.encoding_signature` equality — the registry's uniform
encoding is what lets an encoded row decode to the same configuration across
tenants, and transfer refuses to run without it.

Safeguards (transfer must never end up worse than cold start):

* **No-source fallback** — when no tenant clears ``min_similarity`` the plan
  is empty and the target session is untouched: its RNG, warm-up schedule and
  every subsequent decision are *bit-identical* to a cold start.
* **Divergence guard** — after ``check_after`` fresh local evaluations, a GP
  fitted on the imported rows alone predicts the fresh measurements; when the
  median standardized error exceeds ``divergence_threshold`` the imports are
  purged from the history (:func:`purge_imports`), returning the surrogate to
  locally-measured data only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.gp import GP
from ..core.pareto import non_dominated_mask
from ..core.session import TuningSession
from ..core.tuner import Observation

from .descriptor import DescriptorEmbedding, WorkloadDescriptor


@dataclasses.dataclass(frozen=True)
class TransferPolicy:
    """Knobs for cross-tenant observation transfer."""

    k_sources: int = 2  # at most this many source tenants
    min_similarity: float = 0.25  # sources below this never transfer
    max_import_per_source: int = 12  # observation cap per source
    noise_base: float = 1.5  # inflation at similarity 1.0
    noise_ceil: float = 16.0  # inflation clip
    check_after: int = 4  # fresh evals before the divergence check
    divergence_threshold: float = 3.0  # median |err|/std_y gate

    def __post_init__(self):
        if self.k_sources < 1:
            raise ValueError(f"k_sources must be >= 1, got {self.k_sources}")
        if not 0.0 <= self.min_similarity <= 1.0:
            raise ValueError(f"min_similarity must be in [0, 1], got {self.min_similarity}")
        if self.max_import_per_source < 1:
            raise ValueError("max_import_per_source must be >= 1")
        if self.noise_base < 1.0 or self.noise_ceil < self.noise_base:
            raise ValueError("need noise_ceil >= noise_base >= 1")
        if self.check_after < 1 or self.divergence_threshold <= 0:
            raise ValueError("need check_after >= 1 and divergence_threshold > 0")

    def noise_for(self, similarity: float) -> float:
        """Per-source GP noise-variance inflation: trust decays with
        dissimilarity, clipped to [noise_base, noise_ceil]."""
        return float(np.clip(self.noise_base / max(similarity, 1e-6), self.noise_base, self.noise_ceil))


@dataclasses.dataclass
class TransferReport:
    """What a warm-start actually did (rides in the fleet ledger)."""

    target: str
    sources: List[Dict[str, Any]]  # [{"name", "similarity", "noise_scale", "n_imported"}]
    n_imported: int
    fallback: bool  # True = no source cleared the similarity floor

    def to_dict(self) -> Dict[str, Any]:
        return {
            "target": self.target,
            "sources": [dict(s) for s in self.sources],
            "n_imported": int(self.n_imported),
            "fallback": bool(self.fallback),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TransferReport":
        return cls(
            target=str(d["target"]),
            sources=[dict(s) for s in d["sources"]],
            n_imported=int(d["n_imported"]),
            fallback=bool(d["fallback"]),
        )


def rank_sources(
    embedding: DescriptorEmbedding,
    target: WorkloadDescriptor,
    candidates: Sequence[Tuple[str, WorkloadDescriptor]],
    policy: TransferPolicy,
    target_summary: Optional[np.ndarray] = None,
    candidate_summaries: Optional[Dict[str, np.ndarray]] = None,
) -> List[Tuple[str, float]]:
    """Top-K candidate tenants by similarity, floor applied, deterministic
    tie-break by candidate order."""
    sims = []
    for i, (name, desc) in enumerate(candidates):
        s = embedding.similarity(
            target,
            desc,
            summary_a=target_summary,
            summary_b=(candidate_summaries or {}).get(name),
        )
        sims.append((name, float(s), i))
    sims = [t for t in sims if t[1] >= policy.min_similarity]
    sims.sort(key=lambda t: (-t[1], t[2]))
    return [(name, s) for name, s, _ in sims[: policy.k_sources]]


def _knee_order(obs: Sequence[Observation]) -> List[int]:
    """Indices of ``obs`` by descending knee score (normalized objective sum),
    the same balance heuristic the tuners' deploy pool uses."""
    Y = np.stack([np.asarray(o.y, np.float64) for o in obs])
    span = Y.max(axis=0) - Y.min(axis=0)
    span = np.where(span > 1e-12, span, 1.0)
    Yn = (Y - Y.min(axis=0)) / span
    score = Yn.sum(axis=1)
    return list(np.argsort(-score, kind="stable"))


def select_observations(history: Sequence[Observation], n: int) -> List[Observation]:
    """The rows worth exporting from a source ledger: non-dominated fresh
    observations first (by knee score), then best-knee fill — capped at ``n``,
    deterministic, failures excluded."""
    ok = [o for o in history if not o.failed and not o.bootstrap]
    if not ok:
        return []
    Y = np.stack([np.asarray(o.y, np.float64) for o in ok])
    nd = non_dominated_mask(Y)
    front = [o for o, keep in zip(ok, nd) if keep]
    rest = [o for o, keep in zip(ok, nd) if not keep]
    picked = [front[i] for i in _knee_order(front)] if front else []
    if len(picked) < n and rest:
        picked += [rest[i] for i in _knee_order(rest)]
    return picked[:n]


def apply_transfer(
    session: TuningSession,
    target: str,
    ranked: Sequence[Tuple[str, float]],
    source_histories: Dict[str, Sequence[Observation]],
    policy: TransferPolicy,
    source_signatures: Optional[Dict[str, str]] = None,
) -> TransferReport:
    """Import the ranked sources' best observations into ``session``.

    ``source_signatures`` maps source name -> its space's
    ``encoding_signature()``; mismatches raise (the cross-tenant encoding
    guard). An empty ``ranked`` produces the cold-start fallback report and
    leaves the session untouched.
    """
    if not ranked:
        return TransferReport(target=target, sources=[], n_imported=0, fallback=True)
    own_sig = session.tuner.space.encoding_signature()
    sources, total = [], 0
    for name, sim in ranked:
        sig = (source_signatures or {}).get(name, own_sig)
        if sig != own_sig:
            raise ValueError(
                f"transfer {name!r} -> {target!r} refused: encoding signature "
                f"{sig!r} != {own_sig!r}"
            )
        picked = select_observations(source_histories.get(name, []), policy.max_import_per_source)
        scale = policy.noise_for(sim)
        n_imp = session.import_observations(picked, noise_scale=scale, space_signature=sig)
        sources.append(
            {"name": name, "similarity": float(sim), "noise_scale": scale, "n_imported": n_imp}
        )
        total += n_imp
    return TransferReport(target=target, sources=sources, n_imported=total, fallback=total == 0)


def divergence_score(session: TuningSession, policy: TransferPolicy) -> Optional[float]:
    """Median standardized error of a GP fitted on the *imported* rows alone
    predicting the tenant's *fresh* measurements — None until ``check_after``
    fresh observations exist (or when there is nothing imported)."""
    imported = [o for o in session.history if o.bootstrap and o.noise_scale != 1.0]
    fresh = [o for o in session.history if not o.bootstrap and not o.failed]
    if not imported or len(fresh) < policy.check_after:
        return None
    space = session.tuner.space
    Xi = np.stack([space.encode(o.config) for o in imported])
    Yi = np.stack([np.asarray(o.y, np.float64) for o in imported])
    Xf = np.stack([space.encode(o.config) for o in fresh])
    Yf = np.stack([np.asarray(o.y, np.float64) for o in fresh])
    gp = GP(seed=0, fit_steps=60).fit(Xi, Yi)
    mean, _ = gp.predict(Xf)
    std = Yi.std(axis=0) + 1e-9
    err = np.abs(mean - Yf) / std[None, :]
    return float(np.median(err.max(axis=1)))


def purge_imports(session: TuningSession) -> int:
    """Drop transfer-imported rows (bootstrap entries with inflated noise)
    from the tuner history, re-numbering iterations; returns how many went."""
    hist = session.tuner.history
    kept = [o for o in hist if not (o.bootstrap and o.noise_scale != 1.0)]
    purged = len(hist) - len(kept)
    for i, o in enumerate(kept):
        o.iteration = i
    session.tuner.history = kept
    return purged


def check_divergence(session: TuningSession, policy: TransferPolicy) -> Optional[bool]:
    """Run the divergence guard once: None = not enough evidence yet,
    False = imports consistent, True = imports purged."""
    score = divergence_score(session, policy)
    if score is None:
        return None
    if score <= policy.divergence_threshold:
        return False
    purge_imports(session)
    return True
