"""Fleet tuning: multi-tenant sessions, shared budget, cross-workload transfer.

See docs/FLEET.md for the architecture and a worked 4-tenant example.
"""

from .descriptor import (
    FEATURE_NAMES,
    FEATURES,
    DescriptorEmbedding,
    WorkloadDescriptor,
    config_summary,
    describe_dataset,
    describe_env,
    describe_trace,
    feature_table,
)
from .fleet import (
    FLEET_LEDGER_SCHEMA,
    FLEET_STATE_VERSION,
    FleetBudget,
    FleetScheduler,
    FleetSession,
    analytic_eval_cost,
)
from .transfer import (
    TransferPolicy,
    TransferReport,
    apply_transfer,
    check_divergence,
    divergence_score,
    purge_imports,
    rank_sources,
    select_observations,
)

__all__ = [
    "FEATURES",
    "FEATURE_NAMES",
    "FLEET_LEDGER_SCHEMA",
    "FLEET_STATE_VERSION",
    "DescriptorEmbedding",
    "FleetBudget",
    "FleetScheduler",
    "FleetSession",
    "TransferPolicy",
    "TransferReport",
    "WorkloadDescriptor",
    "analytic_eval_cost",
    "apply_transfer",
    "check_divergence",
    "config_summary",
    "describe_dataset",
    "describe_env",
    "describe_trace",
    "divergence_score",
    "feature_table",
    "purge_imports",
    "rank_sources",
    "select_observations",
]
