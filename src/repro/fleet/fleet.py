"""`FleetSession`: N per-tenant tuning sessions under one evaluation budget.

The paper tunes one collection; a production fleet tunes many at once, and
the scarce resource is *evaluation seconds* (index builds + trace replays
dwarf recommend time by >100x on the measured benches). The fleet loop is:

    while budget remains and any tenant wants observations:
        tenant  = scheduler.pick(runnable tenants)
        round   = tenant.session.run_round()        # one ask + drain
        cost    = sum of the round's evaluation cost (analytic seconds)
        budget.charge(cost); scheduler.update(tenant, hv_gain, cost)

Two scheduler policies ship: ``"round_robin"`` (the fairness baseline) and
``"gain_per_cost"`` — a decayed empirical estimate of hypervolume gain per
eval-second, optimistic for never-run tenants, which is the practical proxy
for the EHVI-per-cost allocation rule (the acquisition's own expected-gain
signal is only comparable *within* a tenant; realized HV gain per second is
comparable across tenants and needs no extra surrogate evaluations).

Evaluation cost is charged from the *analytic* cost model when the raw
result carries build/search timings (deterministic, so CI gates and resumed
runs charge identical budgets) and falls back to measured wall time.

The fleet ledger is schema-versioned JSON; ``state_dict()``/``restore()``
round-trip mid-round bit-identically — scheduler state, shared budget, every
tenant's session (pending queues included), transfer reports and the fitted
embedding all ride along. Serving integration: ``outcome_hook(name)`` returns
a callback for :class:`~repro.serving.controller.ServingController` so a
promote/rollback on any tenant's serving plane lands in that tenant's fleet
ledger (and optionally its GP, via the controller's own ``canary_feedback``).
"""

from __future__ import annotations

import copy
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.hypervolume import hv_2d
from ..core.pareto import pareto_front
from ..core.session import TuningSession
from ..core.tuner import Observation

from .descriptor import DescriptorEmbedding, WorkloadDescriptor, config_summary
from .transfer import (
    TransferPolicy,
    TransferReport,
    apply_transfer,
    check_divergence,
    rank_sources,
)

FLEET_STATE_VERSION = 1
FLEET_LEDGER_SCHEMA = 1


def analytic_eval_cost(obs: Observation) -> float:
    """Eval-seconds one observation cost the fleet.

    Prefers the *modeled* timings in the raw result (``seal_build_s`` +
    ``search_s`` — the analytic cost model's replay seconds), falling back
    to measured wall time. ``build_time`` is deliberately excluded: even in
    analytic mode it is the wall-clock time of running the simulated build,
    so including it would make budget charges differ across runs — and the
    fleet's CI gates compare charge trajectories for exact equality.
    """
    raw = obs.raw or {}
    cost = 0.0
    for key in ("seal_build_s", "search_s"):
        if key in raw:
            cost += float(raw[key])
    if cost > 0.0:
        return cost
    return float(obs.eval_time)


class FleetBudget:
    """Shared eval-second budget across every tenant in the fleet."""

    def __init__(self, total_s: float):
        if total_s <= 0:
            raise ValueError(f"total_s must be > 0, got {total_s}")
        self.total_s = float(total_s)
        self.spent_s = 0.0

    def charge(self, seconds: float) -> None:
        self.spent_s += float(seconds)

    @property
    def remaining_s(self) -> float:
        return self.total_s - self.spent_s

    @property
    def exhausted(self) -> bool:
        return self.spent_s >= self.total_s

    def state_dict(self) -> Dict[str, Any]:
        return {"total_s": self.total_s, "spent_s": self.spent_s}

    def load_state_dict(self, state: Dict[str, Any]) -> "FleetBudget":
        self.total_s = float(state["total_s"])
        self.spent_s = float(state["spent_s"])
        return self


class FleetScheduler:
    """Budget allocator over runnable tenants.

    ``round_robin`` cycles a cursor over the tenant order. ``gain_per_cost``
    keeps an exponentially-decayed estimate of hypervolume gain per
    eval-second per tenant; never-run tenants are optimistic (picked first,
    in order), then the argmax estimate wins with deterministic first-in-order
    tie-break. Fully JSON-serializable.
    """

    POLICIES = ("round_robin", "gain_per_cost")

    def __init__(self, policy: str = "round_robin", decay: float = 0.5):
        if policy not in self.POLICIES:
            raise ValueError(f"policy must be one of {self.POLICIES}, got {policy!r}")
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.policy = policy
        self.decay = float(decay)
        self._cursor = 0
        self._est: Dict[str, float] = {}  # decayed gain per eval-second
        self._n: Dict[str, int] = {}  # rounds run per tenant

    def pick(self, order: Sequence[str], runnable: Sequence[str]) -> str:
        runnable_set = set(runnable)
        if not runnable_set:
            raise ValueError("no runnable tenants")
        if self.policy == "round_robin":
            for _ in range(len(order)):
                name = order[self._cursor % len(order)]
                self._cursor += 1
                if name in runnable_set:
                    return name
            raise ValueError("runnable tenants not in fleet order")
        # gain_per_cost: optimism for the unexplored, then argmax estimate
        never = [n for n in order if n in runnable_set and self._n.get(n, 0) == 0]
        if never:
            return never[0]
        best, best_g = None, -np.inf
        for n in order:
            if n not in runnable_set:
                continue
            g = self._est.get(n, 0.0)
            if g > best_g:
                best, best_g = n, g
        return best

    def update(self, name: str, hv_gain: float, cost_s: float) -> None:
        g = float(hv_gain) / max(float(cost_s), 1e-9)
        k = self._n.get(name, 0)
        self._est[name] = g if k == 0 else self.decay * self._est[name] + (1.0 - self.decay) * g
        self._n[name] = k + 1

    def state_dict(self) -> Dict[str, Any]:
        return {
            "policy": self.policy,
            "decay": self.decay,
            "cursor": int(self._cursor),
            "est": {k: float(v) for k, v in self._est.items()},
            "n": {k: int(v) for k, v in self._n.items()},
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "FleetScheduler":
        self.policy = str(state["policy"])
        self.decay = float(state["decay"])
        self._cursor = int(state["cursor"])
        self._est = {k: float(v) for k, v in state["est"].items()}
        self._n = {k: int(v) for k, v in state["n"].items()}
        return self


class _Tenant:
    """Per-tenant fleet bookkeeping around one TuningSession."""

    def __init__(
        self,
        name: str,
        session: TuningSession,
        descriptor: WorkloadDescriptor,
        n_iters: int,
    ):
        self.name = name
        self.session = session
        self.descriptor = descriptor
        self.n_iters = int(n_iters)
        self.rounds: List[Dict[str, Any]] = []
        self.events: List[Dict[str, Any]] = []  # serving promote/rollback etc.
        self.charged_s = 0.0
        self.last_hv = 0.0
        self.transfer: Optional[TransferReport] = None
        self.divergence_checked = False

    @property
    def wants_more(self) -> bool:
        return self.session.n_observations < self.n_iters

    def hypervolume(self) -> float:
        """HV of the fresh (locally measured) front over the fixed (0, 0)
        reference — the per-tenant progress signal the scheduler compares."""
        fresh = [o for o in self.session.history if not o.bootstrap and not o.failed]
        if not fresh:
            return 0.0
        Y = np.stack([np.asarray(o.y, np.float64) for o in fresh])
        front = pareto_front(Y)
        front = front[(front > 0).all(axis=1)]
        if front.size == 0:
            return 0.0
        return float(hv_2d(front, np.zeros(2)))


class FleetSession:
    """Orchestrates N per-tenant :class:`TuningSession`s under one budget."""

    def __init__(
        self,
        budget: FleetBudget,
        scheduler: Any = "round_robin",
        transfer_policy: Optional[TransferPolicy] = None,
        embedding: Optional[DescriptorEmbedding] = None,
        cost_fn: Callable[[Observation], float] = analytic_eval_cost,
    ):
        self.budget = budget
        self.scheduler = (
            scheduler if isinstance(scheduler, FleetScheduler) else FleetScheduler(scheduler)
        )
        self.transfer_policy = transfer_policy
        self.embedding = embedding if embedding is not None else DescriptorEmbedding()
        self.cost_fn = cost_fn
        self._tenants: Dict[str, _Tenant] = {}
        self._order: List[str] = []

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def add_tenant(
        self,
        name: str,
        session: TuningSession,
        descriptor: WorkloadDescriptor,
        n_iters: int,
    ) -> None:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already in the fleet")
        self._tenants[name] = _Tenant(name, session, descriptor, n_iters)
        self._order.append(name)

    @property
    def tenant_names(self) -> List[str]:
        return list(self._order)

    def tenant(self, name: str) -> _Tenant:
        return self._tenants[name]

    def session_of(self, name: str) -> TuningSession:
        return self._tenants[name].session

    # ------------------------------------------------------------------
    # transfer warm-start
    # ------------------------------------------------------------------
    def warm_start(self, name: str) -> TransferReport:
        """Seed ``name``'s GP from the most similar tenants' ledgers.

        Must run before the tenant's first fresh observation (importing into
        a half-tuned session would corrupt the warm-up bookkeeping). With no
        transfer policy, or no source above the similarity floor, the tenant
        is left bit-identical to cold start and the report says so.
        """
        t = self._tenants[name]
        if t.session.n_observations > 0:
            raise ValueError(f"tenant {name!r} already has fresh observations")
        if t.transfer is not None:
            raise ValueError(f"tenant {name!r} was already warm-started")
        policy = self.transfer_policy
        sources = [
            (o, self._tenants[o])
            for o in self._order
            if o != name and any(not x.bootstrap and not x.failed for x in self._tenants[o].session.history)
        ]
        if policy is None or not sources:
            t.transfer = TransferReport(target=name, sources=[], n_imported=0, fallback=True)
            return t.transfer
        descs = [t.descriptor] + [s.descriptor for _, s in sources]
        summaries = [None] + [
            config_summary(s.session.tuner.space, s.session.history) for _, s in sources
        ]
        self.embedding.fit(descs, summaries)
        cand_summaries = {
            n: s for (n, _), s in zip(sources, summaries[1:]) if s is not None
        }
        ranked = rank_sources(
            self.embedding,
            t.descriptor,
            [(n, s.descriptor) for n, s in sources],
            policy,
            target_summary=None,
            candidate_summaries=cand_summaries,
        )
        t.transfer = apply_transfer(
            t.session,
            name,
            ranked,
            {n: s.session.history for n, s in sources},
            policy,
            {n: s.session.tuner.space.encoding_signature() for n, s in sources},
        )
        return t.transfer

    # ------------------------------------------------------------------
    # the shared-budget loop
    # ------------------------------------------------------------------
    def run(self, max_rounds: Optional[int] = None) -> "FleetSession":
        """Spend the shared budget: pick a tenant, run one round, charge its
        evaluation cost, update the scheduler with realized HV gain."""
        n_rounds = 0
        while not self.budget.exhausted:
            runnable = [n for n in self._order if self._tenants[n].wants_more]
            if not runnable:
                break
            if max_rounds is not None and n_rounds >= max_rounds:
                break
            name = self.scheduler.pick(self._order, runnable)
            self.run_tenant_round(name)
            n_rounds += 1
        return self

    def run_tenant_round(self, name: str) -> List[Observation]:
        """One scheduled round for one tenant (the loop body of :meth:`run`,
        public so callers can drive custom schedules)."""
        t = self._tenants[name]
        want = max(t.n_iters - t.session.n_observations, 1)
        new_obs = t.session.run_round(want)
        cost = float(sum(self.cost_fn(o) for o in new_obs if not o.bootstrap))
        hv = t.hypervolume()
        gain = hv - t.last_hv
        self.budget.charge(cost)
        self.scheduler.update(name, gain, cost)
        t.charged_s += cost
        t.rounds.append(
            {
                "round": len(t.rounds),
                "n_evals": sum(1 for o in new_obs if not o.bootstrap),
                "cost_s": cost,
                "hv": hv,
                "hv_gain": gain,
                "budget_spent_s": self.budget.spent_s,
            }
        )
        t.last_hv = hv
        if (
            self.transfer_policy is not None
            and t.transfer is not None
            and not t.transfer.fallback
            and not t.divergence_checked
        ):
            verdict = check_divergence(t.session, self.transfer_policy)
            if verdict is not None:
                t.divergence_checked = True
                if verdict:
                    t.events.append({"event": "transfer_purged", "round": len(t.rounds) - 1})
        return new_obs

    # ------------------------------------------------------------------
    # serving integration
    # ------------------------------------------------------------------
    def outcome_hook(self, name: str) -> Callable[[str, Dict[str, Any], Dict[str, float]], None]:
        """Callback for a tenant's :class:`ServingController` — promote and
        rollback outcomes land in that tenant's fleet ledger."""
        t = self._tenants[name]

        def hook(kind: str, config: Dict[str, Any], raw: Dict[str, float]) -> None:
            t.events.append(
                {
                    "event": str(kind),
                    "config": dict(config),
                    "raw": {k: float(v) for k, v in raw.items()},
                }
            )

        return hook

    # ------------------------------------------------------------------
    # ledger + checkpointing
    # ------------------------------------------------------------------
    def ledger_dict(self) -> Dict[str, Any]:
        """Schema-versioned fleet ledger: budget, scheduler, per-tenant
        rounds/events/transfer plus each session's own ledger block."""
        return {
            "schema": FLEET_LEDGER_SCHEMA,
            "budget": self.budget.state_dict(),
            "scheduler": self.scheduler.state_dict(),
            "tenants": {
                n: {
                    "descriptor": t.descriptor.to_dict(),
                    "n_iters": t.n_iters,
                    "charged_s": t.charged_s,
                    "hv": t.last_hv,
                    "rounds": copy.deepcopy(t.rounds),
                    "events": copy.deepcopy(t.events),
                    "transfer": t.transfer.to_dict() if t.transfer is not None else None,
                    "session": t.session.ledger_dict(),
                }
                for n, t in self._tenants.items()
            },
        }

    def state_dict(self) -> Dict[str, Any]:
        """JSON-compatible checkpoint of the whole fleet (bit-identical
        resume, mid-round included — per-tenant pending queues ride in each
        session's own state)."""
        return {
            "version": FLEET_STATE_VERSION,
            "order": list(self._order),
            "budget": self.budget.state_dict(),
            "scheduler": self.scheduler.state_dict(),
            "embedding": self.embedding.state_dict(),
            "tenants": {
                n: {
                    "descriptor": t.descriptor.to_dict(),
                    "n_iters": t.n_iters,
                    "charged_s": t.charged_s,
                    "last_hv": t.last_hv,
                    "rounds": copy.deepcopy(t.rounds),
                    "events": copy.deepcopy(t.events),
                    "transfer": t.transfer.to_dict() if t.transfer is not None else None,
                    "divergence_checked": t.divergence_checked,
                    "session": t.session.state_dict(),
                }
                for n, t in self._tenants.items()
            },
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "FleetSession":
        """Restore onto a fleet whose tenants were re-added with freshly
        constructed sessions (same constructor args), mirroring
        :meth:`TuningSession.restore`."""
        version = state.get("version")
        if version != FLEET_STATE_VERSION:
            raise ValueError(f"unsupported fleet state version {version!r}")
        if list(state["order"]) != self._order:
            raise ValueError(
                f"fleet tenants {self._order} do not match checkpoint {state['order']}"
            )
        self.budget.load_state_dict(state["budget"])
        self.scheduler.load_state_dict(state["scheduler"])
        self.embedding.load_state_dict(state["embedding"])
        for n, ts in state["tenants"].items():
            t = self._tenants[n]
            t.descriptor = WorkloadDescriptor.from_dict(ts["descriptor"])
            t.n_iters = int(ts["n_iters"])
            t.charged_s = float(ts["charged_s"])
            t.last_hv = float(ts["last_hv"])
            t.rounds = copy.deepcopy(ts["rounds"])
            t.events = copy.deepcopy(ts["events"])
            t.transfer = (
                TransferReport.from_dict(ts["transfer"]) if ts["transfer"] is not None else None
            )
            t.divergence_checked = bool(ts["divergence_checked"])
            t.session.load_state_dict(ts["session"])
        return self
