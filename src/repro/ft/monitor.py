"""Fault-tolerance runtime pieces: straggler detection and preemption-aware
shutdown. On a real multi-pod job these hooks feed the cluster scheduler; on a
single host they degrade to logging + clean checkpoint-on-SIGTERM.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
from typing import List, Optional


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    flagged: bool


class StragglerMonitor:
    """Flags steps slower than `threshold` x the trailing-median step time.

    At pod scale the same statistic is computed per host from all-gathered
    step timestamps; hosts that flag persistently get drained/replaced. Here
    the monitor records and exposes the decision signal.
    """

    def __init__(self, window: int = 32, threshold: float = 2.0, patience: int = 3):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self.history: List[StepStats] = []
        self._consecutive = 0

    def record(self, step: int, seconds: float) -> StepStats:
        recent = [s.seconds for s in self.history[-self.window :]]
        median = sorted(recent)[len(recent) // 2] if recent else seconds
        flagged = len(recent) >= 8 and seconds > self.threshold * median
        stat = StepStats(step=step, seconds=seconds, flagged=flagged)
        self.history.append(stat)
        self._consecutive = self._consecutive + 1 if flagged else 0
        return stat

    @property
    def should_replace(self) -> bool:
        """True when this worker has been a persistent straggler."""
        return self._consecutive >= self.patience

    def median_step(self) -> Optional[float]:
        recent = [s.seconds for s in self.history[-self.window :]]
        return sorted(recent)[len(recent) // 2] if recent else None


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the train loop polls; the loop then writes
    a final checkpoint and exits cleanly (standard preemption protocol)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = threading.Event()
        self._prev = {}
        self.signals = signals

    def __enter__(self):
        for s in self.signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._requested.set()

    @property
    def preempted(self) -> bool:
        return self._requested.is_set()
