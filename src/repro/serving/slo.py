"""Declarative SLO guardrails for the serving control plane.

:class:`SLOSpec` states the service-level objectives a deployment must hold —
a recall floor (the paper's §IV-F user preference, mapped onto the CEI
constraint objective via :mod:`repro.core.objectives`), a p99 latency budget,
and a memory cap. :class:`SLOMonitor` evaluates a spec over sliding windows
of live measurements (per-query latencies, recall probes, memory snapshots)
and emits breach events; the serving controller uses those events — alongside
drift detection — as its re-tune trigger.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core.objectives import ObjectiveSpec, streaming_sustained


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """What the deployment promises. ``None`` disables a guardrail.

    ``recall_floor`` — mean windowed recall must stay >= this (also the CEI
    constraint the re-tuner optimizes under, see :meth:`objective_spec`);
    ``p99_latency_s`` — windowed p99 per-query latency budget (seconds);
    ``mem_gib_cap`` — live-instance footprint cap (GiB);
    ``latency_window`` — per-query latency samples in the sliding window;
    ``recall_window`` — recall probes in the sliding window;
    ``min_samples`` — latency samples required before the latency guardrail
    is considered armed (cold windows never breach).
    """

    recall_floor: Optional[float] = None
    p99_latency_s: Optional[float] = None
    mem_gib_cap: Optional[float] = None
    latency_window: int = 256
    recall_window: int = 8
    min_samples: int = 32

    def __post_init__(self):
        if self.recall_floor is not None and not 0.0 < self.recall_floor <= 1.0:
            raise ValueError(f"recall_floor must be in (0, 1], got {self.recall_floor}")
        if self.p99_latency_s is not None and self.p99_latency_s <= 0:
            raise ValueError(f"p99_latency_s must be > 0, got {self.p99_latency_s}")
        if self.mem_gib_cap is not None and self.mem_gib_cap <= 0:
            raise ValueError(f"mem_gib_cap must be > 0, got {self.mem_gib_cap}")
        if self.latency_window < 1 or self.recall_window < 1:
            raise ValueError("windows must be >= 1")
        if not (self.recall_floor or self.p99_latency_s or self.mem_gib_cap):
            raise ValueError("SLOSpec with every guardrail disabled is meaningless")

    def objective_spec(self, alpha: float = 1.0) -> ObjectiveSpec:
        """The tuning objective this SLO induces: sustained QPS x recall with
        the recall floor carried as the CEI constraint (``rlim``), so a
        re-tune triggered by a breach optimizes under the same contract the
        guardrail enforces."""
        return streaming_sustained(alpha=alpha, rlim=self.recall_floor)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SLOStatus:
    """One guardrail evaluation: ``ok`` plus the measured window values."""

    ok: bool
    breaches: Tuple[str, ...]
    p99_latency_s: float
    recall: float
    mem_gib: float
    n_latency_samples: int
    n_recall_samples: int
    at_time: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["breaches"] = list(self.breaches)
        return d


class SLOMonitor:
    """Sliding-window evaluator for one :class:`SLOSpec`.

    Feed it live measurements (:meth:`observe_query`, :meth:`observe_recall`,
    :meth:`observe_mem`) and call :meth:`evaluate` at control ticks; every
    not-ok status is appended to :attr:`events`. :meth:`reset` clears the
    windows (call after a promote, so the new config starts a fresh window).
    """

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self._lat: Deque[float] = deque(maxlen=spec.latency_window)
        self._recall: Deque[float] = deque(maxlen=spec.recall_window)
        self._mem = 0.0
        self.events: List[Dict[str, Any]] = []
        self.n_evaluations = 0

    # --- feeds ---------------------------------------------------------
    def observe_query(self, latency_s) -> None:
        """One latency or an array of per-query latencies (seconds)."""
        arr = np.atleast_1d(np.asarray(latency_s, np.float64))
        self._lat.extend(arr.tolist())

    def observe_recall(self, recall: float) -> None:
        self._recall.append(float(recall))

    def observe_mem(self, mem_gib: float) -> None:
        self._mem = float(mem_gib)

    def reset(self) -> None:
        self._lat.clear()
        self._recall.clear()

    # --- evaluation ----------------------------------------------------
    @property
    def windowed_recall(self) -> float:
        return float(np.mean(self._recall)) if self._recall else 1.0

    @property
    def windowed_p99(self) -> float:
        if not self._lat:
            return 0.0
        return float(np.percentile(np.asarray(self._lat, np.float64), 99.0))

    def evaluate(self, at_time: float = 0.0) -> SLOStatus:
        spec = self.spec
        breaches: List[str] = []
        p99 = self.windowed_p99
        recall = self.windowed_recall
        if (
            spec.p99_latency_s is not None
            and len(self._lat) >= spec.min_samples
            and p99 > spec.p99_latency_s
        ):
            breaches.append("p99_latency")
        if spec.recall_floor is not None and self._recall and recall < spec.recall_floor:
            breaches.append("recall_floor")
        if spec.mem_gib_cap is not None and self._mem > spec.mem_gib_cap:
            breaches.append("mem_cap")
        status = SLOStatus(
            ok=not breaches,
            breaches=tuple(breaches),
            p99_latency_s=p99,
            recall=recall,
            mem_gib=self._mem,
            n_latency_samples=len(self._lat),
            n_recall_samples=len(self._recall),
            at_time=float(at_time),
        )
        self.n_evaluations += 1
        if breaches:
            self.events.append(status.to_dict())
        return status
