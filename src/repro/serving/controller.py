"""The autonomous serving control plane: SLO-guarded shadow/canary retune.

:class:`ServingController` wraps a live serving instance and a
:class:`~repro.core.session.TuningSession` into a closed loop:

1. **Serve + observe** — replay live traffic through the primary instance,
   feeding per-query latencies, recall probes and lifecycle stats into the
   metrics ledger and the :class:`~repro.serving.slo.SLOMonitor`.
2. **Trigger** — at control ticks, evaluate the SLO guardrails (and an
   optional :class:`~repro.core.session.DriftDetector` fed with the same
   live window); any breach or drift firing triggers a re-tune.
3. **Retune in shadow** — snapshot the session, re-enter BO on a trailing
   window of the live trace (``TuningSession.retune``), build the candidate
   config as a *shadow* instance bootstrapped from the primary's visible
   vectors (build cost charged via the analytic model), and mirror a slice
   of live traffic to both instances (dual-index, the pgvector migration
   pattern).
4. **Promote or roll back** — after the canary window, compare both arms on
   the SLO-constrained :func:`~repro.core.objectives.promotion_score`.
   A winning shadow becomes the primary (the old index is dropped); a losing
   one is dropped and the session checkpoint is restored **bit-identically**
   (``TuningSession.load_state_dict``) — as if the candidate never existed.

Trace timestamps are normalized to [0, 1]; the report scales time-integrated
quantities (SLO violation time, recall-under-floor time) by
``trace_minutes`` so they read as violation-minutes.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.objectives import promotion_score
from ..core.session import DriftDetector, TuningSession
from ..vdms.datasets import exact_topk_masked, recall_at_k_masked
from ..vdms.engine import LiveVDMS
from ..vdms.faults import FaultError, FaultInjector, FaultPlan, ShadowBuildOOM
from ..vdms.tuning_env import VDMSTuningEnv
from ..vdms.workload import (
    OP_INSERT,
    OP_SEARCH,
    WorkloadTrace,
    time_aware_ground_truth,
)
from .metrics import (
    MetricsLedger,
    attach_live,
    attach_straggler,
    observe_stats,
    serving_ledger,
)
from .slo import SLOMonitor, SLOSpec


class GidMappedVDMS:
    """A :class:`LiveVDMS` addressed by trace-global ids.

    A shadow instance is bootstrapped mid-trace from the primary's visible
    vectors, so its local id space is dense while the trace speaks global
    ids; this wrapper carries the local<->global maps for inserts, deletes
    and search results. The initial primary uses the same wrapper with an
    identity bootstrap, so both arms run one code path. (Engine gids are
    stable across tombstones and compaction — survivors keep their ids — so
    the maps never go stale.)
    """

    def __init__(
        self,
        config: Dict[str, Any],
        dim: int,
        capacity: int,
        seed: int = 0,
        compact_threshold: float = 0.3,
    ):
        self.config = dict(config)
        self.live = LiveVDMS(
            config, dim, capacity, seed=seed, compact_threshold=compact_threshold
        )
        # local -> global; the extra sentinel slot keeps -1 mapping to -1
        self._gid_of = np.full(capacity + 1, -1, np.int64)
        self._local_of: Dict[int, int] = {}

    def bootstrap(self, vectors: np.ndarray, gids: np.ndarray) -> None:
        gids = np.asarray(gids, np.int64)
        if vectors.shape[0] != gids.shape[0]:
            raise ValueError("bootstrap vectors/gids length mismatch")
        self.live.bootstrap(vectors)
        self._gid_of[: gids.size] = gids
        self._local_of = {int(g): i for i, g in enumerate(gids)}

    def insert(self, gid: int, vec: np.ndarray) -> None:
        loc = int(self.live.insert(vec)[0])
        self._gid_of[loc] = int(gid)
        self._local_of[int(gid)] = loc

    def delete(self, gid: int) -> bool:
        loc = self._local_of.get(int(gid), -1)
        return self.live.delete(loc) if loc >= 0 else False

    def search(
        self, queries: np.ndarray, topk: int, mode: str = "analytic"
    ) -> Tuple[np.ndarray, float]:
        ids, secs = self.live.search(queries, topk, mode=mode)
        out = np.where(ids >= 0, self._gid_of[ids], -1).astype(np.int32)
        return out, secs

    def visible_gids(self) -> np.ndarray:
        """Trace-global ids of every vector currently visible to searches."""
        local = self.live.visible_ids()
        return self._gid_of[local].astype(np.int64)

    def searchable_gids(self) -> np.ndarray:
        """Trace-global ids a search can return *right now* — excludes
        quarantined segments and the graceful-window-hidden tail. This is the
        visible set honest degraded-mode recall is scored against."""
        local = self.live.searchable_ids()
        return self._gid_of[local].astype(np.int64)


def mirror_count(credit: float, fraction: float, n: int) -> Tuple[int, float]:
    """Exact deterministic mirror subsample for one canary flush.

    Accumulates ``fraction * n`` mirror credit, mirrors the integer part and
    carries the fractional remainder to the next flush — so over many small
    flushes the mirrored share converges to ``fraction`` exactly, instead of
    per-flush ceil-rounding (which on small flushes mirrors everything
    regardless of the configured fraction). ``fraction=1.0`` reduces to
    ``(n, 0.0)`` exactly.
    """
    total = credit + fraction * n
    m = int(total)
    return m, total - m


@dataclasses.dataclass(frozen=True)
class ControllerParams:
    """Control-loop knobs (op counts are trace operations, not seconds)."""

    check_every: int = 48  # ops between control ticks
    cooldown_ops: int = 96  # no new trigger this many ops after a decision
    retune_iters: int = 8  # fresh BO evaluations per retune
    retune_window_ops: int = 400  # trailing trace window the retune env replays
    min_window_searches: int = 12  # skip retune when the window has no signal
    canary_queries: int = 48  # mirrored queries before promote-or-rollback
    traffic_mirror: float = 1.0  # fraction of each canary flush mirrored
    canary_feedback: bool = True  # tell both arms' live measurements to the tuner
    alpha: float = 1.0  # ingest weight in the promotion score
    min_win_margin: float = 0.0  # candidate must beat primary by this rel. margin
    build_amortize_queries: int = 10_000  # horizon the shadow build is amortized over
    floor_margin: float = 0.01  # extra recall headroom required on the retune window
    repair_anchors: bool = True  # reanchor retunes with breach-repair variants
    # breach-storm hysteresis: each consecutive rollback multiplies the
    # post-rollback cooldown (capped), so a latency storm that keeps failing
    # canaries cannot thrash the controller into a retune loop
    storm_cooldown_factor: float = 2.0
    storm_cooldown_cap_ops: int = 1024

    def __post_init__(self):
        if not 0.0 < self.traffic_mirror <= 1.0:
            raise ValueError(
                f"traffic_mirror must be in (0, 1], got {self.traffic_mirror}"
            )
        if min(self.canary_queries, self.retune_iters, self.check_every) < 1:
            raise ValueError("canary_queries, retune_iters, check_every must be >= 1")
        if self.storm_cooldown_factor < 1.0 or self.storm_cooldown_cap_ops < 1:
            raise ValueError(
                "need storm_cooldown_factor >= 1 and storm_cooldown_cap_ops >= 1"
            )


class _Canary:
    """One in-flight canary: the shadow arm plus mirrored-slice stats."""

    def __init__(self, shadow: GidMappedVDMS, snapshot: Dict[str, Any], op: int):
        self.shadow = shadow
        self.snapshot = snapshot
        self.started_op = op
        self.mirrored = 0
        # fractional-mirror accumulator: traffic_mirror * flush_size credit
        # carries across flushes so small flushes don't round up to 100%
        self.mirror_credit = 0.0
        self.primary_lat: List[float] = []
        self.shadow_lat: List[float] = []
        self.primary_recall: List[float] = []
        self.shadow_recall: List[float] = []
        self.primary_seal0 = 0.0
        self.shadow_seal0 = 0.0


class ServingController:
    """Autonomous SLO-guarded serving over a live workload trace.

    Parameters
    ----------
    slo:
        The declarative guardrails (:class:`SLOSpec`).
    session:
        A :class:`TuningSession` whose tuner supplies retune candidates; its
        backend is swapped to a trailing-window streaming env at each retune.
        Optional when serving with ``guard=False`` (monitor-only baseline).
    detector:
        Optional :class:`DriftDetector` fed with windowed live metrics at
        every control tick — drift then triggers retunes alongside breaches.
    ledger:
        Metrics ledger; a fresh :func:`serving_ledger` by default.
    mode:
        ``"analytic"`` (deterministic cost model; default) or ``"wall"``.
    trace_minutes:
        Wall-clock minutes one unit of normalized trace time represents —
        the scale behind ``violation_minutes`` in the report.
    """

    def __init__(
        self,
        slo: SLOSpec,
        session: Optional[TuningSession] = None,
        detector: Optional[DriftDetector] = None,
        ledger: Optional[MetricsLedger] = None,
        params: Optional[ControllerParams] = None,
        mode: str = "analytic",
        seed: int = 0,
        trace_minutes: float = 60.0,
        compact_threshold: float = 0.3,
        outcome_hook: Optional[
            Callable[[str, Dict[str, Any], Dict[str, Any]], None]
        ] = None,
    ):
        self.slo = slo
        self.session = session
        self.detector = detector
        self.ledger = ledger if ledger is not None else serving_ledger()
        self.params = params if params is not None else ControllerParams()
        self.mode = mode
        self.seed = int(seed)
        self.trace_minutes = float(trace_minutes)
        self.compact_threshold = float(compact_threshold)
        # optional (kind, config, raw) callback fired after each canary
        # decision — the fleet ledger's promote/rollback outcome feed
        self.outcome_hook = outcome_hook
        self.monitor = SLOMonitor(slo)
        self.timeline: List[Dict[str, Any]] = []
        self.n_retunes = 0
        self.n_promotes = 0
        self.n_rollbacks = 0
        # lifecycle counter offsets across promotes (ledger counters stay
        # monotone even though a fresh instance's counts restart at zero)
        self._life_off = {
            "n_seals": 0.0,
            "n_compactions": 0.0,
            "n_quarantines": 0.0,
            "n_rebuilds": 0.0,
            "n_rebuild_failures": 0.0,
            "n_seal_retries": 0.0,
        }
        # fault-injection state (None unless serve() is given a FaultPlan):
        # one primary-scoped injector rides across promotes, one shadow-scoped
        # injector persists across canaries (so a shadow OOM fires once)
        self._primary_injector: Optional[FaultInjector] = None
        self._shadow_injector: Optional[FaultInjector] = None
        self._consec_rollbacks = 0
        self._straggler = None
        self._last_snapshot: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------
    # session snapshot / rollback (checkpoint-exact)
    # ------------------------------------------------------------------
    def _snapshot(self) -> Dict[str, Any]:
        return {
            "state": copy.deepcopy(self.session.state_dict()),
            "backend": self.session.backend,
        }

    def _restore(self, snap: Dict[str, Any]) -> None:
        self.session.load_state_dict(snap["state"])
        self.session.backend = snap["backend"]

    def _event(self, kind: str, op: int, t: float, **extra: Any) -> None:
        self.timeline.append({"event": kind, "op": int(op), "time": float(t), **extra})

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def serve(
        self,
        trace: WorkloadTrace,
        config: Dict[str, Any],
        ground_truth: Optional[np.ndarray] = None,
        guard: bool = True,
        fault_plan: Optional[FaultPlan] = None,
    ) -> Dict[str, Any]:
        """Replay ``trace`` under the control loop, starting from ``config``.

        ``guard=False`` runs the monitor-only baseline: identical serving,
        SLO accounting and ledger, but breaches never trigger retunes — the
        frozen arm the serving benchmark compares against.

        ``fault_plan`` arms chaos: a primary-scoped injector on the serving
        engine (riding across promotes) and a shadow-scoped one shared by
        every canary build. The controller then additionally tracks engine
        health transitions, aborts canaries struck by faults mid-mirror
        (checkpoint-exact), applies breach-storm hysteresis after rollbacks,
        and scores every flush's *visible-set* recall against the brute-force
        oracle restricted to searchable vectors.
        """
        if guard and self.session is None:
            raise ValueError("guarded serving requires a session (tuner) to retune with")
        p = self.params
        k = trace.k
        gt = (
            ground_truth
            if ground_truth is not None
            else time_aware_ground_truth(trace, k)
        )
        primary = GidMappedVDMS(
            config, trace.dim, trace.capacity, seed=self.seed,
            compact_threshold=self.compact_threshold,
        )
        primary.bootstrap(trace.base, np.arange(trace.n_base))
        attach_live(self.ledger, primary.live)
        self._straggler = attach_straggler(self.ledger, primary.live, self._straggler)
        all_vecs: Optional[np.ndarray] = None
        flush_vis: List[Dict[str, Any]] = []
        coverage_min = 1.0
        if fault_plan is not None:
            self._primary_injector = FaultInjector(fault_plan, scope="primary")
            self._shadow_injector = FaultInjector(fault_plan, scope="shadow")
            primary.live.arm_faults(self._primary_injector)
            all_vecs = trace.all_vectors()
        config = dict(config)
        config_history = [{"op": 0, "time": 0.0, "config": dict(config)}]

        preds = -np.ones((trace.n_searches, k), np.int32)
        lat_all: List[np.ndarray] = []
        search_s = 0.0
        canary: Optional[_Canary] = None
        pending: List[int] = []
        last_tick_op = 0
        last_tick_time = 0.0
        cooldown_until = -1
        last_health = "healthy"
        violation_time = 0.0
        recall_floor_time = 0.0
        breached_now = False
        recall_breached_now = False
        recall_probe = self.ledger.histogram("vdms_recall_probe")

        def promote(c: _Canary, op_i: int, t: float, p_score, c_score) -> None:
            nonlocal primary, config, cooldown_until
            stats = primary.live.stats()
            for key in self._life_off:
                self._life_off[key] += stats.get(key, 0)
            primary = c.shadow  # the old index is dropped here
            config = dict(c.shadow.config)
            attach_live(self.ledger, primary.live)
            self._straggler = attach_straggler(
                self.ledger, primary.live, self._straggler
            )
            if self._primary_injector is not None:
                # the promoted engine carried the shadow-scoped injector while
                # it was a canary; the primary fault clock takes over now
                primary.live.arm_faults(self._primary_injector)
            config_history.append(
                {"op": int(op_i), "time": float(t), "config": dict(config)}
            )
            self.n_promotes += 1
            self._consec_rollbacks = 0
            self.ledger.counter("vdms_promote_total").inc()
            self.monitor.reset()
            if self.detector is not None:
                self.detector.reset()
            cooldown_until = op_i + p.cooldown_ops
            self._event(
                "promote", op_i, t,
                primary_score=list(p_score), candidate_score=list(c_score),
            )

        def rollback(c: _Canary, op_i: int, t: float, p_score, c_score) -> None:
            nonlocal cooldown_until
            self._restore(c.snapshot)
            self.n_rollbacks += 1
            self._consec_rollbacks += 1
            self.ledger.counter("vdms_rollback_total").inc()
            cooldown_until = op_i + self._rollback_cooldown()
            self._event(
                "rollback", op_i, t,
                primary_score=list(p_score), candidate_score=list(c_score),
            )

        def abort_canary(op_i: int, t: float, reason: str) -> None:
            # a fault struck mid-mirror: the comparison is contaminated, so
            # drop the shadow and restore the session checkpoint-exactly —
            # hysteresis cooldown applies (a storm must not thrash retunes)
            nonlocal canary, cooldown_until
            self._restore(canary.snapshot)
            self.n_rollbacks += 1
            self._consec_rollbacks += 1
            self.ledger.counter("vdms_rollback_total").inc()
            self.ledger.counter("vdms_canary_fault_abort_total").inc()
            cooldown_until = op_i + self._rollback_cooldown()
            self._event("canary_fault_abort", op_i, t, reason=reason)
            canary = None

        def decide(c: _Canary, op_i: int, t: float) -> None:
            nonlocal canary
            p_busy = float(np.sum(c.primary_lat))
            c_busy = float(np.sum(c.shadow_lat))
            p_seal = max(primary.live.seal_build_model_s - c.primary_seal0, 0.0)
            c_seal = max(c.shadow.live.seal_build_model_s - c.shadow_seal0, 0.0)
            # the shadow's bootstrap build cost, amortized over the horizon a
            # promoted config is expected to live (the analytic build model)
            amort = c.mirrored / max(p.build_amortize_queries, 1)
            c_build = c.shadow.live.bootstrap_build_model_s * amort
            n = float(c.mirrored)
            p_raw = {
                "speed": n / max(p_busy, 1e-12),
                "recall": float(np.mean(c.primary_recall)),
                "n_searches": n,
                "search_s": p_busy,
                "seal_build_s": p_seal,
            }
            c_raw = {
                "speed": n / max(c_busy, 1e-12),
                "recall": float(np.mean(c.shadow_recall)),
                "n_searches": n,
                "search_s": c_busy,
                "seal_build_s": c_seal + c_build,
            }
            p_score = promotion_score(p_raw, rlim=self.slo.recall_floor, alpha=p.alpha)
            c_score = promotion_score(c_raw, rlim=self.slo.recall_floor, alpha=p.alpha)
            wins = c_score[0] > p_score[0] or (
                c_score[0] == p_score[0]
                and c_score[1] > p_score[1] * (1.0 + p.min_win_margin)
            )
            incumbent = dict(config)
            if wins:
                promote(c, op_i, t, p_score, c_score)
                outcome = "promote"
            else:
                rollback(c, op_i, t, p_score, c_score)
                outcome = "rollback"
            # feed both arms' live measurements into the tuner as external
            # tells — after promote/rollback, so a rollback's checkpoint
            # restore cannot wipe them; bootstrap=True keeps these free
            # byproducts of serving out of the fresh-evaluation budget
            # (they feed the GP and fronts, not the recommend/eval ledger)
            if p.canary_feedback and self.session is not None:
                self.session.tell(incumbent, dict(p_raw), bootstrap=True)
                self.session.tell(dict(c.shadow.config), dict(c_raw), bootstrap=True)
            if self.outcome_hook is not None:
                self.outcome_hook(outcome, dict(c.shadow.config), dict(c_raw))
            canary = None

        def flush(op_i: int) -> None:
            nonlocal search_s, coverage_min
            if not pending:
                return
            rows = np.asarray(pending, np.int64)
            pending.clear()
            q = trace.queries[rows]
            ids, secs = primary.search(q, k, mode=self.mode)
            lat = primary.live.last_latencies
            preds[rows] = ids
            lat_all.append(lat)
            search_s += secs
            self.monitor.observe_query(lat)
            recall = float(recall_at_k_masked(ids[:, :k], gt[rows, :k]))
            self.monitor.observe_recall(recall)
            recall_probe.observe(recall)
            self.monitor.observe_mem(primary.live.memory_gib())
            if fault_plan is not None:
                # honest degraded-mode accounting: score this flush against
                # the brute-force oracle restricted to the vectors a search
                # could actually have returned (searchable = visible minus
                # quarantined segments minus the graceful-hidden tail)
                cov = float(primary.live.last_coverage)
                coverage_min = min(coverage_min, cov)
                self.ledger.gauge("vdms_coverage").set(cov)
                svis = primary.searchable_gids()
                dead = np.ones(all_vecs.shape[0], bool)
                dead[svis] = False
                vis_gt = exact_topk_masked(all_vecs, q, dead, k)
                vrecall = float(recall_at_k_masked(ids[:, :k], vis_gt[:, :k]))
                flush_vis.append(
                    {
                        "op": int(op_i),
                        "rows": int(rows.size),
                        "visible": int(svis.size),
                        "coverage": cov,
                        "recall": vrecall,
                    }
                )
            if canary is not None:
                t_now = float(trace.times[min(op_i, trace.n_ops - 1)])
                if fault_plan is not None and (
                    primary.live.quarantined or primary.live._pending_seal is not None
                ):
                    abort_canary(op_i, t_now, "primary_fault")
                    return
                m, canary.mirror_credit = mirror_count(
                    canary.mirror_credit, p.traffic_mirror, rows.size
                )
                if m == 0:
                    return  # mirror credit carries into the next flush
                try:
                    s_ids, _ = canary.shadow.search(q[:m], k, mode=self.mode)
                except FaultError:
                    abort_canary(op_i, t_now, "shadow_fault")
                    return
                canary.primary_lat.extend(lat[:m].tolist())
                canary.shadow_lat.extend(canary.shadow.live.last_latencies.tolist())
                canary.primary_recall.append(
                    float(recall_at_k_masked(ids[:m, :k], gt[rows[:m], :k]))
                )
                canary.shadow_recall.append(
                    float(recall_at_k_masked(s_ids[:, :k], gt[rows[:m], :k]))
                )
                canary.mirrored += m
                if canary.mirrored >= p.canary_queries:
                    t = float(trace.times[min(op_i, trace.n_ops - 1)])
                    decide(canary, op_i, t)

        def control_tick(op_i: int, t: float) -> None:
            nonlocal last_tick_op, last_tick_time, violation_time, canary
            nonlocal recall_floor_time, breached_now, recall_breached_now
            nonlocal last_health, cooldown_until
            # integrate violation time over the elapsed interval first: the
            # state observed at the previous tick held for [last_tick, now)
            dt = max(t - last_tick_time, 0.0)
            if breached_now:
                violation_time += dt
            if recall_breached_now:
                recall_floor_time += dt
            status = self.monitor.evaluate(at_time=t)
            breached_now = not status.ok
            recall_breached_now = "recall_floor" in status.breaches
            if not status.ok:
                self.ledger.counter("vdms_slo_breach_total").inc()
                self._event(
                    "breach", op_i, t, breaches=list(status.breaches),
                    p99=status.p99_latency_s, recall=status.recall,
                )
            drift_fired = False
            if self.detector is not None and status.n_latency_samples > 0:
                probe = {
                    "speed": status.n_latency_samples
                    / max(float(np.sum(self.monitor._lat)), 1e-12),
                    "recall": status.recall,
                }
                if self.session is not None:
                    drift_fired = self.session.probe_drift(
                        self.detector, config, raw=probe
                    )
                else:
                    drift_fired = self.detector.observe(probe)
                if drift_fired:
                    self._event("drift", op_i, t)
            stats = primary.live.stats()
            adj = dict(stats)
            for key, off in self._life_off.items():
                adj[key] = stats.get(key, 0) + off
            observe_stats(self.ledger, adj)
            health = primary.live.health()
            if health != last_health:
                self._event("health", op_i, t, state=health, prev=last_health)
                last_health = health
            last_tick_op, last_tick_time = op_i, t
            if not guard or canary is not None or op_i < cooldown_until:
                return
            if status.ok and not drift_fired:
                return
            try:
                canary = self._start_canary(trace, config, primary, op_i, t)
            except ShadowBuildOOM as e:
                # the shadow build itself blew up: restore the pre-retune
                # checkpoint so the session is as if the retune never ran,
                # and back off (hysteresis) before trying again
                self._restore(self._last_snapshot)
                self.n_rollbacks += 1
                self._consec_rollbacks += 1
                self.ledger.counter("vdms_rollback_total").inc()
                self.ledger.counter("vdms_canary_fault_abort_total").inc()
                cooldown_until = op_i + self._rollback_cooldown()
                self._event("canary_aborted_oom", op_i, t, reason=str(e))
                canary = None

        # --- replay -------------------------------------------------------
        for i in range(trace.n_ops):
            kind = int(trace.kinds[i])
            t = float(trace.times[i])
            if kind == OP_SEARCH:
                pending.append(int(trace.payload[i]))
            else:
                flush(i)
                row = int(trace.payload[i])
                if kind == OP_INSERT:
                    # the j-th insert op creates global id n_base + j, and
                    # insert payloads are assigned sequentially: gid follows
                    gid = trace.n_base + row
                    primary.insert(gid, trace.inserts[row])
                    if canary is not None:
                        canary.shadow.insert(gid, trace.inserts[row])
                else:
                    primary.delete(row)
                    if canary is not None:
                        canary.shadow.delete(row)
            if i - last_tick_op >= p.check_every:
                flush(i)
                control_tick(i, t)
        flush(trace.n_ops - 1)
        t_end = float(trace.times[-1]) if trace.n_ops else 1.0
        control_tick(trace.n_ops - 1, t_end)
        if canary is not None:
            # the trace ended mid-canary: decide on whatever mirrored traffic
            # accumulated, or abort back to the incumbent (checkpoint-exact)
            if canary.mirrored > 0:
                decide(canary, trace.n_ops - 1, t_end)
            else:
                self._restore(canary.snapshot)
                self.n_rollbacks += 1
                self.ledger.counter("vdms_rollback_total").inc()
                self._event("canary_aborted", trace.n_ops - 1, t_end)
                canary = None

        # --- report -------------------------------------------------------
        lats = np.concatenate(lat_all) if lat_all else np.empty(0, np.float64)
        p50, p99 = (
            np.percentile(lats, (50.0, 99.0)) if lats.size else (0.0, 0.0)
        )
        overall_recall = float(
            recall_at_k_masked(preds[:, :k], gt[:, :k]) if trace.n_searches else 0.0
        )
        report_extra: Dict[str, Any] = {"health": primary.live.health()}
        if fault_plan is not None:
            stats = primary.live.stats()
            n_rows = sum(f["rows"] for f in flush_vis)
            report_extra["visible_recall"] = (
                float(sum(f["recall"] * f["rows"] for f in flush_vis) / n_rows)
                if n_rows
                else 1.0
            )
            report_extra["flush_visibility"] = flush_vis
            report_extra["fault"] = {
                "plan": fault_plan.to_dict(),
                "n_injected": int(
                    self._primary_injector.n_injected
                    + self._shadow_injector.n_injected
                ),
                "n_quarantines": int(
                    stats["n_quarantines"] + self._life_off["n_quarantines"]
                ),
                "n_rebuilds": int(
                    stats["n_rebuilds"] + self._life_off["n_rebuilds"]
                ),
                "n_rebuild_failures": int(
                    stats["n_rebuild_failures"]
                    + self._life_off["n_rebuild_failures"]
                ),
                "n_seal_retries": int(
                    stats["n_seal_retries"] + self._life_off["n_seal_retries"]
                ),
                "n_canary_fault_aborts": int(
                    self.ledger.counter("vdms_canary_fault_abort_total").value
                ),
                "coverage_min": float(coverage_min),
            }
        return {
            **report_extra,
            "guard": bool(guard),
            "trace": trace.name,
            "n_ops": int(trace.n_ops),
            "n_searches": int(trace.n_searches),
            "recall": overall_recall,
            "search_s": float(search_s),
            "speed": float(trace.n_searches / max(search_s, 1e-9)),
            "lat_p50_s": float(p50),
            "lat_p99_s": float(p99),
            "slo": self.slo.to_dict(),
            "violation_time": float(violation_time),
            "violation_minutes": float(violation_time * self.trace_minutes),
            "recall_under_floor_time": float(recall_floor_time),
            "recall_under_floor_minutes": float(recall_floor_time * self.trace_minutes),
            "n_breach_events": len(self.monitor.events),
            "n_retunes": int(self.n_retunes),
            "n_promotes": int(self.n_promotes),
            "n_rollbacks": int(self.n_rollbacks),
            "config_history": config_history,
            "timeline": copy.deepcopy(self.timeline),
            "final_stats": primary.live.stats(),
        }

    def _rollback_cooldown(self) -> int:
        """Post-rollback cooldown with breach-storm hysteresis: doubles (by
        ``storm_cooldown_factor``) per consecutive rollback, capped."""
        p = self.params
        n = max(self._consec_rollbacks, 1) - 1
        return int(
            min(
                p.cooldown_ops * p.storm_cooldown_factor**n,
                p.storm_cooldown_cap_ops,
            )
        )

    # ------------------------------------------------------------------
    # retune + canary start
    # ------------------------------------------------------------------
    def _start_canary(
        self,
        trace: WorkloadTrace,
        config: Dict[str, Any],
        primary: GidMappedVDMS,
        op_i: int,
        t: float,
    ) -> Optional[_Canary]:
        """Retune on the trailing trace window; on a genuinely new candidate,
        build it as a shadow instance and open the canary. Returns None when
        the window is too thin or the tuner retains the incumbent."""
        p = self.params
        lo = max(0, op_i - p.retune_window_ops)
        window = trace.window(lo, op_i)
        if window.n_searches < p.min_window_searches:
            self._event("retune_skipped", op_i, t, reason="window has too few searches")
            return None
        snap = self._snapshot()
        self._last_snapshot = snap
        env = VDMSTuningEnv(
            trace=window,
            workload="streaming",
            mode=self.mode,
            seed=self.seed,
            n_phases=1,
            compact_threshold=self.compact_threshold,
        )
        self.session.backend = env
        anchors = (
            self._repair_anchors(config) if p.repair_anchors else [dict(config)]
        )
        self.session.retune(p.retune_iters, reanchor=anchors)
        self.n_retunes += 1
        self.ledger.counter("vdms_retune_total").inc()
        # window replays bootstrap the visible state as fully-indexed sealed
        # segments, which flatters recall vs the live sliding window — demand
        # a margin above the floor before a candidate is considered feasible
        rlim = self.slo.recall_floor
        if rlim is not None:
            rlim = min(1.0, rlim + p.floor_margin)
        candidate = self.session.tuner.best_config(rlim=rlim)
        if candidate is None or self._canon(candidate) == self._canon(config):
            # the incumbent is still the best the tuner can find: no canary,
            # but the freshly-learned surrogate state is kept
            self._event("retune_retained", op_i, t)
            return None
        shadow = self._build_shadow(trace, candidate, primary)
        self._event(
            "canary_start", op_i, t, candidate=dict(candidate),
            shadow_build_model_s=float(shadow.live.bootstrap_build_model_s),
        )
        self.ledger.counter("vdms_shadow_build_seconds_total").inc(
            float(shadow.live.bootstrap_build_model_s)
        )
        c = _Canary(shadow, snap, op_i)
        c.primary_seal0 = primary.live.seal_build_model_s
        c.shadow_seal0 = shadow.live.seal_build_model_s
        return c

    def _repair_anchors(self, config: Dict[str, Any]) -> List[Dict[str, Any]]:
        """The incumbent plus breach-repair variants, re-measured first under
        the current window (they flow through ``retune``'s reanchor path).

        The variants are a DBA's playbook for recall breaches, not a search:
        open the bounded-consistency window fully (``graceful_time`` at its
        minimum — drifted queries hit the newest, unindexed inserts hardest)
        and widen the per-segment merge. BO still explores beyond them.
        """
        anchors = [dict(config)]
        space = getattr(self.session.tuner, "space", None)
        if space is None:
            return anchors
        by_name = {q.name: q for q in space.system_params}

        def bound(q, hi: bool = False):
            if q.kind in ("grid", "cat"):
                return q.choices[-1] if hi else q.choices[0]
            return q.high if hi else q.low

        g = by_name.get("graceful_time")
        if g is not None and "graceful_time" in config:
            full_vis = dict(config, graceful_time=bound(g))
            anchors.append(full_vis)
            w = by_name.get("topk_merge_width")
            if w is not None and "topk_merge_width" in config:
                anchors.append(dict(full_vis, topk_merge_width=bound(w, hi=True)))
        seen = set()
        out = []
        for a in anchors:
            key = self._canon(a)
            if key not in seen:
                seen.add(key)
                out.append(a)
        return out

    def _build_shadow(
        self, trace: WorkloadTrace, candidate: Dict[str, Any], primary: GidMappedVDMS
    ) -> GidMappedVDMS:
        vis = primary.visible_gids()
        shadow = GidMappedVDMS(
            candidate, trace.dim, trace.capacity,
            seed=self.seed + 1 + self.n_retunes,
            compact_threshold=self.compact_threshold,
        )
        if self._shadow_injector is not None:
            # armed before bootstrap so a scheduled shadow OOM can strike the
            # canary build itself (the injector persists across canaries)
            shadow.live.arm_faults(self._shadow_injector)
        shadow.bootstrap(trace.all_vectors()[vis], vis)
        return shadow

    @staticmethod
    def _canon(cfg: Dict[str, Any]) -> Tuple:
        return tuple(
            (k, round(v, 6) if isinstance(v, float) else v)
            for k, v in sorted(cfg.items())
        )
