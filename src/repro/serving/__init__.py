"""Autonomous serving control plane for a live VDMS deployment.

Three pieces close the loop between serving and tuning:

* :mod:`~repro.serving.metrics` — a Prometheus-style metrics ledger
  (counters / gauges / histograms with text exposition and JSON dumps) fed
  by the live engine's per-search instrumentation hooks.
* :mod:`~repro.serving.slo` — declarative SLO guardrails (recall floor, p99
  latency budget, memory cap) evaluated over sliding windows of live
  measurements.
* :mod:`~repro.serving.controller` — the :class:`ServingController` loop:
  SLO breaches and drift trigger a re-tune, candidates deploy as shadow
  instances with mirrored traffic, and promotion is decided on the
  SLO-constrained score — with checkpoint-exact session rollback for losing
  canaries.

See README "Serving control plane".
"""
from .controller import (
    ControllerParams,
    GidMappedVDMS,
    ServingController,
    mirror_count,
)
from .metrics import (
    DEFAULT_BUCKETS,
    UNIT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsLedger,
    attach_live,
    attach_sharded,
    attach_straggler,
    ledger_table,
    observe_sharded_stats,
    observe_stats,
    percentiles,
    serving_ledger,
)
from .slo import SLOMonitor, SLOSpec, SLOStatus

__all__ = [
    "Counter",
    "ControllerParams",
    "DEFAULT_BUCKETS",
    "Gauge",
    "GidMappedVDMS",
    "Histogram",
    "MetricsLedger",
    "SLOMonitor",
    "SLOSpec",
    "SLOStatus",
    "ServingController",
    "UNIT_BUCKETS",
    "attach_live",
    "attach_sharded",
    "attach_straggler",
    "ledger_table",
    "mirror_count",
    "observe_sharded_stats",
    "observe_stats",
    "percentiles",
    "serving_ledger",
]
