"""Prometheus-style metrics ledger for the serving control plane.

Three metric kinds, one registry:

* :class:`Counter` — monotonically increasing totals (queries served, retune /
  promote / rollback events);
* :class:`Gauge` — instantaneous values (QPS, memory, tombstone fraction,
  seal/compaction debt);
* :class:`Histogram` — bucketed distributions with an exact sliding-window
  reservoir, so the ledger can both export cumulative Prometheus buckets and
  answer live percentile queries (p50/p95/p99 query latency, recall probes).

:class:`MetricsLedger` owns the metrics and renders them two ways: the
Prometheus text exposition format (``to_text``) and a JSON dump
(``to_json`` / ``dump_json``) that CI uploads as the control-plane artifact.

The ledger is fed by the engine's instrumentation hooks: :func:`attach_live`
subscribes it to a ``LiveVDMS``'s per-search hook stream, and
:func:`observe_stats` syncs the lifecycle gauges from ``LiveVDMS.stats()``.
Nothing in ``repro.vdms`` imports this module — the dependency points one way.
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

#: Default latency-style histogram bounds (seconds), log-spaced.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
#: Fraction-valued histograms (recall probes) use linear bounds.
UNIT_BUCKETS: Tuple[float, ...] = tuple(round(0.05 * i, 2) for i in range(1, 21))


def _label_str(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Metric:
    """Shared name/help plumbing; subclasses define ``kind`` and rendering."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        if not name or not name.replace("_", "a").isalnum():
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help

    def exposition(self) -> List[str]:  # pragma: no cover - abstract
        raise NotImplementedError

    def to_dict(self) -> Dict[str, Any]:  # pragma: no cover - abstract
        raise NotImplementedError

    def _header(self) -> List[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        return lines


class Counter(Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += float(v)

    def exposition(self) -> List[str]:
        return self._header() + [f"{self.name} {self.value:g}"]

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "help": self.help, "value": float(self.value)}


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += float(v)

    def exposition(self) -> List[str]:
        return self._header() + [f"{self.name} {self.value:g}"]

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "help": self.help, "value": float(self.value)}


class Histogram(Metric):
    """Cumulative Prometheus buckets plus an exact sliding-window reservoir.

    Buckets/count/sum accumulate over the metric's lifetime (the exposition
    contract); ``percentile`` answers over the most recent ``window``
    observations — the sliding view SLO guardrails evaluate.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str = "",
        buckets: Sequence[float] = DEFAULT_BUCKETS,
        window: int = 4096,
    ):
        super().__init__(name, help)
        bounds = tuple(float(b) for b in buckets)
        if list(bounds) != sorted(set(bounds)):
            raise ValueError(f"histogram {name}: buckets must be strictly increasing")
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)  # +Inf bucket last
        self.count = 0
        self.sum = 0.0
        self.window: deque = deque(maxlen=int(window))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.window.append(v)
        # first bound >= v (linear scan is fine at these cardinalities)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def observe_many(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.observe(v)

    def percentile(self, q: float) -> float:
        """Exact percentile (``q`` in [0, 100]) over the sliding window;
        0.0 when nothing (finite) has been observed yet."""
        arr = np.asarray(self.window, np.float64)
        arr = arr[np.isfinite(arr)]
        if arr.size == 0:
            return 0.0
        return float(np.percentile(arr, q))

    @property
    def window_mean(self) -> float:
        return float(np.mean(self.window)) if self.window else 0.0

    def exposition(self) -> List[str]:
        lines = self._header()
        cum = 0
        for b, n in zip(self.bounds, self.bucket_counts[:-1]):
            cum += n
            lines.append(f'{self.name}_bucket{_label_str({"le": f"{b:g}"})} {cum}')
        lines.append(f'{self.name}_bucket{_label_str({"le": "+Inf"})} {self.count}')
        lines.append(f"{self.name}_sum {self.sum:g}")
        lines.append(f"{self.name}_count {self.count}")
        return lines

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "help": self.help,
            "count": int(self.count),
            "sum": float(self.sum),
            "buckets": {f"{b:g}": int(n) for b, n in zip(self.bounds, self.bucket_counts)},
            "inf": int(self.bucket_counts[-1]),
            "window_n": len(self.window),
            "percentiles": {
                "p50": self.percentile(50.0),
                "p95": self.percentile(95.0),
                "p99": self.percentile(99.0),
            },
        }


class MetricsLedger:
    """A named registry of counters/gauges/histograms with text + JSON export.

    The factory methods are get-or-create (re-registering a name returns the
    existing metric; a kind mismatch raises), so instrumentation sites can be
    written without caring who registered first.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    # --- registration --------------------------------------------------
    def _get_or_create(self, cls, name: str, *args, **kwargs) -> Any:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(f"metric {name!r} already registered as {m.kind}")
            return m
        m = cls(name, *args, **kwargs)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self,
        name: str,
        help: str = "",
        buckets: Sequence[float] = DEFAULT_BUCKETS,
        window: int = 4096,
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets, window)

    def get(self, name: str) -> Metric:
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return list(self._metrics)

    # --- export --------------------------------------------------------
    def to_text(self) -> str:
        """Prometheus text exposition (one scrape payload)."""
        lines: List[str] = []
        for m in self._metrics.values():
            lines.extend(m.exposition())
        return "\n".join(lines) + "\n"

    def to_json(self) -> Dict[str, Any]:
        out = {name: m.to_dict() for name, m in self._metrics.items()}
        # strict-JSON guard: no NaN/Inf leaks into CI artifacts
        def clean(v):
            if isinstance(v, float) and not math.isfinite(v):
                return None
            return v
        return json.loads(json.dumps(out, default=clean))

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


# ---------------------------------------------------------------------------
# the serving instrument set
# ---------------------------------------------------------------------------
def serving_ledger() -> MetricsLedger:
    """A ledger pre-registered with the control plane's standard metrics."""
    led = MetricsLedger()
    led.counter("vdms_queries_total", "Queries served by the live instance")
    led.histogram("vdms_query_latency_seconds", "Per-query wall latency")
    led.gauge("vdms_qps", "Throughput over the last search micro-batch")
    led.histogram("vdms_recall_probe", "Windowed recall probes vs oracle", buckets=UNIT_BUCKETS)
    led.gauge("vdms_mem_gib", "Live instance memory footprint (GiB)")
    led.gauge("vdms_tombstone_fraction", "Dead fraction of inserted vectors")
    led.gauge("vdms_tail_size", "Unsealed growing-tail length")
    led.gauge("vdms_sealed_segments", "Sealed segment count")
    led.gauge("vdms_seal_debt_seconds", "Accumulated seal+compaction build seconds (analytic)")
    led.counter("vdms_seals_total", "Segment seal events")
    led.counter("vdms_compactions_total", "Segment compaction events")
    led.counter("vdms_slo_breach_total", "SLO guardrail breach events")
    led.counter("vdms_retune_total", "Re-tune triggers (drift or SLO breach)")
    led.counter("vdms_promote_total", "Canary promotions (shadow replaced primary)")
    led.counter("vdms_rollback_total", "Canary rollbacks (checkpoint-exact)")
    led.counter("vdms_shadow_build_seconds_total", "Analytic build cost charged for shadow instances")
    # fault-injection / degraded-mode instruments (all stay zero fault-free)
    led.counter("vdms_fault_injected_total", "Faults applied by the armed FaultPlan")
    led.counter("vdms_quarantine_total", "Sealed segments quarantined (loss/corruption)")
    led.counter("vdms_rebuild_total", "Quarantined segments rebuilt from the vector store")
    led.counter("vdms_rebuild_failure_total", "Quarantine rebuilds whose retry budget exhausted")
    led.counter("vdms_seal_retry_total", "Crashed incremental builds retried with backoff")
    led.counter("vdms_canary_fault_abort_total", "Canaries aborted because a fault struck mid-mirror")
    led.gauge("vdms_coverage", "Visible fraction served by the last search (1.0 = full)")
    led.gauge("vdms_quarantined_segments", "Segments currently quarantined")
    led.gauge("vdms_health_state", "Engine health: 0=healthy 1=rebuilding 2=degraded")
    led.gauge("vdms_straggler_flagged", "Straggler-flagged search calls (StragglerMonitor)")
    # sharded multi-device serving instruments (1-shard defaults fault-free)
    led.gauge("vdms_shards", "Shard count of the serving mesh (1 = unsharded)")
    led.gauge("vdms_shard_skew", "Max/mean sealed-segment imbalance across populated shards")
    led.gauge("vdms_shard_min_coverage", "Smallest per-shard alive fraction")
    return led


def ledger_table() -> str:
    """Markdown table of the standard serving-ledger metrics — the generated
    block the README embeds (doc-sync-tested, like the kernel table)."""
    led = serving_ledger()
    lines = ["| metric | kind | description |", "| --- | --- | --- |"]
    for name in led.names():
        m = led.get(name)
        lines.append(f"| `{name}` | {m.kind} | {m.help} |")
    return "\n".join(lines)


def attach_live(ledger: MetricsLedger, live) -> None:
    """Subscribe the ledger to a ``LiveVDMS``'s per-search hook stream:
    every search feeds the query counter, the latency histogram, and the
    instantaneous-QPS gauge."""
    queries = ledger.counter("vdms_queries_total")
    lat = ledger.histogram("vdms_query_latency_seconds")
    qps = ledger.gauge("vdms_qps")

    def hook(nq: int, latencies: np.ndarray, elapsed: float) -> None:
        queries.inc(nq)
        lat.observe_many(np.asarray(latencies, np.float64).tolist())
        qps.set(nq / max(elapsed, 1e-12))

    live.search_hooks.append(hook)


def observe_stats(ledger: MetricsLedger, stats: Dict[str, float]) -> None:
    """Sync the lifecycle gauges/counters from one ``LiveVDMS.stats()``
    snapshot (counters advance by the delta vs their current value, so
    repeated syncs are idempotent)."""
    ledger.gauge("vdms_mem_gib").set(stats["mem_gib"])
    ledger.gauge("vdms_tombstone_fraction").set(stats["tombstone_fraction"])
    ledger.gauge("vdms_tail_size").set(stats["tail_size"])
    ledger.gauge("vdms_sealed_segments").set(stats["n_sealed"])
    ledger.gauge("vdms_seal_debt_seconds").set(
        stats["seal_build_model_s"] + stats["bootstrap_build_model_s"]
    )
    # fault/degraded-mode gauges: .get-guarded so snapshots from engines
    # predating the fault layer still sync cleanly
    ledger.gauge("vdms_coverage").set(float(stats.get("coverage", 1.0)))
    ledger.gauge("vdms_quarantined_segments").set(float(stats.get("quarantined_segments", 0)))
    ledger.gauge("vdms_health_state").set(float(stats.get("health_code", 0)))
    for counter_name, key in (
        ("vdms_seals_total", "n_seals"),
        ("vdms_compactions_total", "n_compactions"),
        ("vdms_fault_injected_total", "n_faults_injected"),
        ("vdms_quarantine_total", "n_quarantines"),
        ("vdms_rebuild_total", "n_rebuilds"),
        ("vdms_rebuild_failure_total", "n_rebuild_failures"),
        ("vdms_seal_retry_total", "n_seal_retries"),
    ):
        c = ledger.counter(counter_name)
        delta = float(stats.get(key, 0.0)) - c.value
        if delta > 0:
            c.inc(delta)


def attach_sharded(ledger: MetricsLedger, sharded) -> None:
    """Wire a :class:`~repro.vdms.sharded.ShardedVDMS` into the ledger:
    the search-hook stream feeds the same query/latency/QPS instruments as
    :func:`attach_live`, and :func:`observe_sharded_stats` syncs the shard
    gauges — ``ShardedVDMS`` exposes the identical hook contract, so this is
    ``attach_live`` plus one initial gauge sync."""
    attach_live(ledger, sharded)
    observe_sharded_stats(ledger, sharded.stats())


def observe_sharded_stats(ledger: MetricsLedger, stats: Dict[str, Any]) -> None:
    """Sync the shard placement/coverage gauges from one
    ``ShardedVDMS.stats()`` snapshot."""
    ledger.gauge("vdms_shards").set(float(stats.get("n_shards", 1)))
    ledger.gauge("vdms_shard_skew").set(float(stats.get("shard_skew", 0.0)))
    ledger.gauge("vdms_shard_min_coverage").set(
        float(stats.get("min_shard_coverage", 0.0))
    )
    ledger.gauge("vdms_mem_gib").set(float(stats.get("mem_gib", 0.0)))
    ledger.gauge("vdms_coverage").set(float(stats.get("coverage", 1.0)))


def attach_straggler(ledger: MetricsLedger, live, monitor=None):
    """Wire the fault-tolerance :class:`~repro.ft.monitor.StragglerMonitor`
    into the serving latency path: every search call's elapsed time is a
    "step" the monitor judges against its trailing median, and the flagged
    count is exported as the ``vdms_straggler_flagged`` gauge. Returns the
    monitor (created with serving-friendly defaults when not given) so the
    controller can poll ``should_replace``."""
    from ..ft.monitor import StragglerMonitor

    if monitor is None:
        monitor = StragglerMonitor(window=32, threshold=3.0, patience=4)
    flagged = ledger.gauge("vdms_straggler_flagged")

    def hook(nq: int, latencies: np.ndarray, elapsed: float) -> None:
        monitor.record(len(monitor.history), float(elapsed))
        flagged.set(float(sum(1 for s in monitor.history if s.flagged)))

    live.search_hooks.append(hook)
    return monitor


def percentiles(values: Sequence[float], qs: Sequence[float] = (50.0, 95.0, 99.0)) -> Dict[str, float]:
    """Tiny convenience: ``{"p50": ..., ...}`` over ``values`` (0.0 if empty)."""
    arr = np.asarray(values, np.float64)
    if arr.size == 0:
        return {f"p{q:g}": 0.0 for q in qs}
    return {f"p{q:g}": float(np.percentile(arr, q)) for q in qs}
