"""Budget allocation among index types: scoring + successive abandon
(paper §IV-D, Eq. 5–6, windowed trigger).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .hypervolume import hv_2d
from .normalize import balanced_base
from .pareto import non_dominated_mask


def scores_by_hv_influence(
    Y: np.ndarray, types: np.ndarray, remaining: Sequence[str]
) -> Dict[str, float]:
    """Eq. 6: Score(t) = max_t' HV(r, Y/Y_t') - HV(r, Y/Y_t).

    Y are *raw* observations of all types; the non-dominated subset and the
    reference point r = 0.5 * ȳ (ȳ per Eq. 3 computed over the whole
    non-dominated set) follow the paper. Higher score = bigger marginal
    hypervolume contribution.
    """
    Y = np.asarray(Y, np.float64)
    types = np.asarray(types)
    # scale-normalize per objective so the HV is not dominated by the axis
    # with the larger dynamic range (QPS ~1e3 vs recall <=1); Eq. 3's balance
    # criterion is scale-aware in the same way.
    ymax = Y.max(axis=0)
    ymax = np.where(ymax <= 0, 1.0, ymax)
    Y = Y / ymax[None, :]
    nd_mask = non_dominated_mask(Y)
    nd_Y = Y[nd_mask]
    nd_types = types[nd_mask]
    ybar = balanced_base(nd_Y)
    r = 0.5 * ybar

    hv_without: Dict[str, float] = {}
    for t in remaining:
        rest = nd_Y[nd_types != t]
        hv_without[t] = hv_2d(rest, r) if rest.size else 0.0
    mx = max(hv_without.values()) if hv_without else 0.0
    return {t: mx - hv_without[t] for t in remaining}


class SuccessiveAbandon:
    """Windowed abandon trigger: if one index type ranks worst for `window`
    consecutive scoring rounds, drop it (never below one remaining type).
    """

    def __init__(self, types: Sequence[str], window: int = 10):
        self.remaining: List[str] = list(types)
        self.window = window
        self._worst_history: List[str] = []
        self.abandoned: List[str] = []
        self.score_log: List[Dict[str, float]] = []

    # --- checkpointing (JSON-compatible) --------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "remaining": list(self.remaining),
            "worst_history": list(self._worst_history),
            "abandoned": list(self.abandoned),
            "score_log": [dict(s) for s in self.score_log],
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "SuccessiveAbandon":
        self.remaining = list(state["remaining"])
        self._worst_history = list(state["worst_history"])
        self.abandoned = list(state["abandoned"])
        self.score_log = [{k: float(v) for k, v in s.items()} for s in state["score_log"]]
        return self

    def step(self, Y: np.ndarray, types: np.ndarray) -> Optional[str]:
        """Score remaining types on the observations so far; abandon and return
        the consistently-worst type if the windowed trigger fires, else None."""
        if len(self.remaining) <= 1:
            return None
        scores = scores_by_hv_influence(Y, types, self.remaining)
        self.score_log.append(dict(scores))
        worst = min(self.remaining, key=lambda t: scores[t])
        self._worst_history.append(worst)
        recent = self._worst_history[-self.window :]
        if len(recent) == self.window and all(w == worst for w in recent):
            self.remaining.remove(worst)
            self.abandoned.append(worst)
            self._worst_history.clear()
            return worst
        return None
