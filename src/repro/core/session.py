"""`TuningSession`: one driver for every tuner.

The session owns everything the ask/tell recommenders do not: evaluation
dispatch (sequential, vectorized ``evaluate_batch``, or a pluggable
executor), the worst-value failure feedback path, stop conditions, the
recommend/eval time ledger, callbacks, and serializable checkpoints.

Lifecycle::

        ┌──────────────── TuningSession.run(n) ────────────────┐
        │                                                      │
        │   cfgs = tuner.ask(remaining)      # pure recommender │
        │   results = executor(backend, cfgs)  # EvalBackend    │
        │   for cfg, result in zip(cfgs, results):              │
        │       tuner.tell(cfg, result)      # + ledger, cbs    │
        │                                                      │
        └── until budget met / tuner exhausted / StopSession ──┘

Checkpointing: ``session.state_dict()`` captures the tuner state (history,
RNG, polling/abandon state, §IV-F bootstrap observations, and — for
warm-started tuners — the previous GP fit's hyperparameters, so resumed
warm refits are bit-identical) plus the session's own in-flight state —
configurations that were asked but not yet told — as a JSON-compatible
dict. ``TuningSession.restore(state, tuner)`` resumes
bit-identically: the pending queue is re-evaluated first (deterministic
backends, e.g. the cached ``VDMSTuningEnv``, reproduce the same results),
then recommendation continues from the exact saved RNG state.
"""
from __future__ import annotations

import copy
import dataclasses
import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from .objectives import EvalBackend, TuningFailure
from .space import Config
from .tuner import Observation, TunerBase

STATE_VERSION = 1
LEDGER_SCHEMA = 1

Callback = Callable[["TuningSession", Observation], None]


class StopSession(Exception):
    """Raised from a callback (or executor) to stop the session cleanly.

    The session stays consistent: every already-told observation is kept and
    the not-yet-told remainder of the current round survives in the pending
    queue, so ``state_dict()`` right after the stop checkpoints mid-round.
    """


# ---------------------------------------------------------------------------
# Workload drift detection (the re-tune trigger)
# ---------------------------------------------------------------------------
class DriftDetector:
    """Detects workload drift from repeated probes of a fixed configuration.

    The deployed incumbent is periodically re-measured through the backend
    (:meth:`TuningSession.probe_drift`); the first ``warmup`` probes after a
    (re)set establish the per-metric reference, and a later probe *fires*
    when any watched metric deviates from its reference by more than
    ``rel_threshold`` relative — the signal that the optimum may have moved
    and the session should re-enter BO (:meth:`TuningSession.retune`).

    State is JSON-compatible (``state_dict``/``load_state_dict``) so drift
    tracking can ride in session checkpoints.
    """

    def __init__(
        self,
        metrics: Sequence[str] = ("speed", "recall"),
        rel_threshold: float = 0.2,
        warmup: int = 1,
    ):
        if rel_threshold <= 0:
            raise ValueError(f"rel_threshold must be > 0, got {rel_threshold}")
        if warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {warmup}")
        self.metrics = tuple(metrics)
        self.rel_threshold = float(rel_threshold)
        self.warmup = int(warmup)
        self.reference: Optional[Dict[str, float]] = None
        self._ref_buf: List[Dict[str, float]] = []
        self.n_fired = 0
        self.log: List[Dict[str, Any]] = []

    def observe(self, raw: Dict[str, float]) -> bool:
        """Feed one probe measurement; returns True when drift fired."""
        vals = {m: float(raw[m]) for m in self.metrics}
        if self.reference is None:
            self._ref_buf.append(vals)
            if len(self._ref_buf) >= self.warmup:
                self.reference = {
                    m: sum(v[m] for v in self._ref_buf) / len(self._ref_buf)
                    for m in self.metrics
                }
                self._ref_buf = []
            self.log.append({"metrics": vals, "rel": 0.0, "fired": False})
            return False
        rel = max(
            abs(vals[m] - self.reference[m]) / max(abs(self.reference[m]), 1e-12)
            for m in self.metrics
        )
        fired = rel > self.rel_threshold
        if fired:
            self.n_fired += 1
        self.log.append({"metrics": vals, "rel": float(rel), "fired": bool(fired)})
        return fired

    def reset(self) -> None:
        """Restart reference collection (call after re-tuning re-deploys)."""
        self.reference = None
        self._ref_buf = []

    # --- checkpointing (JSON-compatible) --------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "metrics": list(self.metrics),
            "rel_threshold": self.rel_threshold,
            "warmup": self.warmup,
            "reference": dict(self.reference) if self.reference is not None else None,
            "ref_buf": [dict(v) for v in self._ref_buf],
            "n_fired": self.n_fired,
            "log": copy.deepcopy(self.log),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "DriftDetector":
        self.metrics = tuple(state["metrics"])
        self.rel_threshold = float(state["rel_threshold"])
        self.warmup = int(state["warmup"])
        ref = state.get("reference")
        self.reference = {k: float(v) for k, v in ref.items()} if ref is not None else None
        self._ref_buf = [dict(v) for v in state.get("ref_buf", [])]
        self.n_fired = int(state.get("n_fired", 0))
        self.log = copy.deepcopy(state.get("log", []))
        return self


# ---------------------------------------------------------------------------
# Transient-failure retry policy (the honest failure taxonomy's session half)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How a session treats *transient* :class:`TuningFailure`s.

    A transient failure (environment fault — lost segment, flaky build,
    injected chaos — not the configuration's doing) is retried up to
    ``max_retries`` times with exponential backoff before falling through to
    the tuner's worst-value failure feedback; a retried-and-recovered
    evaluation is told as a *normal* observation with the wasted attempts'
    wall time charged to its build seconds, so the GP never learns from
    faults it cannot control. ``eval_timeout_s`` bounds each evaluation's
    wall clock (a timeout is itself a transient failure).
    """

    max_retries: int = 2
    backoff_s: float = 0.25  # first retry delay (seconds); 0 disables sleeping
    backoff_factor: float = 2.0
    eval_timeout_s: Optional[float] = None

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0 or self.backoff_factor < 1.0:
            raise ValueError("need backoff_s >= 0 and backoff_factor >= 1")
        if self.eval_timeout_s is not None and self.eval_timeout_s <= 0:
            raise ValueError(f"eval_timeout_s must be > 0, got {self.eval_timeout_s}")

    def backoff(self, attempt: int) -> float:
        """Delay before retry number ``attempt`` (1-based)."""
        return self.backoff_s * self.backoff_factor ** max(attempt - 1, 0)


class _TimeoutBackend:
    """Per-evaluation wall-clock timeout wrapper around an EvalBackend.

    Deliberately does NOT expose ``evaluate_batch``: a vectorized batch
    cannot be timed out per config, so batch executors fall back to their
    sequential path through this proxy. On timeout the worker thread is
    abandoned (``shutdown(wait=False)``) rather than joined — the stuck
    evaluation keeps running to completion in the background, but the
    session moves on with a *transient* :class:`TuningFailure`.
    """

    def __init__(self, backend: EvalBackend, timeout_s: float):
        self._backend = backend
        self._timeout_s = float(timeout_s)

    def __call__(self, cfg: Config) -> Any:
        ex = ThreadPoolExecutor(max_workers=1)
        fut = ex.submit(self._backend, cfg)
        try:
            return fut.result(timeout=self._timeout_s)
        except FuturesTimeout:
            raise TuningFailure(
                f"evaluation timed out after {self._timeout_s:.3g}s", transient=True
            ) from None
        finally:
            ex.shutdown(wait=False)


# ---------------------------------------------------------------------------
# Evaluation executors
# ---------------------------------------------------------------------------
class SequentialExecutor:
    """Evaluate one config at a time through ``backend(cfg)`` — results are
    yielded as they land, so observations are told (and checkpointable)
    between evaluations."""

    name = "sequential"

    def execute(self, backend: EvalBackend, cfgs: Sequence[Config]) -> Iterator[Tuple[Any, float]]:
        for cfg in cfgs:
            t0 = time.perf_counter()
            try:
                result: Any = backend(cfg)
            except TuningFailure as e:
                result = e
            yield result, time.perf_counter() - t0


class BatchExecutor:
    """Vectorized dispatch through the backend's ``evaluate_batch``.

    Mirrors the pre-redesign batch path exactly: single-config rounds and
    backends without ``evaluate_batch`` fall back to sequential evaluation;
    batch eval time is amortized per config.
    """

    name = "batch"

    def execute(self, backend: EvalBackend, cfgs: Sequence[Config]) -> Iterator[Tuple[Any, float]]:
        eb = getattr(backend, "evaluate_batch", None)
        if eb is None or len(cfgs) == 1:
            yield from SequentialExecutor().execute(backend, cfgs)
            return
        t0 = time.perf_counter()
        results = eb(list(cfgs))
        per_cfg = (time.perf_counter() - t0) / max(len(cfgs), 1)
        for result in results:
            yield result, per_cfg


class ThreadedExecutor:
    """Concurrent per-config evaluation in a thread pool, yielded in config
    order — for backends whose evaluations are independent and release the
    GIL (network-attached VDMS replicas, subprocess benchmarks)."""

    name = "threaded"

    def __init__(self, max_workers: Optional[int] = None):
        self.max_workers = max_workers

    def execute(self, backend: EvalBackend, cfgs: Sequence[Config]) -> Iterator[Tuple[Any, float]]:
        def one(cfg: Config) -> Tuple[Any, float]:
            t0 = time.perf_counter()
            try:
                result: Any = backend(cfg)
            except TuningFailure as e:
                result = e
            return result, time.perf_counter() - t0

        workers = self.max_workers or min(max(len(cfgs), 1), os.cpu_count() or 4)
        if len(cfgs) <= 1 or workers == 1:
            yield from (one(c) for c in cfgs)
            return
        with ThreadPoolExecutor(max_workers=workers) as ex:
            yield from ex.map(one, cfgs)


_EXECUTORS = {
    "sequential": SequentialExecutor,
    "batch": BatchExecutor,
    "auto": BatchExecutor,  # batch when available, sequential otherwise
    "threaded": ThreadedExecutor,
}

ExecutorLike = Union[str, None, SequentialExecutor, BatchExecutor, ThreadedExecutor, Any]


def resolve_executor(executor: ExecutorLike, tuner: TunerBase):
    if executor is None:
        executor = tuner.preferred_executor()
    if isinstance(executor, str):
        try:
            return _EXECUTORS[executor]()
        except KeyError:
            raise ValueError(
                f"unknown executor {executor!r}; choose from {sorted(_EXECUTORS)} "
                "or pass an object with .execute(backend, cfgs)"
            ) from None
    if not hasattr(executor, "execute"):
        raise TypeError(f"executor must expose .execute(backend, cfgs), got {executor!r}")
    return executor


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------
class TuningSession:
    """Drives one tuner against one evaluation backend.

    Parameters
    ----------
    tuner:
        Any ask/tell recommender (``VDTuner`` or a baseline).
    backend:
        The evaluation service (``EvalBackend``). Defaults to the tuner's
        own ``objective`` for the legacy construction style.
    executor:
        ``"sequential"`` | ``"batch"`` | ``"auto"`` | ``"threaded"``, an
        object with ``.execute(backend, cfgs)``, or ``None`` to use the
        tuner's ``preferred_executor()`` (which reproduces pre-redesign
        dispatch exactly).
    callbacks:
        Callables ``cb(session, observation)`` invoked after every told
        observation — checkpoint hooks, progress bars, early stopping (raise
        :class:`StopSession`).
    retry:
        Optional :class:`RetryPolicy`. When set, *transient* failures are
        retried with backoff (and each evaluation is wall-clock bounded by
        ``eval_timeout_s``) before any worst-value feedback reaches the
        tuner. ``None`` (default) reproduces pre-policy behavior exactly.
    """

    def __init__(
        self,
        tuner: TunerBase,
        backend: Optional[EvalBackend] = None,
        executor: ExecutorLike = None,
        callbacks: Sequence[Callback] = (),
        retry: Optional[RetryPolicy] = None,
    ):
        self.tuner = tuner
        self.backend = backend if backend is not None else tuner.objective
        if self.backend is None:
            raise ValueError("no evaluation backend: pass backend= or construct the tuner with an objective")
        self.executor = resolve_executor(executor, tuner)
        self.callbacks: List[Callback] = list(callbacks)
        self.retry = retry
        self.rounds: List[Dict[str, Any]] = []
        self._pending: List[Config] = []
        self._pending_recommend_s = 0.0
        # per-config transient-retry bookkeeping, keyed by canonical config
        # JSON: {"attempts", "wasted_s", "backoff_s"} — JSON-compatible so it
        # checkpoints (a resume mid-retry continues the backoff schedule)
        self._retry_state: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    # progress views
    # ------------------------------------------------------------------
    @property
    def history(self) -> List[Observation]:
        return self.tuner.history

    @property
    def n_observations(self) -> int:
        """Fresh (non-bootstrap) observations — the budget currency."""
        return sum(1 for o in self.tuner.history if not o.bootstrap)

    @property
    def pending(self) -> List[Config]:
        """Asked-but-not-yet-told configurations (read-only copy)."""
        return [dict(c) for c in self._pending]

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def run(
        self,
        n_iters: int,
        max_wall_s: Optional[float] = None,
        stop: Optional[Callable[["TuningSession"], bool]] = None,
    ) -> "TuningSession":
        """Run until ``n_iters`` fresh observations (counting any restored
        ones), the wall-clock budget, a ``stop`` predicate, tuner exhaustion
        (empty ask), or a :class:`StopSession` from a callback.

        A round already in flight is always drained before stop conditions
        are re-checked, so a mandatory warm-up batch may overshoot the budget
        — exactly like the pre-redesign tuner loops.
        """
        t_start = time.perf_counter()
        try:
            while True:
                if self._pending:
                    self._drain()
                    continue
                if self.n_observations >= n_iters:
                    break
                if max_wall_s is not None and time.perf_counter() - t_start >= max_wall_s:
                    break
                if stop is not None and stop(self):
                    break
                t0 = time.perf_counter()
                cfgs = list(self.tuner.ask(n_iters - self.n_observations))
                ask_s = time.perf_counter() - t0
                if not cfgs:
                    break  # recommender exhausted (e.g. DefaultOnly)
                self._pending = cfgs
                self._pending_recommend_s = ask_s / len(cfgs)
                self.rounds.append(
                    {"round": len(self.rounds), "n_asked": len(cfgs), "ask_s": ask_s, "evals": []}
                )
        except StopSession:
            pass
        return self

    def _drain(self) -> None:
        """Evaluate the pending queue, telling each result as it lands.

        ``_pending`` is popped before callbacks fire, so a checkpoint taken
        from a callback (or after a :class:`StopSession`) holds exactly the
        not-yet-told remainder.
        """
        cfgs = list(self._pending)
        backend = self.backend
        if self.retry is not None:
            self._sleep_backoff(cfgs[0])
            if self.retry.eval_timeout_s is not None:
                backend = _TimeoutBackend(self.backend, self.retry.eval_timeout_s)
        for result, eval_s in self.executor.execute(backend, cfgs):
            cfg = self._pending[0]
            retries = 0
            if self.retry is not None:
                if self._note_transient(cfg, result, eval_s):
                    # the config stays at the head of the pending queue; the
                    # run() loop re-enters _drain, which sleeps the backoff
                    # and re-evaluates — the tuner never hears about it
                    return
                retries, result, eval_s = self._charge_retries(cfg, result, eval_s)
            obs = self.tuner.tell(
                cfg, result, recommend_time=self._pending_recommend_s, eval_time=eval_s
            )
            self._pending.pop(0)
            self._ledger_obs(obs, eval_s, retries)
            for cb in self.callbacks:
                cb(self, obs)

    # --- transient-retry plumbing (no-ops unless a RetryPolicy is set) ---
    @staticmethod
    def _cfg_key(cfg: Config) -> str:
        return json.dumps(cfg, sort_keys=True, default=repr)

    def _sleep_backoff(self, cfg: Config) -> None:
        st = self._retry_state.get(self._cfg_key(cfg))
        if st is not None and st.get("backoff_s", 0.0) > 0.0:
            time.sleep(st["backoff_s"])
            st["backoff_s"] = 0.0  # consumed; re-set if the retry fails again

    def _note_transient(self, cfg: Config, result: Any, eval_s: float) -> bool:
        """Record a transient failure; True = retry (leave cfg pending)."""
        if not (isinstance(result, TuningFailure) and getattr(result, "transient", False)):
            return False
        key = self._cfg_key(cfg)
        st = self._retry_state.setdefault(
            key, {"attempts": 0, "wasted_s": 0.0, "backoff_s": 0.0}
        )
        if st["attempts"] >= self.retry.max_retries:
            return False  # budget exhausted: fall through to failure feedback
        st["attempts"] += 1
        st["wasted_s"] += float(eval_s)
        st["backoff_s"] = self.retry.backoff(int(st["attempts"]))
        return True

    def _charge_retries(self, cfg: Config, result: Any, eval_s: float):
        """Fold a config's retry history into its final result: wasted wall
        time is charged to build seconds (the honest place — retries re-build
        the instance), and the eval time the ledger sees includes it."""
        st = self._retry_state.pop(self._cfg_key(cfg), None)
        if st is None:
            return 0, result, eval_s
        wasted = float(st["wasted_s"])
        eval_s = float(eval_s) + wasted
        if isinstance(result, dict):
            result = dict(result)
            if "seal_build_s" in result:
                result["seal_build_s"] = float(result["seal_build_s"]) + wasted
            elif "build_time" in result:
                result["build_time"] = float(result["build_time"]) + wasted
        return int(st["attempts"]), result, eval_s

    def _ledger_obs(self, obs: Observation, eval_s: float, retries: int = 0) -> None:
        if not self.rounds:  # restored mid-round: ledger continues in a fresh row
            self.rounds.append({"round": 0, "n_asked": 0, "ask_s": 0.0, "evals": []})
        row = {
            "iteration": int(obs.iteration),
            "recommend_s": float(obs.recommend_time),
            "eval_s": float(eval_s),
            "failed": bool(obs.failed),
        }
        if retries:  # only recovered-after-retry rows carry the key, so
            row["retries"] = int(retries)  # no-retry ledgers stay byte-identical
        self.rounds[-1]["evals"].append(row)

    # ------------------------------------------------------------------
    # external observations & fleet delegation
    # ------------------------------------------------------------------
    def tell(
        self,
        config: Config,
        result: Any,
        eval_time: float = 0.0,
        recommend_time: float = 0.0,
        bootstrap: bool = False,
        noise_scale: float = 1.0,
    ) -> Observation:
        """Feed one externally-measured result into the tuner.

        This is the entry point for observations the session did not itself
        dispatch: live canary measurements from the serving control plane,
        or another tenant's ledger rows during fleet transfer. The
        observation lands in the tuner history (feeding the GP, fronts, and
        abandon bookkeeping) but NOT in the recommend/eval ledger — it is
        deployment/transfer feedback, not a budgeted BO evaluation.
        ``bootstrap=True`` additionally keeps it out of the fresh-observation
        budget count; ``noise_scale > 1`` down-weights it in the GP fit.
        """
        obs = self.tuner.tell(
            dict(config), result, recommend_time=recommend_time, eval_time=eval_time
        )
        if bootstrap:
            obs.bootstrap = True
        if noise_scale != 1.0:
            obs.noise_scale = float(noise_scale)
        return obs

    def import_observations(
        self,
        observations: Sequence[Union[Observation, Dict[str, Any]]],
        noise_scale: float = 1.0,
        space_signature: Optional[str] = None,
    ) -> int:
        """Seed the tuner with observations from another session's ledger.

        Each observation is appended as a §IV-F-style *bootstrap* entry: it
        feeds the GP (marking its index type "seen", so warm-started tenants
        skip the mandatory per-type default evaluations) and the Pareto
        front, but never counts against the fresh-observation budget.
        Objective values are recomputed from ``raw`` through this tuner's
        own transform so imports land in local objective units; failed
        source rows are skipped. ``noise_scale`` (> 1 for cross-tenant
        imports) rides on each row into the GP's per-row noise hook.

        ``space_signature`` — the source space's ``encoding_signature()`` —
        guards the registry's uniform encoding: imports are refused unless
        it matches this tuner's space, since encoded rows would otherwise
        decode to different configurations.
        """
        if space_signature is not None:
            own = self.tuner.space.encoding_signature()
            if space_signature != own:
                raise ValueError(
                    f"cannot import observations: source space signature "
                    f"{space_signature!r} != target {own!r}"
                )
        n_imported = 0
        for o in observations:
            if isinstance(o, dict):
                o = Observation.from_dict(o)
            if o.failed:
                continue
            raw = dict(o.raw)
            try:
                y = np.asarray(self.tuner.transform(raw), np.float64) if raw else None
            except Exception:
                continue  # raw lacks what the local objective needs
            if y is None or not np.all(np.isfinite(y)):
                continue
            self.tuner.history.append(
                Observation(
                    iteration=len(self.tuner.history),
                    config=dict(o.config),
                    y=y,
                    raw=raw,
                    recommend_time=0.0,
                    eval_time=0.0,
                    failed=False,
                    bootstrap=True,
                    noise_scale=float(noise_scale),
                )
            )
            n_imported += 1
        return n_imported

    def run_round(self, n: int = 1) -> List[Observation]:
        """Run exactly one ask round (draining any restored pending queue
        first) and return the observations it produced.

        This is the fleet scheduler's unit of budget delegation: the
        ``FleetSession`` calls ``run_round`` on whichever tenant it picked,
        charges the returned observations' evaluation cost to the shared
        budget, and re-decides. ``n`` caps the batch request passed to
        ``ask`` (warm-up batches may exceed it, exactly as in ``run``).
        """
        start = len(self.tuner.history)
        try:
            if not self._pending:
                t0 = time.perf_counter()
                cfgs = list(self.tuner.ask(max(int(n), 1)))
                ask_s = time.perf_counter() - t0
                if not cfgs:
                    return []
                self._pending = cfgs
                self._pending_recommend_s = ask_s / len(cfgs)
                self.rounds.append(
                    {"round": len(self.rounds), "n_asked": len(cfgs), "ask_s": ask_s, "evals": []}
                )
            while self._pending:
                self._drain()
        except StopSession:
            pass
        return list(self.tuner.history[start:])

    # ------------------------------------------------------------------
    # drift tracking (moving-optimum workloads)
    # ------------------------------------------------------------------
    def probe_drift(
        self,
        detector: DriftDetector,
        config: Config,
        raw: Optional[Dict[str, float]] = None,
    ) -> bool:
        """Re-measure the deployed ``config`` through the backend and feed
        the drift detector. Probes live outside the tuning budget and the
        recommend/eval ledger — they are deployment monitoring, not BO
        iterations. An incumbent that now *fails* outright counts as drift.

        With ``raw`` given the backend is not called: the supplied
        measurement (e.g. the serving control plane's windowed live metrics)
        is judged directly, so probes can come from real traffic instead of
        a synthetic re-evaluation.
        """
        if raw is None:
            try:
                raw = self.backend(config)
            except TuningFailure:
                detector.n_fired += 1
                # finite sentinel keeps detector state/artifacts strict-JSON safe
                detector.log.append({"metrics": {}, "rel": 1e9, "fired": True, "failed": True})
                return True
        return detector.observe(raw)

    def retune(
        self,
        n_iters: int = 0,
        reanchor: Sequence[Config] = (),
        keep_stale: bool = False,
    ) -> int:
        """Re-enter BO after workload drift, warm-started where the knowledge
        still transfers.

        By default the stale observations are *dropped*: their measured
        objective values no longer describe the workload, and keeping them
        would wedge unreachable pre-drift points into the surrogate's front
        and its NPI normalization. What carries over is exactly what remains
        valid: the warm-started GP *hyperparameters* (``warm_start=True``
        tuners resume from the previous fit), while successive-abandon state
        resets so index types abandoned under the old workload get
        reconsidered. ``reanchor`` configs — typically the deployed Pareto
        set — are re-measured first under the current workload as the fresh
        foundation (they count as fresh observations and flow through the
        executor/ledger like any round). The evaluation backend decides what
        re-measurement means (the streaming ``VDMSTuningEnv`` keys its cache
        by phase, so configurations are genuinely re-evaluated after the
        workload moved).

        ``keep_stale=True`` instead demotes old observations to §IV-F-style
        bootstrap entries (they keep feeding the GP and keep every index
        type "seen" but stop counting against the budget) — the right mode
        when the objective *scale* is expected to survive the drift.

        Returns the number of stale observations handled; with
        ``n_iters > 0`` immediately runs until that many fresh evaluations
        (re-anchors included) have landed.
        """
        stale = sum(1 for o in self.tuner.history if not o.bootstrap)
        if keep_stale:
            for obs in self.tuner.history:
                obs.bootstrap = True
        else:
            self.tuner.history = []
        self._pending = []
        self._pending_recommend_s = 0.0
        self._retry_state = {}
        abandon = getattr(self.tuner, "abandon", None)
        if abandon is not None:
            self.tuner.abandon = type(abandon)(
                self.tuner.space.type_names, window=abandon.window
            )
        if reanchor:
            self._pending = [dict(c) for c in reanchor]
            self._pending_recommend_s = 0.0
            # a fresh ledger round: re-anchor evals are post-drift work
            self.rounds.append(
                {"round": len(self.rounds), "n_asked": len(self._pending), "ask_s": 0.0, "evals": []}
            )
            self._drain()
        if n_iters:
            self.run(n_iters)
        return stale

    # ------------------------------------------------------------------
    # ledger
    # ------------------------------------------------------------------
    def ledger_dict(self) -> Dict[str, Any]:
        """The recommend/eval time ledger with a stable schema (BENCH json
        ``session`` block)."""
        evals = [e for r in self.rounds for e in r["evals"]]
        recommend_s = float(sum(e["recommend_s"] for e in evals))
        totals = {
            "n_rounds": len(self.rounds),
            "n_evals": len(evals),
            "n_failures": sum(1 for e in evals if e["failed"]),
            "ask_s": float(sum(r["ask_s"] for r in self.rounds)),
            "recommend_s": recommend_s,
            # per-iteration recommendation overhead — the figure
            # bench_overhead tracks and CI gates
            "recommend_s_per_eval": recommend_s / max(len(evals), 1),
            "eval_s": float(sum(e["eval_s"] for e in evals)),
        }
        n_retries = sum(e.get("retries", 0) for e in evals)
        if n_retries:  # key appears only on fault-affected sessions, keeping
            totals["n_retries"] = int(n_retries)  # clean ledgers byte-identical
        return {
            "schema": LEDGER_SCHEMA,
            "tuner": self.tuner.name,
            "executor": getattr(self.executor, "name", type(self.executor).__name__),
            "rounds": copy.deepcopy(self.rounds),
            "totals": totals,
        }

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-compatible checkpoint: tuner state + in-flight session state."""
        return {
            "version": STATE_VERSION,
            "tuner": self.tuner.state_dict(),
            "pending": [dict(c) for c in self._pending],
            "pending_recommend_s": float(self._pending_recommend_s),
            "rounds": copy.deepcopy(self.rounds),
            # optional key (absent in older checkpoints): in-flight transient
            # retry bookkeeping, so a resume mid-retry keeps its backoff state
            "retry": copy.deepcopy(self._retry_state),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "TuningSession":
        """In-place restore of a ``state_dict()`` checkpoint onto this
        session (tuner state included); backend, executor and callbacks are
        untouched. This is the rollback half of the serving control plane's
        canary protocol: snapshot before a candidate retune, load back on a
        losing canary — bit-identical to never having retuned.
        """
        version = state.get("version")
        if version != STATE_VERSION:
            raise ValueError(f"unsupported session state version {version!r}")
        self.tuner.load_state_dict(state["tuner"])
        self._pending = [dict(c) for c in state.get("pending", [])]
        self._pending_recommend_s = float(state.get("pending_recommend_s", 0.0))
        self.rounds = copy.deepcopy(state.get("rounds", []))
        self._retry_state = copy.deepcopy(state.get("retry", {}))
        return self

    @classmethod
    def restore(
        cls,
        state: Dict[str, Any],
        tuner: TunerBase,
        backend: Optional[EvalBackend] = None,
        executor: ExecutorLike = None,
        callbacks: Sequence[Callback] = (),
        retry: Optional[RetryPolicy] = None,
    ) -> "TuningSession":
        """Rebuild a session from ``state_dict()`` output.

        ``tuner`` must be freshly constructed with the same constructor
        arguments as the checkpointed one (its mutable state — history, RNG,
        polling/abandon, bootstrap observations — is overwritten from the
        checkpoint). The continuation is bit-identical to an uninterrupted
        run for deterministic backends.
        """
        session = cls(tuner, backend=backend, executor=executor, callbacks=callbacks, retry=retry)
        return session.load_state_dict(state)


def checkpoint_every(
    path_fn: Callable[[int], str], every: int = 1
) -> Callback:
    """Convenience callback factory: JSON-dump ``session.state_dict()`` every
    ``every`` observations to ``path_fn(iteration)``."""
    import json

    def cb(session: TuningSession, obs: Observation) -> None:
        if session.n_observations % every == 0:
            with open(path_fn(obs.iteration), "w") as f:
                json.dump(session.state_dict(), f)

    return cb
