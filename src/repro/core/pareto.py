"""Pareto utilities (maximization convention throughout)."""
from __future__ import annotations

import numpy as np


def non_dominated_mask(Y: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows of Y (n, m), maximizing every column.

    A point is dominated if some other point is >= in all objectives and > in
    at least one.
    """
    Y = np.asarray(Y, dtype=np.float64)
    n = Y.shape[0]
    if n == 0:
        return np.zeros((0,), dtype=bool)
    ge = np.all(Y[None, :, :] >= Y[:, None, :], axis=-1)  # ge[i,j]: j >= i everywhere
    gt = np.any(Y[None, :, :] > Y[:, None, :], axis=-1)  # gt[i,j]: j > i somewhere
    dominated = np.any(ge & gt, axis=1)
    return ~dominated


def pareto_front(Y: np.ndarray) -> np.ndarray:
    """The unique non-dominated rows, sorted by the first objective descending."""
    m = non_dominated_mask(Y)
    front = np.unique(np.asarray(Y, np.float64)[m], axis=0)
    order = np.argsort(-front[:, 0], kind="stable")
    return front[order]
