"""Hybrid search space for VDTuner.

The space mirrors the paper's structure (§II-B, Table I): one categorical
*index type* dimension, per-index-type *index parameters* (the tunable set
changes with the index type — the "non-fixed parameter space" challenge), and
global *system parameters* shared by every index type.

Encoding for the GP surrogate: the index type is one-hot encoded (T dims) and
every numeric parameter of every index type gets exactly one unit-interval
dimension (shared/system parameters have a single copy — the paper's holistic
model, §IV-A). Parameters not owned by a configuration's index type sit at
their encoded default, so the GP input is always fully specified.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

Config = Dict[str, Any]  # {"index_type": str, <param>: value, ...}


@dataclasses.dataclass(frozen=True)
class Param:
    """One tunable parameter.

    kind:
      "float"     continuous in [low, high]
      "log_float" continuous, log-uniform in [low, high]
      "int"       integer in [low, high] (uniform)
      "grid"      one of `choices` (ordered numeric grid — encoded ordinally)
      "cat"       one of `choices` (unordered — encoded ordinally but decoded
                  by nearest bucket; small cardinalities only)
    """

    name: str
    kind: str
    low: float = 0.0
    high: float = 1.0
    choices: Tuple[Any, ...] = ()
    default: Any = None

    def __post_init__(self):
        if self.kind in ("grid", "cat") and not self.choices:
            raise ValueError(f"{self.name}: grid/cat parameter needs choices")
        if self.default is None:
            raise ValueError(f"{self.name}: default required")

    # --- unit-interval encode/decode -------------------------------------
    def encode(self, value: Any) -> float:
        if self.kind == "float":
            return float((value - self.low) / (self.high - self.low))
        if self.kind == "log_float":
            lo, hi = math.log(self.low), math.log(self.high)
            return float((math.log(value) - lo) / (hi - lo))
        if self.kind == "int":
            return float((value - self.low) / (self.high - self.low))
        if self.kind in ("grid", "cat"):
            try:
                idx = self.choices.index(value)
            except ValueError:
                # off-grid numeric observation (e.g. a hand-tuned serving
                # config re-anchored through retune): embed at the nearest
                # choice — the surrogate needs *some* cell for a measured
                # config it could never itself propose
                if not isinstance(value, (int, float)) or isinstance(value, bool):
                    raise
                numeric = [
                    (i, c)
                    for i, c in enumerate(self.choices)
                    if isinstance(c, (int, float)) and not isinstance(c, bool)
                ]
                if not numeric:
                    raise
                idx = min(numeric, key=lambda ic: abs(ic[1] - value))[0]
            return (idx + 0.5) / len(self.choices)
        raise ValueError(self.kind)

    def decode(self, u: float) -> Any:
        u = float(np.clip(u, 0.0, 1.0))
        if self.kind == "float":
            return self.low + u * (self.high - self.low)
        if self.kind == "log_float":
            lo, hi = math.log(self.low), math.log(self.high)
            return float(math.exp(lo + u * (hi - lo)))
        if self.kind == "int":
            return int(round(self.low + u * (self.high - self.low)))
        if self.kind in ("grid", "cat"):
            idx = min(int(u * len(self.choices)), len(self.choices) - 1)
            return self.choices[idx]
        raise ValueError(self.kind)


class SearchSpace:
    """Holistic VDMS search space: index type + per-type params + system params."""

    def __init__(
        self,
        index_types: Mapping[str, Sequence[Param]],
        system_params: Sequence[Param],
    ):
        self.index_types: Dict[str, Tuple[Param, ...]] = {
            t: tuple(ps) for t, ps in index_types.items()
        }
        self.type_names: Tuple[str, ...] = tuple(self.index_types)
        self.system_params: Tuple[Param, ...] = tuple(system_params)

        # Holistic layout: [type one-hot (T)] + [index params, per type, in
        # declaration order] + [system params]. Shared system params have one
        # copy; index params are namespaced "<type>.<name>" so e.g. IVF_FLAT
        # and IVF_PQ each own their `nlist` copy unless declared shared.
        self._cols: List[Tuple[str, Optional[str], Param]] = []  # (col, owner, p)
        for t, ps in self.index_types.items():
            for p in ps:
                self._cols.append((f"{t}.{p.name}", t, p))
        for p in self.system_params:
            self._cols.append((p.name, None, p))
        self.n_types = len(self.type_names)
        self.dims = self.n_types + len(self._cols)

    @classmethod
    def from_families(
        cls, families: Sequence[Any], system_params: Sequence[Param]
    ) -> "SearchSpace":
        """Registry-driven construction: each family object contributes its
        ``name`` and declared ``params`` (duck-typed, so any index-family
        registry can drive the space without this module knowing about it)."""
        return cls(
            index_types={f.name: tuple(f.params) for f in families},
            system_params=system_params,
        )

    def encoding_signature(self) -> str:
        """Stable digest of the encoded layout: type names, column order, and
        every parameter's kind/bounds/choices/default. Two spaces with equal
        signatures encode any config to bit-identical rows, so observations
        may be transferred between their tuners; fleet transfer refuses
        imports across differing signatures."""
        payload = {
            "types": list(self.type_names),
            "cols": [
                [col, owner, p.kind, p.low, p.high, [repr(c) for c in p.choices],
                 repr(p.default)]
                for col, owner, p in self._cols
            ],
        }
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def _require_type(self, index_type: str) -> str:
        if index_type not in self.index_types:
            raise ValueError(
                f"unknown index type {index_type!r}; registered families: "
                f"{sorted(self.index_types)}"
            )
        return index_type

    # ------------------------------------------------------------------
    def params_of(self, index_type: str) -> Tuple[Param, ...]:
        return self.index_types[self._require_type(index_type)] + self.system_params

    def default_config(self, index_type: str) -> Config:
        cfg: Config = {"index_type": index_type}
        for p in self.params_of(index_type):
            cfg[p.name] = p.default
        return cfg

    # --- encode / decode ---------------------------------------------------
    def encode(self, cfg: Config) -> np.ndarray:
        x = np.zeros(self.dims, dtype=np.float64)
        t = self._require_type(cfg["index_type"])
        x[self.type_names.index(t)] = 1.0
        for j, (col, owner, p) in enumerate(self._cols):
            if owner is None or owner == t:
                val = cfg.get(p.name, p.default)
            else:
                val = p.default  # non-owned index params pinned to default
            x[self.n_types + j] = p.encode(val)
        return x

    def decode(self, x: np.ndarray, index_type: Optional[str] = None) -> Config:
        x = np.asarray(x, dtype=np.float64)
        if index_type is None:
            index_type = self.type_names[int(np.argmax(x[: self.n_types]))]
        else:
            self._require_type(index_type)
        cfg: Config = {"index_type": index_type}
        for j, (col, owner, p) in enumerate(self._cols):
            if owner is None or owner == index_type:
                cfg[p.name] = p.decode(x[self.n_types + j])
        return cfg

    def free_mask(self, index_type: str) -> np.ndarray:
        """Boolean mask over dims that the acquisition may vary when polling
        `index_type` (its own index params + system params). The one-hot block
        and foreign index params stay fixed (paper §IV-C)."""
        self._require_type(index_type)
        m = np.zeros(self.dims, dtype=bool)
        for j, (col, owner, p) in enumerate(self._cols):
            if owner is None or owner == index_type:
                m[self.n_types + j] = True
        return m

    # --- bulk encoded candidates ------------------------------------------
    def owned_cols(self, index_type: str) -> List[int]:
        """Indices into ``self._cols`` of the parameters ``index_type`` owns
        (its index params, then the system params) — ``params_of()`` order."""
        self._require_type(index_type)
        own = [j for j, (col, owner, p) in enumerate(self._cols) if owner == index_type]
        sys = [j for j, (col, owner, p) in enumerate(self._cols) if owner is None]
        return own + sys

    def encoded_template(self, index_type: str) -> np.ndarray:
        """Encoded row with the type one-hot set and every parameter at its
        encoded default — the fixed part of any candidate of this type."""
        x = np.zeros(self.dims, dtype=np.float64)
        x[self.type_names.index(self._require_type(index_type))] = 1.0
        for j, (col, owner, p) in enumerate(self._cols):
            x[self.n_types + j] = p.encode(p.default)
        return x

    def sample_encoded(
        self, rng: np.random.Generator, n: int, index_type: str
    ) -> np.ndarray:
        """Bulk equivalent of ``sample(rng, n, index_type=...)`` returning raw
        encoded rows (n, dims). One C-order ``rng.random`` matrix consumes the
        generator identically to n sequential ``sample`` calls, and
        ``decode(row, index_type)`` reproduces each sampled config exactly."""
        cols = self.owned_cols(index_type)
        U = rng.random((n, len(cols)))
        X = np.tile(self.encoded_template(index_type), (n, 1))
        for k, j in enumerate(cols):
            X[:, self.n_types + j] = U[:, k]
        return X

    def snap_encoded(self, X: np.ndarray, index_type: str) -> np.ndarray:
        """Vectorized ``encode(decode(x))`` over the owned columns: the
        encoded matrix the GP sees after raw candidate rows are snapped to
        representable parameter values. Matches the scalar
        ``Param.encode``/``decode`` round-trip bit-for-bit per column."""
        X = np.array(X, dtype=np.float64, copy=True)
        for j, (col, owner, p) in enumerate(self._cols):
            if not (owner is None or owner == index_type):
                continue
            u = np.clip(X[:, self.n_types + j], 0.0, 1.0)
            if p.kind == "float":
                v = p.low + u * (p.high - p.low)
                s = (v - p.low) / (p.high - p.low)
            elif p.kind == "int":
                v = np.round(p.low + u * (p.high - p.low))
                s = (v - p.low) / (p.high - p.low)
            elif p.kind in ("grid", "cat"):
                nc = len(p.choices)
                idx = np.minimum((u * nc).astype(np.int64), nc - 1)
                s = (idx + 0.5) / nc
            else:  # log_float: math.log/exp differ from np.log/exp by ulps,
                # so round-trip through the scalar path to stay bit-exact
                s = np.array([p.encode(p.decode(float(ui))) for ui in u])
            X[:, self.n_types + j] = s
        return X

    # --- sampling ------------------------------------------------------------
    def sample(
        self, rng: np.random.Generator, n: int, index_type: Optional[str] = None
    ) -> List[Config]:
        if index_type is not None:
            self._require_type(index_type)
        out = []
        for i in range(n):
            t = index_type or self.type_names[int(rng.integers(self.n_types))]
            cfg: Config = {"index_type": t}
            for p in self.params_of(t):
                cfg[p.name] = p.decode(float(rng.random()))
            out.append(cfg)
        return out

    def lhs(self, rng: np.random.Generator, n: int) -> List[Config]:
        """Latin hypercube over the holistic space; index types cycled so every
        type appears (matches how the paper extends fixed-space baselines)."""
        d = len(self._cols)
        # stratified unit samples per column
        u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T + rng.random((n, d))) / n
        out = []
        for i in range(n):
            t = self.type_names[i % self.n_types]
            cfg: Config = {"index_type": t}
            for j, (col, owner, p) in enumerate(self._cols):
                if owner is None or owner == t:
                    cfg[p.name] = p.decode(u[i, j])
            out.append(cfg)
        return out

    def perturb(
        self, rng: np.random.Generator, cfg: Config, scale: float = 0.15
    ) -> Config:
        """Gaussian perturbation in encoded space, keeping the index type."""
        t = cfg["index_type"]
        x = self.encode(cfg)
        noise = rng.normal(0.0, scale, size=self.dims)
        x = np.clip(x + noise * self.free_mask(t), 0.0, 1.0)
        return self.decode(x, index_type=t)
