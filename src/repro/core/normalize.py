"""Polling-surrogate NPI normalization (paper §IV-B, Eq. 2–3).

Each index type's observations are divided by a per-type *base* — the most
balanced non-dominated configuration of that type — so the GP sees relative
improvements rather than absolute performance. This removes the inter-index
performance offsets that otherwise make BO exploit only the currently-best
index type ("polling surrogate").
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .pareto import non_dominated_mask

EPS = 1e-12


def balanced_base(Y_t: np.ndarray) -> np.ndarray:
    """Eq. 3: among a type's non-dominated observations, pick the one that
    maximizes 1 / |y_spd/y_spd_max - y_rec/y_rec_max| (the most balanced).

    Y_t: (n, 2) raw (speed, recall) observations for one index type.
    Returns the (2,) base value  (ȳ_spd, ȳ_rec).
    """
    Y_t = np.asarray(Y_t, np.float64).reshape(-1, 2)
    nd = Y_t[non_dominated_mask(Y_t)]
    ymax = nd.max(axis=0)
    ymax = np.where(ymax <= 0, 1.0, ymax)
    imbalance = np.abs(nd[:, 0] / ymax[0] - nd[:, 1] / ymax[1])
    base = nd[int(np.argmin(imbalance))]
    return np.maximum(base, EPS)


def max_base(Y_t: np.ndarray) -> np.ndarray:
    """Constraint-mode base (paper §IV-F): the per-objective maximum of the
    type, which 'relaxes the goal of achieving both objectives simultaneously'."""
    Y_t = np.asarray(Y_t, np.float64).reshape(-1, 2)
    return np.maximum(Y_t.max(axis=0), EPS)


def npi_normalize(
    Y: np.ndarray,
    types: np.ndarray,
    mode: str = "balanced",
) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Eq. 2: divide each observation by its index type's base value.

    Y: (n, 2) raw observations; types: (n,) index-type label per row.
    Returns (normalized Y, {type: base}).
    """
    Y = np.asarray(Y, np.float64)
    types = np.asarray(types)
    bases: Dict[str, np.ndarray] = {}
    Yn = np.empty_like(Y)
    base_fn = balanced_base if mode == "balanced" else max_base
    for t in np.unique(types):
        sel = types == t
        base = base_fn(Y[sel])
        bases[str(t)] = base
        Yn[sel] = Y[sel] / base[None, :]
    return Yn, bases
