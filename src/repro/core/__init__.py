"""VDTuner core: multi-objective Bayesian optimization for system tuning.

The public tuning API is the ask/tell trio: an ask/tell recommender
(``VDTuner`` or a baseline), an ``ObjectiveSpec`` (what to maximize), and a
``TuningSession`` (who drives recommendation, evaluation dispatch, ledger,
checkpoints). See README "Tuning API".
"""
from .acquisition import cei, ehvi_mc, ei, greedy_select, qehvi_sequential_greedy
from .acquisition_jax import (
    cei_jax,
    ehvi_mc_jax,
    ei_jax,
    fused_cei_select,
    fused_qehvi_select,
    hvi_2d_jax,
)
from .baselines import ALL_BASELINES, DefaultOnly, OpenTunerLike, OtterTuneLike, QEHVI, RandomLHS
from .budget import SuccessiveAbandon, scores_by_hv_influence
from .gp import GP, GPParams
from .hypervolume import hv_2d, hvi_2d
from .normalize import balanced_base, max_base, npi_normalize
from .objectives import (
    OBJECTIVES,
    EvalBackend,
    ObjectiveSpec,
    SequentialBatchMixin,
    as_eval_backend,
    cost_aware,
    cost_aware_transform,
    default_transform,
    promotion_score,
    recall_floor,
    speed_recall,
    streaming_sustained,
    sustained_transform,
)
from .pareto import non_dominated_mask, pareto_front
from .session import (
    BatchExecutor,
    DriftDetector,
    RetryPolicy,
    SequentialExecutor,
    StopSession,
    ThreadedExecutor,
    TuningSession,
    checkpoint_every,
)
from .space import Config, Param, SearchSpace
from .tuner import Observation, TunerBase, TuningFailure, VDTuner

__all__ = [
    "ALL_BASELINES", "BatchExecutor", "Config", "DefaultOnly", "DriftDetector",
    "EvalBackend", "GP", "GPParams", "OBJECTIVES", "ObjectiveSpec", "Observation",
    "OpenTunerLike", "OtterTuneLike", "Param", "QEHVI", "RandomLHS", "RetryPolicy",
    "SearchSpace",
    "SequentialBatchMixin", "SequentialExecutor", "StopSession", "SuccessiveAbandon",
    "ThreadedExecutor", "TunerBase", "TuningFailure", "TuningSession", "VDTuner",
    "as_eval_backend", "balanced_base", "cei", "cei_jax", "checkpoint_every",
    "cost_aware", "cost_aware_transform", "default_transform", "ehvi_mc",
    "ehvi_mc_jax", "ei", "ei_jax", "fused_cei_select", "fused_qehvi_select",
    "greedy_select", "hv_2d", "hvi_2d", "hvi_2d_jax", "max_base",
    "non_dominated_mask", "npi_normalize", "pareto_front", "promotion_score",
    "qehvi_sequential_greedy",
    "recall_floor", "scores_by_hv_influence", "speed_recall", "streaming_sustained",
    "sustained_transform",
]
