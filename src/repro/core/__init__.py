"""VDTuner core: multi-objective Bayesian optimization for system tuning."""
from .acquisition import cei, ehvi_mc, ei, greedy_select, qehvi_sequential_greedy
from .baselines import ALL_BASELINES, DefaultOnly, OpenTunerLike, OtterTuneLike, QEHVI, RandomLHS
from .budget import SuccessiveAbandon, scores_by_hv_influence
from .gp import GP
from .hypervolume import hv_2d, hvi_2d
from .normalize import balanced_base, max_base, npi_normalize
from .pareto import non_dominated_mask, pareto_front
from .space import Config, Param, SearchSpace
from .tuner import Observation, TunerBase, TuningFailure, VDTuner, cost_aware_transform

__all__ = [
    "ALL_BASELINES", "Config", "DefaultOnly", "GP", "Observation", "OpenTunerLike",
    "OtterTuneLike", "Param", "QEHVI", "RandomLHS", "SearchSpace", "SuccessiveAbandon",
    "TunerBase", "TuningFailure", "VDTuner", "balanced_base", "cei", "cost_aware_transform",
    "ehvi_mc", "ei", "greedy_select", "hv_2d", "hvi_2d", "max_base", "non_dominated_mask",
    "npi_normalize", "pareto_front", "qehvi_sequential_greedy", "scores_by_hv_influence",
]
