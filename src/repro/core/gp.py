"""Gaussian-process surrogate in pure JAX.

Matérn-5/2 ARD kernel (paper §IV-B chooses Matérn 5/2 "owing to its excellent
ability to balance flexibility and smoothness"). Multi-output is handled by
independent per-output hyperparameters (the paper's multi-output GP "assumes
each output to be independent").

Implementation notes
--------------------
* Inputs live on the unit cube (``SearchSpace.encode``); outputs are
  standardized per-output before fitting, so float32 + adaptive jitter is
  numerically fine at the ≤ a-few-hundred-points scale BO operates at.
* Training sets grow by one point per iteration. To keep ``jax.jit`` cache
  hits, X/Y are padded to the next multiple of ``PAD`` and padded rows get a
  huge observation-noise term, which removes them from the posterior to
  numerical precision without changing array shapes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = 32
_BIG_NOISE = 1e4
_JITTER = 1e-5
_NOISE_FLOOR = 1e-4  # variance floor keeps f32 Cholesky well-conditioned


@dataclasses.dataclass
class GPParams:
    log_ls: jnp.ndarray  # (m, d) per-output ARD lengthscales
    log_sf: jnp.ndarray  # (m,)  signal stddev
    log_noise: jnp.ndarray  # (m,) observation noise stddev


@dataclasses.dataclass
class GPState:
    params: GPParams
    x: jnp.ndarray  # (n_pad, d)
    y: jnp.ndarray  # (n_pad, m) standardized
    mask: jnp.ndarray  # (n_pad,) 1.0 for real rows
    chol: jnp.ndarray  # (m, n_pad, n_pad)
    alpha: jnp.ndarray  # (m, n_pad)
    y_mean: jnp.ndarray  # (m,)
    y_std: jnp.ndarray  # (m,)


def _sqdist(a: jnp.ndarray, b: jnp.ndarray, inv_ls: jnp.ndarray) -> jnp.ndarray:
    a = a * inv_ls
    b = b * inv_ls
    d2 = jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :] - 2.0 * a @ b.T
    return jnp.maximum(d2, 0.0)


def matern52(a, b, log_ls, log_sf):
    inv_ls = jnp.exp(-log_ls)
    r = jnp.sqrt(_sqdist(a, b, inv_ls) + 1e-12)
    s5 = jnp.sqrt(5.0) * r
    sf2 = jnp.exp(2.0 * log_sf)
    return sf2 * (1.0 + s5 + (5.0 / 3.0) * r * r) * jnp.exp(-s5)


def _nll_single(log_ls, log_sf, log_noise, x, y, mask):
    """Negative log marginal likelihood for one output (padded rows masked)."""
    n = x.shape[0]
    log_ls = jnp.clip(log_ls, jnp.log(0.05), jnp.log(20.0))
    log_sf = jnp.clip(log_sf, jnp.log(0.05), jnp.log(4.0))
    k = matern52(x, x, log_ls, log_sf)
    sf2 = jnp.exp(2.0 * log_sf)
    # noise floor & jitter RELATIVE to the signal variance: keeps the f32
    # Cholesky well-conditioned whatever scale the fit settles on
    noise = (sf2 * _NOISE_FLOOR + jnp.exp(2.0 * log_noise)) * mask + _BIG_NOISE * (1.0 - mask)
    k = k + jnp.diag(noise + _JITTER * sf2)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    # padded rows: y=0 there so the quadratic term contributes ~0; logdet picks
    # up a constant ~log(BIG_NOISE) per pad row that does not affect gradients
    # w.r.t. hyperparameters in any material way.
    nll = 0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol))) + 0.5 * n * jnp.log(2 * jnp.pi)
    # weak log-normal priors keep hyperparameters in a sane band
    prior = 0.05 * jnp.sum((log_ls - jnp.log(0.5)) ** 2) + 0.05 * log_sf**2 + 0.02 * (
        log_noise - jnp.log(0.05)
    ) ** 2
    return nll + prior


@partial(jax.jit, static_argnames=("steps",))
def _fit_padded(x, y, mask, key, steps: int = 120):
    """Adam on the NLL, vmapped over outputs. Returns fitted params + chol/alpha."""
    n, d = x.shape
    m = y.shape[1]

    def fit_one(y_col, key_i):
        log_ls0 = jnp.log(0.5) * jnp.ones((d,))
        log_sf0 = jnp.array(0.0)
        log_noise0 = jnp.array(jnp.log(0.1))
        params = (log_ls0, log_sf0, log_noise0)
        opt_state = jax.tree.map(lambda p: (jnp.zeros_like(p), jnp.zeros_like(p)), params)
        lr = 0.05

        bounds = (
            (jnp.log(0.05), jnp.log(20.0)),  # log_ls
            (jnp.log(0.05), jnp.log(4.0)),  # log_sf
            (jnp.log(1e-3), jnp.log(1.0)),  # log_noise
        )

        def step(carry, i):
            params, opt_state = carry
            grads = jax.grad(lambda ps: _nll_single(*ps, x, y_col, mask))(params)
            new_params, new_state = [], []
            for p, g, (m1, m2), (lo, hi) in zip(params, grads, opt_state, bounds):
                g = jnp.where(jnp.isfinite(g), g, 0.0)  # NaN-guard the step
                m1 = 0.9 * m1 + 0.1 * g
                m2 = 0.999 * m2 + 0.001 * g * g
                m1h = m1 / (1 - 0.9 ** (i + 1))
                m2h = m2 / (1 - 0.999 ** (i + 1))
                new_p = jnp.clip(p - lr * m1h / (jnp.sqrt(m2h) + 1e-8), lo, hi)
                new_params.append(new_p)
                new_state.append((m1, m2))
            return (tuple(new_params), tuple(new_state)), 0.0

        (params, _), _ = jax.lax.scan(step, (params, opt_state), jnp.arange(steps))
        log_ls, log_sf, log_noise = params
        # clamp for safety (posterior uses these values directly)
        log_ls = jnp.clip(log_ls, jnp.log(0.05), jnp.log(20.0))
        log_sf = jnp.clip(log_sf, jnp.log(0.05), jnp.log(20.0))
        log_noise = jnp.clip(log_noise, jnp.log(1e-3), jnp.log(1.0))
        return log_ls, log_sf, log_noise

    keys = jax.random.split(key, m)
    log_ls, log_sf, log_noise = jax.vmap(fit_one, in_axes=(1, 0))(y, keys)
    chol, alpha = _posterior_padded(log_ls, log_sf, log_noise, x, y, mask)
    return (log_ls, log_sf, log_noise), chol, alpha


@jax.jit
def _posterior_padded(log_ls, log_sf, log_noise, x, y, mask):
    """Cholesky + weights per output for fixed hyperparameters (padded rows
    removed through the big-noise mask). Shared by fit and `condition_on`."""

    def posterior_terms(ls_i, sf_i, nz_i, y_col):
        k = matern52(x, x, ls_i, sf_i)
        sf2 = jnp.exp(2.0 * sf_i)
        noise = (sf2 * _NOISE_FLOOR + jnp.exp(2.0 * nz_i)) * mask + _BIG_NOISE * (1.0 - mask)
        k = k + jnp.diag(noise + _JITTER * sf2)
        chol = jnp.linalg.cholesky(k)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y_col)
        return chol, alpha

    return jax.vmap(posterior_terms, in_axes=(0, 0, 0, 1))(log_ls, log_sf, log_noise, y)


@jax.jit
def _predict_padded(log_ls, log_sf, chol, alpha, x_train, x_test):
    def one(ls_i, sf_i, chol_i, alpha_i):
        ks = matern52(x_test, x_train, ls_i, sf_i)  # (t, n)
        mean = ks @ alpha_i
        v = jax.scipy.linalg.solve_triangular(chol_i, ks.T, lower=True)  # (n, t)
        kss = jnp.exp(2.0 * sf_i)
        var = jnp.maximum(kss - jnp.sum(v * v, axis=0), 1e-10)
        return mean, var

    mean, var = jax.vmap(one)(log_ls, log_sf, chol, alpha)
    return mean.T, var.T  # (t, m)


class GP:
    """Exact multi-output GP with Matérn-5/2 ARD kernel.

    fit(X (n,d), Y (n,m)) then predict(Xt) -> (mean, std), in the original Y
    units (standardization handled internally).
    """

    def __init__(self, seed: int = 0, fit_steps: int = 120):
        self._key = jax.random.PRNGKey(seed)
        self.fit_steps = fit_steps
        self.state: GPState | None = None

    def fit(self, X: np.ndarray, Y: np.ndarray) -> "GP":
        X = np.asarray(X, np.float32)
        Y = np.asarray(Y, np.float32)
        if Y.ndim == 1:
            Y = Y[:, None]
        n, d = X.shape
        m = Y.shape[1]
        y_mean = Y.mean(axis=0)
        y_std = Y.std(axis=0) + 1e-8
        Yn = (Y - y_mean) / y_std
        n_pad = int(np.ceil(max(n, 1) / PAD) * PAD)
        xp = np.zeros((n_pad, d), np.float32)
        yp = np.zeros((n_pad, m), np.float32)
        maskp = np.zeros((n_pad,), np.float32)
        xp[:n] = X
        yp[:n] = Yn
        maskp[:n] = 1.0
        self._key, sub = jax.random.split(self._key)
        (log_ls, log_sf, log_noise), chol, alpha = _fit_padded(
            jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(maskp), sub, steps=self.fit_steps
        )
        self.state = GPState(
            params=GPParams(log_ls, log_sf, log_noise),
            x=jnp.asarray(xp),
            y=jnp.asarray(yp),
            mask=jnp.asarray(maskp),
            chol=chol,
            alpha=alpha,
            y_mean=jnp.asarray(y_mean),
            y_std=jnp.asarray(y_std),
        )
        return self

    def predict(self, Xt: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        assert self.state is not None, "fit() first"
        s = self.state
        Xt = jnp.asarray(np.asarray(Xt, np.float32))
        mean, var = _predict_padded(
            s.params.log_ls, s.params.log_sf, s.chol, s.alpha, s.x, Xt
        )
        mean = np.asarray(mean) * np.asarray(s.y_std) + np.asarray(s.y_mean)
        std = np.sqrt(np.asarray(var)) * np.asarray(s.y_std)
        return mean, std

    def condition_on(self, X_new: np.ndarray, Y_new: np.ndarray) -> "GP":
        """Posterior conditioning on extra observations (original Y units)
        without refitting hyperparameters.

        Used for Kriging-believer fantasies in sequential-greedy batch
        acquisition: the fitted kernel is kept, the new points join the
        training set (into free padded rows, re-padding when full), and only
        the Cholesky/weights are recomputed. Returns a new GP; self is
        untouched.
        """
        assert self.state is not None, "fit() first"
        s = self.state
        d = s.x.shape[1]
        m = s.y.shape[1]
        n_real = int(np.asarray(s.mask).sum())
        X_new = np.asarray(X_new, np.float32).reshape(-1, d)
        Y_new = np.asarray(Y_new, np.float32).reshape(-1, m)
        Yn_new = (Y_new - np.asarray(s.y_mean)) / np.asarray(s.y_std)
        n_tot = n_real + X_new.shape[0]
        n_pad = int(np.ceil(n_tot / PAD) * PAD)
        xp = np.zeros((n_pad, d), np.float32)
        yp = np.zeros((n_pad, m), np.float32)
        maskp = np.zeros((n_pad,), np.float32)
        xp[:n_real] = np.asarray(s.x)[:n_real]
        yp[:n_real] = np.asarray(s.y)[:n_real]
        xp[n_real:n_tot] = X_new
        yp[n_real:n_tot] = Yn_new
        maskp[:n_tot] = 1.0
        chol, alpha = _posterior_padded(
            s.params.log_ls, s.params.log_sf, s.params.log_noise,
            jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(maskp),
        )
        out = GP(fit_steps=self.fit_steps)
        out._key = self._key
        out.state = GPState(
            params=s.params,
            x=jnp.asarray(xp),
            y=jnp.asarray(yp),
            mask=jnp.asarray(maskp),
            chol=chol,
            alpha=alpha,
            y_mean=s.y_mean,
            y_std=s.y_std,
        )
        return out
