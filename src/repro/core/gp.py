"""Gaussian-process surrogate in pure JAX.

Matérn-5/2 ARD kernel (paper §IV-B chooses Matérn 5/2 "owing to its excellent
ability to balance flexibility and smoothness"). Multi-output is handled by
independent per-output hyperparameters (the paper's multi-output GP "assumes
each output to be independent").

Implementation notes
--------------------
* Inputs live on the unit cube (``SearchSpace.encode``); outputs are
  standardized per-output before fitting, so float32 + adaptive jitter is
  numerically fine at the ≤ a-few-hundred-points scale BO operates at.
* Training sets grow by one point per iteration. To keep ``jax.jit`` cache
  hits, X/Y are padded to the next multiple of ``PAD``. Padded rows are
  *exactly inert*: kernel cross-terms are masked to zero and the pad
  diagonal is the constant ``_BIG_NOISE``, so the padded posterior equals
  the unpadded one, growing capacity is an exact block extension of the
  Cholesky (``sqrt(_BIG_NOISE)`` on the new diagonal), and conditioning on
  an extra observation is an exact O(n²) bordered-Cholesky append into the
  first free pad row — ``condition_on`` never refactorizes.
* ``fit`` supports warm starts: pass ``init`` (the ``GPParams`` of a
  previous fit) and the optimizer runs ``warm_fit_steps`` Adam steps from
  there instead of ``fit_steps`` from the default initialization. The
  tuners thread this state between iterations (and through checkpoints) to
  cut recommendation overhead.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = 32
_BIG_NOISE = 1e4
_JITTER = 1e-5
_NOISE_FLOOR = 1e-4  # variance floor keeps f32 Cholesky well-conditioned


@dataclasses.dataclass
class GPParams:
    log_ls: jnp.ndarray  # (m, d) per-output ARD lengthscales
    log_sf: jnp.ndarray  # (m,)  signal stddev
    log_noise: jnp.ndarray  # (m,) observation noise stddev

    # --- serialization (JSON-compatible; exact f32 round-trip) -----------
    def to_lists(self) -> Dict[str, Any]:
        return {
            "log_ls": np.asarray(self.log_ls, np.float64).tolist(),
            "log_sf": np.asarray(self.log_sf, np.float64).tolist(),
            "log_noise": np.asarray(self.log_noise, np.float64).tolist(),
        }

    @classmethod
    def from_lists(cls, d: Dict[str, Any]) -> "GPParams":
        return cls(
            log_ls=jnp.asarray(np.asarray(d["log_ls"], np.float32)),
            log_sf=jnp.asarray(np.asarray(d["log_sf"], np.float32)),
            log_noise=jnp.asarray(np.asarray(d["log_noise"], np.float32)),
        )


@dataclasses.dataclass
class GPState:
    params: GPParams
    x: jnp.ndarray  # (n_pad, d)
    y: jnp.ndarray  # (n_pad, m) standardized
    mask: jnp.ndarray  # (n_pad,) 1.0 for real rows
    chol: jnp.ndarray  # (m, n_pad, n_pad)
    alpha: jnp.ndarray  # (m, n_pad)
    y_mean: jnp.ndarray  # (m,)
    y_std: jnp.ndarray  # (m,)


def _sqdist(a: jnp.ndarray, b: jnp.ndarray, inv_ls: jnp.ndarray) -> jnp.ndarray:
    a = a * inv_ls
    b = b * inv_ls
    d2 = jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :] - 2.0 * a @ b.T
    return jnp.maximum(d2, 0.0)


def matern52(a, b, log_ls, log_sf):
    inv_ls = jnp.exp(-log_ls)
    r = jnp.sqrt(_sqdist(a, b, inv_ls) + 1e-12)
    s5 = jnp.sqrt(5.0) * r
    sf2 = jnp.exp(2.0 * log_sf)
    return sf2 * (1.0 + s5 + (5.0 / 3.0) * r * r) * jnp.exp(-s5)


def _kernel_matrix(x, mask, log_ls, log_sf, log_noise, noise_scale=1.0):
    """K̃ with exactly-inert padding: masked cross-terms, constant BIG pad
    diagonal. The Cholesky is block-diagonal [L_real, sqrt(BIG)·I].

    ``noise_scale`` multiplies the learned observation-noise variance per
    row (scalar 1.0 or an (n,) vector). Rows imported from another tenant's
    ledger carry a scale > 1 so they inform the posterior without being
    trusted as much as locally-measured points."""
    sf2 = jnp.exp(2.0 * log_sf)
    k = matern52(x, x, log_ls, log_sf) * (mask[:, None] * mask[None, :])
    noise = (
        sf2 * _NOISE_FLOOR + jnp.exp(2.0 * log_noise) * noise_scale + _JITTER * sf2
    ) * mask + _BIG_NOISE * (1.0 - mask)
    return k + jnp.diag(noise)


def _nll_single(log_ls, log_sf, log_noise, x, y, mask, noise_scale=1.0):
    """Negative log marginal likelihood for one output (padded rows inert)."""
    n = x.shape[0]
    log_ls = jnp.clip(log_ls, jnp.log(0.05), jnp.log(20.0))
    log_sf = jnp.clip(log_sf, jnp.log(0.05), jnp.log(4.0))
    k = _kernel_matrix(x, mask, log_ls, log_sf, log_noise, noise_scale)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    # padded rows: y=0 and zero cross-terms, so the quadratic term is exactly
    # 0 there; logdet picks up the constant 0.5*log(BIG_NOISE) per pad row,
    # which does not affect gradients w.r.t. hyperparameters.
    nll = 0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol))) + 0.5 * n * jnp.log(2 * jnp.pi)
    # weak log-normal priors keep hyperparameters in a sane band
    prior = 0.05 * jnp.sum((log_ls - jnp.log(0.5)) ** 2) + 0.05 * log_sf**2 + 0.02 * (
        log_noise - jnp.log(0.05)
    ) ** 2
    return nll + prior


@partial(jax.jit, static_argnames=("steps",))
def _fit_padded(x, y, mask, key, ls0, sf0, nz0, steps: int, noise_scale=1.0):
    """Adam on the NLL, vmapped over outputs, starting from (ls0, sf0, nz0)
    — the default initialization for cold fits, the previous iteration's
    hyperparameters for warm starts. Returns fitted params + chol/alpha."""

    def fit_one(y_col, key_i, ls0_i, sf0_i, nz0_i):
        params = (ls0_i, sf0_i, nz0_i)
        opt_state = jax.tree.map(lambda p: (jnp.zeros_like(p), jnp.zeros_like(p)), params)
        lr = 0.05

        bounds = (
            (jnp.log(0.05), jnp.log(20.0)),  # log_ls
            (jnp.log(0.05), jnp.log(4.0)),  # log_sf
            (jnp.log(1e-3), jnp.log(1.0)),  # log_noise
        )

        def step(carry, i):
            params, opt_state = carry
            grads = jax.grad(lambda ps: _nll_single(*ps, x, y_col, mask, noise_scale))(params)
            new_params, new_state = [], []
            for p, g, (m1, m2), (lo, hi) in zip(params, grads, opt_state, bounds):
                g = jnp.where(jnp.isfinite(g), g, 0.0)  # NaN-guard the step
                m1 = 0.9 * m1 + 0.1 * g
                m2 = 0.999 * m2 + 0.001 * g * g
                m1h = m1 / (1 - 0.9 ** (i + 1))
                m2h = m2 / (1 - 0.999 ** (i + 1))
                new_p = jnp.clip(p - lr * m1h / (jnp.sqrt(m2h) + 1e-8), lo, hi)
                new_params.append(new_p)
                new_state.append((m1, m2))
            return (tuple(new_params), tuple(new_state)), 0.0

        (params, _), _ = jax.lax.scan(step, (params, opt_state), jnp.arange(steps))
        log_ls, log_sf, log_noise = params
        # clamp for safety (posterior uses these values directly)
        log_ls = jnp.clip(log_ls, jnp.log(0.05), jnp.log(20.0))
        log_sf = jnp.clip(log_sf, jnp.log(0.05), jnp.log(20.0))
        log_noise = jnp.clip(log_noise, jnp.log(1e-3), jnp.log(1.0))
        return log_ls, log_sf, log_noise

    m = y.shape[1]
    keys = jax.random.split(key, m)
    log_ls, log_sf, log_noise = jax.vmap(fit_one, in_axes=(1, 0, 0, 0, 0))(y, keys, ls0, sf0, nz0)
    chol, alpha = _posterior_padded(log_ls, log_sf, log_noise, x, y, mask, noise_scale)
    return (log_ls, log_sf, log_noise), chol, alpha


@jax.jit
def _posterior_padded(log_ls, log_sf, log_noise, x, y, mask, noise_scale=1.0):
    """Cholesky + weights per output for fixed hyperparameters (padded rows
    exactly inert). Full refactorization — used after ``fit``; incremental
    growth goes through ``_append_rows``."""

    def posterior_terms(ls_i, sf_i, nz_i, y_col):
        k = _kernel_matrix(x, mask, ls_i, sf_i, nz_i, noise_scale)
        chol = jnp.linalg.cholesky(k)
        alpha = jax.scipy.linalg.cho_solve((chol, True), y_col)
        return chol, alpha

    return jax.vmap(posterior_terms, in_axes=(0, 0, 0, 1))(log_ls, log_sf, log_noise, y)


@jax.jit
def _predict_padded(log_ls, log_sf, chol, alpha, x_train, mask, x_test):
    def one(ls_i, sf_i, chol_i, alpha_i):
        ks = matern52(x_test, x_train, ls_i, sf_i) * mask[None, :]  # (t, n)
        mean = ks @ alpha_i
        v = jax.scipy.linalg.solve_triangular(chol_i, ks.T, lower=True)  # (n, t)
        kss = jnp.exp(2.0 * sf_i)
        var = jnp.maximum(kss - jnp.sum(v * v, axis=0), 1e-10)
        return mean, var

    mean, var = jax.vmap(one)(log_ls, log_sf, chol, alpha)
    return mean.T, var.T  # (t, m)


@jax.jit
def _append_rows(log_ls, log_sf, log_noise, x, y, mask, chol, x_new, y_new):
    """Insert rows ``x_new`` (k, d) / ``y_new`` (k, m; standardized) into the
    first free pad slots, updating the Cholesky by one bordered row each —
    O(n²) per row. Exact (not approximate) because pad rows are inert: the
    new row's cross-terms to later pad rows are zero, so no row below it
    changes. Returns the updated (x, y, mask, chol, alpha)."""
    sf2 = jnp.exp(2.0 * log_sf)
    row_noise = sf2 * (_NOISE_FLOOR + _JITTER) + jnp.exp(2.0 * log_noise)  # (m,)

    def body(carry, inp):
        x, y, mask, chol = carry
        xn, yn = inp
        r = jnp.sum(mask).astype(jnp.int32)  # first free pad row
        kv = jax.vmap(lambda ls, sf: matern52(xn[None], x, ls, sf)[0])(log_ls, log_sf)
        kv = kv * mask[None, :]  # (m, n_pad)
        w = jax.vmap(lambda L, b: jax.scipy.linalg.solve_triangular(L, b, lower=True))(chol, kv)
        kself = jax.vmap(lambda ls, sf: matern52(xn[None], xn[None], ls, sf)[0, 0])(log_ls, log_sf)
        l_rr = jnp.sqrt(jnp.maximum(kself + row_noise - jnp.sum(w * w, axis=1), 1e-10))
        chol = chol.at[:, r, :].set(w)  # w is 0 at rows >= r (inert pads)
        chol = chol.at[:, r, r].set(l_rr)
        x = x.at[r].set(xn)
        y = y.at[r].set(yn)
        mask = mask.at[r].set(1.0)
        return (x, y, mask, chol), 0.0

    (x, y, mask, chol), _ = jax.lax.scan(body, (x, y, mask, chol), (x_new, y_new))
    alpha = jax.vmap(
        lambda L, y_col: jax.scipy.linalg.cho_solve((L, True), y_col), in_axes=(0, 1)
    )(chol, y)
    return x, y, mask, chol, alpha


def _extend_padding(chol: jnp.ndarray, alpha: jnp.ndarray, n_new: int):
    """Exact capacity growth: block-extend the Cholesky with the constant
    pad diagonal sqrt(BIG_NOISE) and zero-pad the weights."""
    m, n, _ = chol.shape
    c = jnp.zeros((m, n_new, n_new), chol.dtype).at[:, :n, :n].set(chol)
    idx = jnp.arange(n, n_new)
    c = c.at[:, idx, idx].set(jnp.sqrt(jnp.asarray(_BIG_NOISE, chol.dtype)))
    a = jnp.zeros((m, n_new), alpha.dtype).at[:, :n].set(alpha)
    return c, a


class GP:
    """Exact multi-output GP with Matérn-5/2 ARD kernel.

    fit(X (n,d), Y (n,m)) then predict(Xt) -> (mean, std), in the original Y
    units (standardization handled internally).
    """

    def __init__(self, seed: int = 0, fit_steps: int = 120, warm_fit_steps: int = 30):
        self._key = jax.random.PRNGKey(seed)
        self.fit_steps = fit_steps
        self.warm_fit_steps = warm_fit_steps
        self.state: GPState | None = None
        # optional prior mean callable X (n,d) -> (n,m) in original Y units;
        # the GP then models residuals Y - mu(X) (transfer warm-starts can
        # encode a source tenant's response surface here)
        self._prior_mean = None

    @property
    def params(self) -> GPParams:
        assert self.state is not None, "fit() first"
        return self.state.params

    @property
    def n_real(self) -> int:
        assert self.state is not None, "fit() first"
        return int(np.asarray(self.state.mask).sum())

    def fit(
        self,
        X: np.ndarray,
        Y: np.ndarray,
        init: Optional[GPParams] = None,
        steps: Optional[int] = None,
        noise_scale: Optional[np.ndarray] = None,
        prior_mean=None,
    ) -> "GP":
        """Fit hyperparameters by Adam on the NLL.

        ``init`` warm-starts the optimizer from a previous fit's
        hyperparameters (running ``warm_fit_steps`` instead of ``fit_steps``
        unless ``steps`` overrides); shape-mismatched ``init`` (e.g. a
        checkpoint from a different space) silently falls back to a cold fit.

        ``noise_scale`` is an optional (n,) per-row multiplier on the learned
        observation-noise variance — rows transferred from another tenant's
        ledger carry a scale > 1 so they shape the posterior without being
        trusted like local measurements. ``prior_mean`` is an optional
        callable ``X (n,d) -> (n,m)`` in original Y units; the GP fits the
        residuals and ``predict`` adds the prior back. Both default to the
        exact pre-existing behavior.
        """
        X = np.asarray(X, np.float32)
        Y = np.asarray(Y, np.float32)
        if Y.ndim == 1:
            Y = Y[:, None]
        n, d = X.shape
        m = Y.shape[1]
        self._prior_mean = prior_mean
        if prior_mean is not None:
            Y = Y - np.asarray(prior_mean(X), np.float32).reshape(n, m)
        y_mean = Y.mean(axis=0)
        y_std = Y.std(axis=0) + 1e-8
        Yn = (Y - y_mean) / y_std
        n_pad = int(np.ceil(max(n, 1) / PAD) * PAD)
        xp = np.zeros((n_pad, d), np.float32)
        yp = np.zeros((n_pad, m), np.float32)
        maskp = np.zeros((n_pad,), np.float32)
        xp[:n] = X
        yp[:n] = Yn
        maskp[:n] = 1.0
        if init is not None and np.asarray(init.log_ls).shape != (m, d):
            init = None
        if init is None:
            ls0 = np.full((m, d), np.log(0.5), np.float32)
            sf0 = np.zeros((m,), np.float32)
            nz0 = np.full((m,), np.log(0.1), np.float32)
            n_steps = self.fit_steps if steps is None else steps
        else:
            ls0 = np.asarray(init.log_ls, np.float32)
            sf0 = np.asarray(init.log_sf, np.float32)
            nz0 = np.asarray(init.log_noise, np.float32)
            n_steps = self.warm_fit_steps if steps is None else steps
        if noise_scale is None:
            scale = jnp.float32(1.0)  # scalar broadcast: bitwise the legacy path
        else:
            sp = np.ones((n_pad,), np.float32)
            sp[:n] = np.asarray(noise_scale, np.float32).reshape(n)
            scale = jnp.asarray(sp)
        self._key, sub = jax.random.split(self._key)
        (log_ls, log_sf, log_noise), chol, alpha = _fit_padded(
            jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(maskp), sub,
            jnp.asarray(ls0), jnp.asarray(sf0), jnp.asarray(nz0), steps=int(n_steps),
            noise_scale=scale,
        )
        self.state = GPState(
            params=GPParams(log_ls, log_sf, log_noise),
            x=jnp.asarray(xp),
            y=jnp.asarray(yp),
            mask=jnp.asarray(maskp),
            chol=chol,
            alpha=alpha,
            y_mean=jnp.asarray(y_mean),
            y_std=jnp.asarray(y_std),
        )
        return self

    def predict(self, Xt: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        assert self.state is not None, "fit() first"
        s = self.state
        Xt = jnp.asarray(np.asarray(Xt, np.float32))
        mean, var = _predict_padded(
            s.params.log_ls, s.params.log_sf, s.chol, s.alpha, s.x, s.mask, Xt
        )
        mean = np.asarray(mean) * np.asarray(s.y_std) + np.asarray(s.y_mean)
        std = np.sqrt(np.asarray(var)) * np.asarray(s.y_std)
        if self._prior_mean is not None:
            Xt_np = np.asarray(Xt, np.float32)
            mean = mean + np.asarray(self._prior_mean(Xt_np), np.float32).reshape(mean.shape)
        return mean, std

    def with_capacity(self, n_total: int) -> "GP":
        """A GP whose padded arrays hold at least ``n_total`` rows (self if
        they already do). Growth is the exact block extension — no
        refactorization, identical posterior."""
        assert self.state is not None, "fit() first"
        s = self.state
        n_pad = s.x.shape[0]
        if n_total <= n_pad:
            return self
        n_new = int(np.ceil(n_total / PAD) * PAD)
        xp = np.zeros((n_new, s.x.shape[1]), np.float32)
        yp = np.zeros((n_new, s.y.shape[1]), np.float32)
        maskp = np.zeros((n_new,), np.float32)
        xp[:n_pad] = np.asarray(s.x)
        yp[:n_pad] = np.asarray(s.y)
        maskp[:n_pad] = np.asarray(s.mask)
        chol, alpha = _extend_padding(s.chol, s.alpha, n_new)
        out = GP(fit_steps=self.fit_steps, warm_fit_steps=self.warm_fit_steps)
        out._key = self._key
        out._prior_mean = self._prior_mean
        out.state = GPState(
            params=s.params,
            x=jnp.asarray(xp),
            y=jnp.asarray(yp),
            mask=jnp.asarray(maskp),
            chol=chol,
            alpha=alpha,
            y_mean=s.y_mean,
            y_std=s.y_std,
        )
        return out

    def condition_on(self, X_new: np.ndarray, Y_new: np.ndarray) -> "GP":
        """Posterior conditioning on extra observations (original Y units)
        without refitting hyperparameters.

        Used for Kriging-believer fantasies in sequential-greedy batch
        acquisition: the fitted kernel is kept and each new point is a
        rank-1 bordered-Cholesky append into a free pad row (O(n²) per
        output), growing the padding by an exact block extension when the
        PAD block is full. Returns a new GP; self is untouched.
        """
        assert self.state is not None, "fit() first"
        d = self.state.x.shape[1]
        m = self.state.y.shape[1]
        n_real = self.n_real
        X_new = np.asarray(X_new, np.float32).reshape(-1, d)
        Y_new = np.asarray(Y_new, np.float32).reshape(-1, m)
        base = self.with_capacity(n_real + X_new.shape[0])
        s = base.state
        if self._prior_mean is not None:
            Y_new = Y_new - np.asarray(self._prior_mean(X_new), np.float32).reshape(Y_new.shape)
        Yn_new = (Y_new - np.asarray(s.y_mean)) / np.asarray(s.y_std)
        x, y, mask, chol, alpha = _append_rows(
            s.params.log_ls, s.params.log_sf, s.params.log_noise,
            s.x, s.y, s.mask, s.chol,
            jnp.asarray(X_new), jnp.asarray(Yn_new),
        )
        out = GP(fit_steps=self.fit_steps, warm_fit_steps=self.warm_fit_steps)
        out._key = self._key
        out._prior_mean = self._prior_mean
        out.state = GPState(
            params=s.params, x=x, y=y, mask=mask, chol=chol, alpha=alpha,
            y_mean=s.y_mean, y_std=s.y_std,
        )
        return out
