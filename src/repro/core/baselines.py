"""Baseline tuners evaluated in the paper (§V-A):

* Default      — no tuning; per-index-type default configurations.
* RandomLHS    — Latin hypercube sampling over the holistic space [33, 34].
* OtterTuneLike— single-objective GP-BO on a weighted sum of normalized
                 objectives, EI acquisition [11].
* QEHVI        — vanilla multi-objective BO: holistic GP on raw standardized
                 objectives, MC-EHVI with reference point 0, index type treated
                 as just another searched dimension (no polling / NPI /
                 abandon) [24].
* OpenTunerLike— AUC-bandit meta-search over numerical techniques (random,
                 annealing-style perturbation, crossover) on the weighted-sum
                 reward [20].

All baselines speak the same ask/tell protocol as ``VDTuner`` and are driven
by ``TuningSession`` — one harness for every tuner, so paper comparisons
(Fig. 6–7, Table VI) measure the recommenders, not five different loops.
The observation sequences are bit-identical to the pre-redesign per-tuner
``run()`` loops (regression-tested in ``tests/test_session.py``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .acquisition import ehvi_mc, ei
from .pareto import non_dominated_mask
from .space import Config
from .tuner import Observation, TunerBase, _WarmGPMixin


class DefaultOnly(TunerBase):
    name = "default"

    def ask(self, n: int = 1) -> List[Config]:
        # one default per index type, in declaration order, up to the budget;
        # exhausted (empty ask) once every type has been tried.
        done = len(self.history)
        todo = self.space.type_names[done : done + max(n, 0)]
        return [self.space.default_config(t) for t in todo]


class RandomLHS(TunerBase):
    name = "random_lhs"

    def ask(self, n: int = 1) -> List[Config]:
        # the whole remaining budget is one LHS plan, so the stratification
        # covers it exactly like the legacy single-shot design.
        return self.space.lhs(self.rng, max(n, 1))


def _weighted_sum(Y: np.ndarray, w: float = 0.5) -> np.ndarray:
    """Normalized weighted-sum scalarization used to port single-objective
    baselines to the bi-objective problem (paper §V-A)."""
    mx = Y.max(axis=0)
    mx = np.where(mx <= 0, 1.0, mx)
    return w * Y[:, 0] / mx[0] + (1 - w) * Y[:, 1] / mx[1]


class OtterTuneLike(_WarmGPMixin, TunerBase):
    name = "ottertune"

    def __init__(
        self, *args, n_init: int = 10, n_candidates: int = 512,
        warm_start: bool = False, gp_warm_fit_steps: int = 30, **kw,
    ):
        super().__init__(*args, **kw)
        self.n_init = n_init
        self.n_candidates = n_candidates
        self._init_warm(warm_start, gp_warm_fit_steps)

    def ask(self, n: int = 1) -> List[Config]:
        if not self.history:
            return self.space.lhs(self.rng, min(self.n_init, max(n, 1)))
        Y = self.Y
        scal = _weighted_sum(Y)
        gp = self._fit_gp(self.X_enc, scal[:, None])
        cands = self.space.sample(self.rng, self.n_candidates)
        Xc = np.stack([self.space.encode(c) for c in cands])
        mean, std = gp.predict(Xc)
        acq = ei(mean[:, 0], std[:, 0], float(scal.max()))
        return [cands[int(np.argmax(acq))]]


class QEHVI(_WarmGPMixin, TunerBase):
    name = "qehvi"

    def __init__(
        self, *args, n_init: int = 10, n_candidates: int = 512, mc_samples: int = 64,
        warm_start: bool = False, gp_warm_fit_steps: int = 30, **kw,
    ):
        super().__init__(*args, **kw)
        self.n_init = n_init
        self.n_candidates = n_candidates
        self.mc_samples = mc_samples
        self._init_warm(warm_start, gp_warm_fit_steps)

    def ask(self, n: int = 1) -> List[Config]:
        if not self.history:
            return self.space.lhs(self.rng, min(self.n_init, max(n, 1)))
        Y = self.Y
        gp = self._fit_gp(self.X_enc, Y)
        cands = self.space.sample(self.rng, self.n_candidates)
        Xc = np.stack([self.space.encode(c) for c in cands])
        mean, std = gp.predict(Xc)
        front = Y[non_dominated_mask(Y)]
        ref = np.zeros(2)  # paper: qEHVI reference point set to 0
        acq = ehvi_mc(mean, std, front, ref, self.rng, self.mc_samples)
        return [cands[int(np.argmax(acq))]]


class OpenTunerLike(TunerBase):
    """AUC-bandit over low-overhead numerical search techniques."""

    name = "opentuner"

    TECHNIQUES = ("random", "perturb", "crossover", "anneal")

    def __init__(self, *args, window: int = 30, **kw):
        super().__init__(*args, **kw)
        self.window = window
        self._uses: List[str] = []
        self._credits: List[float] = []
        self._temp = 0.5
        # (technique, pre-eval best scalarization) for the in-flight proposal
        self._pending_credit: Optional[Tuple[str, float]] = None

    def _pick_technique(self) -> str:
        # AUC-credit bandit: exploitation score per technique from recent
        # successes, plus a sqrt exploration bonus.
        scores = {}
        n_total = max(len(self._uses), 1)
        for t in self.TECHNIQUES:
            idx = [i for i, u in enumerate(self._uses[-self.window :]) if u == t]
            if not idx:
                scores[t] = float("inf")
                continue
            credit = np.mean([self._credits[-self.window :][i] for i in idx])
            scores[t] = credit + np.sqrt(2.0 * np.log(n_total) / len(idx))
        return max(scores, key=lambda t: scores[t])

    def _propose(self, tech: str) -> Config:
        good = None
        if self.history:
            scal = _weighted_sum(self.Y)
            good = self.history[int(np.argmax(scal))].config
        if tech == "random" or good is None:
            return self.space.sample(self.rng, 1)[0]
        if tech == "perturb":
            return self.space.perturb(self.rng, good, scale=0.1)
        if tech == "anneal":
            cfg = self.space.perturb(self.rng, good, scale=self._temp)
            self._temp = max(self._temp * 0.97, 0.02)
            return cfg
        if tech == "crossover":
            other = self.history[int(self.rng.integers(len(self.history)))].config
            if other["index_type"] != good["index_type"]:
                return self.space.perturb(self.rng, good, scale=0.1)
            xa, xb = self.space.encode(good), self.space.encode(other)
            mask = self.rng.random(xa.shape) < 0.5
            return self.space.decode(np.where(mask, xa, xb), index_type=good["index_type"])
        raise ValueError(tech)

    def ask(self, n: int = 1) -> List[Config]:
        tech = self._pick_technique()
        cfg = self._propose(tech)
        before = _weighted_sum(self.Y).max() if self.history else -np.inf
        self._pending_credit = (tech, float(before))
        return [cfg]

    def _on_tell(self, obs: Observation) -> None:
        if self._pending_credit is None:
            return
        tech, before = self._pending_credit
        self._pending_credit = None
        after = float(_weighted_sum(self.Y).max())
        self._uses.append(tech)
        self._credits.append(1.0 if after > before else 0.0)

    def _extra_state(self) -> Dict[str, Any]:
        return {
            "uses": list(self._uses),
            "credits": [float(c) for c in self._credits],
            "temp": float(self._temp),
            "pending_credit": list(self._pending_credit) if self._pending_credit else None,
        }

    def _load_extra_state(self, extra: Dict[str, Any]) -> None:
        self._uses = list(extra["uses"])
        self._credits = [float(c) for c in extra["credits"]]
        self._temp = float(extra["temp"])
        pc = extra.get("pending_credit")
        self._pending_credit = (str(pc[0]), float(pc[1])) if pc else None


ALL_BASELINES = {
    c.name: c for c in (DefaultOnly, RandomLHS, OtterTuneLike, QEHVI, OpenTunerLike)
}
