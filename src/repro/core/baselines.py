"""Baseline tuners evaluated in the paper (§V-A):

* Default      — no tuning; per-index-type default configurations.
* RandomLHS    — Latin hypercube sampling over the holistic space [33, 34].
* OtterTuneLike— single-objective GP-BO on a weighted sum of normalized
                 objectives, EI acquisition [11].
* QEHVI        — vanilla multi-objective BO: holistic GP on raw standardized
                 objectives, MC-EHVI with reference point 0, index type treated
                 as just another searched dimension (no polling / NPI /
                 abandon) [24].
* OpenTunerLike— AUC-bandit meta-search over numerical techniques (random,
                 annealing-style perturbation, crossover) on the weighted-sum
                 reward [20].
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from .acquisition import ehvi_mc, ei
from .gp import GP
from .pareto import non_dominated_mask
from .space import Config
from .tuner import TunerBase


class DefaultOnly(TunerBase):
    name = "default"

    def run(self, n_iters: int) -> "DefaultOnly":
        for t in self.space.type_names:
            if len(self.history) >= n_iters:
                break
            self._evaluate(self.space.default_config(t), recommend_time=0.0)
        return self


class RandomLHS(TunerBase):
    name = "random_lhs"

    def run(self, n_iters: int) -> "RandomLHS":
        t0 = time.perf_counter()
        cfgs = self.space.lhs(self.rng, n_iters)
        rec = time.perf_counter() - t0
        for c in cfgs:
            self._evaluate(c, recommend_time=rec / max(n_iters, 1))
        return self


def _weighted_sum(Y: np.ndarray, w: float = 0.5) -> np.ndarray:
    """Normalized weighted-sum scalarization used to port single-objective
    baselines to the bi-objective problem (paper §V-A)."""
    mx = Y.max(axis=0)
    mx = np.where(mx <= 0, 1.0, mx)
    return w * Y[:, 0] / mx[0] + (1 - w) * Y[:, 1] / mx[1]


class OtterTuneLike(TunerBase):
    name = "ottertune"

    def __init__(self, *args, n_init: int = 10, n_candidates: int = 512, **kw):
        super().__init__(*args, **kw)
        self.n_init = n_init
        self.n_candidates = n_candidates

    def run(self, n_iters: int) -> "OtterTuneLike":
        for c in self.space.lhs(self.rng, min(self.n_init, n_iters)):
            self._evaluate(c, recommend_time=0.0)
        while len(self.history) < n_iters:
            t0 = time.perf_counter()
            Y = self.Y
            scal = _weighted_sum(Y)
            gp = GP(seed=int(self.rng.integers(2**31)))
            gp.fit(self.X_enc, scal[:, None])
            cands = self.space.sample(self.rng, self.n_candidates)
            Xc = np.stack([self.space.encode(c) for c in cands])
            mean, std = gp.predict(Xc)
            acq = ei(mean[:, 0], std[:, 0], float(scal.max()))
            cfg = cands[int(np.argmax(acq))]
            self._evaluate(cfg, recommend_time=time.perf_counter() - t0)
        return self


class QEHVI(TunerBase):
    name = "qehvi"

    def __init__(self, *args, n_init: int = 10, n_candidates: int = 512, mc_samples: int = 64, **kw):
        super().__init__(*args, **kw)
        self.n_init = n_init
        self.n_candidates = n_candidates
        self.mc_samples = mc_samples

    def run(self, n_iters: int) -> "QEHVI":
        for c in self.space.lhs(self.rng, min(self.n_init, n_iters)):
            self._evaluate(c, recommend_time=0.0)
        while len(self.history) < n_iters:
            t0 = time.perf_counter()
            Y = self.Y
            gp = GP(seed=int(self.rng.integers(2**31)))
            gp.fit(self.X_enc, Y)
            cands = self.space.sample(self.rng, self.n_candidates)
            Xc = np.stack([self.space.encode(c) for c in cands])
            mean, std = gp.predict(Xc)
            front = Y[non_dominated_mask(Y)]
            ref = np.zeros(2)  # paper: qEHVI reference point set to 0
            acq = ehvi_mc(mean, std, front, ref, self.rng, self.mc_samples)
            cfg = cands[int(np.argmax(acq))]
            self._evaluate(cfg, recommend_time=time.perf_counter() - t0)
        return self


class OpenTunerLike(TunerBase):
    """AUC-bandit over low-overhead numerical search techniques."""

    name = "opentuner"

    TECHNIQUES = ("random", "perturb", "crossover", "anneal")

    def __init__(self, *args, window: int = 30, **kw):
        super().__init__(*args, **kw)
        self.window = window
        self._uses: List[str] = []
        self._credits: List[float] = []
        self._temp = 0.5

    def _pick_technique(self) -> str:
        # AUC-credit bandit: exploitation score per technique from recent
        # successes, plus a sqrt exploration bonus.
        scores = {}
        n_total = max(len(self._uses), 1)
        for t in self.TECHNIQUES:
            idx = [i for i, u in enumerate(self._uses[-self.window :]) if u == t]
            if not idx:
                scores[t] = float("inf")
                continue
            credit = np.mean([self._credits[-self.window :][i] for i in idx])
            scores[t] = credit + np.sqrt(2.0 * np.log(n_total) / len(idx))
        return max(scores, key=lambda t: scores[t])

    def _propose(self, tech: str) -> Config:
        good = None
        if self.history:
            scal = _weighted_sum(self.Y)
            good = self.history[int(np.argmax(scal))].config
        if tech == "random" or good is None:
            return self.space.sample(self.rng, 1)[0]
        if tech == "perturb":
            return self.space.perturb(self.rng, good, scale=0.1)
        if tech == "anneal":
            cfg = self.space.perturb(self.rng, good, scale=self._temp)
            self._temp = max(self._temp * 0.97, 0.02)
            return cfg
        if tech == "crossover":
            other = self.history[int(self.rng.integers(len(self.history)))].config
            if other["index_type"] != good["index_type"]:
                return self.space.perturb(self.rng, good, scale=0.1)
            xa, xb = self.space.encode(good), self.space.encode(other)
            mask = self.rng.random(xa.shape) < 0.5
            return self.space.decode(np.where(mask, xa, xb), index_type=good["index_type"])
        raise ValueError(tech)

    def run(self, n_iters: int) -> "OpenTunerLike":
        while len(self.history) < n_iters:
            t0 = time.perf_counter()
            tech = self._pick_technique()
            cfg = self._propose(tech)
            rec = time.perf_counter() - t0
            before = _weighted_sum(self.Y).max() if self.history else -np.inf
            obs = self._evaluate(cfg, recommend_time=rec)
            after = _weighted_sum(self.Y).max()
            self._uses.append(tech)
            self._credits.append(1.0 if after > before else 0.0)
        return self


ALL_BASELINES = {
    c.name: c for c in (DefaultOnly, RandomLHS, OtterTuneLike, QEHVI, OpenTunerLike)
}
