"""Acquisition functions: MC-EHVI (Eq. 4), EI, constrained EI (Eq. 7), and
sequential-greedy q-EHVI batch selection with Kriging-believer fantasies.

This is the host-side reference implementation; the device-resident fused
path lives in :mod:`.acquisition_jax` and is property-tested against it."""
from __future__ import annotations

from typing import List

import numpy as np
from scipy.special import erf as _erf  # vectorized float64 erf

from .hypervolume import hvi_2d
from .pareto import pareto_front


def _phi(z: np.ndarray) -> np.ndarray:
    """Standard normal pdf."""
    return np.exp(-0.5 * z * z) / np.sqrt(2.0 * np.pi)


def _Phi(z: np.ndarray) -> np.ndarray:
    """Standard normal cdf via erf."""
    return 0.5 * (1.0 + _erf(np.asarray(z, np.float64) / np.sqrt(2.0)))


def ehvi_mc(
    mean: np.ndarray,
    std: np.ndarray,
    front: np.ndarray,
    ref: np.ndarray,
    rng: np.random.Generator,
    n_samples: int = 64,
) -> np.ndarray:
    """Monte-Carlo EHVI for `c` candidates with independent-normal posteriors.

    mean/std: (c, 2); front: (k, 2) current non-dominated set; ref: (2,).
    Returns (c,) expected exclusive hypervolume improvement (paper Eq. 4,
    estimated by Monte-Carlo integration as in qEHVI [24]).
    """
    c = mean.shape[0]
    eps = rng.standard_normal((n_samples, c, 2))
    samples = mean[None] + std[None] * eps  # (S, c, 2)
    flat = samples.reshape(-1, 2)
    hvi = hvi_2d(flat, front, ref).reshape(n_samples, c)
    return hvi.mean(axis=0)


def greedy_select(gp, Xc: np.ndarray, q: int, score_fn, on_fantasy=None) -> List[int]:
    """Sequential-greedy batch selection with Kriging-believer fantasies.

    Picks ``q`` distinct candidate indices: at each round ``score_fn(mean,
    std)`` scores all candidates from the current posterior, the best
    still-available one is taken, and the posterior is conditioned on the
    fantasy (the posterior mean at the pick) via ``gp.condition_on`` so later
    picks spread across the candidate set instead of clustering on one
    acquisition peak. ``on_fantasy(fantasy)`` lets callers update incumbent
    state (running front, best-feasible) between picks.

    For ``q == 1`` this is exactly one ``gp.predict`` + one ``score_fn``
    call — identical RNG consumption and argmax to a single-point step.
    """
    q = min(q, Xc.shape[0])
    chosen: List[int] = []
    avail = np.ones(Xc.shape[0], dtype=bool)
    for j in range(q):
        mean, std = gp.predict(Xc)
        acq = np.where(avail, score_fn(mean, std), -np.inf)
        i = int(np.argmax(acq))
        chosen.append(i)
        avail[i] = False
        if j + 1 < q:
            fantasy = mean[i]
            gp = gp.condition_on(Xc[i][None], fantasy[None])
            if on_fantasy is not None:
                on_fantasy(fantasy)
    return chosen


def qehvi_sequential_greedy(
    gp,
    Xc: np.ndarray,
    front: np.ndarray,
    ref: np.ndarray,
    rng: np.random.Generator,
    q: int,
    n_samples: int = 64,
) -> List[int]:
    """Sequential-greedy q-EHVI: each Kriging-believer fantasy joins the
    running non-dominated front before the next pick."""
    state = {"front": np.asarray(front, np.float64)}

    def score(mean, std):
        return ehvi_mc(mean, std, state["front"], ref, rng, n_samples)

    def on_fantasy(fantasy):
        state["front"] = pareto_front(np.vstack([state["front"], fantasy[None]]))

    return greedy_select(gp, Xc, q, score, on_fantasy)


def ei(mean: np.ndarray, std: np.ndarray, best: float) -> np.ndarray:
    """Closed-form expected improvement (maximization)."""
    std = np.maximum(std, 1e-12)
    z = (mean - best) / std
    return (mean - best) * _Phi(z) + std * _phi(z)


def cei(
    mean_spd: np.ndarray,
    std_spd: np.ndarray,
    mean_rec: np.ndarray,
    std_rec: np.ndarray,
    best_feasible: float,
    rlim: float,
) -> np.ndarray:
    """Constrained EI (paper Eq. 7):  EI(speed) * Pr(recall > rlim)."""
    p_feas = 1.0 - _Phi((rlim - mean_rec) / np.maximum(std_rec, 1e-12))
    if not np.isfinite(best_feasible):
        # no feasible observation yet: chase feasibility only
        return p_feas
    return ei(mean_spd, std_spd, best_feasible) * p_feas
