"""Acquisition functions: MC-EHVI (Eq. 4), EI, and constrained EI (Eq. 7)."""
from __future__ import annotations

import math as _math

import numpy as np

from .hypervolume import hvi_2d

_erf_vec = np.frompyfunc(_math.erf, 1, 1)


def _erf(x: np.ndarray) -> np.ndarray:
    return _erf_vec(x).astype(np.float64)


def _phi(z: np.ndarray) -> np.ndarray:
    """Standard normal pdf."""
    return np.exp(-0.5 * z * z) / np.sqrt(2.0 * np.pi)


def _Phi(z: np.ndarray) -> np.ndarray:
    """Standard normal cdf via erf (vectorized, no scipy dependency)."""
    return 0.5 * (1.0 + _erf(np.asarray(z, np.float64) / np.sqrt(2.0)))


def ehvi_mc(
    mean: np.ndarray,
    std: np.ndarray,
    front: np.ndarray,
    ref: np.ndarray,
    rng: np.random.Generator,
    n_samples: int = 64,
) -> np.ndarray:
    """Monte-Carlo EHVI for `c` candidates with independent-normal posteriors.

    mean/std: (c, 2); front: (k, 2) current non-dominated set; ref: (2,).
    Returns (c,) expected exclusive hypervolume improvement (paper Eq. 4,
    estimated by Monte-Carlo integration as in qEHVI [24]).
    """
    c = mean.shape[0]
    eps = rng.standard_normal((n_samples, c, 2))
    samples = mean[None] + std[None] * eps  # (S, c, 2)
    flat = samples.reshape(-1, 2)
    hvi = hvi_2d(flat, front, ref).reshape(n_samples, c)
    return hvi.mean(axis=0)


def ei(mean: np.ndarray, std: np.ndarray, best: float) -> np.ndarray:
    """Closed-form expected improvement (maximization)."""
    std = np.maximum(std, 1e-12)
    z = (mean - best) / std
    return (mean - best) * _Phi(z) + std * _phi(z)


def cei(
    mean_spd: np.ndarray,
    std_spd: np.ndarray,
    mean_rec: np.ndarray,
    std_rec: np.ndarray,
    best_feasible: float,
    rlim: float,
) -> np.ndarray:
    """Constrained EI (paper Eq. 7):  EI(speed) * Pr(recall > rlim)."""
    p_feas = 1.0 - _Phi((rlim - mean_rec) / np.maximum(std_rec, 1e-12))
    if not np.isfinite(best_feasible):
        # no feasible observation yet: chase feasibility only
        return p_feas
    return ei(mean_spd, std_spd, best_feasible) * p_feas
