"""VDTuner: polling multi-objective Bayesian optimization (paper Algorithm 1).

The tuner maximizes two objectives — (search speed, recall) by default, or
(QP$, recall) in cost-aware mode — over a `SearchSpace` whose tunable set
changes with the index type. Components:

* holistic GP surrogate over all index types (one copy of shared params),
* NPI polling normalization (Eq. 2–3),
* MC-EHVI acquisition with ref = 0.5 * per-type balanced base (Eq. 4),
* round-robin polling with successive abandon (Eq. 5–6, windowed trigger),
* optional recall-floor constraint mode with CEI (Eq. 7) and bootstrapping
  from previous constraint levels (§IV-F),
* batch-parallel rounds (``q > 1``): sequential-greedy q-EHVI / q-CEI with
  Kriging-believer fantasies, evaluated through the objective's vectorized
  ``evaluate_batch`` when available. ``q == 1`` reproduces the original
  single-point trajectory exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .acquisition import cei, greedy_select, qehvi_sequential_greedy
from .budget import SuccessiveAbandon
from .gp import GP
from .normalize import npi_normalize
from .pareto import non_dominated_mask, pareto_front
from .space import Config, SearchSpace

Objective = Callable[[Config], Dict[str, float]]


class TuningFailure(RuntimeError):
    """Raised by an objective when a configuration crashes / times out."""


@dataclasses.dataclass
class Observation:
    iteration: int
    config: Config
    y: np.ndarray  # (2,) raw objective values (speed-like, recall)
    raw: Dict[str, float]
    recommend_time: float
    eval_time: float
    failed: bool = False
    bootstrap: bool = False

    @property
    def index_type(self) -> str:
        return self.config["index_type"]


def default_transform(result: Dict[str, float]) -> Tuple[float, float]:
    return float(result["speed"]), float(result["recall"])


def cost_aware_transform(eta: float = 1.0) -> Callable[[Dict[str, float]], Tuple[float, float]]:
    """Eq. 8: QP$ = speed / (eta * memory GiB). Any resource/price function can
    be swapped in here; NPI normalization makes the tuner invariant to eta."""

    def tf(result: Dict[str, float]) -> Tuple[float, float]:
        mem = max(float(result.get("mem_gib", 1.0)), 1e-9)
        return float(result["speed"]) / (eta * mem), float(result["recall"])

    return tf


class TunerBase:
    """Shared bookkeeping: evaluation with failure fallback + history."""

    name = "base"

    def __init__(
        self,
        space: SearchSpace,
        objective: Objective,
        seed: int = 0,
        transform: Callable[[Dict[str, float]], Tuple[float, float]] = default_transform,
    ):
        self.space = space
        self.objective = objective
        self.rng = np.random.default_rng(seed)
        self.transform = transform
        self.history: List[Observation] = []
        self._seed = seed

    # ------------------------------------------------------------------
    def _record(
        self, cfg: Config, result: Any, recommend_time: float, eval_time: float
    ) -> Observation:
        """Append one observation. ``result`` is either the raw objective dict
        or an Exception instance marking a failed evaluation (paper §V-A:
        failed configs get the worst values in history at record time)."""
        failed = False
        if isinstance(result, Exception):
            failed, raw, y = True, {}, self._worst_so_far()
        else:
            raw = result
            try:
                y = np.asarray(self.transform(raw), np.float64)
                if not np.all(np.isfinite(y)):
                    raise TuningFailure("non-finite objective")
            except TuningFailure:
                failed, raw, y = True, {}, self._worst_so_far()
        obs = Observation(
            iteration=len(self.history),
            config=cfg,
            y=y,
            raw=raw,
            recommend_time=recommend_time,
            eval_time=eval_time,
            failed=failed,
        )
        self.history.append(obs)
        return obs

    def _evaluate(self, cfg: Config, recommend_time: float) -> Observation:
        t0 = time.perf_counter()
        try:
            result: Any = self.objective(cfg)
        except TuningFailure as e:
            result = e
        return self._record(cfg, result, recommend_time, time.perf_counter() - t0)

    def _evaluate_batch(
        self, cfgs: Sequence[Config], recommend_time: float
    ) -> List[Observation]:
        """Evaluate a batch, preferring the objective's vectorized
        ``evaluate_batch`` (e.g. ``VDMSTuningEnv``) when it exposes one.

        Results are recorded in config order one at a time, so the worst-value
        fallback for failed configs sees exactly the history a sequential run
        would have seen. Single-config batches always take the sequential path
        (keeps q=1 behavior identical to the pre-batch tuner).
        """
        eb = getattr(self.objective, "evaluate_batch", None)
        if eb is None or len(cfgs) == 1:
            return [self._evaluate(c, recommend_time) for c in cfgs]
        t0 = time.perf_counter()
        results = eb(list(cfgs))
        per_cfg = (time.perf_counter() - t0) / max(len(cfgs), 1)
        return [self._record(c, r, recommend_time, per_cfg) for c, r in zip(cfgs, results)]

    def _worst_so_far(self) -> np.ndarray:
        ys = [o.y for o in self.history if not o.failed]
        if not ys:
            return np.array([1e-6, 1e-6])
        return np.min(np.stack(ys), axis=0)

    # --- views ----------------------------------------------------------
    @property
    def X_enc(self) -> np.ndarray:
        return np.stack([self.space.encode(o.config) for o in self.history])

    @property
    def Y(self) -> np.ndarray:
        return np.stack([o.y for o in self.history])

    @property
    def types(self) -> np.ndarray:
        return np.array([o.index_type for o in self.history])

    def pareto(self) -> np.ndarray:
        return pareto_front(self.Y)

    def best_speed_at_recall(self, rlim: float) -> float:
        """Best observed speed among configs with recall >= rlim (paper Fig. 6)."""
        ys = self.Y
        ok = ys[:, 1] >= rlim
        return float(ys[ok, 0].max()) if ok.any() else float("nan")

    def run(self, n_iters: int) -> "TunerBase":
        raise NotImplementedError


class VDTuner(TunerBase):
    """Algorithm 1: polling BO with NPI surrogate + successive abandon."""

    name = "vdtuner"

    def __init__(
        self,
        space: SearchSpace,
        objective: Objective,
        seed: int = 0,
        transform=default_transform,
        abandon_window: int = 10,
        n_candidates: int = 512,
        mc_samples: int = 64,
        gp_fit_steps: int = 120,
        rlim: Optional[float] = None,
        bootstrap_history: Optional[Sequence[Observation]] = None,
        q: int = 1,
    ):
        super().__init__(space, objective, seed, transform)
        if q < 1:
            raise ValueError(f"q must be >= 1, got {q}")
        self.abandon = SuccessiveAbandon(space.type_names, window=abandon_window)
        self.n_candidates = n_candidates
        self.mc_samples = mc_samples
        self.gp_fit_steps = gp_fit_steps
        self.rlim = rlim  # user recall-floor preference (constraint mode)
        self.q = q  # configurations proposed (and evaluated) per BO round
        self._poll_cursor = 0
        if bootstrap_history:
            # §IV-F: warm-start the surrogate with data from previous
            # constraint levels. These observations feed the GP/fronts but are
            # not re-evaluated.
            for o in bootstrap_history:
                self.history.append(dataclasses.replace(o, bootstrap=True))

    # ------------------------------------------------------------------
    def _initial_sampling(self):
        """Algorithm 1 lines 1–5: each index type's default configuration.

        With ``q > 1`` the defaults go through the batch evaluation path (they
        are independent, so batching them is free parallelism); with ``q == 1``
        they are evaluated sequentially exactly as before.
        """
        seen = set(o.index_type for o in self.history)
        todo = [self.space.default_config(t) for t in self.space.type_names if t not in seen]
        if not todo:
            return
        if self.q > 1:
            self._evaluate_batch(todo, recommend_time=0.0)
        else:
            for cfg in todo:
                self._evaluate(cfg, recommend_time=0.0)

    def _next_poll_type(self) -> str:
        remaining = self.abandon.remaining
        t = remaining[self._poll_cursor % len(remaining)]
        self._poll_cursor += 1
        return t

    def _candidates(self, t: str) -> List[Config]:
        """Candidate set within type-t's subspace: uniform + perturbations of
        the type's (and globally) best observed configurations."""
        n_uniform = self.n_candidates // 2
        cands = self.space.sample(self.rng, n_uniform, index_type=t)
        # exploit: perturb non-dominated configs of this type
        ys = self.Y
        nd = non_dominated_mask(ys)
        seeds = [o.config for o, keep in zip(self.history, nd) if keep and o.index_type == t]
        if not seeds:  # fall back to the type's best-speed and best-recall configs
            mine = [o for o in self.history if o.index_type == t and not o.failed]
            if mine:
                seeds = [
                    max(mine, key=lambda o: o.y[0]).config,
                    max(mine, key=lambda o: o.y[1]).config,
                ]
        while len(cands) < self.n_candidates and seeds:
            base = seeds[len(cands) % len(seeds)]
            scale = float(self.rng.choice([0.05, 0.1, 0.2]))
            cands.append(self.space.perturb(self.rng, base, scale=scale))
        if len(cands) < self.n_candidates:
            cands += self.space.sample(self.rng, self.n_candidates - len(cands), index_type=t)
        return cands

    def _cei_select(
        self,
        gp: GP,
        Xc: np.ndarray,
        Y: np.ndarray,
        bases: Dict[str, np.ndarray],
        t: str,
        q: int,
    ) -> List[int]:
        """Sequential-greedy constrained-EI selection (Eq. 7) for a batch.

        Thresholds are in the polled type's normalized units. After each pick
        the Kriging-believer fantasy conditions the posterior, and — if the
        fantasy clears the recall floor — raises the feasible-speed incumbent.
        """
        base_t = bases.get(t, np.array([1.0, 1.0]))
        rlim_n = self.rlim / base_t[1]
        feas = Y[:, 1] >= self.rlim
        if feas.any():
            spd_n = np.array(
                [o.y[0] / bases[o.index_type][0] for o, f in zip(self.history, feas) if f]
            )
            best_feasible = float(spd_n.max())
        else:
            best_feasible = float("-inf")
        state = {"best": best_feasible}

        def score(mean, std):
            return cei(mean[:, 0], std[:, 0], mean[:, 1], std[:, 1], state["best"], rlim_n)

        def on_fantasy(fantasy):
            if fantasy[1] >= rlim_n:
                state["best"] = max(state["best"], float(fantasy[0]))

        return greedy_select(gp, Xc, q, score, on_fantasy)

    def step(self, max_new: Optional[int] = None) -> List[Observation]:
        """One BO round: poll a type, propose ``q`` configs by sequential-greedy
        acquisition (Kriging-believer fantasies between picks), evaluate the
        batch, and record the observations in proposal order.

        ``max_new`` clamps the batch so a run never overshoots its iteration
        budget. With ``q == 1`` the round consumes exactly the same RNG draws
        and picks the same argmax as the original single-point step.
        """
        t0 = time.perf_counter()
        q = self.q if max_new is None else max(1, min(self.q, max_new))
        Y, types = self.Y, self.types

        # --- successive abandon (lines 7–14) ---------------------------
        self.abandon.step(Y, types)

        # --- NPI normalization + holistic surrogate (lines 15–18) ------
        mode = "balanced" if self.rlim is None else "max"
        Yn, bases = npi_normalize(Y, types, mode=mode)
        gp = GP(seed=int(self.rng.integers(2**31)), fit_steps=self.gp_fit_steps)
        gp.fit(self.X_enc, Yn)

        # --- poll next index type & recommend (lines 19–21) ------------
        t = self._next_poll_type()
        cands = self._candidates(t)
        Xc = np.stack([self.space.encode(c) for c in cands])

        if self.rlim is None:
            # EHVI with ref = 0.5 * base; in normalized space the base is
            # (1, 1), so r = (0.5, 0.5); the front is the normalized
            # non-dominated set across all types (§IV-C).
            front = Yn[non_dominated_mask(Yn)]
            ref = np.array([0.5, 0.5])
            idx = qehvi_sequential_greedy(
                gp, Xc, front, ref, self.rng, q, self.mc_samples
            )
        else:
            # constraint mode: EI(speed) * Pr(recall > rlim).
            idx = self._cei_select(gp, Xc, Y, bases, t, q)

        cfgs = [cands[i] for i in idx]
        rec_time = time.perf_counter() - t0

        # --- evaluate & update (line 22) --------------------------------
        return self._evaluate_batch(cfgs, recommend_time=rec_time / len(cfgs))

    def run(self, n_iters: int) -> "VDTuner":
        self._initial_sampling()
        while True:
            done = len([o for o in self.history if not o.bootstrap])
            if done >= n_iters:
                break
            self.step(max_new=n_iters - done)
        return self
