"""VDTuner: polling multi-objective Bayesian optimization (paper Algorithm 1).

The tuner maximizes two objectives — (search speed, recall) by default, or
(QP$, recall) in cost-aware mode — over a `SearchSpace` whose tunable set
changes with the index type. Components:

* holistic GP surrogate over all index types (one copy of shared params),
* NPI polling normalization (Eq. 2–3),
* MC-EHVI acquisition with ref = 0.5 * per-type balanced base (Eq. 4),
* round-robin polling with successive abandon (Eq. 5–6, windowed trigger),
* optional recall-floor constraint mode with CEI (Eq. 7) and bootstrapping
  from previous constraint levels (§IV-F),
* batch-parallel rounds (``q > 1``): sequential-greedy q-EHVI / q-CEI with
  Kriging-believer fantasies. ``q == 1`` reproduces the original
  single-point trajectory exactly.

Ask/tell protocol
-----------------
Every tuner is a pure *recommender*: ``ask(n)`` proposes up to ``n``
configurations (it may exceed ``n`` for mandatory warm-up, e.g. the per-type
default sampling of Algorithm 1 lines 1–5, and may return fewer — or none,
signalling exhaustion). ``tell(config, result)`` feeds one result back:
either the raw measurement dict or a ``TuningFailure``; failures receive the
worst values in history at record time (paper §V-A). The tuner never calls
the objective itself — evaluation dispatch, budgets, the time ledger and
checkpointing belong to :class:`repro.core.session.TuningSession`. The
legacy ``tuner.run(n)`` is a thin shim over a session and reproduces the
pre-redesign trajectory exactly (regression-tested).

Objectives are first-class (:mod:`repro.core.objectives`): pass
``objective_spec=recall_floor(0.9)`` instead of the legacy bare ``transform``
callable; both remain accepted.

Checkpointing: ``state_dict()`` / ``load_state_dict()`` round-trip history,
RNG state, and polling/abandon state through JSON-compatible dicts so a
killed tuning run resumes bit-identically (see ``TuningSession.restore``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .acquisition import cei, greedy_select, qehvi_sequential_greedy
from .acquisition_jax import fused_cei_select, fused_qehvi_select
from .budget import SuccessiveAbandon
from .gp import GP, GPParams
from .normalize import npi_normalize
from .objectives import (
    ObjectiveSpec,
    TuningFailure,
    cost_aware_transform,
    default_transform,
    spec_from_transform,
)
from .pareto import non_dominated_mask, pareto_front
from .space import Config, SearchSpace

__all__ = [
    "Observation", "TunerBase", "TuningFailure", "VDTuner",
    "cost_aware_transform", "default_transform",
]

Objective = Callable[[Config], Dict[str, float]]


@dataclasses.dataclass
class Observation:
    iteration: int
    config: Config
    y: np.ndarray  # (2,) raw objective values (speed-like, recall)
    raw: Dict[str, float]
    recommend_time: float
    eval_time: float
    failed: bool = False
    bootstrap: bool = False
    # GP observation-noise variance multiplier; > 1 for observations imported
    # from another tenant's ledger (fleet transfer), 1.0 for local measurements
    noise_scale: float = 1.0

    @property
    def index_type(self) -> str:
        return self.config["index_type"]

    # --- serialization (JSON-compatible) --------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = {
            "iteration": int(self.iteration),
            "config": dict(self.config),
            "y": [float(v) for v in np.asarray(self.y).ravel()],
            "raw": {k: float(v) for k, v in self.raw.items()},
            "recommend_time": float(self.recommend_time),
            "eval_time": float(self.eval_time),
            "failed": bool(self.failed),
            "bootstrap": bool(self.bootstrap),
        }
        if self.noise_scale != 1.0:  # keep pre-fleet checkpoints byte-identical
            d["noise_scale"] = float(self.noise_scale)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Observation":
        return cls(
            iteration=int(d["iteration"]),
            config=dict(d["config"]),
            y=np.asarray(d["y"], np.float64),
            raw=dict(d["raw"]),
            recommend_time=float(d["recommend_time"]),
            eval_time=float(d["eval_time"]),
            failed=bool(d["failed"]),
            bootstrap=bool(d["bootstrap"]),
            noise_scale=float(d.get("noise_scale", 1.0)),
        )


class TunerBase:
    """Shared recommender bookkeeping: history + worst-value failure feedback.

    Subclasses implement ``ask``; ``tell`` is shared. ``objective`` is kept
    for the legacy self-driving path (``run`` / ``step``) and as the default
    backend when a ``TuningSession`` is built from the tuner alone — new code
    may pass ``objective=None`` and wire the backend into the session.
    """

    name = "base"

    def __init__(
        self,
        space: SearchSpace,
        objective: Optional[Objective] = None,
        seed: int = 0,
        transform: Optional[Callable[[Dict[str, float]], Tuple[float, float]]] = None,
        objective_spec: Optional[ObjectiveSpec] = None,
    ):
        if transform is not None and objective_spec is not None:
            raise ValueError("pass either transform= (legacy) or objective_spec=, not both")
        self.space = space
        self.objective = objective
        self.rng = np.random.default_rng(seed)
        self.spec = objective_spec if objective_spec is not None else spec_from_transform(transform)
        self.transform = self.spec.transform  # back-compat attribute
        self.history: List[Observation] = []
        self._seed = seed

    # ------------------------------------------------------------------
    # ask/tell protocol
    # ------------------------------------------------------------------
    def ask(self, n: int = 1) -> List[Config]:
        """Propose up to ``n`` configurations to evaluate next.

        May exceed ``n`` for mandatory warm-up batches and may return fewer;
        an empty list means the recommender is exhausted (e.g. ``DefaultOnly``
        after covering every index type).
        """
        raise NotImplementedError

    def tell(
        self,
        config: Config,
        result: Any,
        recommend_time: float = 0.0,
        eval_time: float = 0.0,
    ) -> Observation:
        """Feed back one evaluation result (raw dict or ``TuningFailure``)."""
        obs = self._record(config, result, recommend_time, eval_time)
        self._on_tell(obs)
        return obs

    def _on_tell(self, obs: Observation) -> None:
        """Subclass hook run after each observation lands (e.g. bandit credit)."""

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-compatible snapshot of all mutable tuner state.

        Constructor arguments (space, objective spec, hyperparameters) are
        NOT serialized — ``load_state_dict`` expects a tuner constructed with
        identical arguments, mirroring how model checkpoints work.
        """
        return {
            "tuner": self.name,
            "seed": self._seed,
            "rng": self.rng.bit_generator.state,
            "history": [o.to_dict() for o in self.history],
            "extra": self._extra_state(),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> "TunerBase":
        if state.get("tuner") != self.name:
            raise ValueError(
                f"state is for tuner {state.get('tuner')!r}, not {self.name!r}"
            )
        self.rng.bit_generator.state = state["rng"]
        self.history = [Observation.from_dict(d) for d in state["history"]]
        self._load_extra_state(state.get("extra", {}))
        return self

    def _extra_state(self) -> Dict[str, Any]:
        return {}

    def _load_extra_state(self, extra: Dict[str, Any]) -> None:
        pass

    # ------------------------------------------------------------------
    def _record(
        self, cfg: Config, result: Any, recommend_time: float, eval_time: float
    ) -> Observation:
        """Append one observation. ``result`` is either the raw objective dict
        or an Exception instance marking a failed evaluation (paper §V-A:
        failed configs get the worst values in history at record time)."""
        failed = False
        if isinstance(result, Exception):
            failed, raw, y = True, {}, self._worst_so_far()
        else:
            raw = result
            try:
                y = np.asarray(self.transform(raw), np.float64)
                if not np.all(np.isfinite(y)):
                    raise TuningFailure("non-finite objective")
            except TuningFailure:
                failed, raw, y = True, {}, self._worst_so_far()
        obs = Observation(
            iteration=len(self.history),
            config=cfg,
            y=y,
            raw=raw,
            recommend_time=recommend_time,
            eval_time=eval_time,
            failed=failed,
        )
        self.history.append(obs)
        return obs

    def _evaluate(self, cfg: Config, recommend_time: float) -> Observation:
        t0 = time.perf_counter()
        try:
            result: Any = self.objective(cfg)
        except TuningFailure as e:
            result = e
        return self._record(cfg, result, recommend_time, time.perf_counter() - t0)

    def _evaluate_batch(
        self, cfgs: Sequence[Config], recommend_time: float
    ) -> List[Observation]:
        """Evaluate a batch, preferring the objective's vectorized
        ``evaluate_batch`` (e.g. ``VDMSTuningEnv``) when it exposes one.

        Results are recorded in config order one at a time, so the worst-value
        fallback for failed configs sees exactly the history a sequential run
        would have seen. Single-config batches always take the sequential path
        (keeps q=1 behavior identical to the pre-batch tuner).
        """
        eb = getattr(self.objective, "evaluate_batch", None)
        if eb is None or len(cfgs) == 1:
            return [self._evaluate(c, recommend_time) for c in cfgs]
        t0 = time.perf_counter()
        results = eb(list(cfgs))
        per_cfg = (time.perf_counter() - t0) / max(len(cfgs), 1)
        return [self._record(c, r, recommend_time, per_cfg) for c, r in zip(cfgs, results)]

    def _worst_so_far(self) -> np.ndarray:
        ys = [o.y for o in self.history if not o.failed]
        if not ys:
            return np.array([1e-6, 1e-6])
        return np.min(np.stack(ys), axis=0)

    # --- views ----------------------------------------------------------
    @property
    def X_enc(self) -> np.ndarray:
        return np.stack([self.space.encode(o.config) for o in self.history])

    @property
    def Y(self) -> np.ndarray:
        return np.stack([o.y for o in self.history])

    @property
    def types(self) -> np.ndarray:
        return np.array([o.index_type for o in self.history])

    def pareto(self) -> np.ndarray:
        return pareto_front(self.Y)

    def best_speed_at_recall(self, rlim: float) -> float:
        """Best observed speed among configs with recall >= rlim (paper Fig. 6)."""
        ys = self.Y
        ok = ys[:, 1] >= rlim
        return float(ys[ok, 0].max()) if ok.any() else float("nan")

    def _deploy_pool(self) -> List[Observation]:
        """Observations eligible for deployment decisions: fresh (current
        workload) non-failed ones, falling back to bootstrap history when no
        fresh observation exists yet (e.g. right after ``retune``)."""
        ok = [o for o in self.history if not o.failed]
        fresh = [o for o in ok if not o.bootstrap]
        return fresh or ok

    def best_config(self, rlim: Optional[float] = None) -> Config:
        """Deployment incumbent: with a recall floor, the fastest feasible
        configuration; otherwise the knee of the observed front (max product
        of per-objective max-normalized values)."""
        pool = self._deploy_pool()
        if not pool:
            raise ValueError("no successful observations yet")
        ys = np.stack([o.y for o in pool])
        if rlim is not None:
            ok = ys[:, 1] >= rlim
            if ok.any():
                idx = np.flatnonzero(ok)[int(np.argmax(ys[ok, 0]))]
                return dict(pool[idx].config)
        norm = ys.max(axis=0)
        norm = np.where(norm <= 0, 1.0, norm)
        return dict(pool[int(np.argmax((ys / norm).prod(axis=1)))].config)

    def pareto_configs(self, max_n: Optional[int] = None) -> List[Config]:
        """Non-dominated configurations of the deployment pool (the set a
        deployment would keep live); ``max_n`` keeps the highest-knee-score
        subset when the front is larger."""
        pool = self._deploy_pool()
        if not pool:
            return []
        ys = np.stack([o.y for o in pool])
        nd = non_dominated_mask(ys)
        front = [o for o, keep in zip(pool, nd) if keep]
        if max_n is not None and len(front) > max_n:
            fy = np.stack([o.y for o in front])
            norm = fy.max(axis=0)
            norm = np.where(norm <= 0, 1.0, norm)
            score = (fy / norm).prod(axis=1)
            keep = np.argsort(-score, kind="stable")[:max_n]
            front = [front[i] for i in sorted(keep)]
        return [dict(o.config) for o in front]

    # ------------------------------------------------------------------
    # legacy self-driving shim
    # ------------------------------------------------------------------
    def preferred_executor(self) -> str:
        """Evaluation-dispatch policy reproducing this tuner's pre-ask/tell
        behavior when a session is built with ``executor=None``."""
        return "sequential"

    def run(self, n_iters: int) -> "TunerBase":
        """Legacy one-call driver: build a ``TuningSession`` over the tuner's
        own objective and run it. Kept as a thin shim; reproduces the
        pre-redesign observation sequence exactly."""
        from .session import TuningSession  # deferred: session imports tuner

        TuningSession(self).run(n_iters)
        return self


class _WarmGPMixin:
    """Shared GP warm-start machinery for surrogate-based tuners.

    ``warm_start=True`` (the kwarg every surrogate tuner exposes) threads
    the previous round's fitted hyperparameters into the next fit
    (``gp_warm_fit_steps`` Adam steps instead of a cold ``fit_steps``-step
    fit). The warm state is kept on device between rounds and serialized
    (exact f32 round-trip through JSON) by ``_warm_state`` /
    ``_load_warm_state``, so checkpointed runs resume bit-identically.
    """

    def _init_warm(self, warm_start: bool, gp_warm_fit_steps: int) -> None:
        self.warm_start = warm_start
        self.gp_warm_fit_steps = gp_warm_fit_steps
        self._gp_warm: Optional[GPParams] = None

    def _fit_gp(self, X, Y, fit_steps: int = 120, noise_scale=None) -> GP:
        gp = GP(
            seed=int(self.rng.integers(2**31)),
            fit_steps=fit_steps,
            warm_fit_steps=self.gp_warm_fit_steps,
        )
        gp.fit(X, Y, init=self._gp_warm if self.warm_start else None, noise_scale=noise_scale)
        if self.warm_start:
            self._gp_warm = gp.params  # kept on device; serialized lazily
        return gp

    def _warm_state(self) -> Optional[Dict[str, Any]]:
        return self._gp_warm.to_lists() if self._gp_warm is not None else None

    def _load_warm_state(self, warm: Optional[Dict[str, Any]]) -> None:
        self._gp_warm = GPParams.from_lists(warm) if warm is not None else None

    def _extra_state(self) -> Dict[str, Any]:
        return {"gp_warm": self._warm_state()}

    def _load_extra_state(self, extra: Dict[str, Any]) -> None:
        self._load_warm_state(extra.get("gp_warm"))


class VDTuner(_WarmGPMixin, TunerBase):
    """Algorithm 1: polling BO with NPI surrogate + successive abandon.

    ``engine`` selects the acquisition implementation: ``"jax"`` (default)
    runs the whole recommend path — posterior prediction, EHVI/CEI scoring,
    Kriging-believer fantasies — as one fused jitted call per round;
    ``"numpy"`` is the host-side reference. Both select identical
    configuration sequences on seeded runs (regression-tested; scores agree
    to reduction-order rounding).

    ``warm_start=True`` reuses the previous round's GP hyperparameters as
    the optimizer init with ``gp_warm_fit_steps`` Adam steps instead of a
    ``gp_fit_steps``-step cold fit — a large recommend-time saving that
    slightly perturbs the hyperparameter trajectory, so it is opt-in. The
    warm state rides in ``state_dict()`` checkpoints, keeping resumes
    bit-identical.
    """

    name = "vdtuner"

    def __init__(
        self,
        space: SearchSpace,
        objective: Optional[Objective] = None,
        seed: int = 0,
        transform=None,
        abandon_window: int = 10,
        n_candidates: int = 512,
        mc_samples: int = 64,
        gp_fit_steps: int = 120,
        rlim: Optional[float] = None,
        bootstrap_history: Optional[Sequence[Observation]] = None,
        q: int = 1,
        objective_spec: Optional[ObjectiveSpec] = None,
        engine: str = "jax",
        warm_start: bool = False,
        gp_warm_fit_steps: int = 30,
    ):
        super().__init__(space, objective, seed, transform, objective_spec)
        if q < 1:
            raise ValueError(f"q must be >= 1, got {q}")
        if engine not in ("jax", "numpy"):
            raise ValueError(f"engine must be 'jax' or 'numpy', got {engine!r}")
        self.abandon = SuccessiveAbandon(space.type_names, window=abandon_window)
        self.n_candidates = n_candidates
        self.mc_samples = mc_samples
        self.gp_fit_steps = gp_fit_steps
        self.engine = engine
        self._init_warm(warm_start, gp_warm_fit_steps)
        # user recall-floor preference (constraint mode); an ObjectiveSpec
        # carrying rlim (e.g. objectives.recall_floor) sets it implicitly
        if rlim is not None and self.spec.rlim is not None and rlim != self.spec.rlim:
            raise ValueError(
                f"conflicting recall floors: rlim={rlim} but objective_spec "
                f"{self.spec.name!r} carries rlim={self.spec.rlim}"
            )
        self.rlim = rlim if rlim is not None else self.spec.rlim
        self.q = q  # configurations proposed (and evaluated) per BO round
        self._poll_cursor = 0
        if bootstrap_history:
            # §IV-F: warm-start the surrogate with data from previous
            # constraint levels. These observations feed the GP/fronts but are
            # not re-evaluated.
            for o in bootstrap_history:
                self.history.append(dataclasses.replace(o, bootstrap=True))

    # ------------------------------------------------------------------
    # ask/tell
    # ------------------------------------------------------------------
    def ask(self, n: int = 1) -> List[Config]:
        """Recommend the next batch.

        Warm-up (Algorithm 1 lines 1–5): while any index type lacks an
        observation, the remaining per-type defaults are returned as one
        mandatory batch (possibly exceeding ``n`` — exactly the legacy
        initial sampling). Afterwards each call is one BO round proposing
        ``min(q, n)`` configurations of the polled index type.
        """
        seen = set(o.index_type for o in self.history)
        todo = [self.space.default_config(t) for t in self.space.type_names if t not in seen]
        if todo:
            return todo
        q = max(1, min(self.q, n))
        Y, types = self.Y, self.types

        # --- successive abandon (lines 7–14) ---------------------------
        self.abandon.step(Y, types)

        # --- NPI normalization + holistic surrogate (lines 15–18) ------
        mode = "balanced" if self.rlim is None else "max"
        Yn, bases = npi_normalize(Y, types, mode=mode)
        scales = np.array([o.noise_scale for o in self.history], np.float32)
        gp = self._fit_gp(
            self.X_enc, Yn, fit_steps=self.gp_fit_steps,
            noise_scale=scales if np.any(scales != 1.0) else None,
        )

        # --- poll next index type & recommend (lines 19–21) ------------
        t = self._next_poll_type()
        raw, Xc = self._candidates_encoded(t)

        if self.rlim is None:
            # EHVI with ref = 0.5 * base; in normalized space the base is
            # (1, 1), so r = (0.5, 0.5); the front is the normalized
            # non-dominated set across all types (§IV-C).
            front = Yn[non_dominated_mask(Yn)]
            ref = np.array([0.5, 0.5])
            if self.engine == "jax":
                idx = fused_qehvi_select(gp, Xc, front, ref, self.rng, q, self.mc_samples)
            else:
                idx = qehvi_sequential_greedy(
                    gp, Xc, front, ref, self.rng, q, self.mc_samples
                )
        else:
            # constraint mode: EI(speed) * Pr(recall > rlim).
            if self.engine == "jax":
                best_feasible, rlim_n = self._cei_incumbent(Y, bases, t)
                idx = fused_cei_select(gp, Xc, best_feasible, rlim_n, q)
            else:
                idx = self._cei_select(gp, Xc, Y, bases, t, q)

        return [self.space.decode(raw[i], index_type=t) for i in idx]

    def preferred_executor(self) -> str:
        # q=1 evaluated the warm-up defaults sequentially pre-redesign; q>1
        # routed batches through the backend's evaluate_batch.
        return "sequential" if self.q == 1 else "batch"

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------
    def _extra_state(self) -> Dict[str, Any]:
        return {
            "poll_cursor": int(self._poll_cursor),
            "abandon": self.abandon.state_dict(),
            "gp_warm": self._warm_state(),
        }

    def _load_extra_state(self, extra: Dict[str, Any]) -> None:
        self._poll_cursor = int(extra["poll_cursor"])
        self.abandon.load_state_dict(extra["abandon"])
        self._load_warm_state(extra.get("gp_warm"))

    # ------------------------------------------------------------------
    def _initial_sampling(self):
        """Algorithm 1 lines 1–5: each index type's default configuration.

        Legacy helper (the session/ask path emits the same batch through
        ``ask``). With ``q > 1`` the defaults go through the batch evaluation
        path; with ``q == 1`` they are evaluated sequentially exactly as
        before.
        """
        seen = set(o.index_type for o in self.history)
        todo = [self.space.default_config(t) for t in self.space.type_names if t not in seen]
        if not todo:
            return
        if self.q > 1:
            self._evaluate_batch(todo, recommend_time=0.0)
        else:
            for cfg in todo:
                self._evaluate(cfg, recommend_time=0.0)

    def _next_poll_type(self) -> str:
        remaining = self.abandon.remaining
        t = remaining[self._poll_cursor % len(remaining)]
        self._poll_cursor += 1
        return t

    def _candidates(self, t: str) -> List[Config]:
        """Candidate set within type-t's subspace: uniform + perturbations of
        the type's (and globally) best observed configurations. Thin wrapper
        decoding every row of ``_candidates_encoded`` (the recommend path
        only decodes the chosen rows)."""
        raw, _ = self._candidates_encoded(t)
        return [self.space.decode(r, index_type=t) for r in raw]

    def _candidates_encoded(self, t: str) -> Tuple[np.ndarray, np.ndarray]:
        """Bulk candidate generation: ``(raw, Xc)`` where ``raw`` rows decode
        to exactly the configs the legacy per-config loop built (identical
        RNG consumption — the uniform block is one C-order ``rng.random``
        matrix) and ``Xc = snap_encoded(raw)`` is the encoded matrix the GP
        scores, equal bit-for-bit to ``np.stack([encode(c) for c in cands])``.
        """
        n_uniform = self.n_candidates // 2
        blocks = [self.space.sample_encoded(self.rng, n_uniform, t)]
        count = n_uniform
        # exploit: perturb non-dominated configs of this type
        ys = self.Y
        nd = non_dominated_mask(ys)
        seeds = [o.config for o, keep in zip(self.history, nd) if keep and o.index_type == t]
        if not seeds:  # fall back to the type's best-speed and best-recall configs
            mine = [o for o in self.history if o.index_type == t and not o.failed]
            if mine:
                seeds = [
                    max(mine, key=lambda o: o.y[0]).config,
                    max(mine, key=lambda o: o.y[1]).config,
                ]
        if seeds:
            seeds_enc = [self.space.encode(c) for c in seeds]
            free = self.space.free_mask(t)
            rows = []
            # per-candidate draws (choice then normal) keep the generator
            # stream identical to the legacy space.perturb loop
            while count + len(rows) < self.n_candidates:
                base = seeds_enc[(count + len(rows)) % len(seeds_enc)]
                scale = float(self.rng.choice([0.05, 0.1, 0.2]))
                noise = self.rng.normal(0.0, scale, size=self.space.dims)
                rows.append(np.clip(base + noise * free, 0.0, 1.0))
            if rows:
                blocks.append(np.stack(rows))
                count += len(rows)
        if count < self.n_candidates:
            blocks.append(self.space.sample_encoded(self.rng, self.n_candidates - count, t))
        raw = np.concatenate(blocks, axis=0)
        return raw, self.space.snap_encoded(raw, t)

    def _cei_incumbent(self, Y: np.ndarray, bases: Dict[str, np.ndarray], t: str):
        """(best feasible speed, recall floor) in the polled type's
        normalized units — the CEI incumbent state (Eq. 7)."""
        base_t = bases.get(t, np.array([1.0, 1.0]))
        rlim_n = self.rlim / base_t[1]
        feas = Y[:, 1] >= self.rlim
        if feas.any():
            spd_n = np.array(
                [o.y[0] / bases[o.index_type][0] for o, f in zip(self.history, feas) if f]
            )
            best_feasible = float(spd_n.max())
        else:
            best_feasible = float("-inf")
        return best_feasible, rlim_n

    def _cei_select(
        self,
        gp: GP,
        Xc: np.ndarray,
        Y: np.ndarray,
        bases: Dict[str, np.ndarray],
        t: str,
        q: int,
    ) -> List[int]:
        """Sequential-greedy constrained-EI selection (Eq. 7) for a batch.

        Thresholds are in the polled type's normalized units. After each pick
        the Kriging-believer fantasy conditions the posterior, and — if the
        fantasy clears the recall floor — raises the feasible-speed incumbent.
        """
        best_feasible, rlim_n = self._cei_incumbent(Y, bases, t)
        state = {"best": best_feasible}

        def score(mean, std):
            return cei(mean[:, 0], std[:, 0], mean[:, 1], std[:, 1], state["best"], rlim_n)

        def on_fantasy(fantasy):
            if fantasy[1] >= rlim_n:
                state["best"] = max(state["best"], float(fantasy[0]))

        return greedy_select(gp, Xc, q, score, on_fantasy)

    def step(self, max_new: Optional[int] = None) -> List[Observation]:
        """Legacy self-driving round: ``ask`` + evaluate + ``tell`` in one
        call, against the tuner's own objective. Prefer ``TuningSession``.

        ``max_new`` clamps the batch so a run never overshoots its iteration
        budget. With ``q == 1`` the round consumes exactly the same RNG draws
        and picks the same argmax as the original single-point step.
        """
        t0 = time.perf_counter()
        cfgs = self.ask(self.q if max_new is None else max_new)
        rec_time = time.perf_counter() - t0
        return self._evaluate_batch(cfgs, recommend_time=rec_time / len(cfgs))
