"""VDTuner: polling multi-objective Bayesian optimization (paper Algorithm 1).

The tuner maximizes two objectives — (search speed, recall) by default, or
(QP$, recall) in cost-aware mode — over a `SearchSpace` whose tunable set
changes with the index type. Components:

* holistic GP surrogate over all index types (one copy of shared params),
* NPI polling normalization (Eq. 2–3),
* MC-EHVI acquisition with ref = 0.5 * per-type balanced base (Eq. 4),
* round-robin polling with successive abandon (Eq. 5–6, windowed trigger),
* optional recall-floor constraint mode with CEI (Eq. 7) and bootstrapping
  from previous constraint levels (§IV-F).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .acquisition import cei, ehvi_mc
from .budget import SuccessiveAbandon
from .gp import GP
from .normalize import npi_normalize
from .pareto import non_dominated_mask, pareto_front
from .space import Config, SearchSpace

Objective = Callable[[Config], Dict[str, float]]


class TuningFailure(RuntimeError):
    """Raised by an objective when a configuration crashes / times out."""


@dataclasses.dataclass
class Observation:
    iteration: int
    config: Config
    y: np.ndarray  # (2,) raw objective values (speed-like, recall)
    raw: Dict[str, float]
    recommend_time: float
    eval_time: float
    failed: bool = False
    bootstrap: bool = False

    @property
    def index_type(self) -> str:
        return self.config["index_type"]


def default_transform(result: Dict[str, float]) -> Tuple[float, float]:
    return float(result["speed"]), float(result["recall"])


def cost_aware_transform(eta: float = 1.0) -> Callable[[Dict[str, float]], Tuple[float, float]]:
    """Eq. 8: QP$ = speed / (eta * memory GiB). Any resource/price function can
    be swapped in here; NPI normalization makes the tuner invariant to eta."""

    def tf(result: Dict[str, float]) -> Tuple[float, float]:
        mem = max(float(result.get("mem_gib", 1.0)), 1e-9)
        return float(result["speed"]) / (eta * mem), float(result["recall"])

    return tf


class TunerBase:
    """Shared bookkeeping: evaluation with failure fallback + history."""

    name = "base"

    def __init__(
        self,
        space: SearchSpace,
        objective: Objective,
        seed: int = 0,
        transform: Callable[[Dict[str, float]], Tuple[float, float]] = default_transform,
    ):
        self.space = space
        self.objective = objective
        self.rng = np.random.default_rng(seed)
        self.transform = transform
        self.history: List[Observation] = []
        self._seed = seed

    # ------------------------------------------------------------------
    def _evaluate(self, cfg: Config, recommend_time: float) -> Observation:
        t0 = time.perf_counter()
        failed = False
        try:
            raw = self.objective(cfg)
            y = np.asarray(self.transform(raw), np.float64)
            if not np.all(np.isfinite(y)):
                raise TuningFailure("non-finite objective")
        except TuningFailure:
            # paper §V-A: failed configs get the worst values in history
            failed = True
            raw = {}
            y = self._worst_so_far()
        obs = Observation(
            iteration=len(self.history),
            config=cfg,
            y=y,
            raw=raw,
            recommend_time=recommend_time,
            eval_time=time.perf_counter() - t0,
            failed=failed,
        )
        self.history.append(obs)
        return obs

    def _worst_so_far(self) -> np.ndarray:
        ys = [o.y for o in self.history if not o.failed]
        if not ys:
            return np.array([1e-6, 1e-6])
        return np.min(np.stack(ys), axis=0)

    # --- views ----------------------------------------------------------
    @property
    def X_enc(self) -> np.ndarray:
        return np.stack([self.space.encode(o.config) for o in self.history])

    @property
    def Y(self) -> np.ndarray:
        return np.stack([o.y for o in self.history])

    @property
    def types(self) -> np.ndarray:
        return np.array([o.index_type for o in self.history])

    def pareto(self) -> np.ndarray:
        return pareto_front(self.Y)

    def best_speed_at_recall(self, rlim: float) -> float:
        """Best observed speed among configs with recall >= rlim (paper Fig. 6)."""
        ys = self.Y
        ok = ys[:, 1] >= rlim
        return float(ys[ok, 0].max()) if ok.any() else float("nan")

    def run(self, n_iters: int) -> "TunerBase":
        raise NotImplementedError


class VDTuner(TunerBase):
    """Algorithm 1: polling BO with NPI surrogate + successive abandon."""

    name = "vdtuner"

    def __init__(
        self,
        space: SearchSpace,
        objective: Objective,
        seed: int = 0,
        transform=default_transform,
        abandon_window: int = 10,
        n_candidates: int = 512,
        mc_samples: int = 64,
        gp_fit_steps: int = 120,
        rlim: Optional[float] = None,
        bootstrap_history: Optional[Sequence[Observation]] = None,
    ):
        super().__init__(space, objective, seed, transform)
        self.abandon = SuccessiveAbandon(space.type_names, window=abandon_window)
        self.n_candidates = n_candidates
        self.mc_samples = mc_samples
        self.gp_fit_steps = gp_fit_steps
        self.rlim = rlim  # user recall-floor preference (constraint mode)
        self._poll_cursor = 0
        if bootstrap_history:
            # §IV-F: warm-start the surrogate with data from previous
            # constraint levels. These observations feed the GP/fronts but are
            # not re-evaluated.
            for o in bootstrap_history:
                self.history.append(dataclasses.replace(o, bootstrap=True))

    # ------------------------------------------------------------------
    def _initial_sampling(self):
        """Algorithm 1 lines 1–5: each index type's default configuration."""
        seen = set(o.index_type for o in self.history)
        for t in self.space.type_names:
            if t in seen:
                continue  # bootstrapped data already covers this type
            self._evaluate(self.space.default_config(t), recommend_time=0.0)

    def _next_poll_type(self) -> str:
        remaining = self.abandon.remaining
        t = remaining[self._poll_cursor % len(remaining)]
        self._poll_cursor += 1
        return t

    def _candidates(self, t: str) -> List[Config]:
        """Candidate set within type-t's subspace: uniform + perturbations of
        the type's (and globally) best observed configurations."""
        n_uniform = self.n_candidates // 2
        cands = self.space.sample(self.rng, n_uniform, index_type=t)
        # exploit: perturb non-dominated configs of this type
        ys = self.Y
        nd = non_dominated_mask(ys)
        seeds = [o.config for o, keep in zip(self.history, nd) if keep and o.index_type == t]
        if not seeds:  # fall back to the type's best-speed and best-recall configs
            mine = [o for o in self.history if o.index_type == t and not o.failed]
            if mine:
                seeds = [
                    max(mine, key=lambda o: o.y[0]).config,
                    max(mine, key=lambda o: o.y[1]).config,
                ]
        while len(cands) < self.n_candidates and seeds:
            base = seeds[len(cands) % len(seeds)]
            scale = float(self.rng.choice([0.05, 0.1, 0.2]))
            cands.append(self.space.perturb(self.rng, base, scale=scale))
        if len(cands) < self.n_candidates:
            cands += self.space.sample(self.rng, self.n_candidates - len(cands), index_type=t)
        return cands

    def step(self) -> Observation:
        t0 = time.perf_counter()
        Y, types = self.Y, self.types

        # --- successive abandon (lines 7–14) ---------------------------
        self.abandon.step(Y, types)

        # --- NPI normalization + holistic surrogate (lines 15–18) ------
        mode = "balanced" if self.rlim is None else "max"
        Yn, bases = npi_normalize(Y, types, mode=mode)
        gp = GP(seed=int(self.rng.integers(2**31)), fit_steps=self.gp_fit_steps)
        gp.fit(self.X_enc, Yn)

        # --- poll next index type & recommend (lines 19–21) ------------
        t = self._next_poll_type()
        cands = self._candidates(t)
        Xc = np.stack([self.space.encode(c) for c in cands])
        mean, std = gp.predict(Xc)

        if self.rlim is None:
            # EHVI with ref = 0.5 * base; in normalized space the base is
            # (1, 1), so r = (0.5, 0.5); the front is the normalized
            # non-dominated set across all types (§IV-C).
            front = Yn[non_dominated_mask(Yn)]
            ref = np.array([0.5, 0.5])
            acq = ehvi_mc(mean, std, front, ref, self.rng, self.mc_samples)
        else:
            # constraint mode: EI(speed) * Pr(recall > rlim), thresholds in the
            # candidate type's normalized units.
            base_t = bases.get(t, np.array([1.0, 1.0]))
            rlim_n = self.rlim / base_t[1]
            feas = Y[:, 1] >= self.rlim
            if feas.any():
                spd_n = np.array(
                    [o.y[0] / bases[o.index_type][0] for o, f in zip(self.history, feas) if f]
                )
                best_feasible = float(spd_n.max())
            else:
                best_feasible = float("-inf")
            acq = cei(mean[:, 0], std[:, 0], mean[:, 1], std[:, 1], best_feasible, rlim_n)

        cfg = cands[int(np.argmax(acq))]
        rec_time = time.perf_counter() - t0

        # --- evaluate & update (line 22) --------------------------------
        return self._evaluate(cfg, recommend_time=rec_time)

    def run(self, n_iters: int) -> "VDTuner":
        self._initial_sampling()
        while len([o for o in self.history if not o.bootstrap]) < n_iters:
            self.step()
        return self
