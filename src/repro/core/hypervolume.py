"""Exact 2-D hypervolume and vectorized hypervolume improvement.

The paper's objectives are always two (search speed & recall, or QP$ &
recall), so the exact 2-D staircase computation is both faster and more
accurate than a general WFG implementation.  Maximization convention; points
at or below the reference point contribute nothing.
"""
from __future__ import annotations

import numpy as np


def hv_2d(front: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume of the region dominated by `front` and above `ref` (2-D)."""
    front = np.asarray(front, np.float64).reshape(-1, 2)
    ref = np.asarray(ref, np.float64).reshape(2)
    if front.size == 0:
        return 0.0
    f = front[np.all(front > ref, axis=1)]
    if f.size == 0:
        return 0.0
    # sort by f1 desc; on the Pareto staircase f2 then increases
    order = np.argsort(-f[:, 0], kind="stable")
    f = f[order]
    hv = 0.0
    # sweep from the largest f1: each point adds (f1 - ref1) * (f2 - best f2 so far)
    best_f2 = ref[1]
    for x1, x2 in f:
        if x2 > best_f2:
            hv += (x1 - ref[0]) * (x2 - best_f2)
            best_f2 = x2
    return float(hv)


def _staircase(front: np.ndarray, ref: np.ndarray):
    """Segments [a_k, b_k) along obj-1 with staircase height h_k along obj-2.

    Heights are the max obj-2 value among front points whose obj-1 >= the
    segment, i.e. the dominated-region upper boundary. Segment 0 starts at
    ref1; the final (open-ended) segment has height ref2.
    """
    front = np.asarray(front, np.float64).reshape(-1, 2)
    front = front[np.all(front > ref, axis=1)]
    if front.shape[0] == 0:
        return (
            np.array([ref[0]]),
            np.array([np.inf]),
            np.array([ref[1]]),
        )
    order = np.argsort(-front[:, 0], kind="stable")
    f = front[order]  # f1 descending
    # heights[i] = max f2 among points with f1 >= f[i,0]  (cummax along desc f1)
    heights = np.maximum.accumulate(f[:, 1])
    # ascending breakpoints: segment i = (xs[i-1], xs[i]] has height H_i where
    # H_i = max f2 over points with f1 >= any x in that segment.
    xs = np.concatenate([[ref[0]], f[::-1, 0]])  # ascending f1 breakpoints
    a = np.concatenate([xs[:-1], [xs[-1]]])
    b = np.concatenate([xs[1:], [np.inf]])
    h = np.concatenate([heights[::-1], [ref[1]]])
    return a, b, h


def hvi_2d(points: np.ndarray, front: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Exclusive hypervolume improvement of each point w.r.t. `front` (2-D).

    Vectorized over points: HVI(y) = sum over staircase segments of
    overlap([ref1, y1], seg) * max(0, y2 - seg_height).
    """
    pts = np.asarray(points, np.float64).reshape(-1, 2)
    ref = np.asarray(ref, np.float64).reshape(2)
    a, b, h = _staircase(front, ref)
    y1 = np.maximum(pts[:, 0], ref[0])[:, None]
    y2 = pts[:, 1][:, None]
    overlap = np.clip(np.minimum(y1, b[None, :]) - a[None, :], 0.0, None)
    gain = np.clip(y2 - np.maximum(h, ref[1])[None, :], 0.0, None)
    hvi = np.sum(overlap * gain, axis=1)
    # points not strictly above ref in both objectives contribute nothing
    hvi[~np.all(pts > ref, axis=1)] = 0.0
    return hvi
