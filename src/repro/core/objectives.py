"""First-class tuning objectives and the evaluation-backend protocol.

This module decouples the three roles the old API fused into one callable:

* :class:`ObjectiveSpec` — what the tuner optimizes: per-objective names,
  directions, and the transform from a raw measurement dict to the objective
  vector. Built-ins cover the paper's three modes: plain speed x recall
  (Eq. 1), the recall-floor user preference (Eq. 7's constraint target), and
  cost-aware QP$ (Eq. 8).
* :class:`EvalBackend` — who produces raw measurements: any per-config
  callable, optionally exposing a vectorized ``evaluate_batch``.
  :func:`as_eval_backend` upgrades a bare callable with a sequential batch
  adapter so every backend speaks the same protocol.
* :class:`TuningFailure` — how a crashed/timed-out configuration is reported.
  It lives here (rather than in ``tuner``) so backends can depend on the
  protocol module alone; ``repro.core.tuner`` re-exports it unchanged.

Recommenders (``ask``/``tell`` tuners) consume :class:`ObjectiveSpec`;
``TuningSession`` consumes :class:`EvalBackend`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Protocol, Sequence, Tuple, Union, runtime_checkable

Config = Dict[str, Any]
RawResult = Dict[str, float]


class TuningFailure(RuntimeError):
    """Raised by an evaluation backend when a configuration crashes / times out.

    ``transient=True`` marks failures caused by environment faults (injected
    chaos, lost segments, flaky builds) rather than the configuration itself:
    the session retries those with backoff instead of telling the tuner
    worst-value feedback, so the GP only ever sees genuine config faults.
    """

    def __init__(self, message: str = "", transient: bool = False):
        super().__init__(message)
        self.transient = bool(transient)


EvalResult = Union[RawResult, TuningFailure]


# ---------------------------------------------------------------------------
# Objective specifications
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """What a tuner maximizes: named objectives + the raw-result transform.

    ``transform`` maps a backend's raw measurement dict to the objective
    vector in ``names`` order. ``directions`` is one of ``"max"``/``"min"``
    per objective; the MOBO core currently maximizes, so minimized objectives
    must be negated inside the transform (``directions`` then documents the
    original sense). ``rlim`` carries the recall-floor user preference:
    tuners that support constraint mode (VDTuner's CEI, Eq. 7) adopt it as
    their default floor.
    """

    name: str
    names: Tuple[str, ...] = ("speed", "recall")
    transform: Callable[[RawResult], Tuple[float, ...]] = None  # type: ignore[assignment]
    directions: Tuple[str, ...] = ()
    rlim: float | None = None

    def __post_init__(self):
        if self.transform is None:
            object.__setattr__(self, "transform", default_transform)
        if not self.directions:
            object.__setattr__(self, "directions", ("max",) * len(self.names))
        if len(self.directions) != len(self.names):
            raise ValueError(
                f"{self.name}: {len(self.names)} objective names but "
                f"{len(self.directions)} directions"
            )
        bad = set(self.directions) - {"max", "min"}
        if bad:
            raise ValueError(f"{self.name}: invalid directions {sorted(bad)}")

    @property
    def n_objectives(self) -> int:
        return len(self.names)

    def __call__(self, raw: RawResult) -> Tuple[float, ...]:
        return tuple(self.transform(raw))


def default_transform(result: RawResult) -> Tuple[float, float]:
    return float(result["speed"]), float(result["recall"])


def cost_aware_transform(eta: float = 1.0) -> Callable[[RawResult], Tuple[float, float]]:
    """Eq. 8: QP$ = speed / (eta * memory GiB). Any resource/price function can
    be swapped in here; NPI normalization makes the tuner invariant to eta."""

    def tf(result: RawResult) -> Tuple[float, float]:
        mem = max(float(result.get("mem_gib", 1.0)), 1e-9)
        return float(result["speed"]) / (eta * mem), float(result["recall"])

    return tf


def speed_recall() -> ObjectiveSpec:
    """Paper Eq. 1: maximize (search speed, recall) jointly."""
    return ObjectiveSpec(name="speed_recall")


def recall_floor(rlim: float) -> ObjectiveSpec:
    """§IV-F user preference: maximize speed subject to recall >= ``rlim``.

    Tuners with a constraint mode (VDTuner) switch to CEI (Eq. 7); others
    still see both objectives and simply report feasible bests.
    """
    if not 0.0 < rlim <= 1.0:
        raise ValueError(f"rlim must be in (0, 1], got {rlim}")
    return ObjectiveSpec(name=f"recall_floor@{rlim:g}", rlim=float(rlim))


def cost_aware(eta: float = 1.0, rlim: float | None = None) -> ObjectiveSpec:
    """Eq. 8 cost-effectiveness: maximize (QP$, recall), optionally floored."""
    return ObjectiveSpec(
        name=f"cost_aware@{eta:g}",
        names=("qpd", "recall"),
        transform=cost_aware_transform(eta),
        rlim=rlim,
    )


def sustained_transform(alpha: float = 1.0) -> Callable[[RawResult], Tuple[float, float]]:
    """Streaming-replay transform: *sustained* throughput charges the
    incremental seal / compaction index-build seconds against serving time
    (weighted by ``alpha``), so configs that seal tiny segments constantly
    can't fake high search-only QPS. Falls back to plain ``speed`` for raw
    results without the streaming diagnostics (static measurements)."""

    def tf(result: RawResult) -> Tuple[float, float]:
        n = float(result.get("n_searches", 0.0))
        if n <= 0.0:
            return float(result["speed"]), float(result["recall"])
        busy = float(result.get("search_s", 0.0)) + alpha * float(result.get("seal_build_s", 0.0))
        return n / max(busy, 1e-9), float(result["recall"])

    return tf


def streaming_sustained(alpha: float = 1.0, rlim: float | None = None) -> ObjectiveSpec:
    """Streaming regime: maximize (sustained QPS, time-aware recall).

    ``alpha`` is the ingest-overhead weight: 0 reproduces search-only QPS;
    1 (default) counts every incremental build second as lost serving time.
    """
    return ObjectiveSpec(
        name=f"streaming@{alpha:g}",
        names=("sustained_qps", "recall"),
        transform=sustained_transform(alpha),
        rlim=rlim,
    )


def promotion_score(
    raw: RawResult, rlim: float | None = None, alpha: float = 1.0
) -> Tuple[float, float]:
    """SLO-constrained lexicographic score for shadow/canary comparisons.

    Returns ``(feasible, value)`` meant for tuple comparison: a config
    meeting the recall floor always beats one that does not; among feasible
    configs sustained QPS decides (``alpha`` weighs ingest overhead exactly
    as in :func:`sustained_transform`); among infeasible configs the higher
    recall wins — the least-bad candidate while the floor is unreachable.
    The serving controller promotes a canary iff its score strictly exceeds
    the incumbent's.
    """
    qps, recall = sustained_transform(alpha)(raw)
    feasible = rlim is None or recall >= rlim
    return (1.0 if feasible else 0.0, qps if feasible else recall)


#: Registry of built-in objective factories (name -> factory).
OBJECTIVES: Dict[str, Callable[..., ObjectiveSpec]] = {
    "speed_recall": speed_recall,
    "recall_floor": recall_floor,
    "cost_aware": cost_aware,
    "streaming": streaming_sustained,
}


def spec_from_transform(
    transform: Callable[[RawResult], Tuple[float, ...]] | None,
) -> ObjectiveSpec:
    """Back-compat shim: wrap a bare ``transform`` callable (the old API) in an
    anonymous :class:`ObjectiveSpec`."""
    if transform is None or transform is default_transform:
        return speed_recall()
    return ObjectiveSpec(name="custom", transform=transform)


# ---------------------------------------------------------------------------
# Evaluation backends
# ---------------------------------------------------------------------------
@runtime_checkable
class EvalBackend(Protocol):
    """A measurement service: per-config evaluation + vectorized batches.

    ``__call__`` measures one configuration and returns the raw result dict
    (raising :class:`TuningFailure` for crashed configs). ``evaluate_batch``
    measures many, returning one entry per input config aligned with the
    input — either the raw dict or the ``TuningFailure`` instance; it never
    raises per-config (callers decide failure semantics).
    """

    def __call__(self, config: Config) -> RawResult: ...

    def evaluate_batch(self, configs: Sequence[Config]) -> List[EvalResult]: ...


class SequentialBatchMixin:
    """Default adapter: gives any per-config callable the batch half of the
    :class:`EvalBackend` protocol by evaluating sequentially.

    Backends with real batch structure (dedupe, threaded builds, vectorized
    measurement — see ``VDMSTuningEnv``) override ``evaluate_batch``; plain
    environments like ``ServeTuningEnv`` inherit this one for free.
    """

    def evaluate_batch(self, configs: Sequence[Config]) -> List[EvalResult]:
        out: List[EvalResult] = []
        for cfg in configs:
            try:
                out.append(self(cfg))  # type: ignore[operator]
            except TuningFailure as e:
                out.append(e)
        return out


class _CallableBackend(SequentialBatchMixin):
    """Wraps a bare objective function into a full :class:`EvalBackend`."""

    def __init__(self, fn: Callable[[Config], RawResult]):
        self._fn = fn

    def __call__(self, config: Config) -> RawResult:
        return self._fn(config)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_CallableBackend({self._fn!r})"


def as_eval_backend(objective: Callable[[Config], RawResult]) -> EvalBackend:
    """Upgrade ``objective`` to the full protocol. Objects that already expose
    ``evaluate_batch`` are returned unchanged."""
    if hasattr(objective, "evaluate_batch"):
        return objective  # type: ignore[return-value]
    return _CallableBackend(objective)
