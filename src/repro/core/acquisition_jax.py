"""Device-resident acquisition engine.

JAX ports of the acquisition stack (2-D staircase HVI, MC-EHVI, EI,
constrained EI) plus a *fused* sequential-greedy batch selector: one jitted
call produces the whole q-batch — GP posterior prediction over the candidate
matrix, acquisition scoring, the availability-masked argmax, the
Kriging-believer fantasy (an exact rank-1 bordered-Cholesky append, reusing
the prediction solve), and the running front / feasible-incumbent update all
stay on device across the ``lax.scan`` over picks. The numpy implementations
in :mod:`.acquisition` / :mod:`.hypervolume` remain the references this
module is property-tested against.

Numerics
--------
The GP math runs in float32 with the same operation sequence as
``gp._predict_padded`` / ``gp._append_rows``; acquisition scores are then
computed in float64 (under a local ``jax.experimental.enable_x64`` scope)
exactly like the numpy path, which does float64 scoring on the float32
posterior. Selected indices are argmax-equivalent to the numpy path up to
reduction-order rounding (~1e-12 relative on the scores) — seeded q=1/q=4
tuner runs select identical configuration sequences (regression-tested in
``tests/test_acquisition_jax.py``).

Shapes are jit-stable: training arrays use the GP's inert PAD rows
(pre-grown so all q fantasies fit), fronts are padded to multiples of
``FRONT_PAD`` with a validity mask (dominated/masked points never change the
staircase, so fantasies are appended without re-filtering).
"""
from __future__ import annotations

from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .gp import _JITTER, _NOISE_FLOOR, matern52

FRONT_PAD = 16

_SQRT2 = float(np.sqrt(2.0))


def _phi(z):
    return jnp.exp(-0.5 * z * z) / np.sqrt(2.0 * np.pi)


def _Phi(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z / _SQRT2))


# ---------------------------------------------------------------------------
# hypervolume improvement (2-D staircase, fixed padded front)
# ---------------------------------------------------------------------------
def hvi_2d_jax(points, front, front_mask, ref):
    """Exclusive HVI of each point w.r.t. the masked ``front`` (2-D).

    Mirrors ``hypervolume.hvi_2d``; masked-out (and below-ref) front rows are
    pinned to ``ref`` where they form zero-width segments, so a padded front
    gives bit-comparable results to the unpadded numpy staircase.
    """
    valid = front_mask & jnp.all(front > ref[None, :], axis=1)
    f1 = jnp.where(valid, front[:, 0], ref[0])
    f2 = jnp.where(valid, front[:, 1], ref[1])
    order = jnp.argsort(-f1, stable=True)  # f1 descending
    f1s = f1[order]
    f2s = f2[order]
    heights = jax.lax.cummax(f2s)  # max f2 among points with f1 >= f1s[i]
    xs = jnp.concatenate([ref[:1], f1s[::-1]])  # ascending breakpoints
    a = jnp.concatenate([xs[:-1], xs[-1:]])
    b = jnp.concatenate([xs[1:], jnp.full((1,), jnp.inf, xs.dtype)])
    h = jnp.concatenate([heights[::-1], ref[1:]])
    y1 = jnp.maximum(points[:, 0], ref[0])[:, None]
    y2 = points[:, 1][:, None]
    overlap = jnp.clip(jnp.minimum(y1, b[None, :]) - a[None, :], 0.0, None)
    gain = jnp.clip(y2 - jnp.maximum(h, ref[1])[None, :], 0.0, None)
    hvi = jnp.sum(overlap * gain, axis=1)
    return jnp.where(jnp.all(points > ref[None, :], axis=1), hvi, 0.0)


def ehvi_mc_jax(mean, std, front, front_mask, ref, eps):
    """MC-EHVI with externally supplied normal draws ``eps`` (S, c, 2) — the
    host draws them from the tuner's generator so RNG consumption matches
    the numpy path exactly."""
    samples = mean[None] + std[None] * eps  # (S, c, 2)
    flat = samples.reshape(-1, 2)
    hvi = hvi_2d_jax(flat, front, front_mask, ref).reshape(eps.shape[0], -1)
    return hvi.mean(axis=0)


def ei_jax(mean, std, best):
    """Closed-form expected improvement (maximization)."""
    std = jnp.maximum(std, 1e-12)
    z = (mean - best) / std
    return (mean - best) * _Phi(z) + std * _phi(z)


def cei_jax(mean_spd, std_spd, mean_rec, std_rec, best_feasible, rlim):
    """Constrained EI (paper Eq. 7): EI(speed) * Pr(recall > rlim)."""
    p_feas = 1.0 - _Phi((rlim - mean_rec) / jnp.maximum(std_rec, 1e-12))
    finite = jnp.isfinite(best_feasible)
    safe_best = jnp.where(finite, best_feasible, 0.0)
    return jnp.where(finite, ei_jax(mean_spd, std_spd, safe_best) * p_feas, p_feas)


# ---------------------------------------------------------------------------
# fused sequential-greedy selection
# ---------------------------------------------------------------------------
def _posterior_stats(log_ls, log_sf, x, mask, chol, alpha, Xc):
    """(mean, var, v) over candidates — same op sequence as
    ``gp._predict_padded`` (f32); ``v`` is reused for the rank-1 append."""
    ks = jax.vmap(lambda ls, sf: matern52(Xc, x, ls, sf))(log_ls, log_sf) * mask[None, None, :]
    mean = jax.vmap(lambda K, a: K @ a)(ks, alpha)  # (m, c)
    v = jax.vmap(lambda L, K: jax.scipy.linalg.solve_triangular(L, K.T, lower=True))(chol, ks)
    sf2 = jnp.exp(2.0 * log_sf)
    var = jnp.maximum(sf2[:, None] - jnp.sum(v * v, axis=1), 1e-10)
    return mean, var, v  # (m, c), (m, c), (m, n_pad, c)


def _greedy_scan(params, gp_arrays, Xc, y_mean, y_std, score_fn, update_fn, extra0, xs, q):
    """Shared scan over q picks: predict -> score -> masked argmax ->
    append fantasy (rank-1, exact). ``score_fn(mean64, std64, extra, inp)``
    returns f64 scores; ``update_fn(extra, fantasy64)`` folds the pick's
    fantasy into the incumbent state."""
    log_ls, log_sf, log_noise = params
    x0, y0, mask0, chol0, alpha0 = gp_arrays
    sf2 = jnp.exp(2.0 * log_sf)
    row_noise = sf2 * (_NOISE_FLOOR + _JITTER) + jnp.exp(2.0 * log_noise)  # (m,)
    kself = jax.vmap(
        lambda ls, sf: matern52(jnp.zeros((1, x0.shape[1]), x0.dtype),
                                jnp.zeros((1, x0.shape[1]), x0.dtype), ls, sf)[0, 0]
    )(log_ls, log_sf)

    def body(carry, inp):
        x, y, mask, chol, alpha, avail, extra = carry
        mean_s, var, v = _posterior_stats(log_ls, log_sf, x, mask, chol, alpha, Xc)
        # destandardize in f32 exactly like GP.predict, then score in f64
        mean32 = mean_s.T * y_std[None, :] + y_mean[None, :]  # (c, m)
        std32 = jnp.sqrt(var).T * y_std[None, :]
        mean64 = mean32.astype(jnp.float64)
        std64 = std32.astype(jnp.float64)
        acq = jnp.where(avail, score_fn(mean64, std64, extra, inp), -jnp.inf)
        i = jnp.argmax(acq)
        avail = avail.at[i].set(False)
        extra = update_fn(extra, mean64[i])
        # Kriging-believer fantasy: standardize the f32 posterior mean like
        # condition_on does, append as a bordered-Cholesky row (w = the
        # prediction solve's column i — no second triangular solve needed)
        y_new = (mean32[i] - y_mean) / y_std  # (m,) f32
        r = jnp.sum(mask).astype(jnp.int32)
        w = v[:, :, i]  # (m, n_pad); 0 at rows >= r (inert pads)
        l_rr = jnp.sqrt(jnp.maximum(kself + row_noise - jnp.sum(w * w, axis=1), 1e-10))
        chol = chol.at[:, r, :].set(w)
        chol = chol.at[:, r, r].set(l_rr)
        x = x.at[r].set(Xc[i])
        y = y.at[r].set(y_new)
        mask = mask.at[r].set(1.0)
        alpha = jax.vmap(
            lambda L, y_col: jax.scipy.linalg.cho_solve((L, True), y_col), in_axes=(0, 1)
        )(chol, y)
        return (x, y, mask, chol, alpha, avail, extra), i

    avail0 = jnp.ones((Xc.shape[0],), bool)
    carry0 = (x0, y0, mask0, chol0, alpha0, avail0, extra0)
    _, picks = jax.lax.scan(body, carry0, xs, length=q)
    return picks


@partial(jax.jit, static_argnames=("q",))
def _fused_qehvi(log_ls, log_sf, log_noise, x, y, mask, chol, alpha, y_mean, y_std,
                 Xc, front, front_mask, ref, eps, q: int):
    k0 = jnp.sum(front_mask).astype(jnp.int32)

    def score_fn(mean64, std64, extra, eps_j):
        fr, fm, _ = extra
        return ehvi_mc_jax(mean64, std64, fr, fm, ref, eps_j)

    def update_fn(extra, fantasy64):
        fr, fm, n_added = extra
        fr = fr.at[k0 + n_added].set(fantasy64)
        fm = fm.at[k0 + n_added].set(True)
        return (fr, fm, n_added + 1)

    extra0 = (front, front_mask, jnp.asarray(0, jnp.int32))
    return _greedy_scan(
        (log_ls, log_sf, log_noise), (x, y, mask, chol, alpha),
        Xc, y_mean, y_std, score_fn, update_fn, extra0, eps, q,
    )


@partial(jax.jit, static_argnames=("q",))
def _fused_cei(log_ls, log_sf, log_noise, x, y, mask, chol, alpha, y_mean, y_std,
               Xc, best_feasible, rlim_n, q: int):
    def score_fn(mean64, std64, extra, _inp):
        return cei_jax(mean64[:, 0], std64[:, 0], mean64[:, 1], std64[:, 1], extra, rlim_n)

    def update_fn(best, fantasy64):
        return jnp.where(fantasy64[1] >= rlim_n, jnp.maximum(best, fantasy64[0]), best)

    return _greedy_scan(
        (log_ls, log_sf, log_noise), (x, y, mask, chol, alpha),
        Xc, y_mean, y_std, score_fn, update_fn, best_feasible, None, q,
    )


def _gp_operands(gp, n_extra: int):
    """Pre-grow the GP so all fantasies fit (exact block extension), and
    unpack the device operands of the fused call."""
    g = gp.with_capacity(gp.n_real + n_extra)
    s = g.state
    return (
        s.params.log_ls, s.params.log_sf, s.params.log_noise,
        s.x, s.y, s.mask, s.chol, s.alpha, s.y_mean, s.y_std,
    )


def _padded_front(front: np.ndarray, q: int):
    k0 = front.shape[0]
    k_pad = int(np.ceil((k0 + q) / FRONT_PAD) * FRONT_PAD)
    fp = np.zeros((k_pad, 2), np.float64)
    fm = np.zeros((k_pad,), bool)
    fp[:k0] = front
    fm[:k0] = True
    return fp, fm


def fused_qehvi_select(gp, Xc: np.ndarray, front: np.ndarray, ref: np.ndarray,
                       rng: np.random.Generator, q: int, n_samples: int = 64) -> List[int]:
    """Device-resident sequential-greedy q-EHVI: one jitted call per round.

    Argmax-equivalent to ``acquisition.qehvi_sequential_greedy`` and consumes
    the generator identically (q draws of (n_samples, c, 2) normals).
    """
    q = min(int(q), Xc.shape[0])
    eps = np.stack([rng.standard_normal((n_samples, Xc.shape[0], 2)) for _ in range(q)])
    fp, fm = _padded_front(np.asarray(front, np.float64).reshape(-1, 2), q)
    ops = _gp_operands(gp, q)
    with enable_x64():
        picks = _fused_qehvi(
            *ops, jnp.asarray(np.asarray(Xc, np.float32)), fp, fm,
            np.asarray(ref, np.float64), eps, q=q,
        )
        picks = np.asarray(picks)
    return [int(i) for i in picks]


def fused_cei_select(gp, Xc: np.ndarray, best_feasible: float, rlim_n: float,
                     q: int) -> List[int]:
    """Device-resident sequential-greedy constrained-EI batch selection."""
    q = min(int(q), Xc.shape[0])
    ops = _gp_operands(gp, q)
    with enable_x64():
        picks = _fused_cei(
            *ops, jnp.asarray(np.asarray(Xc, np.float32)),
            np.float64(best_feasible), np.float64(rlim_n), q=q,
        )
        picks = np.asarray(picks)
    return [int(i) for i in picks]
