"""AdamW in pure JAX: f32 moments sharded like the parameters (no separate
master copy — update math runs in f32 and casts back to the param dtype).
Optional gradient compression hooks (see ``compression.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: Any  # f32 pytree like params
    v: Any  # f32 pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def state_specs(params_shapes: Any) -> AdamWState:
    """ShapeDtypeStruct pytree for dry-run lowering."""
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_shapes
    )
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=zeros, v=zeros)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(
    params: Any,
    grads: Any,
    state: AdamWState,
    cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
) -> Tuple[Any, AdamWState]:
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
