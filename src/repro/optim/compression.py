"""Gradient compression for cheaper cross-pod all-reduces.

* ``bf16``    — cast gradients to bf16 before the all-reduce (2x wire bytes).
* ``int8_ef`` — per-tensor-scaled int8 quantization with error feedback: the
  quantization residual is carried to the next step, so the compressed
  estimator stays unbiased over time (standard EF-SGD construction).

On the production mesh the quantize happens before the gradient psum (GSPMD
all-reduces the quantized values); numerically everything here is expressed
as quantize -> dequantize so the same code is exact on one host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | bf16 | int8_ef


def _quant_int8(g: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def compress_grads(
    grads: Any, ef_state: Optional[Any], cfg: CompressionConfig
) -> Tuple[Any, Optional[Any]]:
    if cfg.kind == "none":
        return grads, ef_state
    if cfg.kind == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
        ), ef_state
    if cfg.kind == "int8_ef":
        assert ef_state is not None, "int8_ef needs an error-feedback state"

        def one(g, e):
            target = g.astype(jnp.float32) + e
            q = _quant_int8(target)
            return q, target - q

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef_state)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
            [o[1] for o in out]
        )
    raise ValueError(cfg.kind)
