"""Beyond-paper integration: VDTuner's MOBO applied to THIS framework's own
training/serving stack.

The mapping mirrors the VDMS problem exactly:
* "index type"      -> remat strategy (categorical; each strategy changes the
                       compute/memory trade-off the way an ANNS index changes
                       the speed/recall trade-off — and the tunable-set can
                       differ per strategy, the paper's non-fixed-space case),
* index parameters  -> flash-attention block sizes (bq, bk),
* system parameters -> sequence-parallel residuals (on/off), microbatching,
* objectives        -> (estimated step throughput, per-device memory headroom)
                       derived from the COMPILED dry-run artifact: an
                       expensive, black-box, conflicting pair — precisely
                       MOBO's regime.

Each evaluation is a real XLA compile + roofline extraction, taking seconds
to minutes — the same cost profile as the paper's index-rebuild evaluations.
"""
from __future__ import annotations

from typing import Dict


from ..configs.base import SHAPES, ArchConfig
from ..core.objectives import SequentialBatchMixin, TuningFailure
from ..core.space import Param, SearchSpace
from ..distributed.sharding import ShardingRules
from ..kernels import flash_xla
from ..launch import hlo_analysis
from ..launch.dryrun import _compile_step, _costs_of, model_flops_for

HBM_PER_DEV = 16 * 2**30  # v5e


def make_serving_space() -> SearchSpace:
    return SearchSpace(
        index_types={
            "remat_nothing": [],
            "remat_dots": [],
            "remat_dots_no_batch": [],
        },
        system_params=[
            Param("flash_bq", "grid", choices=(128, 256, 512, 1024), default=512),
            Param("flash_bk", "grid", choices=(256, 512, 1024, 2048), default=1024),
            Param("seq_parallel", "cat", choices=(False, True), default=True),
        ],
    )


_REMAT = {
    "remat_nothing": "nothing",
    "remat_dots": "dots",
    "remat_dots_no_batch": "dots_no_batch",
}


class ServeTuningEnv(SequentialBatchMixin):
    """config -> {'speed': est. steps/s at the roofline, 'recall': memory
    headroom fraction} for one (arch, shape, mesh).

    A full ``EvalBackend``: the ``SequentialBatchMixin`` base supplies the
    ``evaluate_batch`` half of the protocol (compiles are process-global via
    the flash-block default, so batches evaluate one at a time)."""

    def __init__(self, cfg: ArchConfig, shape_name: str, mesh):
        self.cfg = cfg
        self.shape = SHAPES[shape_name]
        self.mesh = mesh
        self.cache: Dict = {}

    def __call__(self, config) -> Dict[str, float]:
        key = tuple(sorted((k, str(v)) for k, v in config.items()))
        if key in self.cache:
            return dict(self.cache[key])
        remat = _REMAT[config["index_type"]]
        # save the blocks actually in effect — restoring hardcoded defaults
        # would clobber a caller's own set_default_blocks override
        prev_blocks = flash_xla.get_default_blocks()
        flash_xla.set_default_blocks(config["flash_bq"], config["flash_bk"])
        try:
            rules = ShardingRules(self.mesh, seq_parallel=bool(config["seq_parallel"]))
            _, compiled = _compile_step(self.cfg, self.shape, self.mesh, rules, remat)
            costs = _costs_of(compiled)
            chips = self.mesh.devices.size
            roof = hlo_analysis.Roofline(
                arch=self.cfg.name, shape=self.shape.name, mesh="tune", chips=chips,
                hlo_flops=costs["flops"] * chips, hlo_bytes=costs["bytes"] * chips,
                coll_bytes=float(sum(costs["coll_bytes"].values())) * chips,
                coll_breakdown={}, coll_counts={},
                model_flops=model_flops_for(self.cfg, self.shape),
                peak_mem_per_dev=float(compiled.memory_analysis().temp_size_in_bytes),
            )
            step_s = max(roof.compute_s, roof.memory_s, roof.collective_s)
            headroom = 1.0 - roof.peak_mem_per_dev / HBM_PER_DEV
            if headroom <= 0:
                raise TuningFailure("exceeds HBM")
            result = {
                "speed": 1.0 / max(step_s, 1e-12),
                "recall": headroom,
                "mem_gib": roof.peak_mem_per_dev / 2**30,
            }
        except TuningFailure:
            raise
        except Exception as e:  # compile failure = crashed configuration
            raise TuningFailure(str(e)) from e
        finally:
            flash_xla.set_default_blocks(*prev_blocks)
        self.cache[key] = dict(result)
        return result
