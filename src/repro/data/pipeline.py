"""Deterministic, stateless-resumable, shard-aware synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — there is no iterator
state to checkpoint or lose: after a restart (even with a different DP width)
``batch_at(step)`` reproduces exactly the batch the failed run would have
seen. That property is what makes the elastic-restart story in
``checkpoint/manager.py`` complete.

The synthetic stream is a mixture of Zipf-distributed unigrams and short
Markov-ish repeats, so a ~100M model shows a real, declining loss curve
(structure to learn) rather than flat noise.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    repeat_prob: float = 0.35
    repeat_span: int = 16


class TokenPipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        # fixed zipf table (top of the vocab reserved for specials)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> np.ndarray:
        """(local_batch, seq_len + 1) int32 tokens for this step and shard."""
        cfg = self.cfg
        out = np.empty((self.local_batch, cfg.seq_len + 1), dtype=np.int32)
        for i in range(self.local_batch):
            row_id = step * cfg.global_batch + self.shard * self.local_batch + i
            rng = np.random.default_rng((cfg.seed << 32) ^ row_id)
            seq = rng.choice(cfg.vocab, size=cfg.seq_len + 1, p=self._probs)
            # inject learnable structure: copy short spans backwards
            n_rep = rng.binomial(max(cfg.seq_len // cfg.repeat_span, 1), cfg.repeat_prob)
            for _ in range(n_rep):
                span = int(rng.integers(4, cfg.repeat_span))
                if cfg.seq_len + 1 < 2 * span + 1:
                    continue
                src = int(rng.integers(0, cfg.seq_len + 1 - 2 * span))
                dst = src + span + int(rng.integers(0, span))
                dst = min(dst, cfg.seq_len + 1 - span)
                seq[dst : dst + span] = seq[src : src + span]
            out[i] = seq
        return out

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
