"""Chaos benchmark: guarded serving under injected faults vs unguarded.

Replays the serving benchmark's drifting traces (step / ramp arrival-mix
swings) with three canned fault schedules armed (``repro.vdms.faults``):
``segment_loss`` (two sealed segments die mid-trace), ``flaky_builds``
(seal/rebuild crashes with fail-count budgets plus a segment loss) and
``latency_storm`` (a latency-multiplier window, a shadow-build OOM and a
late segment loss). Both arms serve the *same* trace with the *same* plan
(fresh injectors each, so fault clocks are identical); the guarded arm runs
the full breach -> retune -> canary -> promote/rollback loop with fault
hardening (canary fault aborts, breach-storm hysteresis), the unguarded arm
only keeps the degraded-mode engine alive.

``--check-resilience`` gates three promises:

(a) **no crashes** — every serve() returns a report; faults degrade, they
    never raise out of the control loop;
(b) **honest accounting** — a direct engine replay (exact FLAT index) under
    segment loss returns only ids from ``searchable_ids()`` and matches the
    independently-computed brute-force oracle restricted to that visible
    set *exactly* (recall 1.0 by construction for an exact index);
(c) **guarding helps** — on the step-drift trace, summed over the three
    fault plans, guarded violation-minutes strictly beat unguarded.

``BENCH_chaos.json`` records the full per-case reports.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.serving import ControllerParams, ServingController, SLOSpec
from repro.vdms import (
    FaultInjector,
    LiveVDMS,
    canned_fault_plans,
    exact_topk_masked,
    make_trace,
    recall_at_k_masked,
)
from repro.vdms.workload import OP_INSERT, OP_SEARCH

from .bench_serving import (
    MIX0,
    MIX1,
    RECALL_FLOOR,
    _controller_params,
    _incumbent_config,
    _sizes,
    _tuned_session,
)
from .common import emit

SCHEDULES = ("step", "ramp")
PLANS = ("segment_loss", "flaky_builds", "latency_storm")


def _fault_horizon(n_ops: int) -> int:
    """The engine fault clock ticks once per engine op (mutations plus
    batched search flushes), which lands near ``n_ops // 2`` on these
    traces — schedule the canned plans inside that range so every event
    actually fires."""
    return max(n_ops // 2, 16)


def _arm_summary(report: dict) -> dict:
    out = {
        "crashed": False,
        "violation_minutes": report["violation_minutes"],
        "recall_under_floor_minutes": report["recall_under_floor_minutes"],
        "recall": report["recall"],
        "visible_recall": report.get("visible_recall"),
        "health": report.get("health"),
        "lat_p99_s": report["lat_p99_s"],
        "n_retunes": report["n_retunes"],
        "n_promotes": report["n_promotes"],
        "n_rollbacks": report["n_rollbacks"],
    }
    if "fault" in report:
        f = report["fault"]
        out["fault"] = {
            k: f[k]
            for k in (
                "n_injected", "n_quarantines", "n_rebuilds",
                "n_rebuild_failures", "n_seal_retries",
                "n_canary_fault_aborts", "coverage_min",
            )
        }
    return out


def _crashed(e: Exception) -> dict:
    return {"crashed": True, "error": f"{type(e).__name__}: {e}"}


def run_case(schedule: str, plan_name: str, seed: int = 0, quick: bool = True,
             mode: str = "analytic") -> dict:
    sz = _sizes(quick)
    trace = make_trace(
        "glove_like", n_base=sz["n_base"], n_ops=sz["n_ops"],
        drift=schedule, seed=seed, mix=MIX0, mix_to=MIX1,
    )
    plan = canned_fault_plans(_fault_horizon(sz["n_ops"]))[plan_name]
    cfg = _incumbent_config()
    slo = SLOSpec(recall_floor=RECALL_FLOOR, min_samples=16)
    params = _controller_params(quick)

    try:
        unguarded = _arm_summary(
            ServingController(
                slo, params=ControllerParams(check_every=params.check_every),
                mode=mode, seed=seed,
            ).serve(trace, cfg, guard=False, fault_plan=plan)
        )
    except Exception as e:  # gate (a): a crash is a finding, not an abort
        unguarded = _crashed(e)
    try:
        session = _tuned_session(trace, sz["n_pre_ops"], sz["n_tune"], seed)
        ctrl = ServingController(
            slo, session=session, params=params, mode=mode, seed=seed
        )
        guarded = _arm_summary(ctrl.serve(trace, cfg, guard=True, fault_plan=plan))
    except Exception as e:
        guarded = _crashed(e)

    out = {
        "schedule": schedule, "plan": plan_name, "fault_plan": plan.to_dict(),
        "unguarded": unguarded, "guarded": guarded,
    }
    for arm, rep in (("unguarded", unguarded), ("guarded", guarded)):
        if rep["crashed"]:
            emit(f"chaos/{schedule}/{plan_name}/{arm}", 0.0, "CRASHED")
        else:
            emit(
                f"chaos/{schedule}/{plan_name}/{arm}",
                rep["violation_minutes"],
                f"recall={rep['recall']:.3f};"
                f"vis_recall={rep['visible_recall']:.3f};"
                f"cov_min={rep['fault']['coverage_min']:.3f};"
                f"health={rep['health']}",
            )
    return out


def oracle_exactness_check(seed: int = 0, quick: bool = True) -> dict:
    """Gate (b): direct engine replay under segment loss with an exact FLAT
    index. At every flush, returned ids must come from ``searchable_ids()``
    and must match the brute-force oracle restricted to that set exactly —
    the degraded engine may answer from fewer vectors, but it must never
    misreport what it can see."""
    n_base, n_ops = (600, 400) if quick else (1500, 1000)
    trace = make_trace(
        "glove_like", n_base=n_base, n_ops=n_ops, drift="step", seed=seed,
        mix=MIX0, mix_to=MIX1,
    )
    # stretch the rebuild backoff so quarantined segments stay out of the
    # visible set across many flushes — the exactness claim is only
    # interesting while the engine is actually serving degraded
    plan = dataclasses.replace(
        canned_fault_plans(_fault_horizon(n_ops))["segment_loss"],
        backoff_base_ticks=n_ops // 8,
    )
    cfg = dict(_incumbent_config(), segment_max_size=128)
    live = LiveVDMS(cfg, trace.dim, trace.capacity, seed=seed)
    live.bootstrap(trace.base)
    live.arm_faults(FaultInjector(plan, scope="primary"))
    all_vecs = trace.all_vectors()
    k = trace.k
    n_checks, subset_ok, exact_ok, cov_min = 0, True, True, 1.0
    pending: list = []

    def check_flush() -> None:
        nonlocal n_checks, subset_ok, exact_ok, cov_min
        if not pending:
            return
        q = trace.queries[np.asarray(pending, np.int64)]
        pending.clear()
        ids, _ = live.search(q, k, mode="analytic")
        svis = live.searchable_ids()
        cov_min = min(cov_min, float(live.last_coverage))
        got = np.unique(ids[ids >= 0])
        subset_ok &= bool(np.isin(got, svis).all())
        dead = np.ones(all_vecs.shape[0], bool)
        dead[svis] = False
        vis_gt = exact_topk_masked(all_vecs, q, dead, k)
        exact_ok &= float(recall_at_k_masked(ids[:, :k], vis_gt[:, :k])) == 1.0
        n_checks += 1

    for i in range(trace.n_ops):
        kind = int(trace.kinds[i])
        if kind == OP_SEARCH:
            pending.append(int(trace.payload[i]))
            if len(pending) >= 16:
                check_flush()
        else:
            check_flush()
            row = int(trace.payload[i])
            if kind == OP_INSERT:
                live.insert(trace.inserts[row])
            else:
                live.delete(row)
    check_flush()
    stats = live.stats()
    out = {
        "n_checks": int(n_checks),
        "subset_ok": bool(subset_ok),
        "exact_ok": bool(exact_ok),
        "coverage_min": float(cov_min),
        "degraded_engaged": bool(stats["n_quarantines"] >= 1 and cov_min < 1.0),
        "n_quarantines": int(stats["n_quarantines"]),
        "n_rebuilds": int(stats["n_rebuilds"]),
    }
    emit(
        "chaos/oracle_exactness", n_checks,
        f"subset_ok={subset_ok};exact_ok={exact_ok};cov_min={cov_min:.3f}",
    )
    return out


def run(seed: int = 0, quick: bool = True, schedules=SCHEDULES, mode: str = "analytic"):
    cases = [
        run_case(s, pl, seed=seed, quick=quick, mode=mode)
        for s in schedules
        for pl in PLANS
    ]
    return {"cases": cases, "oracle": oracle_exactness_check(seed=seed, quick=quick)}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI-sized budgets")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mode", default="analytic", choices=("analytic", "wall"))
    p.add_argument(
        "--schedules", nargs="+", default=list(SCHEDULES),
        choices=("step", "ramp", "sine"),
    )
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write results as JSON (CI artifact)")
    p.add_argument(
        "--check-resilience", action="store_true",
        help="exit 1 unless: no serve crashed; visible-set accounting is "
             "oracle-exact; and on step drift guarded strictly beats "
             "unguarded on violation-minutes summed over fault plans",
    )
    args = p.parse_args(argv)

    res = run(seed=args.seed, quick=args.quick, schedules=args.schedules,
              mode=args.mode)
    out = {
        "quick": bool(args.quick), "seed": args.seed, "mode": args.mode,
        "sizes": _sizes(args.quick), **res,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)

    step_g, step_u = 0.0, 0.0
    crashes = []
    for c in res["cases"]:
        for arm in ("unguarded", "guarded"):
            if c[arm]["crashed"]:
                crashes.append(f"{c['schedule']}/{c['plan']}/{arm}: {c[arm]['error']}")
        if not (c["guarded"]["crashed"] or c["unguarded"]["crashed"]):
            tag = (
                f"g={c['guarded']['violation_minutes']:.2f} "
                f"u={c['unguarded']['violation_minutes']:.2f}"
            )
            print(f"{c['schedule']}/{c['plan']}: viol_min {tag}")
            if c["schedule"] == "step":
                step_g += c["guarded"]["violation_minutes"]
                step_u += c["unguarded"]["violation_minutes"]

    rc = 0
    if args.check_resilience:
        oracle = res["oracle"]
        checks = {
            "no_crashes": not crashes,
            "oracle_subset": oracle["subset_ok"],
            "oracle_exact": oracle["exact_ok"],
            "oracle_degraded_engaged": oracle["degraded_engaged"],
            "step_guarded_wins": step_g < step_u,
        }
        for name, ok in checks.items():
            print(f"check {name}: {'ok' if ok else 'FAILED'}")
        for line in crashes:
            print(f"  crash: {line}", file=sys.stderr)
        if not checks["step_guarded_wins"]:
            print(
                f"  step totals: guarded={step_g:.2f} unguarded={step_u:.2f}",
                file=sys.stderr,
            )
        if not all(checks.values()):
            print("RESILIENCE CHECK FAILED", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
