# One module per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   Table IV/V -> bench_autoconfig     Fig. 6/7  -> bench_efficiency
#   Fig. 8-10  -> bench_ablation       Fig. 12   -> bench_preference
#   Fig. 13    -> bench_costaware      Table VI  -> bench_overhead
#   kernels + roofline summary         -> bench_kernels
#   streaming drift re-tuning          -> bench_streaming
#
# REPRO_BENCH_FULL=1 scales to paper-size runs (200 iterations, wall-clock
# QPS at 32k vectors); the default is a fast deterministic configuration.
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main(argv=None) -> None:
    from . import (
        bench_ablation, bench_autoconfig, bench_costaware, bench_efficiency,
        bench_kernels, bench_overhead, bench_preference, bench_streaming,
    )

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--index-types",
        default=None,
        metavar="A,B,...",
        help="restrict registry-aware suites (autoconfig, streaming) to these "
        "index families (comma list validated against the registry; the "
        "public-hook IVF_PQR counts)",
    )
    args = p.parse_args(argv)
    try:
        index_types = bench_streaming.parse_index_types(args.index_types)
    except ValueError as e:
        p.error(str(e))

    full = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))
    print("name,us_per_call,derived")
    suites = [
        ("kernels", bench_kernels.run, {}),
        ("autoconfig(TabIV/V)", bench_autoconfig.run, {"index_types": index_types}),
        ("efficiency(Fig6/7)", bench_efficiency.run, {"datasets": ("glove_like",)}),
        ("ablation(Fig8-10)", bench_ablation.run, {}),
        ("preference(Fig12)", bench_preference.run, {}),
        ("costaware(Fig13)", bench_costaware.run, {}),
        ("overhead(TabVI)", bench_overhead.run, {}),
        ("streaming(drift)", bench_streaming.run, {"quick": not full, "index_types": index_types}),
    ]
    failures = 0
    for name, fn, kw in suites:
        t0 = time.time()
        try:
            fn(**kw)
            print(f"# suite {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# suite {name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
