"""Kernel microbenchmarks (XLA path wall-time on this host + interpret-mode
correctness deltas) and dry-run roofline summary if artifacts exist."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import emit


def _time(fn, *args, repeats=5):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") else None
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run():
    rng = np.random.default_rng(0)
    out = {}
    # distance
    q = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8192, 96)), jnp.float32)
    t = _time(lambda a, b: ops.batched_ip(a, b, impl="xla"), q, x)
    flops = 2 * 128 * 8192 * 96
    emit("kernel/distance_ip_128x8192x96", t * 1e6, f"gflops={flops/t/1e9:.1f}")
    out["distance"] = t
    # pq adc
    lut = jnp.asarray(rng.standard_normal((128, 8, 256)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (8192, 8)), jnp.int32)
    t = _time(lambda a, b: ops.pq_adc(a, b, impl="xla"), lut, codes)
    emit("kernel/pq_adc_128x8192x8x256", t * 1e6, f"lookups_per_s={128*8192*8/t:.2e}")
    out["pq_adc"] = t
    # flash attention fwd
    qq = jnp.asarray(rng.standard_normal((1, 1024, 8, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 1024, 4, 64)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 1024, 4, 64)), jnp.float32)
    t = _time(lambda a, b, c: ops.flash_attention(a, b, c, causal=True, impl="xla"), qq, kk, vv)
    emit("kernel/flash_fwd_b1_s1024_h8_d64", t * 1e6, f"causal_gqa")
    out["flash"] = t
    # roofline summary from dry-run artifacts
    d = Path("experiments/dryrun")
    if d.exists():
        worst, bound_counts = None, {}
        for f in sorted(d.glob("*_256.json")):
            r = json.loads(f.read_text())
            if "skipped" in r or "bottleneck" not in r:
                continue
            bound_counts[r["bottleneck"]] = bound_counts.get(r["bottleneck"], 0) + 1
            frac = r.get("roofline_fraction", 0)
            if worst is None or frac < worst[1]:
                worst = (f.stem, frac)
        if worst:
            emit("roofline/summary", 0.0,
                 f"bounds={bound_counts};worst={worst[0]}@{worst[1]:.4f}")
    return out


if __name__ == "__main__":
    run()
