"""Kernel microbenchmarks (XLA path wall-time on this host + interpret-mode
correctness deltas), end-to-end fused-vs-composed search-pipeline rows, and
the dry-run roofline summary if artifacts exist.

The pipeline section builds one static ``VDMSInstance`` per hot family and
measures the SAME wall-clock search under both pipeline modes
(``set_search_pipeline``), so the reported speedup is exactly what the tuner's
wall-mode evaluations see. ``--check-speedup`` gates fused >= 2x composed QPS
on the hot families (IVF_SQ8, IVF_PQ) and verifies the composed fallback for
families without a fused hook; ``--json`` writes the per-family record
(``BENCH_fused.json`` in CI, rendered by ``roofline_table.py``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.vdms import (
    VDMSInstance,
    get_family,
    get_search_pipeline,
    make_dataset,
    set_search_pipeline,
)
from repro.vdms.ivf_pqr import register as register_ivf_pqr

from .common import emit


def _time(fn, *args, repeats=5):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") else None
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run():
    rng = np.random.default_rng(0)
    out = {}
    # distance
    q = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8192, 96)), jnp.float32)
    t = _time(lambda a, b: ops.batched_ip(a, b, impl="xla"), q, x)
    flops = 2 * 128 * 8192 * 96
    emit("kernel/distance_ip_128x8192x96", t * 1e6, f"gflops={flops/t/1e9:.1f}")
    out["distance"] = t
    # pq adc
    lut = jnp.asarray(rng.standard_normal((128, 8, 256)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (8192, 8)), jnp.int32)
    t = _time(lambda a, b: ops.pq_adc(a, b, impl="xla"), lut, codes)
    emit("kernel/pq_adc_128x8192x8x256", t * 1e6, f"lookups_per_s={128*8192*8/t:.2e}")
    out["pq_adc"] = t
    # flash attention fwd
    qq = jnp.asarray(rng.standard_normal((1, 1024, 8, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 1024, 4, 64)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 1024, 4, 64)), jnp.float32)
    t = _time(lambda a, b, c: ops.flash_attention(a, b, c, causal=True, impl="xla"), qq, kk, vv)
    emit("kernel/flash_fwd_b1_s1024_h8_d64", t * 1e6, "causal_gqa")
    out["flash"] = t
    # roofline summary from dry-run artifacts
    d = Path("experiments/dryrun")
    if d.exists():
        worst, bound_counts = None, {}
        for f in sorted(d.glob("*_256.json")):
            r = json.loads(f.read_text())
            if "skipped" in r or "bottleneck" not in r:
                continue
            bound_counts[r["bottleneck"]] = bound_counts.get(r["bottleneck"], 0) + 1
            frac = r.get("roofline_fraction", 0)
            if worst is None or frac < worst[1]:
                worst = (f.stem, frac)
        if worst:
            emit("roofline/summary", 0.0,
                 f"bounds={bound_counts};worst={worst[0]}@{worst[1]:.4f}")
    return out


# ---------------------------------------------------------------------------
# end-to-end search-pipeline rows (fused vs composed, per family)
# ---------------------------------------------------------------------------
#: families the >=2x fused-QPS gate applies to (the eval hot path)
GATED_FAMILIES = ("IVF_SQ8", "IVF_PQ")
#: a family registered WITHOUT a fused hook — exercises the composed fallback
FALLBACK_FAMILY = "IVF_FLAT"

_FAMILY_PARAMS = {
    "IVF_FLAT": {"nlist": 64, "nprobe": 8},
    "IVF_SQ8": {"nlist": 64, "nprobe": 8},
    "IVF_PQ": {"nlist": 64, "nprobe": 8, "m": 8, "nbits": 8},
    "IVF_PQR": {"nlist": 64, "nprobe": 8, "m": 8, "nbits": 8, "reorder_k": 64},
}


def run_pipelines(quick: bool = False, repeats: int = 5, check_speedup: bool = False):
    """Per-family end-to-end chunk pipeline: composed vs fused wall QPS.

    Builds each instance once, measures the identical query stream under both
    pipeline modes, and (optionally) enforces the fused >= 2x gate plus the
    fallback identity for hook-less families. Returns {family: record}.
    """
    register_ivf_pqr()
    n, seg = (4608, 2048) if quick else (9216, 4096)
    ds = make_dataset("glove_like", n=n, n_queries=128, k=10, seed=0)
    base = {
        "segment_max_size": seg, "seal_proportion": 0.75, "graceful_time": 0.2,
        "search_batch_size": 32, "topk_merge_width": 64, "kmeans_iters": 4,
        "storage_bf16": False,
    }
    records = {}
    prev = get_search_pipeline()
    try:
        for fam, params in _FAMILY_PARAMS.items():
            cfg = dict(base, index_type=fam, **params)
            inst = VDMSInstance(ds, cfg, seed=0)
            n_chunks = (ds.queries.shape[0] + inst.batch - 1) // inst.batch
            res = {}
            for mode in ("composed", "fused"):
                set_search_pipeline(mode)
                r = inst.measure(topk=10, repeats=repeats, mode="wall")
                ms_chunk = ds.queries.shape[0] / r["speed"] / n_chunks * 1e3
                res[mode] = dict(r, ms_chunk=ms_chunk)
                emit(
                    f"pipeline/{fam}_{mode}",
                    ms_chunk * 1e3,
                    f"qps={r['speed']:.0f};recall={r['recall']:.3f}",
                )
            speedup = res["fused"]["speed"] / res["composed"]["speed"]
            fused_hook = get_family(fam).fused_search is not None
            emit(
                f"pipeline/{fam}_speedup",
                0.0,
                f"x={speedup:.2f};fused_hook={int(fused_hook)}",
            )
            records[fam] = {
                "fused_hook": fused_hook,
                "composed_qps": res["composed"]["speed"],
                "fused_qps": res["fused"]["speed"],
                "composed_ms_chunk": res["composed"]["ms_chunk"],
                "fused_ms_chunk": res["fused"]["ms_chunk"],
                "speedup": speedup,
                "recall": res["fused"]["recall"],
            }
            if fused_hook:
                # result-set identity between the two modes on this instance
                set_search_pipeline("composed")
                a = inst.search(ds.queries[:32], 10)
                set_search_pipeline("fused")
                b = inst.search(ds.queries[:32], 10)
                same = all(
                    set(x[x >= 0]) == set(y[y >= 0]) for x, y in zip(a, b)
                )
                if not same:
                    raise AssertionError(f"{fam}: fused result set != composed")
        if check_speedup:
            if get_family(FALLBACK_FAMILY).fused_search is not None:
                raise AssertionError(
                    f"{FALLBACK_FAMILY} grew a fused hook; pick another fallback family"
                )
            fb = records[FALLBACK_FAMILY]["speedup"]
            if not 0.5 < fb < 2.0:
                raise AssertionError(
                    f"fallback family {FALLBACK_FAMILY} should be mode-invariant, "
                    f"got {fb:.2f}x"
                )
            for fam in GATED_FAMILIES:
                s = records[fam]["speedup"]
                if s < 2.0:
                    raise AssertionError(
                        f"fused pipeline gate: {fam} speedup {s:.2f}x < 2.0x"
                    )
            print("check-speedup OK: " + ", ".join(
                f"{f}={records[f]['speedup']:.2f}x" for f in GATED_FAMILIES))
    finally:
        set_search_pipeline(prev)
    return records


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smaller corpus (CI smoke)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--json", metavar="PATH", help="write pipeline records as JSON")
    ap.add_argument(
        "--check-speedup", action="store_true",
        help="fail unless fused >= 2x composed QPS on the gated families",
    )
    ap.add_argument(
        "--ops-only", action="store_true", help="skip the pipeline section",
    )
    args = ap.parse_args(argv)
    run()
    if args.ops_only:
        return
    records = run_pipelines(
        quick=args.quick, repeats=args.repeats, check_speedup=args.check_speedup
    )
    if args.json:
        Path(args.json).write_text(json.dumps(records, indent=2, sort_keys=True))
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
