"""Paper Table IV + Table V: improvement of VDTuner over the Default setting,
and the chosen index/parameters per dataset.

The search space is registry-derived and includes the public-hook
``IVF_PQR`` family; each row records whether it reached the Pareto front.
``index_types=`` (or ``--index-types`` on ``benchmarks.run``) restricts the
run to a comma-listed subset of registered families.
"""
from __future__ import annotations

import numpy as np

from repro.vdms import ivf_pqr, make_space, registered_names, unregister_family

from .common import DATASETS, N_ITERS, emit, make_env, run_method


def best_without_sacrifice(tuner, default_y):
    """Paper's metric: max speed (recall) improvement without sacrificing the
    other objective relative to the default configuration."""
    Y = tuner.Y
    spd_ok = Y[Y[:, 1] >= default_y[1] - 1e-9]
    rec_ok = Y[Y[:, 0] >= default_y[0] - 1e-9]
    spd_imp = (spd_ok[:, 0].max() / default_y[0] - 1) * 100 if len(spd_ok) else float("nan")
    rec_imp = (rec_ok[:, 1].max() / default_y[1] - 1) * 100 if len(rec_ok) else float("nan")
    return spd_imp, rec_imp


def run(seed: int = 0, index_types=None):
    # IVF_PQR joins this suite's space only: scope the registration so later
    # suites in the same process (benchmarks.run) keep the default registry
    added_pqr = ivf_pqr.FAMILY.name not in registered_names()
    if added_pqr:
        ivf_pqr.register()
    try:
        return _run(seed=seed, index_types=index_types)
    finally:
        if added_pqr:
            unregister_family(ivf_pqr.FAMILY.name)


def _run(seed: int = 0, index_types=None):
    space = make_space(include=index_types)
    rows = {}
    for ds in DATASETS:
        env = make_env(ds, seed=seed)
        default = env(make_space().default_config("AUTOINDEX"))
        default_y = np.array([default["speed"], default["recall"]])
        tuner, wall, _session = run_method("vdtuner", env, space, N_ITERS, seed=seed)
        spd_imp, rec_imp = best_without_sacrifice(tuner, default_y)
        best = max(
            (o for o in tuner.history if not o.failed),
            key=lambda o: o.y[0] * (o.y[1] >= default_y[1]),
        )
        front_types = sorted({c["index_type"] for c in tuner.pareto_configs()})
        pqr_on_front = "IVF_PQR" in front_types
        rows[ds] = dict(
            speed_improvement_pct=spd_imp, recall_improvement_pct=rec_imp,
            best_index=best.index_type,
            best_config={k: v for k, v in best.config.items()
                         if k in ("nlist", "nprobe", "m", "nbits", "M",
                                  "efConstruction", "ef", "reorder_k")},
            pareto_index_types=front_types,
            ivf_pqr_on_front=pqr_on_front,
            wall_s=wall,
        )
        emit(
            f"autoconfig/{ds}", wall * 1e6 / N_ITERS,
            f"speed_imp={spd_imp:.1f}%;recall_imp={rec_imp:.1f}%;"
            f"best={best.index_type};pqr_on_front={int(pqr_on_front)}",
        )
    return rows


if __name__ == "__main__":
    print(run())
