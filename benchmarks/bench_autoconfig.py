"""Paper Table IV + Table V: improvement of VDTuner over the Default setting,
and the chosen index/parameters per dataset."""
from __future__ import annotations

import numpy as np

from repro.vdms import make_space

from .common import DATASETS, N_ITERS, emit, make_env, run_method


def best_without_sacrifice(tuner, default_y):
    """Paper's metric: max speed (recall) improvement without sacrificing the
    other objective relative to the default configuration."""
    Y = tuner.Y
    spd_ok = Y[Y[:, 1] >= default_y[1] - 1e-9]
    rec_ok = Y[Y[:, 0] >= default_y[0] - 1e-9]
    spd_imp = (spd_ok[:, 0].max() / default_y[0] - 1) * 100 if len(spd_ok) else float("nan")
    rec_imp = (rec_ok[:, 1].max() / default_y[1] - 1) * 100 if len(rec_ok) else float("nan")
    return spd_imp, rec_imp


def run(seed: int = 0):
    space = make_space()
    rows = {}
    for ds in DATASETS:
        env = make_env(ds, seed=seed)
        default = env(space.default_config("AUTOINDEX"))
        default_y = np.array([default["speed"], default["recall"]])
        tuner, wall, _session = run_method("vdtuner", env, space, N_ITERS, seed=seed)
        spd_imp, rec_imp = best_without_sacrifice(tuner, default_y)
        best = max(
            (o for o in tuner.history if not o.failed),
            key=lambda o: o.y[0] * (o.y[1] >= default_y[1]),
        )
        rows[ds] = dict(
            speed_improvement_pct=spd_imp, recall_improvement_pct=rec_imp,
            best_index=best.index_type,
            best_config={k: v for k, v in best.config.items()
                         if k in ("nlist", "nprobe", "m", "nbits", "M",
                                  "efConstruction", "ef", "reorder_k")},
            wall_s=wall,
        )
        emit(
            f"autoconfig/{ds}", wall * 1e6 / N_ITERS,
            f"speed_imp={spd_imp:.1f}%;recall_imp={rec_imp:.1f}%;best={best.index_type}",
        )
    return rows


if __name__ == "__main__":
    print(run())
