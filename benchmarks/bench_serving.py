"""Serving control plane under drift: guarded controller vs frozen config,
scored as SLO violation-minutes.

Both arms replay the same drifting trace (arrival mix swings from
search-heavy to insert-heavy while the vector distribution drifts) from the
same incumbent configuration and score SLO compliance with identical
accounting. The *frozen* arm never intervenes: breaches are recorded but
the config stays fixed. The *guarded* arm runs the full control loop
(``repro.serving.ServingController``): sliding-window SLO evaluation, a
drift probe on the live instance, shadow/canary retune on breach, and
promotion only when the candidate wins the SLO-constrained score on
mirrored traffic — losing canaries roll back checkpoint-exact.

``BENCH_serving.json`` records, per schedule and arm, SLO
violation-minutes, recall-under-floor minutes, end-to-end recall, latency
percentiles, and the retune/promote/rollback counts; ``--ledger-json``
additionally dumps the guarded arm's metrics ledger. ``--check-improvement``
exits non-zero unless the guarded arm *strictly* reduces violation-minutes
vs frozen on the step-drift trace (any schedule if step is not run).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import TuningSession, VDTuner
from repro.serving import ControllerParams, ServingController, SLOSpec
from repro.vdms import VDMSTuningEnv, make_space, make_trace

from .common import emit

SCHEDULES = ("step", "ramp")
#: search-heavy start -> insert-heavy end (insert, search, delete)
MIX0 = (0.20, 0.75, 0.05)
MIX1 = (0.65, 0.30, 0.05)
RECALL_FLOOR = 0.9


def _sizes(quick: bool):
    if quick:
        return dict(n_base=800, n_ops=640, n_pre_ops=150, n_tune=6)
    return dict(n_base=2048, n_ops=1600, n_pre_ops=320, n_tune=12)


def _controller_params(quick: bool) -> ControllerParams:
    if quick:
        return ControllerParams(
            retune_iters=6, check_every=24, canary_queries=24,
            retune_window_ops=112, cooldown_ops=48, floor_margin=0.02,
        )
    return ControllerParams(
        retune_iters=10, check_every=48, canary_queries=48,
        retune_window_ops=288, cooldown_ops=96, floor_margin=0.02,
    )


def _incumbent_config():
    """A deployable-looking incumbent that is healthy pre-drift but leans on
    ``graceful_time`` staleness — exactly the kind of config that quietly
    falls through a recall floor once the arrival mix turns insert-heavy."""
    return dict(
        make_space().default_config("FLAT"), segment_max_size=256, graceful_time=0.4
    )


def _tuned_session(trace, n_pre_ops: int, n_tune: int, seed: int) -> TuningSession:
    """Tune on the pre-drift prefix, as the deployment that produced the
    incumbent would have."""
    env = VDMSTuningEnv(
        trace=trace.window(0, n_pre_ops), workload="streaming",
        mode="analytic", seed=seed, n_phases=1,
    )
    tuner = VDTuner(make_space(), env, seed=seed, warm_start=True)
    session = TuningSession(tuner)
    session.run(n_tune)
    return session


def _arm_summary(report) -> dict:
    return {
        "violation_minutes": report["violation_minutes"],
        "recall_under_floor_minutes": report["recall_under_floor_minutes"],
        "recall": report["recall"],
        "lat_p50_s": report["lat_p50_s"],
        "lat_p99_s": report["lat_p99_s"],
        "n_breach_events": report["n_breach_events"],
        "n_retunes": report["n_retunes"],
        "n_promotes": report["n_promotes"],
        "n_rollbacks": report["n_rollbacks"],
        "n_configs_served": len(report["config_history"]),
        "timeline": [
            {k: e[k] for k in ("op", "time", "event")} for e in report["timeline"]
        ],
    }


def run_schedule(schedule: str, seed: int = 0, quick: bool = True, mode: str = "analytic"):
    sz = _sizes(quick)
    trace = make_trace(
        "glove_like", n_base=sz["n_base"], n_ops=sz["n_ops"],
        drift=schedule, seed=seed, mix=MIX0, mix_to=MIX1,
    )
    cfg = _incumbent_config()
    slo = SLOSpec(recall_floor=RECALL_FLOOR, min_samples=16)
    params = _controller_params(quick)

    # frozen arm: same SLO accounting cadence, no interventions
    frozen = ServingController(
        slo, params=ControllerParams(check_every=params.check_every),
        mode=mode, seed=seed,
    ).serve(trace, cfg, guard=False)

    # guarded arm: full breach -> retune -> canary -> promote/rollback loop
    session = _tuned_session(trace, sz["n_pre_ops"], sz["n_tune"], seed)
    ctrl = ServingController(slo, session=session, params=params, mode=mode, seed=seed)
    guarded = ctrl.serve(trace, cfg, guard=True)

    out = {
        "schedule": schedule,
        "trace": trace.name,
        "n_ops": int(trace.n_ops),
        "n_searches": int(trace.n_searches),
        "slo": guarded["slo"],
        "frozen": _arm_summary(frozen),
        "guarded": _arm_summary(guarded),
        "delta_violation_minutes": float(
            guarded["violation_minutes"] - frozen["violation_minutes"]
        ),
    }
    for arm, rep in (("frozen", frozen), ("guarded", guarded)):
        emit(
            f"serving/{schedule}/{arm}",
            rep["n_searches"],
            f"viol_min={rep['violation_minutes']:.2f};"
            f"recall={rep['recall']:.3f};promotes={rep['n_promotes']};"
            f"rollbacks={rep['n_rollbacks']}",
        )
    return out, ctrl.ledger


def run(seed: int = 0, quick: bool = True, schedules=SCHEDULES, mode: str = "analytic"):
    out, ledgers = {}, {}
    for s in schedules:
        out[s], ledgers[s] = run_schedule(s, seed=seed, quick=quick, mode=mode)
    return out, ledgers


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI-sized budgets")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mode", default="analytic", choices=("analytic", "wall"))
    p.add_argument("--schedules", nargs="+", default=list(SCHEDULES), choices=("step", "ramp", "sine"))
    p.add_argument("--json", default=None, metavar="PATH", help="write results as JSON (CI artifact)")
    p.add_argument(
        "--ledger-json", default=None, metavar="PATH",
        help="dump the guarded arms' metrics ledgers as JSON (CI artifact)",
    )
    p.add_argument(
        "--check-improvement", action="store_true",
        help="exit 1 unless the guarded arm strictly reduces SLO "
             "violation-minutes vs frozen on step drift",
    )
    args = p.parse_args(argv)

    schedules, ledgers = run(
        seed=args.seed, quick=args.quick, schedules=args.schedules, mode=args.mode,
    )
    out = {
        "quick": bool(args.quick), "seed": args.seed, "mode": args.mode,
        "sizes": _sizes(args.quick), "schedules": schedules,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    if args.ledger_json:
        with open(args.ledger_json, "w") as f:
            json.dump({s: led.to_json() for s, led in ledgers.items()}, f, indent=2)

    wins = {}
    for s, r in schedules.items():
        g, f0 = r["guarded"], r["frozen"]
        wins[s] = g["violation_minutes"] < f0["violation_minutes"]
        print(
            f"{s}: frozen viol_min={f0['violation_minutes']:.2f} "
            f"guarded viol_min={g['violation_minutes']:.2f} "
            f"(delta {r['delta_violation_minutes']:+.2f}, "
            f"retunes={g['n_retunes']}, promotes={g['n_promotes']}, "
            f"rollbacks={g['n_rollbacks']})"
        )
    rc = 0
    if args.check_improvement:
        # the acceptance gate is anchored on step drift; fall back to
        # any-schedule only when step was not part of the run
        ok = wins["step"] if "step" in wins else any(wins.values())
        if not ok:
            print(
                "IMPROVEMENT CHECK FAILED: guarded controller did not reduce "
                "violation-minutes vs frozen",
                file=sys.stderr,
            )
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
