"""Build the EXPERIMENTS.md §Roofline table from dry-run artifacts."""
from __future__ import annotations

import json
from pathlib import Path

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(v):
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.2f}ms"
    return f"{v*1e6:.1f}us"


def load(d="experiments/dryrun", chips="256"):
    rows = []
    for f in sorted(Path(d).glob(f"*_{chips}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def markdown_table(d="experiments/dryrun", chips="256") -> str:
    rows = load(d, chips)
    by_key = {}
    for r in rows:
        by_key[(r["arch"], r["shape"])] = r
    lines = [
        "| arch | shape | compute | memory | collective | bound | useful (6ND/HLO) | roofline frac | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in by_key})
    for arch in archs:
        for shape in ORDER:
            r = by_key.get((arch, shape))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP: {r['skipped'][:42]} | | | |")
                continue
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['bottleneck']} "
                f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
                f"| {r['memory_analysis']['temp_size_in_bytes']/2**30:.1f} GiB |"
            )
    return "\n".join(lines)


def multipod_table(d="experiments/dryrun") -> str:
    rows = load(d, "512")
    lines = [
        "| arch | shape | compiled | temp/dev | fallbacks |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | yes "
            f"| {r['memory_analysis']['temp_size_in_bytes']/2**30:.1f} GiB "
            f"| {', '.join(r.get('fallbacks', [])) or '—'} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
    print()
    print(multipod_table())
