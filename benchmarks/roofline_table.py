"""Build the EXPERIMENTS.md §Roofline table from dry-run artifacts, plus the
end-to-end search-pipeline table from ``bench_kernels --json`` records."""
from __future__ import annotations

import json
from pathlib import Path

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(v):
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.2f}ms"
    return f"{v*1e6:.1f}us"


def load(d="experiments/dryrun", chips="256"):
    rows = []
    for f in sorted(Path(d).glob(f"*_{chips}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def markdown_table(d="experiments/dryrun", chips="256") -> str:
    rows = load(d, chips)
    by_key = {}
    for r in rows:
        by_key[(r["arch"], r["shape"])] = r
    lines = [
        "| arch | shape | compute | memory | collective | bound | useful (6ND/HLO) | roofline frac | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({k[0] for k in by_key})
    for arch in archs:
        for shape in ORDER:
            r = by_key.get((arch, shape))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP: {r['skipped'][:42]} | | | |")
                continue
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['bottleneck']} "
                f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
                f"| {r['memory_analysis']['temp_size_in_bytes']/2**30:.1f} GiB |"
            )
    return "\n".join(lines)


def multipod_table(d="experiments/dryrun") -> str:
    rows = load(d, "512")
    lines = [
        "| arch | shape | compiled | temp/dev | fallbacks |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | yes "
            f"| {r['memory_analysis']['temp_size_in_bytes']/2**30:.1f} GiB "
            f"| {', '.join(r.get('fallbacks', [])) or '—'} |"
        )
    return "\n".join(lines)


def search_pipeline_table(path="BENCH_fused.json") -> str:
    """Render the per-family fused-vs-composed pipeline records written by
    ``benchmarks.bench_kernels --json`` as a markdown table (end-to-end
    per-chunk wall time, not per-op micro numbers)."""
    p = Path(path)
    if not p.exists():
        return f"(no pipeline records at {path} — run benchmarks.bench_kernels --json)"
    records = json.loads(p.read_text())
    lines = [
        "| family | composed ms/chunk | fused ms/chunk | speedup | fused QPS | recall@10 |",
        "|---|---|---|---|---|---|",
    ]
    for fam in sorted(records):
        r = records[fam]
        tag = "" if r.get("fused_hook") else " (fallback)"
        lines.append(
            f"| {fam}{tag} | {r['composed_ms_chunk']:.2f} | {r['fused_ms_chunk']:.2f} "
            f"| {r['speedup']:.2f}x | {r['fused_qps']:.0f} | {r['recall']:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
    print()
    print(multipod_table())
    print()
    print(search_pipeline_table())
