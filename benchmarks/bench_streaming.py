"""Streaming tuning under workload drift: frozen-best vs drift-triggered
re-tuning, scored as hypervolume over time.

Both arms tune on phase 0 of a drifting trace (distribution drift toward a
different generator family + arrival-mix drift from search-heavy to
insert-heavy). The *frozen* arm deploys its phase-0 Pareto set unchanged;
the *re-tuned* arm probes its incumbent each phase through a
``DriftDetector`` and, when the trigger fires, re-enters BO warm-started
(``TuningSession.retune``: history demoted to bootstrap, GP hyperparameters
carried) on the current phase. Each phase's deployed set is re-measured
under that phase and scored as normalized hypervolume (sustained QPS x
time-aware recall; joint per-phase normalization so arms are comparable).

``--check-invariants`` gates two streaming-engine invariants on a small
trace (sealed-segment count nondecreasing; time-aware recall accounting
matching an independent brute-force oracle); ``--check-improvement`` exits
non-zero unless re-tuning beats frozen mean HV for at least one schedule.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import (
    DriftDetector,
    TuningSession,
    VDTuner,
    hv_2d,
    pareto_front,
    streaming_sustained,
)
from repro.vdms import make_space, make_trace, replay_trace, time_aware_ground_truth

from .common import emit

SCHEDULES = ("step", "ramp")
#: search-heavy start -> insert-heavy end (insert, search, delete)
MIX0 = (0.05, 0.90, 0.05)
MIX1 = (0.60, 0.30, 0.10)


def _sizes(quick: bool):
    if quick:
        return dict(n_base=3072, n_ops=1500, n_phases=3, n_init=10, n_retune=14, front_n=4)
    return dict(n_base=8192, n_ops=6000, n_phases=4, n_init=30, n_retune=28, front_n=6)


def _measure_points(env, spec, cfgs):
    """Objective vectors of the deployed configs under the env's current
    phase. Returns ``(points, kept_cfgs)`` aligned; configs that now fail
    drop out of the deployed set."""
    pts, kept = [], []
    for cfg in cfgs:
        try:
            pts.append(list(spec(env(cfg))))
            kept.append(cfg)
        except Exception:
            continue
    return pts, kept


def _dedupe(cfgs):
    seen, out = set(), []
    for cfg in cfgs:
        key = tuple(sorted((k, round(v, 6) if isinstance(v, float) else v) for k, v in cfg.items()))
        if key not in seen:
            seen.add(key)
            out.append(cfg)
    return out


def _make_env(trace, n_phases, mode, seed):
    from repro.vdms import VDMSTuningEnv

    return VDMSTuningEnv(trace=trace, workload="streaming", mode=mode, seed=seed, n_phases=n_phases)


def run_schedule(
    schedule: str,
    seed: int = 0,
    quick: bool = True,
    mode: str = "analytic",
    rel_threshold: float = 0.12,
    index_types=None,
):
    sz = _sizes(quick)
    spec = streaming_sustained()
    space = make_space(include=index_types)
    trace = make_trace(
        "glove_like",
        n_base=sz["n_base"],
        n_ops=sz["n_ops"],
        seed=seed,
        drift=schedule,
        mix=MIX0,
        mix_to=MIX1,
    )
    P = sz["n_phases"]

    # --- frozen arm: tune once on phase 0, deploy unchanged ---------------
    env_f = _make_env(trace, P, mode, seed)
    tuner_f = VDTuner(space, env_f, seed=seed, warm_start=True, objective_spec=spec)
    TuningSession(tuner_f).run(sz["n_init"])
    deployed_f = tuner_f.pareto_configs(max_n=sz["front_n"])
    frozen_pts = []
    for p in range(P):
        env_f.set_phase(p)
        frozen_pts.append(_measure_points(env_f, spec, deployed_f)[0])

    # --- re-tuned arm: probe incumbent, re-enter BO when drift fires ------
    env_r = _make_env(trace, P, mode, seed)
    tuner_r = VDTuner(space, env_r, seed=seed, warm_start=True, objective_spec=spec)
    session_r = TuningSession(tuner_r)
    session_r.run(sz["n_init"])
    detector = DriftDetector(metrics=("speed", "recall"), rel_threshold=rel_threshold)
    incumbent = tuner_r.best_config()
    session_r.probe_drift(detector, incumbent)  # phase-0 reference
    deployed_r = tuner_r.pareto_configs(max_n=sz["front_n"])
    retuned_pts = []
    fired_log = [False]
    n_retunes = 0
    for p in range(P):
        env_r.set_phase(p)
        if p > 0:
            fired = session_r.probe_drift(detector, incumbent)
            fired_log.append(bool(fired))
            if fired:
                # drop stale measurements, re-anchor on the deployed front
                # re-measured under the new phase, top up with fresh BO
                session_r.retune(sz["n_retune"], reanchor=deployed_r)
                n_retunes += 1
                incumbent = tuner_r.best_config()
                # deployment keeps the live configs and *adds* the re-tuned
                # front — re-tuning augments, it doesn't undeploy
                deployed_r = _dedupe(deployed_r + tuner_r.pareto_configs(max_n=sz["front_n"]))
                detector.reset()
                session_r.probe_drift(detector, incumbent)  # re-baseline
        pts, kept = _measure_points(env_r, spec, deployed_r)
        retuned_pts.append(pts)
        # prune to the configs on the *measured* front of this phase (a
        # deployment keeps only its current winners live)
        if len(pts) > 1:
            arr = np.asarray(pts, np.float64)
            nd_front = pareto_front(arr)
            keep = [i for i, y in enumerate(arr) if any(np.allclose(y, f) for f in nd_front)]
            deployed_r = [kept[i] for i in keep[: 2 * sz["front_n"]]]

    # --- hypervolume over time: joint per-phase normalization -------------
    # an arm whose whole deployed set fails under a phase scores hv=0 there
    hv_f, hv_r = [], []
    for p in range(P):
        both = frozen_pts[p] + retuned_pts[p]
        if not both:
            hv_f.append(0.0)
            hv_r.append(0.0)
            continue
        ymax = np.asarray(both, np.float64).max(axis=0)
        ymax = np.where(ymax <= 0, 1.0, ymax)
        ref = np.zeros(2)

        def hv_of(pts):
            if not pts:
                return 0.0
            return hv_2d(pareto_front(np.asarray(pts, np.float64) / ymax), ref)

        hv_f.append(hv_of(frozen_pts[p]))
        hv_r.append(hv_of(retuned_pts[p]))

    out = {
        "schedule": schedule,
        "trace": trace.name,
        "n_phases": P,
        "frozen": {
            "phase_hv": [float(h) for h in hv_f],
            "mean_hv": float(np.mean(hv_f)),
            "n_evals": int(env_f.n_evals),
            "points": frozen_pts,
        },
        "retuned": {
            "phase_hv": [float(h) for h in hv_r],
            "mean_hv": float(np.mean(hv_r)),
            "n_evals": int(env_r.n_evals),
            "n_retunes": int(n_retunes),
            "drift_fired": fired_log,
            "probe_rel": [float(e["rel"]) for e in detector.log],
            "points": retuned_pts,
        },
        "session": session_r.ledger_dict(),
    }
    emit(
        f"streaming/{schedule}/frozen",
        out["frozen"]["n_evals"],
        f"hv={out['frozen']['mean_hv']:.3f}",
    )
    emit(
        f"streaming/{schedule}/retuned",
        out["retuned"]["n_evals"],
        f"hv={out['retuned']['mean_hv']:.3f};retunes={n_retunes}",
    )
    return out


# ---------------------------------------------------------------------------
# invariant checks (CI streaming-smoke gates)
# ---------------------------------------------------------------------------
def _oracle_ground_truth(trace, k):
    """Independent brute-force oracle: per-query python sweep over the ids
    visible at the query's timestamp (no batching, no masking tricks)."""
    all_vec = trace.all_vectors()
    visible: set = set(range(trace.n_base))
    out = -np.ones((trace.n_searches, k), np.int32)
    n_ins = 0
    for i in range(trace.n_ops):
        kind = int(trace.kinds[i])
        if kind == 0:  # insert
            visible.add(trace.n_base + n_ins)
            n_ins += 1
        elif kind == 2:  # delete
            visible.discard(int(trace.payload[i]))
        else:
            ids = np.fromiter(sorted(visible), np.int64)
            q = trace.queries[int(trace.payload[i])]
            sims = all_vec[ids] @ q
            order = np.argsort(-sims, kind="stable")[: min(k, ids.size)]
            row = int(trace.payload[i])
            out[row, : order.size] = ids[order].astype(np.int32)
    return out


def check_invariants(seed: int = 0, mode: str = "analytic"):
    """Returns ``(failures, summary)``: a list of failure strings (empty =
    all invariants hold) plus a replay summary — the engine's structured
    lifecycle snapshot (``LiveVDMS.stats()``) and the serving-facing
    throughput/latency numbers (QPS with p50/p99 percentiles)."""
    failures = []
    trace = make_trace(
        "glove_like",
        n_base=700,
        n_ops=260,
        seed=seed,
        drift="ramp",
        mix=(0.30, 0.55, 0.15),
    )
    cfg = dict(
        index_type="IVF_FLAT",
        nlist=32,
        nprobe=8,
        segment_max_size=512,
        seal_proportion=0.6,
        graceful_time=0.2,
        search_batch_size=16,
        topk_merge_width=32,
        kmeans_iters=4,
        storage_bf16=False,
    )
    result, live = replay_trace(trace, cfg, seed=seed, mode=mode, with_live=True)
    if any(b < a for a, b in zip(live.seal_history, live.seal_history[1:])):
        failures.append(f"sealed-segment count decreased: {live.seal_history}")
    if live.n_seals < 1:
        failures.append("trace too small: no seal event exercised")
    summary = {
        "stats": live.stats(),
        "qps": result["speed"],
        "lat_p50_s": result["lat_p50_s"],
        "lat_p99_s": result["lat_p99_s"],
        "recall": result["recall"],
    }

    gt_fast = time_aware_ground_truth(trace)
    gt_oracle = _oracle_ground_truth(trace, trace.k)
    for row, (a, b) in enumerate(zip(gt_fast, gt_oracle)):
        if set(a.tolist()) != set(b.tolist()):
            failures.append(f"time-aware GT row {row} mismatch: {a} vs oracle {b}")
            break
    r_fast = replay_trace(trace, cfg, seed=seed, mode=mode, ground_truth=gt_fast)
    r_oracle = replay_trace(trace, cfg, seed=seed, mode=mode, ground_truth=gt_oracle)
    if abs(r_fast["recall"] - r_oracle["recall"]) > 1e-12:
        failures.append(f"recall accounting diverges from oracle: " f"{r_fast['recall']} vs {r_oracle['recall']}")
    return failures, summary


def run(seed: int = 0, quick: bool = True, schedules=SCHEDULES, mode: str = "analytic", index_types=None):
    index_types = parse_index_types(index_types)
    return {s: run_schedule(s, seed=seed, quick=quick, mode=mode, index_types=index_types) for s in schedules}


def parse_index_types(value):
    """Normalize an ``--index-types`` value (comma list or sequence) and
    validate it against the registry, raising ``ValueError`` with the sorted
    registered families on unknown names. ``IVF_PQR`` is registered via its
    public hook if (and only if) the filter asks for it."""
    if value is None:
        return None
    from repro.vdms import ivf_pqr, registered_names

    names = tuple(s.strip() for s in value.split(",")) if isinstance(value, str) else tuple(value)
    if ivf_pqr.FAMILY.name in names:
        ivf_pqr.register()
    unknown = sorted(set(names) - set(registered_names()))
    if unknown:
        raise ValueError(f"unknown index types {unknown}; registered families: {sorted(registered_names())}")
    return names


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI-sized budgets")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mode", default="analytic", choices=("analytic", "wall"))
    p.add_argument("--schedules", nargs="+", default=list(SCHEDULES), choices=("none", "ramp", "step", "sine"))
    p.add_argument(
        "--index-types",
        default=None,
        metavar="A,B,...",
        help="restrict tuning to these registered index families (comma list; IVF_PQR included)",
    )
    p.add_argument("--json", default=None, metavar="PATH", help="write results as JSON (CI artifact)")
    p.add_argument("--check-invariants", action="store_true", help="exit 1 unless the streaming-engine invariants hold")
    p.add_argument("--check-improvement", action="store_true",
                   help="exit 1 unless re-tuning beats frozen mean HV for "
                        ">= 1 schedule")
    args = p.parse_args(argv)
    try:
        index_types = parse_index_types(args.index_types)
    except ValueError as e:
        p.error(str(e))

    out = {"quick": bool(args.quick), "seed": args.seed, "mode": args.mode,
           "sizes": _sizes(args.quick), "index_types": args.index_types, "schedules": {}}
    if args.check_invariants:
        failures, summary = check_invariants(seed=args.seed, mode=args.mode)
        out["invariants"] = {"ok": not failures, "failures": failures, "replay": summary}
        for f in failures:
            print(f"INVARIANT FAILED: {f}", file=sys.stderr)
        print(
            f"invariants replay: qps={summary['qps']:.1f} "
            f"p50={summary['lat_p50_s'] * 1e3:.3f}ms "
            f"p99={summary['lat_p99_s'] * 1e3:.3f}ms "
            f"seals={summary['stats']['n_seals']} "
            f"compactions={summary['stats']['n_compactions']} "
            f"tombstones={summary['stats']['tombstone_fraction']:.3f}"
        )
    out["schedules"] = run(
        seed=args.seed,
        quick=args.quick,
        schedules=args.schedules,
        mode=args.mode,
        index_types=index_types,
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    wins = []
    for s, r in out["schedules"].items():
        d = r["retuned"]["mean_hv"] - r["frozen"]["mean_hv"]
        wins.append(d > 0)
        print(
            f"{s}: frozen hv={r['frozen']['mean_hv']:.3f} "
            f"retuned hv={r['retuned']['mean_hv']:.3f} "
            f"(delta {d:+.3f}, retunes={r['retuned']['n_retunes']}, "
            f"fired={r['retuned']['drift_fired']})"
        )
    rc = 0
    if args.check_invariants and not out["invariants"]["ok"]:
        rc = 1
    if args.check_improvement and not any(wins):
        print("IMPROVEMENT CHECK FAILED: re-tuning never beat frozen", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
